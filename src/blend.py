"""Top-level BlendQL entry point: ``import blend; blend.connect(lake)``.

Thin alias over :mod:`repro.query` so user code reads like the paper's
system name.  Everything here is re-exported; see ``repro/query/__init__.py``
for the IR-to-paper mapping.
"""
from repro.query import (And, BlendQLError, Compiled, Counter, DEFAULT_RULES,
                         Expr, Explain, Or, QueryResult, Seek, Session, Sub,
                         connect, corr, counter, fingerprint_query, kw, lower,
                         mc, parse, recover, restore, rewrite, sc)

__all__ = [
    "And", "BlendQLError", "Compiled", "Counter", "DEFAULT_RULES", "Expr",
    "Explain", "Or", "QueryResult", "Seek", "Session", "Sub", "connect",
    "corr", "counter", "fingerprint_query", "kw", "lower", "mc", "parse",
    "recover", "restore", "rewrite", "sc",
]
