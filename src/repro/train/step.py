"""Jittable train / serve step builders used by the launcher and the dry-run."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


def make_train_state(cfg, key, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or _default_opt(cfg)
    params = registry.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def train_state_specs(cfg, opt_cfg: AdamWConfig | None = None):
    return jax.eval_shape(
        lambda: make_train_state(cfg, jax.random.PRNGKey(0), opt_cfg))


def _default_opt(cfg):
    return AdamWConfig(state_dtype=cfg.opt_state_dtype,
                       factored=getattr(cfg, "opt_factored", False))


def make_train_step(cfg, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or _default_opt(cfg)
    loss = registry.loss_fn(cfg)
    accum = max(getattr(cfg, "grad_accum", 1), 1)

    def grads_of(params, batch):
        return jax.value_and_grad(loss, has_aux=True)(params, batch)

    def train_step(state, batch):
        if accum == 1:
            (l, aux), grads = grads_of(state["params"], batch)
        else:
            # microbatched gradient accumulation (activation memory / accum)
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def mb_step(carry, mb):
                gacc, lacc, aacc = carry
                (l, aux), g = grads_of(state["params"], mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gacc, g)
                aacc = jax.tree.map(lambda a, b: a + b, aacc, aux)
                return (gacc, lacc + l, aacc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 state["params"])
            aux0 = jax.eval_shape(lambda p, b: grads_of(p, b)[0][1],
                                  state["params"],
                                  jax.tree.map(lambda x: x[0], micro))
            aux0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux0)
            (grads, l, aux), _ = jax.lax.scan(
                mb_step, (zeros, jnp.zeros(()), aux0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            l = l / accum
            aux = jax.tree.map(lambda a: a / accum, aux)
        params, opt = adamw_update(state["params"], grads, state["opt"], opt_cfg)
        metrics = {"loss": l, **{k: v for k, v in aux.items()}}
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_serve_step(cfg):
    decode = registry.decode_fn(cfg)

    def serve_step(params, cache, token):
        new_cache, logits = decode(params, cache, token)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_cache, next_token, logits

    return serve_step


def make_prefill_step(cfg, max_len: int):
    prefill = registry.prefill_fn(cfg, max_len)

    def prefill_step(params, batch):
        cache, logits = prefill(params, batch)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cache, next_token

    return prefill_step
