"""AdamW with configurable state dtype + optional gradient compression.

State dtype matters at scale: the 480B-param MoE cell keeps the second moment
in bf16 to fit 256 x 16 GB HBM (see EXPERIMENTS §Dry-run).  The compression
hook implements int8 quantization with error feedback (1-bit-Adam-style
residual accumulation) for cross-pod gradient reduction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"
    factored: bool = False   # Adafactor-style factored second moment (>=2D)


def _is_factored(p, cfg) -> bool:
    # factor only genuinely-2D weight matrices (skip stacked norms/gates where
    # one of the trailing dims is small)
    return cfg.factored and p.ndim >= 2 and min(p.shape[-1], p.shape[-2]) >= 128


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)

    def v_init(p):
        if _is_factored(p, cfg):
            return {"vr": jnp.zeros(p.shape[:-1], dt),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
        return jnp.zeros(p.shape, dt)

    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "v": jax.tree.map(v_init, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        mhat = m32 / bc1
        if _is_factored(p, cfg):
            g2 = jnp.square(g32) + 1e-30
            vr = cfg.b2 * v["vr"].astype(jnp.float32) + (1 - cfg.b2) * \
                jnp.mean(g2, axis=-1)
            vc = cfg.b2 * v["vc"].astype(jnp.float32) + (1 - cfg.b2) * \
                jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            vhat = (vr[..., None] * vc[..., None, :]) / \
                jnp.maximum(denom[..., None], 1e-30) / bc2
            v_new = {"vr": vr.astype(dt), "vc": vc.astype(dt)}
        else:
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
            vhat = v32 / bc2
            v_new = v32.astype(dt)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype),
                m32.astype(dt), v_new)

    # flatten everything up to the *params* structure so factored-v dict
    # leaves stay intact
    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(opt_state["m"])
    leaves_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(leaves_p, leaves_g, leaves_m, leaves_v)]
    unflat = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in out])
    return unflat(0), {"m": unflat(1), "v": unflat(2), "step": step}


# --------------------------------------------------------------------------
# gradient compression (int8 + error feedback) — cross-pod reduction trick
# --------------------------------------------------------------------------

def compress_int8(g, residual):
    """Quantize g+residual to int8 with a per-tensor scale; returns
    (q, scale, new_residual)."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, x - deq


def decompress_int8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_grads(grads, residuals):
    """Apply int8+error-feedback compression leaf-wise; returns (grads',
    residuals').  Used on the cross-pod (slow-link) reduction path."""
    out = jax.tree.map(compress_int8, grads, residuals)
    tup = lambda i: jax.tree.map(lambda o: o[i], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    qs, scales, res = tup(0), tup(1), tup(2)
    deq = jax.tree.map(lambda q, s, g: decompress_int8(q, s, g.dtype),
                       qs, scales, grads)
    return deq, res
