"""Checkpointing + elastic resharding.

Atomic (tmp + rename) directory checkpoints: a msgpack manifest (paths,
shapes, dtypes, step) + one raw buffer file per leaf.  ``restore`` can place
leaves onto a *different* mesh than the one that saved them (elastic scaling:
recompute param specs for the new topology and device_put shard-by-shard).
"""
from __future__ import annotations

import os
import shutil
import tempfile
from pathlib import Path

import jax
import msgpack
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        out[key] = leaf
    return out, treedef


def save(state, directory, step: int, keep: int = 3):
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_"))
    leaves, _ = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in leaves.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".bin"
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype), "file": fname}
        with open(tmp / fname, "wb") as f:
            f.write(arr.tobytes())
    with open(tmp / "manifest.msgpack", "wb") as f:
        f.write(msgpack.packb(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(d)


def latest_step(directory) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in directory.iterdir()
             if d.is_dir() and d.name.startswith("step_")]
    return max(steps) if steps else None


def restore(template, directory, step: int | None = None, *, mesh=None,
            shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``shardings`` (same pytree of NamedShardings,
    possibly for a *different* mesh than the saver's), leaves are device_put
    with the new placement — elastic rescale."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    with open(d / "manifest.msgpack", "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves, treedef = _flatten(template)
    shard_leaves = _flatten(shardings)[0] if shardings is not None else {}
    restored = []
    for key, leaf in leaves.items():
        meta = manifest["leaves"][key]
        with open(d / meta["file"], "rb") as f:
            arr = np.frombuffer(f.read(), dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        if key in shard_leaves:
            arr = jax.device_put(arr, shard_leaves[key])
        restored.append(arr)
    keys = list(leaves.keys())
    # rebuild in treedef order
    path_leaves = dict(zip(keys, restored))
    flat = [path_leaves[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, flat), manifest["step"]
