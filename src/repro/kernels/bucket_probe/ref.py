"""Pure-jnp oracle for the bucket probe."""
import jax.numpy as jnp


def bucket_probe_ref(bucket_hashes, bucket_payload, queries, bucket_bits):
    """bucket_hashes/payload: [NB, W]; queries: [M] u32.
    Returns payload where hash matches else -1: [M, W] i32."""
    shift = 32 - bucket_bits
    rows = (queries >> shift).astype(jnp.int32)          # [M]
    bh = bucket_hashes[rows]                             # [M, W]
    bp = bucket_payload[rows]
    hit = bh == queries[:, None]
    return jnp.where(hit, bp, -1)
