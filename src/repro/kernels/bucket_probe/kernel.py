"""Pallas TPU kernel: padded radix-bucket hash probe.

The unified index's bucket table ([2^bits, W] hashes + payloads) stays in
HBM/ANY; each grid step owns a tile of queries in VMEM, DMAs the bucket row
per query (a bounded, rectangular gather — the TPU replacement for B-tree
pointer chasing) and emits matching payload offsets via a vectorized compare.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(q_ref, bh_ref, bp_ref, out_ref, *, bucket_bits, width):
    shift = 32 - bucket_bits
    qb = q_ref[...]                                     # [QB] u32 in VMEM

    def body(i, _):
        q = qb[i]
        row = (q >> shift).astype(jnp.int32)
        hashes = pl.load(bh_ref, (pl.ds(row, 1), pl.ds(0, width)))  # [1, W]
        payload = pl.load(bp_ref, (pl.ds(row, 1), pl.ds(0, width)))
        hit = hashes == q
        out = jnp.where(hit, payload, -1)
        pl.store(out_ref, (pl.ds(i, 1), pl.ds(0, width)), out)
        return 0

    jax.lax.fori_loop(0, qb.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("bucket_bits", "q_block",
                                             "interpret"))
def bucket_probe(bucket_hashes, bucket_payload, queries, *, bucket_bits,
                 q_block=256, interpret=False):
    m = queries.shape[0]
    width = bucket_hashes.shape[1]
    assert m % q_block == 0, "pad queries to q_block"
    grid = (m // q_block,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, bucket_bits=bucket_bits, width=width),
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),     # bucket table stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((q_block, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, width), jnp.int32),
        interpret=interpret,
    )(queries, bucket_hashes, bucket_payload)
