"""Jitted wrapper: pads queries and dispatches kernel vs oracle.

On CPU (tests / benches) the oracle path runs; on TPU the Pallas kernel.
``interpret=True`` forces the kernel body through the Pallas interpreter for
correctness validation anywhere.
"""
import jax
import jax.numpy as jnp

from repro.kernels.bucket_probe.kernel import bucket_probe
from repro.kernels.bucket_probe.ref import bucket_probe_ref


def probe(bucket_hashes, bucket_payload, queries, bucket_bits, *,
          use_kernel=None, interpret=None, q_block=256):
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return bucket_probe_ref(bucket_hashes, bucket_payload, queries,
                                bucket_bits)
    pad = (-queries.shape[0]) % q_block
    q = jnp.pad(queries, (0, pad), constant_values=jnp.uint32(0xFFFFFFFF))
    out = bucket_probe(bucket_hashes, bucket_payload, q,
                       bucket_bits=bucket_bits, q_block=q_block,
                       interpret=bool(interpret) and not on_tpu)
    return out[: queries.shape[0]]
