"""Jitted wrapper matching the model-side chunked_attention signature."""
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal=True, q_block=128, kv_block=128,
              use_kernel=None, interpret=None):
    """q: [B, Sq, H, D]; k/v: [B, Skv, K, D] -> [B, Sq, H, D]."""
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal)
    B, Sq, H, D = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    kx = jnp.repeat(k, G, axis=2)            # expand GQA to per-head kv
    vx = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    o = flash_attention(qf, kf, vf, q_block=q_block, kv_block=kv_block,
                        causal=causal, interpret=bool(interpret) and not on_tpu)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
