"""Pallas TPU kernel: blockwise online-softmax (flash) attention forward.

Grid (batch*kv_head*group, q_blocks); each step keeps a [Tq, D] query tile +
running (m, l, acc) in VMEM and streams KV tiles — the score matrix never
touches HBM, which removes the memory-term bottleneck the dry-run measures
for the pure-JAX chunked path (EXPERIMENTS §Perf).  MXU-aligned tiles
(Tq, Tk multiples of 128; D = head_dim 64/128).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block, causal, sq, skv):
    # q_ref: [Tq, D]; k_ref/v_ref: [Skv, D] (whole kv stream for this head)
    qi = pl.program_id(1)
    tq = q_ref.shape[0]
    d = q_ref.shape[1]
    q = q_ref[...].astype(jnp.float32)
    scale = 1.0 / (d ** 0.5)
    nk = skv // kv_block

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.ds(j * kv_block, kv_block), pl.ds(0, d)))
        v = pl.load(v_ref, (pl.ds(j * kv_block, kv_block), pl.ds(0, d)))
        s = q @ k.astype(jnp.float32).T * scale            # [Tq, Tk]
        if causal:
            qpos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, kv_block), 0)
            kpos = j * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (tq, kv_block), 1)
            s = jnp.where(qpos + (skv - sq) >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((tq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((tq,), jnp.float32)
    a0 = jnp.zeros((tq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_block", "kv_block", "causal",
                                             "interpret"))
def flash_attention(q, k, v, *, q_block=128, kv_block=128, causal=True,
                    interpret=False):
    """q: [BH, Sq, D]; k/v: [BH, Skv, D] (kv already expanded per q-head
    group).  Returns [BH, Sq, D]."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % q_block == 0 and skv % kv_block == 0
    grid = (bh, sq // q_block)
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_block=kv_block, causal=causal,
                          sq=sq, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, q_block, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, skv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, skv, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
