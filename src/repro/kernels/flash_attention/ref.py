"""Pure-jnp oracle: full-materialization attention."""
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """q: [B, Sq, H, D]; k/v: [B, Skv, K, D], H = K*G."""
    B, Sq, H, D = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kf) / jnp.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), Skv - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
