import jax
import jax.numpy as jnp

from repro.kernels.superkey_filter.kernel import (superkey_filter,
                                                  superkey_filter_rows)
from repro.kernels.superkey_filter.ref import (superkey_filter_ref,
                                               superkey_filter_rows_ref)


def filter_rows(sk_lo, sk_hi, q_lo, q_hi, *, use_kernel=None, interpret=None,
                t_block=8, n_block=1024):
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return superkey_filter_ref(sk_lo, sk_hi, q_lo, q_hi)
    tp = (-q_lo.shape[0]) % t_block
    npad = (-sk_lo.shape[0]) % n_block
    out = superkey_filter(
        jnp.pad(sk_lo, (0, npad)), jnp.pad(sk_hi, (0, npad)),
        jnp.pad(q_lo, (0, tp), constant_values=jnp.uint32(0xFFFFFFFF)),
        jnp.pad(q_hi, (0, tp), constant_values=jnp.uint32(0xFFFFFFFF)),
        t_block=t_block, n_block=n_block,
        interpret=bool(interpret) and not on_tpu)
    return out[: q_lo.shape[0], : sk_lo.shape[0]]


def filter_candidates(sk_lo, sk_hi, q_lo, q_hi, *, use_kernel=None,
                      interpret=None, t_block=8):
    """Rowwise bloom prune: sk_lo/hi [T, M] gathered candidate digests,
    q_lo/hi [T] per-row query digests -> [T, M] containment mask (the MC
    seeker's superkey stage)."""
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return superkey_filter_rows_ref(sk_lo, sk_hi, q_lo, q_hi)
    t = q_lo.shape[0]
    t_block = min(t_block, t)
    pad = (-t) % t_block
    pd2 = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
    out = superkey_filter_rows(
        pd2(sk_lo), pd2(sk_hi),
        jnp.pad(q_lo, (0, pad), constant_values=jnp.uint32(0xFFFFFFFF)),
        jnp.pad(q_hi, (0, pad), constant_values=jnp.uint32(0xFFFFFFFF)),
        t_block=t_block, interpret=bool(interpret) and not on_tpu)
    return out[:t]
