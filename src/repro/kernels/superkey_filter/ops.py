import jax
import jax.numpy as jnp

from repro.kernels.superkey_filter.kernel import superkey_filter
from repro.kernels.superkey_filter.ref import superkey_filter_ref


def filter_rows(sk_lo, sk_hi, q_lo, q_hi, *, use_kernel=None, interpret=None,
                t_block=8, n_block=1024):
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return superkey_filter_ref(sk_lo, sk_hi, q_lo, q_hi)
    tp = (-q_lo.shape[0]) % t_block
    npad = (-sk_lo.shape[0]) % n_block
    out = superkey_filter(
        jnp.pad(sk_lo, (0, npad)), jnp.pad(sk_hi, (0, npad)),
        jnp.pad(q_lo, (0, tp), constant_values=jnp.uint32(0xFFFFFFFF)),
        jnp.pad(q_hi, (0, tp), constant_values=jnp.uint32(0xFFFFFFFF)),
        t_block=t_block, n_block=n_block,
        interpret=bool(interpret) and not on_tpu)
    return out[: q_lo.shape[0], : sk_lo.shape[0]]
