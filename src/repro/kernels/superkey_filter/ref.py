"""Pure-jnp oracle for the XASH superkey bloom filter."""
import jax.numpy as jnp


def superkey_filter_ref(sk_lo, sk_hi, q_lo, q_hi):
    """sk_lo/hi: [N] u32 row digests; q_lo/hi: [T] u32 query digests.
    Returns [T, N] bool: (row & q) == q."""
    lo_ok = (sk_lo[None, :] & q_lo[:, None]) == q_lo[:, None]
    hi_ok = (sk_hi[None, :] & q_hi[:, None]) == q_hi[:, None]
    return lo_ok & hi_ok


def superkey_filter_rows_ref(sk_lo, sk_hi, q_lo, q_hi):
    """Rowwise variant: sk_lo/hi [T, M] candidate digests vs q_lo/hi [T]
    per-row query digests.  Returns [T, M] bool."""
    lo_ok = (sk_lo & q_lo[:, None]) == q_lo[:, None]
    hi_ok = (sk_hi & q_hi[:, None]) == q_hi[:, None]
    return lo_ok & hi_ok
