"""Pallas TPU kernel: XASH superkey containment over 2xu32 lanes.

Tiled elementwise bitwise AND + compare: each grid step streams a [T_blk,
N_blk] tile through VMEM (the MC seeker's bloom pruning stage, MATE-style).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sk_kernel(sk_lo_ref, sk_hi_ref, q_lo_ref, q_hi_ref, out_ref):
    sk_lo = sk_lo_ref[...]                    # [N_blk]
    sk_hi = sk_hi_ref[...]
    q_lo = q_lo_ref[...]                      # [T_blk]
    q_hi = q_hi_ref[...]
    lo_ok = (sk_lo[None, :] & q_lo[:, None]) == q_lo[:, None]
    hi_ok = (sk_hi[None, :] & q_hi[:, None]) == q_hi[:, None]
    out_ref[...] = lo_ok & hi_ok


def _sk_rows_kernel(sk_lo_ref, sk_hi_ref, q_lo_ref, q_hi_ref, out_ref):
    sk_lo = sk_lo_ref[...]                    # [T_blk, M]
    sk_hi = sk_hi_ref[...]
    q_lo = q_lo_ref[...]                      # [T_blk]
    q_hi = q_hi_ref[...]
    lo_ok = (sk_lo & q_lo[:, None]) == q_lo[:, None]
    hi_ok = (sk_hi & q_hi[:, None]) == q_hi[:, None]
    out_ref[...] = lo_ok & hi_ok


@functools.partial(jax.jit, static_argnames=("t_block", "interpret"))
def superkey_filter_rows(sk_lo, sk_hi, q_lo, q_hi, *, t_block=8,
                         interpret=False):
    """Rowwise containment: candidate digests sk_lo/hi [T, M] (the gathered
    probe window of tuple t) against that tuple's own query digest q_lo/hi
    [T] — the MC seeker's bloom pruning stage."""
    t, m = sk_lo.shape
    assert t % t_block == 0
    grid = (t // t_block,)
    return pl.pallas_call(
        _sk_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_block, m), lambda i: (i, 0)),
            pl.BlockSpec((t_block, m), lambda i: (i, 0)),
            pl.BlockSpec((t_block,), lambda i: (i,)),
            pl.BlockSpec((t_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t_block, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m), jnp.bool_),
        interpret=interpret,
    )(sk_lo, sk_hi, q_lo, q_hi)


@functools.partial(jax.jit, static_argnames=("t_block", "n_block", "interpret"))
def superkey_filter(sk_lo, sk_hi, q_lo, q_hi, *, t_block=8, n_block=1024,
                    interpret=False):
    n = sk_lo.shape[0]
    t = q_lo.shape[0]
    assert n % n_block == 0 and t % t_block == 0
    grid = (t // t_block, n // n_block)
    return pl.pallas_call(
        _sk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_block,), lambda i, j: (j,)),
            pl.BlockSpec((n_block,), lambda i, j: (j,)),
            pl.BlockSpec((t_block,), lambda i, j: (i,)),
            pl.BlockSpec((t_block,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((t_block, n_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.bool_),
        interpret=interpret,
    )(sk_lo, sk_hi, q_lo, q_hi)
