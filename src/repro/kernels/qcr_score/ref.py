"""Pure-jnp oracle for grouped QCR scoring."""
import jax.numpy as jnp


def qcr_score_ref(quadrants, qbits, valid):
    """quadrants/qbits: [G, H] i8; valid: [G, H] bool.
    QCR per group: |2*sum(quad==qbit) - n| / n  (0 when n < 3)."""
    v = valid.astype(jnp.float32)
    agree = ((quadrants == qbits) & valid).astype(jnp.float32)
    n = jnp.sum(v, axis=1)
    a = jnp.sum(agree, axis=1)
    qcr = jnp.abs(2.0 * a - n) / jnp.maximum(n, 1.0)
    return jnp.where(n >= 3, qcr, 0.0)


def qcr_segments_ref(n_agree, n_all, min_support=3):
    """Epilogue over pre-reduced segment sums: |2a - n| / n, 0 under the
    support floor."""
    qcr = jnp.abs(2.0 * n_agree - n_all) / jnp.maximum(n_all, 1.0)
    return jnp.where(n_all >= min_support, qcr, 0.0)
