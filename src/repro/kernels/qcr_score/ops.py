import jax
import jax.numpy as jnp

from repro.kernels.qcr_score.kernel import qcr_score
from repro.kernels.qcr_score.ref import qcr_score_ref


def score(quadrants, qbits, valid, *, use_kernel=None, interpret=None,
          g_block=128):
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return qcr_score_ref(quadrants, qbits, valid)
    pad = (-quadrants.shape[0]) % g_block
    pd = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
    out = qcr_score(pd(quadrants), pd(qbits), pd(valid), g_block=g_block,
                    interpret=bool(interpret) and not on_tpu)
    return out[: quadrants.shape[0]]
