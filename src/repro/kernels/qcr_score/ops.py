import jax
import jax.numpy as jnp

from repro.kernels.qcr_score.kernel import qcr_score, qcr_segments
from repro.kernels.qcr_score.ref import qcr_score_ref, qcr_segments_ref


def score(quadrants, qbits, valid, *, use_kernel=None, interpret=None,
          g_block=128):
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return qcr_score_ref(quadrants, qbits, valid)
    pad = (-quadrants.shape[0]) % g_block
    pd = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
    out = qcr_score(pd(quadrants), pd(qbits), pd(valid), g_block=g_block,
                    interpret=bool(interpret) and not on_tpu)
    return out[: quadrants.shape[0]]


def score_segments(n_agree, n_all, *, min_support=3, use_kernel=None,
                   interpret=None, d_block=2048):
    """QCR epilogue over per-(table, join_col, num_col) segment sums."""
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if use_kernel is None else use_kernel
    if not use_kernel:
        return qcr_segments_ref(n_agree, n_all, min_support)
    d = n_agree.shape[0]
    d_block = min(d_block, d)
    pad = (-d) % d_block
    out = qcr_segments(jnp.pad(n_agree, (0, pad)), jnp.pad(n_all, (0, pad)),
                       min_support=min_support, d_block=d_block,
                       interpret=bool(interpret) and not on_tpu)
    return out[:d]
