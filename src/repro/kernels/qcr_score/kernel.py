"""Pallas TPU kernel: QCR correlation scores over padded sketch groups.

Input layout: one row per (table, join_col, num_col) group holding up to H
h-sampled (quadrant, query-bit) pairs.  The kernel fuses the agreement
compare, masked reduction and the (2a-n)/n epilogue in VMEM — one HBM pass
over the sketch matrix (the correlation seeker's scoring hot loop).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qcr_kernel(quad_ref, qbit_ref, valid_ref, out_ref):
    quad = quad_ref[...]
    qbit = qbit_ref[...]
    valid = valid_ref[...]
    v = valid.astype(jnp.float32)
    agree = jnp.where(valid & (quad == qbit), 1.0, 0.0)
    n = jnp.sum(v, axis=1)
    a = jnp.sum(agree, axis=1)
    qcr = jnp.abs(2.0 * a - n) / jnp.maximum(n, 1.0)
    out_ref[...] = jnp.where(n >= 3, qcr, 0.0)


def _qcr_seg_kernel(agree_ref, all_ref, out_ref, *, min_support):
    n = all_ref[...]
    a = agree_ref[...]
    qcr = jnp.abs(2.0 * a - n) / jnp.maximum(n, 1.0)
    out_ref[...] = jnp.where(n >= min_support, qcr, 0.0)


@functools.partial(jax.jit, static_argnames=("min_support", "d_block",
                                             "interpret"))
def qcr_segments(n_agree, n_all, *, min_support=3, d_block=2048,
                 interpret=False):
    """Fused QCR epilogue over segment sums: n_agree/n_all f32 [D] (one entry
    per (table, join_col, num_col) triple) -> |2a - n| / n with the support
    floor.  The correlation seeker's scoring stage."""
    d = n_agree.shape[0]
    assert d % d_block == 0
    grid = (d // d_block,)
    return pl.pallas_call(
        functools.partial(_qcr_seg_kernel, min_support=min_support),
        grid=grid,
        in_specs=[pl.BlockSpec((d_block,), lambda i: (i,))] * 2,
        out_specs=pl.BlockSpec((d_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(n_agree, n_all)


@functools.partial(jax.jit, static_argnames=("g_block", "interpret"))
def qcr_score(quadrants, qbits, valid, *, g_block=128, interpret=False):
    g, h = quadrants.shape
    assert g % g_block == 0
    grid = (g // g_block,)
    return pl.pallas_call(
        _qcr_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((g_block, h), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((g_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g,), jnp.float32),
        interpret=interpret,
    )(quadrants, qbits, valid)
