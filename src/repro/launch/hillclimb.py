import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# §Perf hillclimbing harness: re-lower a cell with a config override and
# report the roofline-term deltas vs the recorded baseline.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb --arch smollm-360m \
#       --shape train_4k --set causal_block_skip=True --tag blockskip

import argparse
import json
import time
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch import hlo_analysis
from repro.launch.dryrun import (RESULTS_DIR, build_lowered, model_flops,
                                 run_cell)
from repro.launch.mesh import make_production_mesh

PERF_DIR = RESULTS_DIR.parent / "perf"


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    PERF_DIR.mkdir(parents=True, exist_ok=True)

    overrides = dict(parse_override(kv) for kv in args.set)
    cfg = get_config(args.arch).replace(**overrides)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multipod)

    t0 = time.time()
    lowered, params_tree = build_lowered(cfg, shape, mesh)
    compiled = lowered.compile()
    text = compiled.as_text()
    hlo = hlo_analysis.analyze(text)
    terms = hlo_analysis.roofline_terms(hlo, chips=mesh.size)
    mem = compiled.memory_analysis()
    mflops = model_flops(cfg, shape, params_tree)

    mesh_name = "pod2x16x16" if args.multipod else "pod16x16"
    base_path = RESULTS_DIR / f"{args.arch}__{args.shape}__{mesh_name}.json"
    base = json.loads(base_path.read_text()) if base_path.exists() else {}
    rec = {
        "arch": args.arch, "shape": args.shape, "mesh": mesh_name,
        "tag": args.tag, "overrides": overrides,
        "compile_s": round(time.time() - t0, 2),
        "roofline": terms,
        "peak_gb_per_device": (mem.argument_size_in_bytes +
                               mem.output_size_in_bytes +
                               mem.temp_size_in_bytes -
                               mem.alias_size_in_bytes) / 1e9,
        "hlo": {k: hlo[k] for k in ("flops", "collective_bytes", "hbm_bytes")},
        "useful_flops_ratio": (mflops / mesh.size) / max(hlo["flops"], 1),
    }
    if base.get("roofline"):
        rec["baseline"] = {
            "roofline": base["roofline"],
            "peak_gb_per_device":
                base["memory"]["peak_bytes_per_device"] / 1e9,
            "useful_flops_ratio": base["useful_flops_ratio"],
        }
        rec["delta"] = {
            k: (terms[k] / base["roofline"][k] - 1.0)
            if base["roofline"].get(k) else None
            for k in ("compute_s", "memory_s", "collective_s")
        }
    out = PERF_DIR / f"{args.arch}__{args.shape}__{mesh_name}__{args.tag}.json"
    out.write_text(json.dumps(rec, indent=2, default=str))
    brief = {"tag": args.tag,
             "terms": {k: round(terms[k], 4) for k in
                       ("compute_s", "memory_s", "collective_s")},
             "peak_gb": round(rec["peak_gb_per_device"], 2),
             "useful_ratio": round(rec["useful_flops_ratio"], 4),
             "delta_vs_baseline": rec.get("delta")}
    print(json.dumps(brief, indent=2))


if __name__ == "__main__":
    main()
