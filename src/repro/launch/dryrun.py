import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay first — jax locks the device count on
# first init.  (This also forces the docstring below to be a plain comment.)

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# For each cell this produces, per device: memory analysis (proves HBM fit),
# XLA cost analysis, and a trip-count-aware HLO analysis (FLOPs, HBM traffic,
# collective bytes) feeding EXPERIMENTS.md §Dry-run / §Roofline.
#
# Run one cell:   python -m repro.launch.dryrun --arch yi-6b --shape train_4k
# Run everything: python -m repro.launch.dryrun --all   (resumable; one
# subprocess per cell so a pathological compile cannot kill the sweep).

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.dist.sharding import batch_spec, dp_axes, param_specs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.train.step import (make_prefill_step, make_serve_step,
                              make_train_step, train_state_specs)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


# --------------------------------------------------------------------------
# sharding helpers for non-param pytrees
# --------------------------------------------------------------------------

def _cache_spec(mesh, name: str, shape):
    dp = dp_axes(mesh)
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)

    def dp_if(dim):
        return dp if dim % dpn == 0 and dim >= dpn else None

    def model_if(dim):
        return "model" if dim % msize == 0 and dim >= msize else None

    if name in ("k", "v", "ak", "av", "ek", "ev"):      # [L,B,T,K,hd]
        L, B, T, K, hd = shape
        if model_if(K):
            return P(None, dp_if(B), None if dp_if(B) else dp_if(T), "model", None)
        # few-KV-head GQA: shard the cache sequence dim instead (context-
        # parallel decode; softmax partials are combined by GSPMD collectives)
        return P(None, dp_if(B), model_if(T), None, None)
    if name == "state":                                  # [L,B,H,dk,dv]
        L, B, H, dk, dv = shape
        return P(None, dp_if(B), model_if(H), None, None)
    if name == "conv":                                   # [L,B,w,C]
        L, B, w, C = shape
        return P(None, dp_if(B), None, model_if(C))
    return P(*([None] * len(shape)))


def cache_shardings(cache_tree, mesh):
    def rule(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        return NamedSharding(mesh, _cache_spec(mesh, name, tuple(leaf.shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def batch_shardings(batch_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, batch_spec(mesh, len(s.shape))), batch_tree)


# --------------------------------------------------------------------------
# analytic MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE; decode counts 2*N)
# --------------------------------------------------------------------------

def count_params(tree) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def active_params(cfg, params_tree) -> int:
    total = count_params(params_tree)
    if not cfg.n_experts:
        return total
    per_expert = 3 * cfg.d_model * cfg.d_expert
    expert_total = cfg.n_layers * cfg.n_experts * per_expert
    expert_active = cfg.n_layers * cfg.top_k * per_expert
    return total - expert_total + expert_active


def model_flops(cfg, shape, params_tree) -> float:
    n_act = active_params(cfg, params_tree)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch          # decode: per step


# --------------------------------------------------------------------------
# the cell dry-run
# --------------------------------------------------------------------------

def build_lowered(cfg, shape, mesh):
    """Returns (lowered, params_tree_for_flop_count)."""
    sds = registry.input_specs(cfg, shape)
    if shape.kind == "train":
        state_sds = train_state_specs(cfg)
        ps = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            param_specs(tree, mesh, fsdp=cfg.fsdp,
                        expert_data_shard=getattr(cfg, 'expert_data_shard', False)))
        state_sh = {
            "params": ps(state_sds["params"]),
            "opt": {
                "m": ps(state_sds["opt"]["m"]),
                "v": ps(state_sds["opt"]["v"]),
                "step": NamedSharding(mesh, P()),
            },
        }
        fn = jax.jit(make_train_step(cfg),
                     in_shardings=(state_sh, batch_shardings(sds, mesh)),
                     out_shardings=(state_sh, None),
                     donate_argnums=0)
        with mesh:
            return fn.lower(state_sds, sds), state_sds["params"]

    params_sds = registry.param_specs_tree(cfg)
    params_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_sds, mesh, fsdp=cfg.fsdp,
                    expert_data_shard=getattr(cfg, 'expert_data_shard',
                                              False)))
    if shape.kind == "prefill":
        cache_sh = cache_shardings(
            registry.cache_specs(cfg, shape), mesh)
        fn = jax.jit(make_prefill_step(cfg, max_len=shape.seq_len),
                     in_shardings=(params_sh, batch_shardings(sds, mesh)),
                     out_shardings=(cache_sh, None))
        with mesh:
            return fn.lower(params_sds, sds), params_sds

    # decode
    cache_sds = registry.cache_specs(cfg, shape)
    cache_sh = cache_shardings(cache_sds, mesh)
    dp = dp_axes(mesh)
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    tok_sh = NamedSharding(
        mesh, P(dp) if shape.global_batch % dpn == 0 and
        shape.global_batch >= dpn else P())
    fn = jax.jit(make_serve_step(cfg),
                 in_shardings=(params_sh, cache_sh, tok_sh),
                 out_shardings=(cache_sh, tok_sh, None),
                 donate_argnums=1)
    with mesh:
        return fn.lower(params_sds, cache_sds,
                        jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)), \
            params_sds


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": 512 if multi_pod else 256}
    if not shape_applicable(cfg, shape):
        rec.update(status="skipped",
                   reason="long_500k needs sub-quadratic attention; "
                          "full-attention arch (see DESIGN.md §Arch-applicability)")
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, params_tree = build_lowered(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    hlo = hlo_analysis.analyze(text)
    terms = hlo_analysis.roofline_terms(hlo, chips=rec["chips"],
                                        peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                                        link_bw=LINK_BW)
    mflops = model_flops(cfg, shape, params_tree)
    chips = rec["chips"]
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    rec.update(
        status="ok",
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        hlo_chars=len(text),
        memory=dict(
            argument_bytes=int(mem.argument_size_in_bytes),
            output_bytes=int(mem.output_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            alias_bytes=int(mem.alias_size_in_bytes),
            peak_bytes_per_device=int(mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        ),
        xla_cost=dict(flops=float(cost.get("flops", -1)),
                      bytes_accessed=float(cost.get("bytes accessed", -1))),
        hlo_analysis=hlo,
        model_flops_global=mflops,
        model_flops_per_chip=mflops / chips,
        useful_flops_ratio=(mflops / chips) / max(hlo["flops"], 1),
        roofline=terms,
        dominant_term=dominant,
        params_global=count_params(params_tree),
        params_active_global=active_params(cfg, params_tree),
    )
    return rec


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def cell_path(arch, shape_name, multi_pod) -> Path:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["blend-discovery"])
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                 for mp in (False, True)]
        failures = 0
        for a, s, mp in cells:
            out = cell_path(a, s, mp)
            if out.exists() and not args.force:
                print(f"[dryrun] skip existing {out.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                   "--shape", s] + (["--multipod"] if mp else [])
            print(f"[dryrun] {a} x {s} x "
                  f"{'2x16x16' if mp else '16x16'} ...", flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   env={**os.environ, "PYTHONPATH": "src"})
                if r.returncode != 0:
                    failures += 1
            except subprocess.TimeoutExpired:
                out.write_text(json.dumps({
                    "arch": a, "shape": s,
                    "mesh": "pod2x16x16" if mp else "pod16x16",
                    "status": "timeout", "timeout_s": args.timeout}))
                failures += 1
        print(f"[dryrun] sweep done, failures={failures}")
        sys.exit(1 if failures else 0)

    if args.arch == "blend-discovery":
        from repro.dist.shard import dryrun_discovery
        rec = dryrun_discovery(multi_pod=args.multipod)
        shape_name = args.shape or "lake"
        out = cell_path("blend-discovery", shape_name, args.multipod)
        out.write_text(json.dumps(rec, indent=2, default=str))
        print(json.dumps({k: rec[k] for k in ("arch", "status")
                          if k in rec}, indent=2))
        return

    try:
        rec = run_cell(args.arch, args.shape, args.multipod)
    except Exception as e:  # record the failure for the sweep report
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "pod2x16x16" if args.multipod else "pod16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    cell_path(args.arch, args.shape, args.multipod).write_text(
        json.dumps(rec, indent=2, default=str))
    brief = {k: rec.get(k) for k in
             ("arch", "shape", "mesh", "status", "compile_s", "dominant_term",
              "useful_flops_ratio", "error")}
    brief["peak_gb_per_device"] = (
        rec.get("memory", {}).get("peak_bytes_per_device", 0) / 1e9
        if rec.get("memory") else None)
    print(json.dumps(brief, indent=2))
    if rec["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    main()
