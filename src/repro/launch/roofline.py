"""Aggregate dry-run JSONs into the §Dry-run / §Roofline markdown tables.

    PYTHONPATH=src python -m repro.launch.roofline > benchmarks/results/roofline.md
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def load_all():
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dominant_short(d):
    return {"compute_s": "compute", "memory_s": "memory",
            "collective_s": "collective"}.get(d, d or "-")


def emit_tables(recs, multi_pod_mesh="pod2x16x16"):
    lines = []
    lines.append("### Dry-run matrix (status x mesh)\n")
    lines.append("| arch | shape | 16x16 | 2x16x16 | peak GB/dev (1 pod) | compile s |")
    lines.append("|---|---|---|---|---|---|")
    by_key = {}
    for r in recs:
        if r.get("arch") == "blend-discovery":
            continue
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    seen = sorted({(a, s) for a, s, _ in by_key})
    for a, s in seen:
        r1 = by_key.get((a, s, "pod16x16"), {})
        r2 = by_key.get((a, s, multi_pod_mesh), {})
        peak = r1.get("memory", {}).get("peak_bytes_per_device")
        lines.append(
            f"| {a} | {s} | {r1.get('status','-')} | {r2.get('status','-')} | "
            f"{'' if peak is None else f'{peak/1e9:.1f}'} | "
            f"{r1.get('compile_s','-')} |")

    lines.append("\n### Roofline (single pod, 256 chips, per step)\n")
    lines.append("| arch | shape | compute | memory | collective | dominant | "
                 "useful FLOP ratio | MODEL_FLOPS/chip |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for a, s in seen:
        r = by_key.get((a, s, "pod16x16"), {})
        if r.get("status") != "ok":
            lines.append(f"| {a} | {s} | - | - | - | skipped | - | - |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {a} | {s} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | {dominant_short(r['dominant_term'])} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['model_flops_per_chip']/1e12:.2f}T |")

    # blend-discovery cells
    lines.append("\n### blend-discovery (Gittables-scale index)\n")
    lines.append("| mesh | seeker | compile s | GB/dev | memory term | collective term |")
    lines.append("|---|---|---|---|---|---|")
    for r in recs:
        if r.get("arch") != "blend-discovery":
            continue
        for name, v in r.get("seekers", {}).items():
            t = v["roofline"]
            lines.append(f"| {r['mesh']} | {name} | {v['compile_s']} | "
                         f"{v['memory_gb_per_device']} | "
                         f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} |")
    return "\n".join(lines)


def summary_stats(recs):
    ok = [r for r in recs if r.get("status") == "ok" and
          r.get("arch") != "blend-discovery"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    fits = [r for r in ok if r["memory"]["peak_bytes_per_device"] <= 16e9]
    return {"ok": len(ok), "skipped": len(skipped),
            "fits_16gb": len(fits),
            "over_16gb": sorted({(r['arch'], r['shape'])
                                 for r in ok
                                 if r['memory']['peak_bytes_per_device'] > 16e9})}


def emit_baseline_comparison():
    base_dir = RESULTS.parent / "dryrun_baseline"
    if not base_dir.exists():
        return ""
    lines = ["\n### Baseline (paper-faithful) vs optimized defaults "
             "(single pod, train cells)\n",
             "| arch | shape | memory term base -> opt | collective base -> "
             "opt | useful ratio base -> opt |",
             "|---|---|---|---|---|"]
    for f in sorted(RESULTS.glob("*pod16x16.json")):
        opt = json.loads(f.read_text())
        bf = base_dir / f.name
        if opt.get("status") != "ok" or not bf.exists():
            continue
        base = json.loads(bf.read_text())
        if base.get("status") != "ok" or "roofline" not in base:
            continue
        bo, oo = base["roofline"], opt["roofline"]
        lines.append(
            f"| {opt['arch']} | {opt['shape']} | "
            f"{fmt_s(bo['memory_s'])} -> {fmt_s(oo['memory_s'])} | "
            f"{fmt_s(bo['collective_s'])} -> {fmt_s(oo['collective_s'])} | "
            f"{base['useful_flops_ratio']:.3f} -> "
            f"{opt['useful_flops_ratio']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load_all()
    print(emit_tables(recs))
    print(emit_baseline_comparison())
    print("\n### Summary\n")
    print(json.dumps(summary_stats(recs), indent=2, default=str))
