"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE, so scanned
layer stacks under-report FLOPs by ~n_layers and collectives are invisible in
aggregate.  This module re-derives the three roofline inputs from
``compiled.as_text()``:

* ``flops``            — 2 * prod(result dims) * prod(contracting dims) per
                         ``dot``, multiplied by the while-loop trip counts of
                         every enclosing loop (parsed from the loop condition's
                         comparison constant).
* ``collective_bytes`` — per collective kind, result-buffer bytes x trip
                         count.  The per-chip link-traffic convention applied
                         later: all-reduce 2x, others 1x (ring schedules).
* ``hbm_bytes``        — estimated HBM traffic: for every non-control op,
                         result bytes + operand bytes; fusions are charged at
                         their call site (interior ops are register-level),
                         with dynamic-slice'd parameters charged at slice size.

All numbers are PER DEVICE (the module is the per-partition SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_WHILE_LINE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

_CONTROL_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class OpInfo:
    name: str
    kind: str
    type_str: str
    rest: str
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)        # name -> OpInfo
    order: list = field(default_factory=list)


def parse_hlo(text: str) -> dict:
    """Split module text into computations with per-op symbol tables."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        # computation header at col 0: "%name (args) -> type {" or "ENTRY ..."
        if (not line.startswith(" ")) and stripped.endswith("{") and "(" in stripped:
            header = stripped
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", header)
            if m:
                cur = Computation(m.group(1))
                if header.startswith("ENTRY") or "ENTRY" in header:
                    comps["__entry__"] = cur
                comps[cur.name] = cur
            continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # while ops can have tuple types with /*index=N*/ comments that defeat
        # the generic type regex — handle them first from the raw line.
        if " while(" in rhs:
            op = OpInfo(name, "while", "()", rhs, [])
            cur.ops[name] = op
            cur.order.append(name)
            continue
        # rhs: "<type> <opcode>(<operands>), attrs..."
        tm = re.match(r"((?:\([^=]*?\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?))\s+"
                      r"([\w\-]+)", rhs)
        if not tm:
            continue
        type_str, opcode = tm.group(1), tm.group(2)
        rest = rhs[tm.end():]
        om = _OPERANDS_RE.search(rest)
        operands = []
        if om:
            for tok in om.group(1).split(","):
                # newer XLA prints typed operands ("f32[2,2]{1,0} %x"); older
                # prints bare "%x" — take the %name word either way
                words = [w for w in tok.strip().split() if w.startswith("%")]
                if words:
                    operands.append(words[-1][1:])
        op = OpInfo(name, opcode, type_str, rest, operands)
        cur.ops[name] = op
        cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from a jax-scan-style condition: the s32 scalar constant the
    induction variable is compared against (loops run 0..L-1)."""
    consts = []
    for name in cond.order:
        op = cond.ops[name]
        if op.kind == "constant" and op.type_str.startswith("s32[]"):
            m = re.search(r"\((\-?\d+)\)", op.rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def compute_multiplicities(comps: dict) -> dict:
    """Execution count per computation (entry = 1, while bodies x trip)."""
    entry = comps.get("__entry__")
    if entry is None:  # fall back: computation named main*
        for k, c in comps.items():
            if k.startswith("main"):
                entry = c
                break
    mult = {c.name: 0 for c in comps.values() if c is not entry}
    mult[entry.name] = 1

    # iterate to fixpoint (call graph is a DAG)
    changed = True
    guard = 0
    while changed and guard < 100:
        changed = False
        guard += 1
        for comp in list(comps.values()):
            base = mult.get(comp.name, 0)
            if base == 0:
                continue
            for opname in comp.order:
                op = comp.ops[opname]
                text = op.rest
                wm = _WHILE_RE.search(text)
                if op.kind == "while" and wm:
                    cond_name, body_name = wm.group(1), wm.group(2)
                    tm = _TRIP_RE.search(text)
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        trips = _trip_count(comps[cond_name]) \
                            if cond_name in comps else 1
                    for callee, m in ((body_name, base * max(trips, 1)),
                                      (cond_name, base * max(trips, 1))):
                        if callee in mult and mult[callee] < m:
                            mult[callee] = m
                            changed = True
                else:
                    for cm in _CALL_ATTR_RE.finditer(text):
                        callee = cm.group(1)
                        if callee in mult and mult[callee] < base:
                            mult[callee] = base
                            changed = True
                    # conditionals: branch computations
                    bm = re.search(r"branch_computations=\{([^}]*)\}", text)
                    if bm:
                        for callee in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                            if callee in mult and mult[callee] < base:
                                mult[callee] = base
                                changed = True
                    tm2 = re.search(r"true_computation=%?([\w\.\-]+), "
                                    r"false_computation=%?([\w\.\-]+)", text)
                    if tm2:
                        for callee in tm2.groups():
                            if callee in mult and mult[callee] < base:
                                mult[callee] = base
                                changed = True
    return mult


def _dot_flops(op: OpInfo, comp: Computation) -> int:
    _, rdims = _parse_dims(op.type_str)
    lhs = comp.ops.get(op.operands[0]) if op.operands else None
    cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if lhs is None or cdims_m is None:
        return 0
    _, ldims = _parse_dims(lhs.type_str)
    contract = 1
    if cdims_m.group(1):
        for d in cdims_m.group(1).split(","):
            contract *= ldims[int(d)]
    n = 1
    for d in rdims:
        n *= d
    return 2 * n * contract


def _fusion_hbm_bytes(comp: Computation, fusion_op: OpInfo, caller: Computation) -> int:
    """Fusion call site: root write + parameter reads (sliced params charged
    at slice size)."""
    total = _parse_shape_bytes(fusion_op.type_str)          # write
    # map parameter index -> charged bytes
    sliced_params = {}
    for opname in comp.order:
        op = comp.ops[opname]
        if op.kind in ("dynamic-slice", "slice") and op.operands:
            src = comp.ops.get(op.operands[0])
            if src is not None and src.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", src.rest)
                if m:
                    idx = int(m.group(1))
                    sliced_params[idx] = sliced_params.get(idx, 0) + \
                        _parse_shape_bytes(op.type_str)
        if op.kind == "dynamic-update-slice" and op.operands:
            # charged as a slice-sized write (plus the root write above is
            # aliased; keep the conservative sum)
            pass
    for i, operand_name in enumerate(fusion_op.operands):
        src = caller.ops.get(operand_name)
        if i in sliced_params:
            total += sliced_params[i]
        elif src is not None:
            total += _parse_shape_bytes(src.type_str)
    return total


def analyze(text: str, top: int = 0) -> dict:
    """Returns dict(flops, collective_bytes{kind: bytes}, hbm_bytes[, top_*]).

    With ``top`` > 0, also returns the largest per-op contributors to each
    term — the input to the §Perf hypothesis loop.
    """
    comps = parse_hlo(text)
    mult = compute_multiplicities(comps)
    flops = 0
    coll: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    hbm = 0
    top_flops: list = []
    top_coll: list = []
    top_hbm: list = []

    def note(bucket, comp, op, val, what):
        if top:
            bucket.append((val, f"{comp.name}/{op.name}", what,
                           op.rest.split(", metadata")[0][:160]))

    for comp in {c.name: c for c in comps.values()}.values():
        m = mult.get(comp.name, 0)
        if m == 0:
            continue
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind == "dot":
                f = m * _dot_flops(op, comp)
                flops += f
                note(top_flops, comp, op, f, "dot")
            base_kind = op.kind.rstrip(".0123456789")
            for ck in _COLLECTIVES:
                if base_kind == ck or base_kind == ck + "-start":
                    b = m * _parse_shape_bytes(op.type_str)
                    coll[ck] += b
                    note(top_coll, comp, op, b, ck)
            # HBM traffic: charge non-fusion-interior ops at their site
            contrib = 0
            if op.kind == "fusion":
                callee_m = _CALL_ATTR_RE.search(op.rest)
                if callee_m and callee_m.group(1) in comps:
                    contrib = m * _fusion_hbm_bytes(comps[callee_m.group(1)], op,
                                                    comp)
            elif op.kind in ("dynamic-slice", "slice", "gather"):
                # sliced reads touch only the slice, not the full operand
                contrib = m * 2 * _parse_shape_bytes(op.type_str)
            elif op.kind == "dynamic-update-slice":
                # write (and read-modify) only the updated region
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                if upd is not None:
                    contrib = m * 2 * _parse_shape_bytes(upd.type_str)
            elif op.kind == "scatter":
                upd = comp.ops.get(op.operands[-1]) if op.operands else None
                if upd is not None:
                    contrib = m * 2 * _parse_shape_bytes(upd.type_str)
            elif op.kind not in _CONTROL_OPS and not _is_interior(comp):
                contrib = m * _parse_shape_bytes(op.type_str)
                for o in op.operands:
                    src = comp.ops.get(o)
                    if src is not None and src.kind not in ("constant",):
                        contrib += m * _parse_shape_bytes(src.type_str)
            if contrib:
                hbm += contrib
                note(top_hbm, comp, op, contrib, f"hbm:{op.kind}")
    out = {"flops": int(flops),
           "collective_bytes": {k: int(v) for k, v in coll.items()},
           "collective_bytes_total": int(sum(coll.values())),
           "hbm_bytes": int(hbm)}
    if top:
        for key, bucket in (("top_flops", top_flops), ("top_collectives", top_coll),
                            ("top_hbm", top_hbm)):
            bucket.sort(key=lambda t: -t[0])
            out[key] = [
                {"value": v, "site": s, "what": w, "op": o}
                for v, s, w, o in bucket[:top]]
    return out


def _is_interior(comp: Computation) -> bool:
    """Heuristic: fused/wrapped computations' interior ops are register-level."""
    return comp.name.startswith(("fused_computation", "wrapped_"))


def roofline_terms(analysis: dict, *, chips: int, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, link_bw: float = 50e9) -> dict:
    """Three roofline terms in seconds (per step).  The analysis numbers are
    per-device already, so no division by chips.

    Link-traffic convention (ring schedules): all-reduce = 2x result bytes
    (reduce-scatter + all-gather phases); all-gather / all-to-all /
    collective-permute = result bytes (the received volume); reduce-scatter
    results are 1/n of the input, so traffic ~= result x chips (upper bound:
    the largest group is the whole mesh)."""
    cb = analysis["collective_bytes"]
    link_bytes = (2 * cb.get("all-reduce", 0)
                  + cb.get("all-gather", 0)
                  + cb.get("all-to-all", 0)
                  + cb.get("collective-permute", 0)
                  + chips * cb.get("reduce-scatter", 0))
    return {
        "compute_s": analysis["flops"] / peak_flops,
        "memory_s": analysis["hbm_bytes"] / hbm_bw,
        "collective_s": link_bytes / link_bw,
        "chips": chips,
    }
