"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax device
state.  The dry-run overrides the host device count via XLA_FLAGS *before*
importing jax (see launch/dryrun.py, first two lines).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / benchmarks."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def make_mesh_for(devices: int, *, model_parallel: int = 16):
    """Elastic helper: best-effort (data, model) mesh over ``devices`` chips."""
    model = min(model_parallel, devices)
    while devices % model:
        model -= 1
    return jax.make_mesh((devices // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
