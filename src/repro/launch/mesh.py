"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax device
state.  The dry-run overrides the host device count via XLA_FLAGS *before*
importing jax (see launch/dryrun.py, first two lines).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (Auto) only where the
    installed jax supports it."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / benchmarks."""
    return compat_make_mesh((1, 1), ("data", "model"))


def make_mesh_for(devices: int, *, model_parallel: int = 16):
    """Elastic helper: best-effort (data, model) mesh over ``devices`` chips."""
    model = min(model_parallel, devices)
    while devices % model:
        model -= 1
    return compat_make_mesh((devices // model, model), ("data", "model"))
