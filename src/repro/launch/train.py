"""Fault-tolerant training launcher.

Features exercised end-to-end by examples/train_tiny_lm.py and the tests:
* checkpoint/restart: atomic checkpoints every ``ckpt_every`` steps; on start
  the latest checkpoint is restored and the step-indexed data pipeline
  replays the exact order (no data loss / duplication on restart),
* straggler watchdog: per-step wall times tracked; steps slower than
  ``straggler_factor`` x the running median trigger the (pluggable) callback
  — on a real pod this is where the slow host gets cordoned,
* SIGTERM handling: preemption saves a final checkpoint before exit,
* elastic rescale: restore accepts a different mesh via checkpoint.restore.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.train import checkpoint as ckpt
from repro.train.step import make_train_state, make_train_step


@dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    straggler_factor: float = 3.0
    keep: int = 3


@dataclass
class LoopReport:
    losses: list = field(default_factory=list)
    step_seconds: list = field(default_factory=list)
    straggler_steps: list = field(default_factory=list)
    resumed_from: int | None = None
    final_step: int = 0


def train_loop(cfg, stream, loop_cfg: TrainLoopConfig,
               straggler_cb=None, key=None, hooks=()) -> LoopReport:
    """Run (or resume) a training job.  ``stream.batch_at(step)`` supplies
    deterministic batches."""
    key = key if key is not None else jax.random.PRNGKey(0)
    report = LoopReport()
    step_fn = jax.jit(make_train_step(cfg), donate_argnums=0)

    state = make_train_state(cfg, key)
    start = 0
    last = ckpt.latest_step(loop_cfg.ckpt_dir)
    if last is not None:
        state, start = ckpt.restore(state, loop_cfg.ckpt_dir)
        report.resumed_from = start

    interrupted = {"flag": False}

    def on_term(signum, frame):
        interrupted["flag"] = True

    old = signal.signal(signal.SIGTERM, on_term)
    try:
        for step in range(start, loop_cfg.steps):
            t0 = time.perf_counter()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in stream.batch_at(step).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            report.losses.append(loss)
            report.step_seconds.append(dt)
            med = float(np.median(report.step_seconds))
            if len(report.step_seconds) > 5 and \
                    dt > loop_cfg.straggler_factor * med:
                report.straggler_steps.append(step)
                if straggler_cb is not None:
                    straggler_cb(step, dt, med)
            for h in hooks:
                h(step, state, metrics)
            done = step + 1
            if done % loop_cfg.ckpt_every == 0 or done == loop_cfg.steps or \
                    interrupted["flag"]:
                ckpt.save(state, loop_cfg.ckpt_dir, done, keep=loop_cfg.keep)
            if interrupted["flag"]:
                break
            report.final_step = done
    finally:
        signal.signal(signal.SIGTERM, old)
    return report
