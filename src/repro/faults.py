"""Seeded, deterministic fault injection for durability and serving tests.

The library is sprinkled with **named fault points** — ``faults.checkpoint``
calls around every durability-critical transition.  When no injector is
active a checkpoint is a single global read and a ``None`` compare, so the
hot path pays nothing.  Tests and benchmarks activate an injector to turn
specific points into crashes, component failures, torn writes, or latency:

    inj = FaultInjector(seed=0, crash={"wal.append.pre": 2})
    with faults.inject(inj):
        lake.add_table(t1)          # fine (hit 1)
        lake.add_table(t2)          # raises InjectedCrash (hit 2)

Point taxonomy (the names tests enumerate):

* ``store.add.pre/post``, ``store.drop.pre/post``,
  ``store.compact.pre/post`` — around LiveLake mutations (pre = before the
  in-memory apply, post = after the WAL record is durable).
* ``wal.append.pre/post`` — around one WAL record append; ``torn=`` points
  at ``wal.append`` write a seeded *fraction* of the record then crash —
  the torn-tail case replay must truncate.
* ``snapshot.write.pre``, ``snapshot.rename.pre``, ``snapshot.post`` —
  around the write-temp-then-rename snapshot commit.
* ``shard.probe.{s}`` — before shard ``s``'s fused probe dispatch; ``fail=``
  here raises a *recoverable* :class:`InjectedFault` that the serving tier's
  shard-retry / degraded-response path absorbs.

Crash vs failure: :class:`InjectedCrash` subclasses ``BaseException`` — it
models ``kill -9`` and must never be absorbed by a library ``except
Exception`` recovery path; tests catch it explicitly at top level and then
recover from disk.  :class:`InjectedFault` subclasses :class:`BlendFault`
(an ordinary ``Exception``) — it models a failed component the system is
expected to survive.

Determinism: everything derives from the injector's seed and its per-point
hit counters; ``record=True`` turns the injector into a pure recorder so a
clean run enumerates every point it crossed (the crash-at-every-point
property test iterates exactly that list).
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager

import numpy as np

from repro.errors import BlendFault


class InjectedCrash(BaseException):
    """Simulated process kill at a named fault point.  BaseException on
    purpose: no library ``except Exception`` handler may absorb a simulated
    ``kill -9`` — only the test harness catches it, then recovers from
    disk."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected crash at {point!r} (hit {hit})")
        self.point = point
        self.hit = hit


class InjectedFault(BlendFault):
    """Simulated recoverable component failure (e.g. one shard's probe)."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected fault at {point!r} (hit {hit})")
        self.point = point
        self.hit = hit


class FaultInjector:
    """Deterministic fault plan keyed on named points and 1-based hit
    counts.

    ``crash={point: n}``   — raise :class:`InjectedCrash` on the n-th hit.
    ``fail={point: k}``    — raise :class:`InjectedFault` on hits 1..k
                             (consecutive failures; hit k+1 succeeds — the
                             retry-path knob).
    ``torn={point: n}``    — at the n-th hit of a torn-capable point (WAL
                             appends) write a seeded fraction of the record,
                             then crash.
    ``latency={point: s}`` — sleep ``s`` seconds at every hit (injected
                             ``sleep`` for tests).
    ``record=True``        — never raise; just record the ordered unique
                             point names crossed (``.points``).
    """

    def __init__(self, seed: int = 0, *, crash: dict | None = None,
                 fail: dict | None = None, torn: dict | None = None,
                 latency: dict | None = None, sleep=time.sleep,
                 record: bool = False):
        self.crash = dict(crash or {})
        self.fail = dict(fail or {})
        self.torn = dict(torn or {})
        self.latency = dict(latency or {})
        self.record = record
        self._sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.hits: dict = {}          # point -> hit count so far
        self.points: list = []        # ordered unique points crossed

    def _count(self, point: str) -> int:
        with self._lock:
            n = self.hits.get(point, 0) + 1
            self.hits[point] = n
            if n == 1:
                self.points.append(point)
            return n

    def hit(self, point: str):
        """One checkpoint crossing: count it, then latency / fail / crash
        in that order (a point can both lag and die)."""
        n = self._count(point)
        if self.record:
            return
        lag = self.latency.get(point)
        if lag:
            self._sleep(lag)
        if self.fail.get(point, 0) >= n:
            raise InjectedFault(point, n)
        if self.crash.get(point) == n:
            raise InjectedCrash(point, n)

    def torn_fraction(self, point: str) -> float | None:
        """Non-None when this hit should tear: the seeded fraction of the
        record to actually write before crashing.  Does NOT raise — the
        caller writes the partial record first, then calls
        :meth:`crash_now` so the torn bytes really land on disk."""
        if self.record:
            return None
        n = self._count(point)
        if self.torn.get(point) != n:
            return None
        return float(self._rng.uniform(0.05, 0.95))

    def crash_now(self, point: str):
        raise InjectedCrash(point, self.hits.get(point, 0))


#: the process-wide active injector (None = zero-cost checkpoints)
_active: FaultInjector | None = None


def active() -> FaultInjector | None:
    return _active


@contextmanager
def inject(injector: FaultInjector):
    """Activate ``injector`` for the dynamic extent of the block.  Not
    reentrant across nested distinct injectors (last one wins), which the
    deterministic tests never need."""
    global _active
    prev = _active
    _active = injector
    try:
        yield injector
    finally:
        _active = prev


def checkpoint(point: str):
    """A named fault point.  Near-zero cost when no injector is active."""
    inj = _active
    if inj is not None:
        inj.hit(point)


def torn_fraction(point: str) -> float | None:
    """Torn-write probe for WAL appends (see FaultInjector.torn_fraction)."""
    inj = _active
    return inj.torn_fraction(point) if inj is not None else None


def crash_now(point: str):
    inj = _active
    if inj is not None:
        inj.crash_now(point)
    raise InjectedCrash(point, 0)
