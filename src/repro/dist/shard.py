"""Sharded lakes: the SegmentStore partitioned across a device mesh along
the table axis, with fused per-shard probes and a single cross-shard merge.

Layout.  A ``ShardedStore`` is a coordinator over ``n_shards`` ordinary
per-shard ``SegmentStore``s, each pinned to its own mesh device and each
holding a *subset of whole tables* under the store's global geometry
(table-slot capacity, row stride, padded max-cols are imposed identically on
every shard, and table ids are global).  Because a table's postings live
wholly inside exactly one segment — the LiveLake invariant — table-axis
partitioning makes **every** seeker fully shard-local: SC/KW distinct
counts, MC superkey validation and the correlation row-join all group by
table, so a shard computes exact scores for its own tables and literal
zeros everywhere else.  The only cross-shard operation left is summing the
per-shard ``[n_seekers, n_tables]`` score matrices — exact in f32 (one
nonzero contributor per slot) and fused into the single whole-DAG program
(core/fused.py), so a whole plan still costs ``~n_kinds + 1`` logical
launches and results are bit-identical to a 1-shard run on the same data
(as long as no probe window overflows; parity tests assert overflow == 0).

Mutations stay shard-local: ``add_table`` allocates a global id at the
coordinator and routes the new L0 delta to the least-loaded shard;
``drop_table`` tombstones in place on the owner.  Global geometry changes
(slot-capacity growth, row-stride widening, max-cols growth) are the one
coordinated path — they change the static shapes every shard's seekers
compile against, so they land on *every* shard and bump its epoch.  The
store's ``epoch`` is the tuple of shard epochs; it flows through the
ordinary ``index_epoch_key`` fingerprint, so the QueryCache can never serve
results staled by any shard's mutation.

``ShardedExecutor`` builds one ``MatchEngine`` per shard (arrays committed
to the shard's device via ``MatchEngine.from_store(device=...)``), rebuilds
only the shards whose epoch moved, and executes exclusively on the fused
path: ``core/fused.py`` dispatches each seeker group once per shard with
*per-shard* capacity windows (a shard only holds its own postings, so its
window is ~``1/n_shards`` of the global rung — the scale-out win) and sums
the staged score matrices on the merge device inside the DAG program.

Validated on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tests/test_distributed.py); when ``n_shards`` exceeds the visible device
count, shards wrap onto devices round-robin so the MPMD layout (and its
bit-identity) is testable on a single device.

``dryrun_discovery()`` lowers the per-shard fused seeker programs over a
Gittables-scale shard on the production mesh — the blend-discovery dry-run
cell (launch/dryrun.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seekers as seek
from repro.core.executor import Executor
from repro.core.index import _ceil_pow2, validate_row_stride
from repro.core.match import EngineConfig, MatchEngine
from repro.store.compact import (CompactionPolicy, compact_store,
                                 maybe_compact as _maybe_compact)
from repro.store.segments import SegmentStore


def shard_devices(n_shards: int) -> list:
    """One device per shard, wrapping round-robin when the host exposes
    fewer devices than shards (single-device test fallback: the MPMD
    layout, capacities and merge are identical, only the parallelism is
    lost)."""
    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(n_shards)]


def make_shard_mesh(n_shards: int):
    """A 1-axis ``('shard',)`` jax.sharding mesh over the first ``n_shards``
    devices, or None when the host exposes fewer devices (round-robin
    fallback — no true mesh exists)."""
    devs = jax.devices()
    if n_shards > len(devs):
        return None
    return jax.sharding.Mesh(np.array(devs[:n_shards]), ("shard",))


class ShardedStore:
    """Coordinator over per-shard ``SegmentStore``s (see module docstring).

    Duck-types the executor/planner surface of a single ``SegmentStore``
    (``n_tables`` / ``max_cols`` / ``row_stride`` / ``host_counts`` /
    ``segments`` / ``epoch`` / ``shape`` / mutation API), so sessions,
    caches and cost models treat a sharded lake like any live store."""

    def __init__(self, lake=None, *, n_shards: int = 2, bucket_bits: int = 12,
                 seed: int = 0, with_quadrants: bool = True, devices=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        tables = list(lake.tables) if lake is not None else []
        n = len(tables)
        # global geometry, imposed identically on every shard
        max_rows = max([t.n_rows for t in tables], default=1)
        row_stride = _ceil_pow2(max(max_rows, 1))
        table_cap = _ceil_pow2(max(n + SegmentStore.MIN_HEADROOM, 16))
        max_cols = max([t.n_cols for t in tables], default=1)
        validate_row_stride(table_cap, row_stride, max_rows)
        self.n_shards = n_shards
        self.devices = list(devices) if devices is not None \
            else shard_devices(n_shards)
        self.mesh = make_shard_mesh(n_shards) if devices is None else None
        # round-robin initial placement: global id g -> shard g % n_shards
        # (matches enumerate order, so LiveLake's id bookkeeping is exact)
        self.shards = []
        for s in range(n_shards):
            entries = [(g, t) for g, t in enumerate(tables)
                       if g % n_shards == s]
            names = [t.name if g % n_shards == s else None
                     for g, t in enumerate(tables)]
            self.shards.append(SegmentStore(
                bucket_bits=bucket_bits, seed=seed,
                with_quadrants=with_quadrants, entries=entries,
                table_names=names, table_cap=table_cap,
                row_stride=row_stride, max_cols=max_cols))

    # -------------------------------------------------------------- geometry
    @property
    def n_tables(self) -> int:
        return self.shards[0].n_tables

    @property
    def n_slots(self) -> int:
        return max(s.n_slots for s in self.shards)

    @property
    def max_cols(self) -> int:
        return max(s.max_cols for s in self.shards)

    @property
    def row_stride(self) -> int:
        return self.shards[0].row_stride

    @property
    def bucket_bits(self) -> int:
        return self.shards[0].bucket_bits

    @property
    def n_postings(self) -> int:
        return sum(s.n_postings for s in self.shards)

    @property
    def epoch(self) -> tuple:
        """Global epoch vector: one counter per shard.  Hashable, compares
        by value — the QueryCache fingerprint and ``Executor.refresh`` use
        it exactly like the scalar epoch of a single store."""
        return tuple(s.epoch for s in self.shards)

    @property
    def segments(self) -> list:
        """All shards' segments (read-only concatenation: statistics and
        duck-type checks — mutations go through the shard owning a run)."""
        return [seg for s in self.shards for seg in s.segments]

    @property
    def alive(self) -> np.ndarray:
        out = self.shards[0].alive.copy()
        for s in self.shards[1:]:
            out |= s.alive
        return out

    @property
    def table_names(self) -> list:
        names = [None] * self.n_slots
        for s in self.shards:
            for i in range(s.n_slots):
                if s.alive[i] and s.table_names[i] is not None:
                    names[i] = s.table_names[i]
        return names

    @property
    def pending_dead(self) -> set:
        return set().union(*(s.pending_dead for s in self.shards))

    @property
    def quadrant(self):
        # cost_model only truth-tests this attribute (store duck type)
        return self.shards[0].quadrant

    @property
    def sketch_config(self):
        return self.shards[0].sketch_config

    def live_ids(self) -> list:
        return sorted(t for s in self.shards for t in s.live_ids())

    def storage_bytes(self) -> int:
        return sum(s.storage_bytes() for s in self.shards)

    # ------------------------------------------------------------ statistics
    def host_counts(self, q_hashes, live_only: bool = False,
                    per_shard: bool = False) -> np.ndarray:
        """Match counts per query hash.  ``per_shard=True`` returns the
        ``[n_shards, nq]`` matrix the fused dispatcher sizes per-shard probe
        windows from; the default sums it — identical to a 1-shard store's
        counts on the same data."""
        per = np.stack([s.host_counts(q_hashes, live_only=live_only)
                        for s in self.shards])
        return per if per_shard else per.sum(axis=0)

    def shape(self) -> dict:
        """Observable index shape (Session.explain): mesh layout plus
        per-shard segment/posting/tombstone counts."""
        tomb = sorted(str(s.table_names[t])
                      for s in self.shards for t in s.pending_dead)
        per = [{"shard": i, "device": str(d), "epoch": s.epoch,
                "segments": len(s.segments), "postings": s.n_postings,
                "live_tables": int(s.alive.sum()),
                "tombstones": len(s.pending_dead)}
               for i, (s, d) in enumerate(zip(self.shards, self.devices))]
        return {
            "mode": "sharded",
            "shards": self.n_shards,
            "mesh_shape": (self.n_shards,),
            "mesh_axes": ("shard",),
            "epoch": self.epoch,
            "segments": sum(len(s.segments) for s in self.shards),
            "postings": self.n_postings,
            "live_tables": int(self.alive.sum()),
            "tombstoned": tomb,
            "table_slots": self.n_tables,
            "row_stride": self.row_stride,
            "per_shard": per,
        }

    # ------------------------------------------------------------- mutations
    def resolve(self, ref) -> int:
        for s in self.shards:
            try:
                return s.resolve(ref)
            except KeyError:
                pass
        raise KeyError(f"no live table matching {ref!r}")

    def owner_of(self, ref) -> int:
        """Shard index owning a live table reference."""
        for i, s in enumerate(self.shards):
            try:
                s.resolve(ref)
                return i
            except KeyError:
                pass
        raise KeyError(f"no live table matching {ref!r}")

    def least_loaded(self) -> int:
        return min(range(self.n_shards),
                   key=lambda i: self.shards[i].n_postings)

    def _alloc_gid(self) -> int:
        # reuse a freed global id if any shard relinquished one; the new
        # owner may be a different shard — the old owner's slot is dead
        # everywhere, so ownership transfers cleanly
        for s in self.shards:
            if s.free_ids:
                return s.free_ids.pop()
        return self.n_slots

    def _sync_max_cols(self):
        """Propagate padded max-cols growth to every shard: it is a static
        seeker shape, so a grown shard and a stale shard must never serve
        the same query with different paddings."""
        mc = max(s._max_cols_real for s in self.shards)
        for s in self.shards:
            if s._max_cols_real != mc:
                before = s.max_cols
                s._max_cols_real = mc
                if s.max_cols != before:
                    s.bump_epoch()

    def add_table(self, table, name: str | None = None,
                  tid: int | None = None, shard: int | None = None) -> int:
        """Route one new table to the least-loaded shard under a
        coordinator-allocated global id.  Only that shard re-indexes (one L0
        delta); global geometry changes — stride widening, capacity growth,
        max-cols growth — are the exception and land on every shard.

        ``tid`` / ``shard`` pin the global id and destination shard — WAL
        replay (store/wal.py) uses both so a recovered lake reproduces the
        uninterrupted run's placement (and therefore its per-shard epochs,
        probe windows and future least-loaded routing) exactly."""
        name = table.name if name is None else name
        if table.n_rows > self.row_stride:
            for s in self.shards:
                s._widen_stride(table.n_rows)
                s.bump_epoch()
        if tid is None:
            gid = self._alloc_gid()
        else:
            gid = int(tid)
            for s in self.shards:
                if gid in s.free_ids:
                    s.free_ids.remove(gid)
        if gid >= self.n_tables:
            cap = self.n_tables
            while gid >= cap:
                cap *= 2
            for s in self.shards:
                s.grow_capacity(cap)      # bumps every shard's epoch
        dest = self.least_loaded() if shard is None else int(shard)
        self.shards[dest].add_table(table, name, tid=gid)
        self._sync_max_cols()
        return gid

    def drop_table(self, ref) -> int:
        """Tombstone on the owner shard (single-table L0 runs are removed
        outright, exactly like the single-store path)."""
        for s in self.shards:
            try:
                gid = s.resolve(ref)
            except KeyError:
                continue
            return s.drop_table(gid)
        raise KeyError(f"no live table matching {ref!r}")

    # ------------------------------------------------------------ compaction
    def maybe_compact(self, policy: CompactionPolicy | None = None) -> bool:
        ran = False
        for s in self.shards:
            ran |= _maybe_compact(s, policy)
        return ran

    def compact(self, policy: CompactionPolicy | None = None,
                full: bool = False, reclaim_ids: bool = False):
        if reclaim_ids:
            raise ValueError(
                "reclaim_ids is unsupported on a sharded lake: table ids "
                "are global across shards and results would be renumbered "
                "per shard")
        for s in self.shards:
            compact_store(s, policy, full=full)
        return None


class ShardedExecutor(Executor):
    """Executor over a ``ShardedStore``: one committed MatchEngine per shard,
    fused-path-only execution, per-shard epoch tracking (a shard-local
    mutation rebuilds exactly one engine)."""

    def __init__(self, store, m_cap_max: int = 1024, row_cap: int = 8,
                 backend: str = "sorted", interpret: bool = False,
                 bucket_width: int | None = None):
        if not hasattr(store, "shards"):
            raise TypeError("ShardedExecutor needs a ShardedStore; use "
                            "Executor for single-device lakes")
        self.n_shards = store.n_shards
        self.devices = list(store.devices)
        # the DAG program (and its cached-result inputs) live on the default
        # device, which is also shard 0's device — staged per-shard scores
        # meet the cache-fed vectors there with no extra hop
        self.merge_device = jax.devices()[0]
        self._shard_epochs = [None] * store.n_shards
        self.engines = [None] * store.n_shards
        super().__init__(store, m_cap_max=m_cap_max, row_cap=row_cap,
                         backend=backend, interpret=interpret,
                         bucket_width=bucket_width)

    def _build_engine(self):
        store = self.index
        if self.bucket_width is not None:
            raise ValueError(
                "bucket_width is not configurable on a live store: "
                "each segment sizes its own lossless bucket layout")
        for s, shard in enumerate(store.shards):
            if self._shard_epochs[s] != shard.epoch:
                self.engines[s] = MatchEngine.from_store(
                    shard, backend=self.backend, interpret=self.interpret,
                    device=self.devices[s])
                self._shard_epochs[s] = shard.epoch
        self.engine = self.engines[0]       # stats/back-compat surface
        self.dev = self.engine.dev
        self._engine_epoch = store.epoch
        self.n_tables = store.n_tables
        self.max_cols = store.max_cols

    def reset_shard(self, s: int):
        """Throw away shard ``s``'s MatchEngine and rebuild it from the
        store — the recovery lever for a failed shard probe (core/fused.py
        retries exactly once on the rebuilt engine before dropping the
        shard from the merge).  Returns the fresh engine."""
        self.engines[s] = None
        self._shard_epochs[s] = None
        self._build_engine()
        return self.engines[s]

    def run(self, plan, optimize: bool = True, cost_model=None,
            sync: bool = True, cache=None, fused: bool = True):
        # sharded plans execute on the fused path only: the per-shard
        # dispatch + merge epilogue IS the execution model (the unfused
        # node-at-a-time walk has no cross-shard merge)
        return super().run(plan, optimize=optimize, cost_model=cost_model,
                           sync=sync, cache=cache, fused=True)

    def run_seeker(self, spec, allowed=None, sync: bool = True):
        raise NotImplementedError(
            "single-seeker dispatch is not defined on a sharded lake; "
            "run a plan (fused path) instead")

    def _sketch_sources(self):
        # one pack per shard, committed to the shard's device like its
        # MatchEngine; table-axis partitioning makes the probe shard-local
        # (a shard's pack is all-zero outside its own tables) so the
        # cross-shard merge in sketch_probe is an exact elementwise sum
        return [(shard.sketch_map(), None, dev)
                for shard, dev in zip(self.index.shards, self.devices)]


# --------------------------------------------------------------------------
# the blend-discovery dry-run cell (lake scale, production mesh)
# --------------------------------------------------------------------------

GITTABLES_SCALE = dict(n_postings=1_400_000_000, n_numeric=350_000_000,
                       n_tables=1_500_000, max_cols=8, row_stride=1 << 8)


def dryrun_discovery(multi_pod: bool = False, nq: int = 1024, m_cap: int = 64,
                     n_tuples: int = 256, n_cols: int = 2, row_cap: int = 8):
    """Lower + compile the per-shard fused seeker programs over a
    Gittables-scale shard (ShapeDtypeStructs, no allocation) sized for the
    production mesh.  Under table-axis MPMD sharding every device runs the
    same shard-local program on ``1/chips`` of the postings, so the
    per-shard lowering IS the per-device serving cost; the cross-shard
    merge is one dense ``[n_seekers, n_tables]`` sum fused into the DAG
    program (negligible next to the probes at this scale)."""
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    sc = GITTABLES_SCALE
    n_dev = mesh.size
    npad = _ceil_pow2(max((sc["n_postings"] + n_dev - 1) // n_dev, 1))
    nnum = _ceil_pow2(max((sc["n_numeric"] + n_dev - 1) // n_dev, 1))
    sds = jax.ShapeDtypeStruct
    dev = {"hash": sds((npad,), jnp.uint32),
           "table": sds((npad,), jnp.int32),
           "col": sds((npad,), jnp.int32),
           "row": sds((npad,), jnp.int32),
           "sk_lo": sds((npad,), jnp.uint32),
           "sk_hi": sds((npad,), jnp.uint32),
           "quadrant": sds((npad,), jnp.int8),
           "rank_conv": sds((npad,), jnp.int32),
           "rank_rand": sds((npad,), jnp.int32),
           "num_rowkey": sds((nnum,), jnp.int32),
           "num_table": sds((nnum,), jnp.int32),
           "num_col": sds((nnum,), jnp.int32),
           "num_quadrant": sds((nnum,), jnp.int8),
           "num_rank_conv": sds((nnum,), jnp.int32),
           "num_rank_rand": sds((nnum,), jnp.int32)}
    cfg = EngineConfig(backend="sorted", interpret=False, bucket_bits=12,
                       bucket_widths=(), seg_bounds=((0, npad, npad),),
                       num_bounds=((0, nnum, nnum),),
                       n_tables=sc["n_tables"], max_cols=sc["max_cols"],
                       row_stride=sc["row_stride"])
    eng = MatchEngine(dev, None, None, cfg)
    nsp = 4                        # one fused group of 4 batched seekers
    fns = {
        "sc": (seek.sc_seeker_seg,
               (eng, sds((nq,), jnp.uint32), sds((nq,), jnp.bool_),
                sds((nq,), jnp.int32), sds((nq,), jnp.int32)),
               dict(m_cap=m_cap, n_seekers=nsp, n_tables=sc["n_tables"],
                    max_cols=sc["max_cols"])),
        "kw": (seek.kw_seeker_seg,
               (eng, sds((nq,), jnp.uint32), sds((nq,), jnp.bool_),
                sds((nq,), jnp.int32), sds((nq,), jnp.int32)),
               dict(m_cap=m_cap, n_seekers=nsp, n_tables=sc["n_tables"])),
        "mc": (seek.mc_seeker_seg,
               (eng, sds((n_tuples, n_cols), jnp.uint32),
                sds((n_tuples,), jnp.int32), sds((n_tuples,), jnp.uint32),
                sds((n_tuples,), jnp.uint32), sds((n_tuples,), jnp.int32),
                sds((n_tuples,), jnp.int32)),
               dict(m_cap=m_cap, n_seekers=nsp, n_tables=sc["n_tables"],
                    n_cols=n_cols, row_stride=sc["row_stride"])),
        "c": (seek.c_seeker_seg,
              (eng, sds((nq,), jnp.uint32), sds((nq,), jnp.bool_),
               sds((nq,), jnp.int8), sds((nq,), jnp.int32),
               sds((nq,), jnp.int32)),
              dict(m_cap=m_cap, row_cap=row_cap, n_seekers=nsp,
                   n_tables=sc["n_tables"], max_cols=sc["max_cols"],
                   h_sample=256, row_stride=sc["row_stride"])),
    }
    rec = {"arch": "blend-discovery",
           "mesh": "pod2x16x16" if multi_pod else "pod16x16",
           "chips": mesh.size, "scale": sc, "status": "ok", "seekers": {}}
    for name, (fn, args, kw) in fns.items():
        t0 = time.time()
        compiled = fn.lower(*args, **kw).compile()
        text = compiled.as_text()
        analysis = hlo_analysis.analyze(text)
        mem = compiled.memory_analysis()
        terms = hlo_analysis.roofline_terms(analysis, chips=mesh.size)
        rec["seekers"][name] = {
            "compile_s": round(time.time() - t0, 2),
            "memory_gb_per_device": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                 mem.output_size_in_bytes) / 1e9, 3),
            "hlo": analysis, "roofline": terms,
        }
    return rec
