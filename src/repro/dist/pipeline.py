"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Each device holds one stage's parameters; microbatches stream through the
stages with ``ppermute`` shifts.  The schedule runs ``n_micro + n_stages - 1``
ticks; device s computes real work on ticks [s, s + n_micro) and bubbles
elsewhere (``bubble_fraction``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn, stage_params, microbatches, *, mesh, axis="stage"):
    """Run ``stage_fn`` over all stages in pipeline order.

    stage_fn: (params_slice, x) -> y, same shape as x.
    stage_params: pytree stacked on a leading [n_stages] axis.
    microbatches: [n_micro, mb, ...] inputs.
    Returns [n_micro, mb, ...] outputs after all stages.
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    ticks = n_micro + n_stages - 1

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis)), out_specs=P(axis),
        check_rep=False)
    def run(params, xs):
        # params: leading stage dim is 1 locally; xs: local slice of the
        # microbatch stack [n_micro/S, mb, ...] — regather it so every stage
        # sees the full queue and feeds from it on its own clock.
        xs = jax.lax.all_gather(xs, axis, tiled=True)        # [n_micro, ...]
        local = jax.tree.map(lambda p: p[0], params)
        sidx = jax.lax.axis_index(axis)
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t from the queue; others use the
            # value shifted in from the previous stage at the end of t-1
            inject = jnp.where(t < n_micro, xs[jnp.minimum(t, n_micro - 1)], 0)
            x_in = jnp.where(sidx == 0, inject, buf)
            y = stage_fn(local, x_in)
            mb_idx = t - sidx                        # microbatch at this stage
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # last stage writes its finished microbatch to the output queue
            write = active & (sidx == n_stages - 1)
            outs = jnp.where(
                write,
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, jnp.clip(mb_idx, 0, n_micro - 1), 0),
                outs)
            y = jnp.where(active, y, 0)
            buf = jax.lax.ppermute(y, axis, perm=fwd)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # outs is populated only on the last stage; reduce to share it, then
        # return this shard's slice of the microbatch stack
        outs = jax.lax.psum(outs, axis)
        shard = n_micro // n_stages
        return jax.lax.dynamic_slice_in_dim(outs, sidx * shard, shard, 0)

    return run(stage_params, microbatches)
