"""Sharding rules: logical-axis constraints + parameter partition specs.

Models annotate activations with *logical* axes ("batch", "seq", "model");
``maybe_constrain`` maps them onto whatever physical mesh is ambient (or is a
no-op outside a mesh context, so every model runs unchanged on a single CPU
device).  ``param_specs`` derives PartitionSpecs for an arbitrary parameter
pytree from shapes alone: tensor-parallel on the model axis, optional ZeRO-3
(fsdp) sharding over the data axes, and optional resident expert-parallelism
over the data axis for MoE expert stacks.

Mesh conventions (see launch/mesh.py): axis names are a subset of
("pod", "data", "model"); "pod" and "data" together form the data-parallel
group, "model" is the tensor-parallel group.
"""
from __future__ import annotations

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec as P


def ambient_mesh():
    """The mesh of the enclosing ``with mesh:`` context, or None."""
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def dp_axes(mesh) -> tuple:
    """The data-parallel mesh axes (every axis except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _dp_entry(mesh):
    """Single axis name when the dp group is one axis, else the tuple."""
    dp = dp_axes(mesh)
    return dp[0] if len(dp) == 1 else dp


# logical activation axis -> physical mesh axes.  "seq" rides the model axis:
# sequence-parallel residual streams / context-parallel attention.
def _physical(mesh, logical):
    if logical in ("batch", "data"):
        return dp_axes(mesh)
    if logical in ("seq", "model"):
        return ("model",) if "model" in mesh.axis_names else ()
    raise ValueError(f"unknown logical axis {logical!r}")


def maybe_constrain(x, *logical_axes):
    """with_sharding_constraint(x, <mapped spec>) inside a mesh context;
    identity outside.  Axes that do not divide their dim are dropped (the
    constraint must stay legal for every reduced/smoke shape)."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    entries = []
    for dim, logical in zip(x.shape, logical_axes):
        if logical is None:
            entries.append(None)
            continue
        phys = _physical(mesh, logical)
        size = _axes_size(mesh, phys)
        if size > 1 and dim % size == 0:
            entries.append(phys[0] if len(phys) == 1 else phys)
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def batch_spec(mesh, ndim: int) -> P:
    """Batch-leading input spec: dim 0 over the data-parallel axes."""
    return P(_dp_entry(mesh), *([None] * (ndim - 1)))


# --------------------------------------------------------------------------
# parameter partition specs
# --------------------------------------------------------------------------

_EXPERT_LEAVES = ("experts_gate", "experts_up", "experts_down")


def _leaf_name(path) -> str:
    if not path:
        return ""
    last = path[-1]
    return last.key if hasattr(last, "key") else str(last)


def _largest_divisible(shape, size, taken=()) -> int | None:
    """Index of the largest dim divisible by ``size`` (ties -> later dim,
    i.e. the output/ffn side of a matmul)."""
    best = None
    for i, d in enumerate(shape):
        if i in taken or d < size or d % size:
            continue
        if best is None or d >= shape[best]:
            best = i
    return best


def param_specs(tree, mesh, *, fsdp: bool = False,
                expert_data_shard: bool = False):
    """PartitionSpec pytree for a parameter pytree (arrays or SDS leaves).

    * model axis: tensor parallelism on the largest divisible dim of every
      >=2-D leaf (trailing dim preferred on ties -> column parallel).
    * fsdp: additionally shard the largest remaining divisible dim over the
      data axes (ZeRO-3; elastic restore re-gathers via device_put).
    * expert_data_shard: MoE expert stacks [L, E, d, f] become resident on
      the data axes (E -> data) with the ffn dim on model — tokens all-to-all
      to the experts, weights never re-gathered.
    Every assignment is divisibility-checked, so the specs are always legal
    jit input shardings for any arch x mesh combination.
    """
    msize = mesh.shape.get("model", 1)
    dsize = _axes_size(mesh, dp_axes(mesh))
    dp = _dp_entry(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if len(shape) < 2:
            return P(*spec)
        name = _leaf_name(path)
        if expert_data_shard and name in _EXPERT_LEAVES and len(shape) >= 3:
            e_dim = len(shape) - 3
            f_dim = len(shape) - (2 if name == "experts_down" else 1)
            if dsize > 1 and shape[e_dim] % dsize == 0:
                spec[e_dim] = dp
            if msize > 1 and shape[f_dim] % msize == 0:
                spec[f_dim] = "model"
            return P(*spec)
        taken = []
        if msize > 1:
            i = _largest_divisible(shape, msize)
            if i is not None:
                spec[i] = "model"
                taken.append(i)
        if fsdp and dsize > 1:
            j = _largest_divisible(shape, dsize, taken)
            if j is not None:
                spec[j] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        rule, tree, is_leaf=lambda x: hasattr(x, "shape"))
