"""Client-side retry policy for the serving front tier.

The server answers admission pressure with *typed values*, not exceptions:
``Overloaded(reason='rate_limit')`` carries ``retry_after_s`` (when the
tenant's token bucket will have refilled) and ``'queue_full'`` means lane
backpressure.  A well-behaved client therefore retries with **capped
exponential backoff seeded from the server's own hint** — never a tight
resubmit loop that amplifies the overload it is reacting to.

:class:`RetryingClient` wraps a ``DiscoveryServer`` (or anything with its
``submit`` signature) and encodes that policy::

    client = RetryingClient(server, max_retries=4)
    resp = client.serve(expr, tenant="alice")     # retries Overloaded
    client.stats()["retries"]                     # resubmission accounting

Only :class:`~repro.errors.Overloaded` is retried.  A
:class:`~repro.errors.DeadlineExceeded` is final by definition — the
caller's latency budget already passed, so a retry could only return an
answer nobody is waiting for; callers that still want one resubmit with a
fresh ``deadline_s``.  Backoff is seeded-deterministic: delays derive from
the client's own RNG, so trace replays reproduce.
"""
from __future__ import annotations

import time

import numpy as np

from repro.errors import Overloaded


class RetryingClient:
    """Submit-with-backoff wrapper (see module docstring).

    ``base_backoff_s * 2**attempt`` doubling, floored by the server's
    ``retry_after_s`` hint, capped at ``max_backoff_s``, then stretched by
    up to ``jitter`` (proportional, seeded) so synchronized clients don't
    retry in lockstep.  ``sleep``/``now`` are injectable for tests."""

    def __init__(self, server, *, max_retries: int = 4,
                 base_backoff_s: float = 0.01, max_backoff_s: float = 1.0,
                 jitter: float = 0.5, seed: int = 0,
                 sleep=time.sleep, now=time.monotonic):
        self.server = server
        self.max_retries = int(max_retries)
        self.base_backoff_s = float(base_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.jitter = float(jitter)
        self._rng = np.random.default_rng(seed)
        self._sleep = sleep
        self._now = now
        self.retries = 0              # resubmissions performed
        self.gave_up = 0              # still Overloaded after max_retries
        self.backoff_total_s = 0.0

    def backoff_s(self, resp, attempt: int) -> float:
        """Delay before retry number ``attempt + 1`` (0-based attempts)."""
        base = self.base_backoff_s * (2.0 ** attempt)
        if isinstance(resp, Overloaded) and resp.retry_after_s:
            base = max(base, float(resp.retry_after_s))
        delay = min(base, self.max_backoff_s)
        if self.jitter:
            delay *= 1.0 + self.jitter * float(self._rng.uniform(0.0, 1.0))
        return delay

    def submit_and_wait(self, query, **kw):
        """``submit().result()`` with the retry loop around it.  Returns
        the final response — a ``DiscoveryResponse``, a ``DeadlineExceeded``
        (never retried), or the last ``Overloaded`` when retries ran out."""
        for attempt in range(self.max_retries + 1):
            resp = self.server.submit(query, **kw).result()
            if not isinstance(resp, Overloaded):
                return resp
            if attempt >= self.max_retries:
                self.gave_up += 1
                return resp
            self.retries += 1
            delay = self.backoff_s(resp, attempt)
            self.backoff_total_s += delay
            self._sleep(delay)
        return resp                   # unreachable; loop always returns

    # DiscoveryServer-compatible alias so call sites can swap the wrapper in
    serve = submit_and_wait

    def stats(self) -> dict:
        return {"retries": self.retries, "gave_up": self.gave_up,
                "backoff_total_s": round(self.backoff_total_s, 4),
                "max_retries": self.max_retries}
