"""QueryCache: the two-level semantic cache behind the serving stack.

Discovery workloads are highly repetitive — users iterate on pipelines that
share whole subtrees (joinability -> correlation -> union chains), so the
largest serving win left after retrace-free dispatch is not executing the
same logical plan twice.  Three levels, all keyed on canonical fingerprints
(query/fingerprint.py), all validated against ``(epoch, index fingerprint)``:

* **plan cache** — query text / expression -> ``Compiled`` (parse + rewrite +
  lower skipped on repeats).  Compilation is index-independent, so this
  level *survives* epoch changes.
* **result cache** — plan fingerprint -> (ResultSet, table ids, ExecInfo).
  A hit serves ranked ids without touching the executor at all.
* **seeker (subplan) cache** — per hash-consed seeker node: seeker-spec
  fingerprint -> its unrestricted ResultSet.  The executor short-circuits
  ``run_seeker`` on a hit; only *unrestricted* runs (``allowed=None``) are
  cached or served, so a partially-cached plan stays bit-identical to a cold
  run — a seeker that would execute under a threaded optimizer mask still
  executes.

Result and seeker levels are LRU with byte-budget accounting (a dense
ResultSet costs 5 bytes/table slot: f32 scores + bool mask).  Any epoch-key
mismatch wipes both — LiveLake ``add_table`` / ``drop_table`` / ``compact``
can never serve stale ids; the plan level is only keyed by query content and
is left intact.

The cache object is engine-agnostic: the executor duck-types ``seeker_key``
/ ``get_seeker`` / ``put_seeker`` (core/ never imports serve/).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

from repro import obs
from repro.query.fingerprint import (fingerprint_plan, fingerprint_spec,
                                     index_epoch_key)

#: default byte budget across the result + seeker levels
DEFAULT_BYTES = 64 << 20
#: entry cap for the (tiny, index-independent) compiled-plan level
PLAN_ENTRIES = 256


@dataclass
class CacheInfo:
    """Per-request cache telemetry, carried on ``QueryResult.cache`` and
    ``DiscoveryResponse.cache`` and rendered by ``session.explain``."""
    status: str                   # 'hit' | 'partial' | 'miss'
    seekers_cached: int = 0       # seeker nodes served from the subplan cache
    seekers_run: int = 0          # seeker nodes actually executed
    entries: int = 0              # resident entries (result + seeker levels)
    bytes: int = 0                # resident bytes (result + seeker levels)
    evictions: int = 0            # lifetime LRU evictions
    invalidations: int = 0        # lifetime epoch wipes
    epoch: int = 0                # epoch the request was served at

    def as_dict(self) -> dict:
        return {"status": self.status, "seekers_cached": self.seekers_cached,
                "seekers_run": self.seekers_run, "entries": self.entries,
                "bytes": self.bytes, "evictions": self.evictions,
                "invalidations": self.invalidations, "epoch": self.epoch}


@dataclass
class CachedResult:
    """One exact-result entry: everything ``serve`` needs, executor-free."""
    result: object                # combiners.ResultSet (device-side)
    info: object                  # ExecInfo of the producing run
    plan_nodes: int
    ids: list | None = None       # ranked table ids, materialized on first hit
    approx: object | None = None  # core.sketch.ApproxInfo for approx entries


@dataclass
class CachedSeeker:
    """One subplan entry: an unrestricted seeker ResultSet + its overflow."""
    result: object
    overflow: object


@dataclass
class _Entry:
    value: object
    nbytes: int


class _LRU:
    """Byte-budgeted LRU dict (move-to-front on get, evict-oldest on put)."""

    def __init__(self, max_bytes: int):
        self.max_bytes = max_bytes
        self.data: OrderedDict = OrderedDict()
        self.bytes = 0
        self.evictions = 0

    def get(self, key):
        e = self.data.get(key)
        if e is None:
            return None
        self.data.move_to_end(key)
        return e.value

    def put(self, key, value, nbytes: int):
        old = self.data.pop(key, None)
        if old is not None:
            self.bytes -= old.nbytes
        if nbytes > self.max_bytes:
            return                       # oversized: never cache, never evict
        self.data[key] = _Entry(value, nbytes)
        self.bytes += nbytes
        while self.bytes > self.max_bytes and len(self.data) > 1:
            _, victim = self.data.popitem(last=False)
            self.bytes -= victim.nbytes
            self.evictions += 1

    def clear(self):
        self.data.clear()
        self.bytes = 0

    def __len__(self):
        return len(self.data)


class QueryCache:
    """See module docstring.  Owned by a ``Session`` (``connect(lake,
    cache=True)``); shared by every query and ``serve_many`` batch on it."""

    def __init__(self, max_bytes: int = DEFAULT_BYTES,
                 result_fraction: float = 0.5):
        result_bytes = int(max_bytes * result_fraction)
        self.results = _LRU(result_bytes)
        self.seekers = _LRU(max_bytes - result_bytes)
        self.plans: OrderedDict = OrderedDict()
        self._epoch_key = None
        self.hits = 0
        self.misses = 0
        self.partial = 0
        self.invalidations = 0

    # ------------------------------------------------------------ validation
    def begin(self, index, config=None) -> tuple:
        """Validate against the live ``(epoch, index fingerprint)`` plus the
        session's execution ``config`` (executor opts + cost-model identity:
        a different m_cap ladder or seeker ranking is a different
        computation); a moved key wipes the result + seeker levels (stale or
        foreign entries are unservable) and keeps the query-content-only
        plan level.  Returns the key."""
        key = (index_epoch_key(index), config)
        if key != self._epoch_key:
            if self._epoch_key is not None:
                self.invalidations += 1
                obs.registry().counter("cache.invalidations").inc()
            self.results.clear()
            self.seekers.clear()
            self._epoch_key = key
        return key

    # ------------------------------------------------------------ plan level
    def get_plan(self, key):
        got = self.plans.get(key)
        if got is not None:
            self.plans.move_to_end(key)
        return got

    def put_plan(self, key, compiled):
        self.plans[key] = compiled
        self.plans.move_to_end(key)
        while len(self.plans) > PLAN_ENTRIES:
            self.plans.popitem(last=False)

    # ---------------------------------------------------------- result level
    @staticmethod
    def result_key(plan, optimize: bool, approx=None) -> tuple:
        """Canonical result identity: plan fingerprint + optimizer mode (the
        B-NO baseline may rank differently, so it gets its own entries).
        ``approx`` is the ``ApproxParams.key()`` tuple for sketch-tier
        requests — different (epsilon, confidence) settings are different
        computations and must never cross-serve with each other or with
        exact entries (``approx=None``)."""
        return (fingerprint_plan(plan), bool(optimize), approx)

    def get_result(self, key) -> CachedResult | None:
        return self.results.get(key)

    def put_result(self, key, entry: CachedResult, n_tables: int):
        # 5 B/table of device arrays (f32 scores + bool mask) plus 36 B/table
        # headroom for the host ids list a hit materializes into the entry
        # (8 B list slot + a Python int object) — charged up front so the
        # write-back can never carry the level past its budget
        nbytes = 41 * n_tables + 96 * max(entry.plan_nodes, 1)
        self.results.put(key, entry, nbytes)

    # ------------------------------------------- seeker level (executor API)
    @staticmethod
    def seeker_key(spec) -> str:
        return fingerprint_spec(spec)

    def get_seeker(self, key) -> CachedSeeker | None:
        got = self.seekers.get(key)
        obs.registry().counter(
            "cache.seeker.hit" if got is not None else "cache.seeker.miss"
        ).inc()
        return got

    def put_seeker(self, key, result, overflow, n_tables: int):
        self.seekers.put(key, CachedSeeker(result, overflow),
                         5 * n_tables + 64)

    # ------------------------------------------------------------- telemetry
    def note(self, status: str):
        if status == "hit":
            self.hits += 1
        elif status == "partial":
            self.partial += 1
        else:
            self.misses += 1
        reg = obs.registry()
        reg.counter(f"cache.result.{status}").inc()
        if reg.enabled:
            reg.gauge("cache.bytes").set(self.resident_bytes)
            reg.gauge("cache.entries").set(self.entries)
            reg.gauge("cache.evictions").set(self.evictions)

    @property
    def entries(self) -> int:
        return len(self.results) + len(self.seekers)

    @property
    def resident_bytes(self) -> int:
        return self.results.bytes + self.seekers.bytes

    @property
    def evictions(self) -> int:
        return self.results.evictions + self.seekers.evictions

    def request_info(self, status: str, *, seekers_cached: int = 0,
                     seekers_run: int = 0) -> CacheInfo:
        """Snapshot the cache state into one request's telemetry record."""
        epoch = self._epoch_key[0][0] if self._epoch_key else 0
        return CacheInfo(status=status, seekers_cached=seekers_cached,
                         seekers_run=seekers_run, entries=self.entries,
                         bytes=self.resident_bytes, evictions=self.evictions,
                         invalidations=self.invalidations, epoch=epoch)

    def stats(self) -> dict:
        """Lifetime counters (benchmarks / observability)."""
        return {"hits": self.hits, "misses": self.misses,
                "partial": self.partial, "entries": self.entries,
                "bytes": self.resident_bytes, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "plans": len(self.plans)}

    def clear(self):
        self.results.clear()
        self.seekers.clear()
        self.plans.clear()
        self._epoch_key = None
