"""Trace-driven load generation for the serving front tier.

Two halves, split so determinism is structural rather than accidental:

* **trace generation** (:func:`make_trace`) is a pure function of its seed —
  no wall clock, no global RNG.  It emits a list of timestamped
  :class:`TraceEvent`\\ s: a Zipf-distributed query mix over a fixed pool of
  distinct BlendQL queries (repeats share canonical fingerprints, so the
  hot head of the distribution is exactly the query-cache-friendly part of
  the space), bursty Markov-modulated Poisson arrivals (ON periods run at
  ``burst_factor`` times the base rate), a tenant/lane mix, and optional
  mutation traffic (add/drop cycles over deterministically generated
  tables, dropped by name so replay never waits on an add's table id).
* **replay** (:func:`replay`) walks a trace against a live
  ``DiscoveryServer`` in open-loop mode: each event is submitted at its
  scheduled offset regardless of completions (offered load is controlled,
  not gated on service), futures are collected, and the report aggregates
  client-observed latency (submit -> future done), goodput, shed rate, and
  the server's own batching stats.

Reproducibility contract (BENCH_7): everything random derives from
``seed``; replay's only nondeterminism is scheduler jitter on the arrival
sleeps — run-to-run latency distributions match modulo machine noise.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import numpy as np

import blend
from repro.core.lake import Table
from repro.serve.batching import BATCH, INTERACTIVE


@dataclass
class TraceEvent:
    t: float                      # seconds from trace start
    kind: str                     # 'query' | 'add' | 'drop'
    tenant: str = "default"
    lane: str = INTERACTIVE
    qid: int = -1                 # index into the query pool (queries)
    payload: object = None        # query expr / Table to add / name to drop


@dataclass
class Trace:
    events: list
    seed: int
    duration_s: float
    config: dict = field(default_factory=dict)

    @property
    def offered_rps(self) -> float:
        n = sum(1 for e in self.events if e.kind == "query")
        return n / self.duration_s if self.duration_s else 0.0


def query_pool(lake, rng, n_distinct: int = 24, k: int = 24) -> list:
    """A fixed pool of distinct queries covering all four seekers and every
    combiner shape (the fingerprint space Zipf ranks over).  Seeded: the
    same ``rng`` state yields the same pool."""
    pool = []
    for i in range(n_distinct):
        t = lake.tables[int(rng.integers(0, lake.n_tables))]
        rows = rng.choice(t.n_rows, min(6, t.n_rows), replace=False)
        sc = blend.sc([t.columns[0][r] for r in rows], k=k)
        kw = blend.kw([t.columns[1][rows[0]], t.columns[1][rows[1]]], k=k)
        mc = blend.mc([(t.columns[0][r], t.columns[1][r]) for r in rows[:4]],
                      k=k)
        corr = blend.corr([t.columns[0][r] for r in rows],
                          [float(j) for j in range(len(rows))], k=k)
        shape = i % 6
        if shape == 0:
            q = (sc & mc).top(10)
        elif shape == 1:
            q = (sc | corr).top(10)
        elif shape == 2:
            q = blend.counter(sc, kw, mc, k=10)
        elif shape == 3:
            q = (mc - kw).top(10)
        elif shape == 4:
            q = ((sc & kw) | corr).top(10)
        else:
            q = sc.top(10)
        pool.append(q)
    return pool


def zipf_qids(rng, n_distinct: int, size: int, a: float = 1.1) -> np.ndarray:
    """Bounded Zipf over pool ranks: P(rank r) ~ 1/r^a.  (``rng.zipf`` is
    unbounded; discovery traffic wants a fixed catalog of hot queries.)"""
    w = 1.0 / np.arange(1, n_distinct + 1, dtype=np.float64) ** a
    return rng.choice(n_distinct, size=size, p=w / w.sum())


def mutation_table(seed: int, i: int, rows: int = 20,
                   vocab: int = 400) -> Table:
    """A deterministically generated table for add/drop traffic; its name
    encodes (seed, i) so drops resolve by name without waiting on ids."""
    rng = np.random.default_rng(900_000 + seed * 10_000 + i)
    return Table(f"loadgen_{seed}_{i}",
                 [[f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [float(x) for x in np.round(rng.normal(0, 5, rows), 3)]])


def make_trace(lake, *, seed: int = 0, duration_s: float = 2.0,
               rate_rps: float = 200.0, zipf_a: float = 1.1,
               n_distinct: int = 24, k: int = 24,
               tenants: tuple = ("tenant_a", "tenant_b", "tenant_c"),
               p_interactive: float = 0.7, p_mutation: float = 0.0,
               burst_factor: float = 4.0, burst_fraction: float = 0.2,
               mean_burst_s: float = 0.05) -> Trace:
    """Generate a deterministic trace (see module docstring).

    Arrivals are Markov-modulated Poisson: exponential ON/OFF state
    holding times (ON mean ``mean_burst_s``, OFF mean chosen so the
    long-run ON fraction is ``burst_fraction``), with the instantaneous
    rate scaled so the *average* offered rate is ``rate_rps``."""
    rng = np.random.default_rng(seed)
    pool = query_pool(lake, rng, n_distinct=n_distinct, k=k)
    bf = min(max(burst_fraction, 0.0), 1.0)
    # average rate = base * ((1 - bf) + bf * burst_factor)
    base = rate_rps / ((1.0 - bf) + bf * burst_factor)
    mean_off_s = mean_burst_s * (1.0 - bf) / bf if 0.0 < bf < 1.0 \
        else float("inf")

    events: list = []
    t = 0.0
    in_burst = bf >= 1.0
    state_end = (rng.exponential(mean_burst_s) if in_burst
                 else rng.exponential(mean_off_s)) if bf not in (0.0, 1.0) \
        else float("inf")
    n_added = 0
    alive: list = []              # names of loadgen tables currently added
    while True:
        rate = base * (burst_factor if in_burst else 1.0)
        t += rng.exponential(1.0 / rate)
        while t > state_end:
            in_burst = not in_burst
            state_end += rng.exponential(
                mean_burst_s if in_burst else mean_off_s)
        if t >= duration_s:
            break
        tenant = str(tenants[int(rng.integers(0, len(tenants)))])
        if p_mutation > 0.0 and rng.random() < p_mutation:
            if alive and (len(alive) > 8 or rng.random() < 0.5):
                name = alive.pop(0)
                events.append(TraceEvent(t=t, kind="drop", tenant=tenant,
                                         payload=name))
            else:
                tab = mutation_table(seed, n_added)
                alive.append(tab.name)
                n_added += 1
                events.append(TraceEvent(t=t, kind="add", tenant=tenant,
                                         payload=tab))
            continue
        qid = int(zipf_qids(rng, n_distinct, 1, a=zipf_a)[0])
        lane = INTERACTIVE if rng.random() < p_interactive else BATCH
        events.append(TraceEvent(t=t, kind="query", tenant=tenant,
                                 lane=lane, qid=qid, payload=pool[qid]))
    return Trace(events=events, seed=seed, duration_s=duration_s,
                 config={"rate_rps": rate_rps, "zipf_a": zipf_a,
                         "n_distinct": n_distinct, "k": k,
                         "tenants": list(tenants),
                         "p_interactive": p_interactive,
                         "p_mutation": p_mutation,
                         "burst_factor": burst_factor,
                         "burst_fraction": burst_fraction})


@dataclass
class ReplayReport:
    offered: int                  # query events submitted
    completed: int                # queries answered with a DiscoveryResponse
    shed: int                     # queries answered with Overloaded
    mutations: int                # mutation events submitted
    makespan_s: float             # first submit -> last future done
    latencies_s: list             # client-observed, completed queries only
    queue_s: list                 # server-reported queue time per response
    batch_sizes: list             # coalesced batch size per response
    shed_reasons: dict
    server_stats: dict
    expired: int = 0              # queries answered with DeadlineExceeded
    retried: int = 0              # shed queries resubmitted with backoff
    gave_up: int = 0              # still Overloaded after max_retries

    @property
    def goodput_rps(self) -> float:
        return self.completed / self.makespan_s if self.makespan_s else 0.0

    @property
    def shed_rate(self) -> float:
        return self.shed / self.offered if self.offered else 0.0

    def percentile_ms(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), q) * 1e3)

    def as_dict(self) -> dict:
        return {
            "offered": self.offered, "completed": self.completed,
            "shed": self.shed, "mutations": self.mutations,
            "makespan_s": round(self.makespan_s, 4),
            "offered_rps": round(self.offered / self.makespan_s, 2)
            if self.makespan_s else 0.0,
            "goodput_rps": round(self.goodput_rps, 2),
            "shed_rate": round(self.shed_rate, 4),
            "shed_reasons": dict(self.shed_reasons),
            "deadline_exceeded": self.expired,
            "retries": {"resubmitted": self.retried,
                        "gave_up": self.gave_up},
            "latency_ms": {"p50": round(self.percentile_ms(50), 3),
                           "p95": round(self.percentile_ms(95), 3),
                           "p99": round(self.percentile_ms(99), 3)},
            "queue_ms_p50": round(float(np.percentile(
                np.asarray(self.queue_s), 50) * 1e3), 3)
            if self.queue_s else 0.0,
            "queue_ms_p99": round(float(np.percentile(
                np.asarray(self.queue_s), 99) * 1e3), 3)
            if self.queue_s else 0.0,
            "batch_size_mean": round(float(np.mean(self.batch_sizes)), 2)
            if self.batch_sizes else 0.0,
            "batch_occupancy_hist":
                self.server_stats["batches"]["size_hist"],
        }


class _Flight:
    """One trace event's lifecycle across (re)submissions."""
    __slots__ = ("ev", "fut", "attempts")

    def __init__(self, ev, fut):
        self.ev = ev
        self.fut = fut
        self.attempts = 0


def replay(server, trace: Trace, *, timeout_s: float = 120.0,
           sleep=time.sleep, now=time.perf_counter,
           deadline_s: float | None = None, max_retries: int = 0,
           base_backoff_s: float = 0.01, max_backoff_s: float = 0.25,
           retry_jitter: float = 0.5) -> ReplayReport:
    """Open-loop replay (see module docstring).  ``sleep``/``now`` are
    injectable for tests that replay without real pacing.

    ``deadline_s`` attaches a per-query latency budget (the server answers
    ``DeadlineExceeded`` for requests whose budget passes while queued;
    counted as ``expired``, never as completed or shed).

    ``max_retries > 0`` turns on well-behaved client retries: a shed query
    is resubmitted after capped exponential backoff floored at the server's
    ``retry_after_s`` hint, with seeded proportional jitter.  Sheds resolve
    synchronously at submit, so retries are scheduled inline on the pacing
    thread and fire at their due times *during* the replay — offered load
    stays open-loop.  Retries default **off**: a pure-shed replay measures
    admission policy, not client politeness."""
    from repro.errors import DeadlineExceeded, Overloaded

    t0 = now()
    rng = np.random.default_rng(trace.seed ^ 0x5E77)
    done_at: dict = {}            # future -> completion wall time
    records: list = []            # of _Flight
    due: list = []                # heap of (due_s, tiebreak, flight)
    tie = itertools.count()
    retried = gave_up = 0

    def _submit(ev):
        if ev.kind == "query":
            kw = {"lane": ev.lane, "tenant": ev.tenant}
            if deadline_s is not None:
                kw["deadline_s"] = deadline_s
            fut = server.submit(ev.payload, **kw)
        elif ev.kind == "add":
            fut = server.add_table(ev.payload, name=ev.payload.name)
        else:
            fut = server.drop_table(ev.payload)
        fut.add_done_callback(lambda f, _now=now: done_at.setdefault(f,
                                                                     _now()))
        return fut

    def _maybe_schedule_retry(fl):
        """Sheds resolve synchronously inside ``submit`` — inspect the
        future right away and queue a backed-off resubmission."""
        nonlocal gave_up
        if not max_retries or fl.ev.kind != "query" or not fl.fut.done():
            return
        try:
            out = fl.fut.result(timeout=0)
        except BaseException:                        # noqa: BLE001
            return
        if not isinstance(out, Overloaded):
            return
        if fl.attempts >= max_retries:
            gave_up += 1
            return
        backoff = base_backoff_s * (2.0 ** fl.attempts)
        if out.retry_after_s:
            backoff = max(backoff, float(out.retry_after_s))
        backoff = min(backoff, max_backoff_s)
        if retry_jitter:
            backoff *= 1.0 + retry_jitter * float(rng.uniform(0.0, 1.0))
        fl.attempts += 1
        heapq.heappush(due, (now() + backoff, next(tie), fl))

    def _drain_due(limit_s):
        nonlocal retried
        while due and due[0][0] <= limit_s:
            _, _, fl = heapq.heappop(due)
            retried += 1
            fl.fut = _submit(fl.ev)
            _maybe_schedule_retry(fl)

    for ev in trace.events:
        _drain_due(now())
        delay = ev.t - (now() - t0)
        if delay > 0:
            sleep(delay)
        fl = _Flight(ev, _submit(ev))
        records.append(fl)
        _maybe_schedule_retry(fl)
    while due:                    # post-trace: flush remaining retries
        wait = due[0][0] - now()
        if wait > 0:
            sleep(wait)
        _drain_due(now())

    offered = completed = shed = mutations = expired = 0
    latencies: list = []
    queue_s: list = []
    batch_sizes: list = []
    shed_reasons: dict = {}
    last_done = t0
    for fl in records:
        ev, fut = fl.ev, fl.fut
        out = fut.result(timeout=timeout_s)
        last_done = max(last_done, done_at.get(fut, now()))
        if ev.kind != "query":
            mutations += 1
            continue
        offered += 1
        if isinstance(out, Overloaded):
            shed += 1
            shed_reasons[out.reason] = shed_reasons.get(out.reason, 0) + 1
            continue
        if isinstance(out, DeadlineExceeded):
            expired += 1
            continue
        completed += 1
        latencies.append(done_at[fut] - (t0 + ev.t))
        queue_s.append(out.queue_seconds)
        batch_sizes.append(out.batch_size)
    return ReplayReport(offered=offered, completed=completed, shed=shed,
                        mutations=mutations, makespan_s=last_done - t0,
                        latencies_s=latencies, queue_s=queue_s,
                        batch_sizes=batch_sizes, shed_reasons=shed_reasons,
                        server_stats=server.stats(), expired=expired,
                        retried=retried, gave_up=gave_up)
