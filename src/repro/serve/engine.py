"""Batched serving engines: LM decode and discovery-query serving.

``LMEngine`` does prefill + greedy decode over a fixed batch of prompts.
``DiscoveryEngine`` serves batched discovery plans over a lake (the paper's
deployment mode: the index is resident, queries stream in).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.executor import ExecInfo
from repro.obs import trace as otrace
from repro.query.session import connect
from repro.train.step import make_prefill_step, make_serve_step


class LMEngine:
    def __init__(self, cfg, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg), donate_argnums=1)

    def generate(self, batch: dict, n_tokens: int):
        cache, tok = self._prefill(self.params, batch)
        out = [np.asarray(tok)]
        for _ in range(n_tokens - 1):
            cache, tok, _ = self._decode(self.params, cache, tok)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)        # [B, n_tokens]


@dataclass
class DiscoveryResponse:
    table_ids: list
    seconds: float
    plan_nodes: int
    # per-request ExecInfo (previously dropped on the floor): what executed,
    # in what order, how long each node took, and the match-buffer overflow —
    # session.explain and the benchmark runner read these without re-running
    # NOTE: on a cache hit (cache['status'] == 'hit') node_seconds/order/
    # overflow describe the PRODUCING run stored with the entry — this
    # request executed nothing; ``seconds`` is its real cost.  Consumers
    # aggregating executed work should filter on the cache status.
    node_seconds: dict = field(default_factory=dict)
    order: list = field(default_factory=list)
    overflow: int = 0
    # device-program dispatches this request cost (ExecInfo.launches): the
    # fused path's observable win — ~n_kinds + 1 per plan vs one per node
    launches: int = 0
    applied_rules: list = field(default_factory=list)
    # query-cache telemetry (serve/cache.py CacheInfo.as_dict()): status
    # hit/partial/miss, seekers served vs run, resident entries/bytes,
    # evictions and epoch invalidations.  None when the cache is disabled.
    cache: dict | None = None
    # front-tier telemetry (serve/server.py): time spent queued before the
    # batch dispatched, and how many requests were coalesced into that
    # batch.  Direct serve/serve_many calls keep the defaults (no queue,
    # batch of one).
    queue_seconds: float = 0.0
    batch_size: int = 1
    # dense f32 [n_tables] score vector (host-side copy) — the full ranking
    # evidence behind table_ids; server parity tests assert it bit-identical
    # between batched and sequential serving
    scores: object = None
    # per-request flight-recorder span tree (obs/trace.py Span), set by
    # DiscoveryServer(trace=True): queue wait, batch formation, epoch pin,
    # per-kind probes, per-shard probes, cross-shard merge, drain, host
    # transfer.  None unless the server is tracing.
    trace: object = None
    # sketch-tier report for ``serve(query, approx=...)`` requests
    # (core/sketch.py ApproxInfo.as_dict): epsilon/confidence, estimator,
    # escalation accounting, and per-hit (estimate, ci_lo, ci_hi) intervals
    # under ``"estimates"``.  None on the exact path.
    approx: dict | None = None
    # graceful degradation (dist/shard.py + core/fused.py): shards whose
    # probe failed twice (initial + one retry on a rebuilt engine) are
    # excluded from the merge instead of failing the request — their tables
    # are simply absent from the ranking.  ``degraded=True`` flags the
    # partial result; ``failed_shards`` names the shard indices dropped.
    degraded: bool = False
    failed_shards: list = field(default_factory=list)

    @property
    def total_node_seconds(self) -> float:
        return sum(self.node_seconds.values())


class DiscoveryEngine:
    """Serves discovery requests (BlendQL expressions, SQL strings, or
    legacy ``Plan`` objects) over a resident lake via one ``Session``.

    With ``live=True`` (or a live session) the engine serves an evolving
    lake: ``add_table`` / ``drop_table`` / ``compact`` / ``snapshot``
    forward to the Session's LiveLake, and in-flight ``serve`` calls always
    observe one consistent index epoch (the executor refreshes between
    requests, never inside one).

    With ``cache=True`` (or a byte budget) the Session serves repeats from
    the semantic query cache (serve/cache.py) — ``DiscoveryResponse.cache``
    reports hit/partial/miss plus resident entries/bytes, and mutations
    invalidate by epoch so cached ids are never stale.

    With ``shards=N`` the lake is partitioned across N devices along the
    table axis (dist/shard.py): every request runs as fused per-shard probes
    plus one cross-shard merge, bit-identical to the unsharded engine."""

    def __init__(self, lake, cost_model=None, backend: str = "sorted",
                 interpret: bool = False, session=None, live: bool = False,
                 cache=False, shards: int | None = None):
        if session is not None:
            if backend != "sorted" or interpret or live or cache or shards:
                raise ValueError("backend/interpret/live/cache/shards are "
                                 "fixed by the given session; pass them to "
                                 "connect() instead")
            if cost_model is not None:
                session.cost_model = cost_model
            self.session = session
        else:
            self.session = connect(lake, cost_model=cost_model,
                                   backend=backend, interpret=interpret,
                                   live=live, cache=cache, shards=shards)
        self.lake = lake

    # -------------------------------------------------- live-lake mutations
    @property
    def live(self):
        return self.session.live

    def add_table(self, table, name=None) -> int:
        return self.session.add_table(table, name=name)

    def drop_table(self, ref) -> int:
        return self.session.drop_table(ref)

    def compact(self, **kw):
        return self.session.compact(**kw)

    def snapshot(self, path):
        return self.session.snapshot(path)

    # Session owns the index/executor/cost model; keep the old attribute
    # surface as thin forwarders.
    @property
    def index(self):
        return self.session.index

    @property
    def executor(self):
        return self.session.executor

    @property
    def cost_model(self):
        return self.session.cost_model

    @cost_model.setter
    def cost_model(self, model):
        self.session.cost_model = model

    @staticmethod
    def _response(res, seconds: float, scores_np=None) -> DiscoveryResponse:
        if scores_np is None:
            scores_np, mask_np = (np.asarray(a) for a in jax.device_get(
                (res.scores, res.result.mask)))
            res.materialize(scores_np, mask_np)
        return DiscoveryResponse(table_ids=res.ids, seconds=seconds,
                                 plan_nodes=len(res.compiled.plan.nodes),
                                 node_seconds=dict(res.info.node_seconds),
                                 order=list(res.info.order),
                                 overflow=res.info.overflow,
                                 launches=res.info.launches,
                                 applied_rules=list(res.applied_rules),
                                 cache=res.cache.as_dict()
                                 if res.cache is not None else None,
                                 scores=scores_np,
                                 approx=res.approx.as_dict(ids=res.ids)
                                 if res.approx is not None else None,
                                 degraded=bool(getattr(res.info,
                                                       "failed_shards", [])),
                                 failed_shards=list(getattr(
                                     res.info, "failed_shards", [])))

    def serve(self, query, optimize: bool = True, fused: bool = False,
              approx=False) -> DiscoveryResponse:
        """One request.  ``approx=`` forwards to ``Session.query`` — the
        response then answers from the sketch tier (estimates + intervals in
        ``DiscoveryResponse.approx``) with only the contended top-k boundary
        escalated to the exact path."""
        res = self.session.query(query, optimize=optimize, fused=fused,
                                 approx=approx)
        return self._response(res, res.seconds)

    @staticmethod
    def _dispatched(res) -> bool:
        """Did this request enqueue any device work?  Only an exact
        result-cache hit enqueues nothing — a 'partial' request still
        dispatches its combiner/top-k ops even when every seeker came from
        the subplan cache, so it keeps its drain share."""
        return res.cache is None or res.cache.status != "hit"

    def serve_many(self, queries, optimize: bool = True,
                   fused: bool = False):
        """Batched serving: every seeker of every request is dispatched
        without host synchronization (no per-seeker ``block_until_ready``, no
        data-dependent compaction stages), value hashing is deduped across
        requests through the executor's hash cache, and the device is drained
        exactly once before the responses are materialized.

        With ``fused=True`` the batch additionally routes through
        ``Session.query_many``: same-kind seekers *across all requests* are
        concatenated into one device program per kind and each request's
        combiner DAG runs as a single jitted program, so a 12-request batch
        costs about ``n_kinds`` shared launches plus 12 tiny DAG programs.
        ``DiscoveryResponse.launches`` is each request's *own* program
        count (~n_kinds + 1); a shared group launch counts once per request
        using it, so summing launches across a batch overstates the actual
        dispatch total — it is a per-request bound, not an additive share.

        ``seconds`` is that request's own compile+dispatch (trace/enqueue)
        time plus an equal share of the single device drain — device time
        within the batch is fungible, so only the host-side cost is
        attributed.  The share is split over the requests that actually
        dispatched device work: an exact query-cache hit enqueued nothing,
        so it pays no drain share and its reported latency stays honest."""
        session = self.session
        rec = otrace.current()
        with rec.span("execute", requests=len(queries), fused=fused):
            if fused:
                pending = [(res, res.seconds) for res in
                           session.query_many(queries, optimize=optimize,
                                              sync=False, fused=True)]
            else:
                pending = []
                for q in queries:
                    t0 = time.perf_counter()
                    res = session.query(q, optimize=optimize, sync=False)
                    pending.append((res, time.perf_counter() - t0))
        hot = [res for res, _ in pending if self._dispatched(res)]
        t0 = time.perf_counter()
        with rec.span("drain", dispatched=len(hot)):
            jax.block_until_ready([res.scores for res in hot])
        drain_share = (time.perf_counter() - t0) / max(len(hot), 1)
        # one host transfer for the whole batch's (scores, mask) pairs —
        # per-response device_get round-trips are a measurable share of the
        # warm batched path
        with rec.span("transfer"):
            fetched = jax.device_get([(res.scores, res.result.mask)
                                      for res, _ in pending])
            ExecInfo.materialize_overflow([res.info for res, _ in pending])
        out = []
        for (res, dispatch_s), (s, m) in zip(pending, fetched):
            s, m = np.asarray(s), np.asarray(m)
            res.materialize(s, m)
            out.append(self._response(
                res, dispatch_s + (drain_share if self._dispatched(res)
                                   else 0.0), scores_np=s))
        return out
