"""Batched serving engines: LM decode and discovery-query serving.

``LMEngine`` does prefill + greedy decode over a fixed batch of prompts.
``DiscoveryEngine`` serves batched discovery plans over a lake (the paper's
deployment mode: the index is resident, queries stream in).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import Executor
from repro.core.index import build_index
from repro.train.step import make_prefill_step, make_serve_step


class LMEngine:
    def __init__(self, cfg, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg), donate_argnums=1)

    def generate(self, batch: dict, n_tokens: int):
        cache, tok = self._prefill(self.params, batch)
        out = [np.asarray(tok)]
        for _ in range(n_tokens - 1):
            cache, tok, _ = self._decode(self.params, cache, tok)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)        # [B, n_tokens]


@dataclass
class DiscoveryResponse:
    table_ids: list
    seconds: float
    plan_nodes: int


class DiscoveryEngine:
    def __init__(self, lake, cost_model=None):
        self.lake = lake
        self.index = build_index(lake)
        self.executor = Executor(self.index)
        self.cost_model = cost_model

    def serve(self, plan, optimize: bool = True) -> DiscoveryResponse:
        t0 = time.perf_counter()
        rs, info = self.executor.run(plan, optimize=optimize,
                                     cost_model=self.cost_model)
        return DiscoveryResponse(table_ids=[int(t) for t in rs.ids()],
                                 seconds=time.perf_counter() - t0,
                                 plan_nodes=len(plan.nodes))

    def serve_many(self, plans, optimize: bool = True):
        return [self.serve(p, optimize=optimize) for p in plans]
