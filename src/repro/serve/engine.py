"""Batched serving engines: LM decode and discovery-query serving.

``LMEngine`` does prefill + greedy decode over a fixed batch of prompts.
``DiscoveryEngine`` serves batched discovery plans over a lake (the paper's
deployment mode: the index is resident, queries stream in).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import Executor
from repro.core.index import build_index
from repro.train.step import make_prefill_step, make_serve_step


class LMEngine:
    def __init__(self, cfg, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg), donate_argnums=1)

    def generate(self, batch: dict, n_tokens: int):
        cache, tok = self._prefill(self.params, batch)
        out = [np.asarray(tok)]
        for _ in range(n_tokens - 1):
            cache, tok, _ = self._decode(self.params, cache, tok)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)        # [B, n_tokens]


@dataclass
class DiscoveryResponse:
    table_ids: list
    seconds: float
    plan_nodes: int


class DiscoveryEngine:
    def __init__(self, lake, cost_model=None, backend: str = "sorted",
                 interpret: bool = False):
        self.lake = lake
        self.index = build_index(lake)
        self.executor = Executor(self.index, backend=backend,
                                 interpret=interpret)
        self.cost_model = cost_model

    def serve(self, plan, optimize: bool = True) -> DiscoveryResponse:
        t0 = time.perf_counter()
        rs, info = self.executor.run(plan, optimize=optimize,
                                     cost_model=self.cost_model)
        return DiscoveryResponse(table_ids=[int(t) for t in rs.ids()],
                                 seconds=time.perf_counter() - t0,
                                 plan_nodes=len(plan.nodes))

    def serve_many(self, plans, optimize: bool = True):
        """Batched serving: every seeker of every plan is dispatched without
        host synchronization (no per-seeker ``block_until_ready``, no
        data-dependent compaction stages), value hashing is deduped across
        plans through the executor's hash cache, and the device is drained
        exactly once before the responses are materialized.

        ``seconds`` is that plan's own dispatch (trace/enqueue) time plus an
        equal share of the single device drain — device time within the
        batch is fungible, so only the host-side cost is attributed."""
        pending = []
        for p in plans:
            t0 = time.perf_counter()
            rs, info = self.executor.run(p, optimize=optimize,
                                         cost_model=self.cost_model,
                                         sync=False)
            pending.append((rs, time.perf_counter() - t0))
        t0 = time.perf_counter()
        jax.block_until_ready([rs.scores for rs, _ in pending])
        drain_share = (time.perf_counter() - t0) / max(len(plans), 1)
        return [DiscoveryResponse(table_ids=[int(t) for t in rs.ids()],
                                  seconds=dispatch_s + drain_share,
                                  plan_nodes=len(p.nodes))
                for p, (rs, dispatch_s) in zip(plans, pending)]
