"""Batch-forming and admission-control primitives for the serving front tier.

The continuous-batching core of ``serve/server.py`` lives here as plain,
clock-injectable state machines so serving *policy* is unit-testable without
threads or sleeps (tests/test_batching.py drives them with a fake clock):

* :class:`TokenBucket` — the per-tenant rate limiter.  ``burst`` tokens of
  capacity refilled at ``rate`` tokens/sec; ``try_acquire`` either debits or
  reports how long until the request would clear.
* :class:`RateLimiter` — a tenant -> bucket map with a default rate and
  per-tenant overrides; tracks sheds per tenant.
* :class:`BatchFormer` — the continuous-batching state machine: priority
  lanes (``interactive`` before ``batch``) with bounded queues, a per-lane
  coalescing window, and FIFO **mutation barriers**.  ``submit`` admits or
  sheds (``queue_full``); ``poll(now)`` returns either a :class:`Batch` of
  coalesced queries, a :class:`Barrier` mutation, or ``None`` (plus
  ``next_deadline`` for the dispatcher's timed wait).  Requests may carry an
  absolute **deadline**: once it passes while queued they are culled into
  ``Batch.expired`` — never dispatched — and the server resolves them with
  a typed ``DeadlineExceeded`` instead of serving stale work.

Barrier semantics — the property the serving tier's bit-identity rests on:
every admitted operation carries a monotone sequence number; a query may
only join a batch if it arrived *before* the oldest pending mutation, and a
mutation only runs once every earlier query has been dispatched.  Queries
therefore observe exactly the index epoch a sequential arrival-order
execution would have shown them (lane priority only reorders read-only
queries *between* barriers, which cannot change any result).  While a
mutation is pending the window is cut short: runnable queries flush
immediately so the barrier drains fast.

Nothing here is thread-safe by itself — the server serializes access under
its own condition variable, and the deterministic tests drive the state
machines single-threaded.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

#: lane names (dict order in ``BatchFormer.lanes`` is dispatch priority)
INTERACTIVE = "interactive"
BATCH = "batch"

#: shed reasons carried on ``Overloaded`` responses and in stats
SHED_QUEUE_FULL = "queue_full"
SHED_RATE_LIMIT = "rate_limit"


@dataclass
class LaneConfig:
    """One priority lane: how long to hold the window open for coalescing,
    and how deep the bounded queue may grow before backpressure sheds."""
    window_s: float = 0.002
    max_queue: int = 256


class TokenBucket:
    """Classic token bucket: ``burst`` capacity, ``rate`` tokens/sec refill.

    ``now`` is injectable (defaults to ``time.monotonic``) so rate decisions
    are testable with a fake clock; every method also takes an explicit
    ``now=`` override.  ``rate=None`` means unlimited (always admits).
    """

    def __init__(self, rate: float | None, burst: float | None = None,
                 now=time.monotonic):
        self.rate = rate
        self.burst = float(burst if burst is not None
                           else (rate if rate is not None else 0) or 1.0)
        self._now = now
        self._tokens = self.burst
        self._t = now()

    def _refill(self, now: float):
        if now > self._t:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now

    def available(self, now: float | None = None) -> float:
        if self.rate is None:
            return float("inf")
        self._refill(self._now() if now is None else now)
        return self._tokens

    def try_acquire(self, n: float = 1.0,
                    now: float | None = None) -> tuple[bool, float]:
        """Debit ``n`` tokens if available.  Returns ``(admitted,
        retry_after_s)`` — ``retry_after_s`` is 0 on admit, else the time
        until ``n`` tokens will have refilled."""
        if self.rate is None:
            return True, 0.0
        self._refill(self._now() if now is None else now)
        if self._tokens >= n:
            self._tokens -= n
            return True, 0.0
        if self.rate <= 0:
            return False, float("inf")
        return False, (n - self._tokens) / self.rate


class RateLimiter:
    """Per-tenant token buckets: one default ``(rate, burst)`` plus
    per-tenant overrides; buckets materialize on first use so tenants need
    no registration.  ``rate=None`` disables limiting entirely."""

    def __init__(self, rate: float | None = None, burst: float | None = None,
                 per_tenant: dict | None = None, now=time.monotonic):
        self.rate, self.burst = rate, burst
        self.per_tenant = dict(per_tenant or {})
        self._now = now
        self._buckets: dict = {}
        self.sheds: dict = {}                 # tenant -> rate-limit sheds

    def _bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            rate, burst = self.per_tenant.get(tenant,
                                              (self.rate, self.burst))
            b = self._buckets[tenant] = TokenBucket(rate, burst,
                                                    now=self._now)
        return b

    def admit(self, tenant: str,
              now: float | None = None) -> tuple[bool, float]:
        ok, retry = self._bucket(tenant).try_acquire(now=now)
        if not ok:
            self.sheds[tenant] = self.sheds.get(tenant, 0) + 1
        return ok, retry


@dataclass
class Pending:
    """One admitted operation waiting in the former.  ``payload`` is opaque
    to the batching layer (the server stores the query/mutation + future).
    ``deadline_s`` is the absolute clock value past which the request must
    not be dispatched — the former culls it into ``Batch.expired`` instead
    of serving stale work."""
    seq: int
    kind: str                     # 'query' | 'mutation'
    lane: str
    tenant: str
    payload: object
    enqueue_s: float
    deadline_s: float | None = None


@dataclass
class Batch:
    """A coalesced set of queries, ready for one fused ``serve_many``.
    ``expired`` carries requests whose deadline passed while queued — never
    dispatched; the server resolves them with ``DeadlineExceeded``.  A batch
    may be *all* expired (``requests == []``)."""
    requests: list                # of Pending, lane-priority order
    formed_s: float
    expired: list = field(default_factory=list)


@dataclass
class Barrier:
    """One mutation, runnable only because every earlier query dispatched."""
    request: Pending


@dataclass
class FormerStats:
    admitted: dict = field(default_factory=dict)     # lane -> count
    shed: dict = field(default_factory=dict)         # reason -> count
    shed_by_lane: dict = field(default_factory=dict)
    batches: int = 0
    batched_requests: int = 0
    batch_size_hist: dict = field(default_factory=dict)   # size -> count
    barriers: int = 0
    expired: int = 0              # deadline-culled, never dispatched

    def note_shed(self, lane: str, reason: str):
        self.shed[reason] = self.shed.get(reason, 0) + 1
        by = self.shed_by_lane.setdefault(lane, {})
        by[reason] = by.get(reason, 0) + 1


class BatchFormer:
    """The continuous-batching state machine (see module docstring)."""

    #: mutation queue bound — mutations shed with ``queue_full`` beyond it
    MUTATION_LANE = "mutation"

    def __init__(self, *, max_batch: int = 16, lanes: dict | None = None,
                 mutation_max_queue: int = 256):
        if lanes is None:
            lanes = {INTERACTIVE: LaneConfig(window_s=0.002, max_queue=256),
                     BATCH: LaneConfig(window_s=0.010, max_queue=1024)}
        self.max_batch = int(max_batch)
        self.lanes = dict(lanes)              # insertion order = priority
        self.mutation_max_queue = int(mutation_max_queue)
        self._queues: dict = {name: deque() for name in self.lanes}
        self._mutations: deque = deque()
        self._seq = 0
        self.stats = FormerStats()

    # ------------------------------------------------------------ admission
    def submit(self, payload, *, lane: str = BATCH, tenant: str = "default",
               kind: str = "query", now: float = 0.0,
               deadline_s: float | None = None):
        """Admit one operation.  Returns ``(Pending, None)`` on admit or
        ``(None, reason)`` on shed (bounded queues are the backpressure:
        beyond ``max_queue`` the request is rejected, never buffered).
        ``deadline_s`` (absolute; queries only) marks the request for
        deadline culling — see :class:`Batch`."""
        if kind == "mutation":
            if len(self._mutations) >= self.mutation_max_queue:
                self.stats.note_shed(self.MUTATION_LANE, SHED_QUEUE_FULL)
                return None, SHED_QUEUE_FULL
            p = Pending(self._next_seq(), kind, self.MUTATION_LANE, tenant,
                        payload, now)
            self._mutations.append(p)
            self.stats.admitted[self.MUTATION_LANE] = \
                self.stats.admitted.get(self.MUTATION_LANE, 0) + 1
            return p, None
        if lane not in self.lanes:
            raise ValueError(f"unknown lane {lane!r}: "
                             f"expected one of {list(self.lanes)}")
        if len(self._queues[lane]) >= self.lanes[lane].max_queue:
            self.stats.note_shed(lane, SHED_QUEUE_FULL)
            return None, SHED_QUEUE_FULL
        p = Pending(self._next_seq(), kind, lane, tenant, payload, now,
                    deadline_s)
        self._queues[lane].append(p)
        self.stats.admitted[lane] = self.stats.admitted.get(lane, 0) + 1
        return p, None

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # -------------------------------------------------------------- forming
    def _barrier_seq(self) -> float:
        return self._mutations[0].seq if self._mutations else float("inf")

    def _runnable(self) -> list:
        """Queries admitted before the oldest pending mutation, in lane
        priority order then FIFO within each lane (test/introspection
        helper — the forming hot path uses the bounded prefix walk in
        ``poll`` instead)."""
        bseq = self._barrier_seq()
        out = []
        for name in self.lanes:
            out.extend(p for p in self._queues[name] if p.seq < bseq)
        return out

    def depth(self) -> dict:
        d = {name: len(q) for name, q in self._queues.items()}
        d[self.MUTATION_LANE] = len(self._mutations)
        return d

    def pending(self) -> int:
        return sum(self.depth().values())

    def next_deadline(self, now: float) -> float | None:
        """When the dispatcher should wake if nothing arrives: the earliest
        window close — or request deadline — among runnable queries
        (``None``: nothing pending, so wait for a submit).  With a mutation
        pending the deadline is ``now`` — runnable queries flush immediately
        so the barrier drains, and a runnable mutation executes without
        waiting.  Only lane *heads* are inspected (O(lanes), not O(depth));
        a non-head request with a shorter deadline than its head is culled
        when it reaches the head or joins a batch, which is exact whenever
        per-lane deadlines are FIFO-ordered (the common case: one deadline
        policy per lane)."""
        if self._mutations:
            return now
        # no mutation pending => every queued query is runnable, and each
        # lane is FIFO, so its earliest window close is its front's
        best = None
        for name, cfg in self.lanes.items():
            q = self._queues[name]
            if q:
                d = q[0].enqueue_s + cfg.window_s
                if q[0].deadline_s is not None:
                    d = min(d, q[0].deadline_s)
                best = d if best is None else min(best, d)
        return best

    def poll(self, now: float):
        """Return ready work: a :class:`Batch`, a :class:`Barrier`, or
        ``None`` (window still open / nothing pending).  A batch is ready
        when it is full, its earliest window closed, or a mutation is
        waiting behind it (barrier flush)."""
        # seqs are assigned at admission, so within each FIFO lane the
        # runnable (pre-barrier) queries are a *prefix* of the deque and the
        # earliest window close is the front's.  Forming is therefore
        # O(max_batch + lanes), independent of queue depth — with thousands
        # queued under overload, a full-queue rescan per poll was the
        # serving tier's throughput cap.
        # deadline culling, part 1: expired lane heads never dispatch — pop
        # them eagerly (any seq: an expired query can't affect any result,
        # so it may leave the queue even from behind a barrier)
        expired: list = []
        for name in self.lanes:
            q = self._queues[name]
            while q and q[0].deadline_s is not None \
                    and now >= q[0].deadline_s:
                expired.append(q.popleft())
        bseq = self._barrier_seq()
        take: list = []
        closed = False
        for name, cfg in self.lanes.items():
            q = self._queues[name]
            if q and q[0].seq < bseq:
                closed = closed or now >= q[0].enqueue_s + cfg.window_s
                if len(take) < self.max_batch:
                    for p in q:
                        if p.seq >= bseq or len(take) >= self.max_batch:
                            break
                        take.append(p)
        if take:
            full = len(take) >= self.max_batch
            flush = bool(self._mutations)
            if full or flush or closed:
                for p in take:        # per-lane prefixes: popleft is exact
                    self._queues[p.lane].popleft()
                # deadline culling, part 2: a mid-prefix request may have
                # expired even though its lane head had not
                kept: list = []
                for p in take:
                    (kept if p.deadline_s is None or now < p.deadline_s
                     else expired).append(p)
                if kept:
                    self.stats.batches += 1
                    self.stats.batched_requests += len(kept)
                    h = self.stats.batch_size_hist
                    h[len(kept)] = h.get(len(kept), 0) + 1
                self.stats.expired += len(expired)
                return Batch(requests=kept, formed_s=now, expired=expired)
            if expired:
                self.stats.expired += len(expired)
                return Batch(requests=[], formed_s=now, expired=expired)
            return None
        if expired:
            self.stats.expired += len(expired)
            return Batch(requests=[], formed_s=now, expired=expired)
        if self._mutations:
            self.stats.barriers += 1
            return Barrier(request=self._mutations.popleft())
        return None
