"""DiscoveryServer: the async serving front tier over a DiscoveryEngine.

The engine (serve/engine.py) is a synchronous in-process object; the fused
path makes ``serve_many`` ~8x cheaper per request than one-at-a-time
``serve`` — but only if something assembles batches from concurrent
traffic.  This module is that something::

    server = DiscoveryServer(DiscoveryEngine(lake, live=True))
    fut = server.submit(expr, lane="interactive", tenant="alice")
    resp = fut.result()        # DiscoveryResponse | Overloaded

Requests enter through ``submit`` (thread-safe, returns a
``concurrent.futures.Future``) and are coalesced by the clock-injectable
:class:`~repro.serve.batching.BatchFormer`: requests arriving within a
lane's batching window form one fused ``serve_many`` call, so responses are
**bit-identical to sequential ``serve``** (table ids and scores) — the
fused batch path already guarantees per-request parity, and mutation
barriers guarantee each query observes the same epoch a sequential
arrival-order execution would have shown it.

Serving policy, not just a queue:

* **priority lanes** — ``interactive`` dispatches before ``batch`` within
  every formed batch; each lane has its own coalescing window.
* **per-tenant rate limits** — token buckets shed excess traffic at
  admission with a typed :class:`Overloaded` (``reason='rate_limit'``)
  carrying ``retry_after_s``.
* **backpressure / load shedding** — lane queues are bounded; beyond
  ``max_queue`` requests are rejected with ``Overloaded('queue_full')``
  rather than queued unboundedly, so queue depth (and therefore p99) stays
  bounded under any offered load.
* **mutation barriers** — ``add_table`` / ``drop_table`` / ``compact`` are
  serialized as barrier ops: a mutation waits for every earlier query to
  dispatch, later queries wait for it, and the whole batch executes under
  ``LiveLake.barrier()`` so one consistent epoch is pinned per batch.

One dispatcher thread owns the engine (jit caches and the executor's
epoch-refresh are not thread-safe); ``explain`` and direct engine access
take the same engine lock.  ``AsyncDiscoveryServer`` is the asyncio façade:
the same futures awaited via ``asyncio.wrap_future``.
"""
from __future__ import annotations

import contextlib
import threading
import time
from collections import deque
from concurrent.futures import Future
from contextlib import nullcontext
from dataclasses import dataclass

from repro import obs
from repro.errors import DeadlineExceeded, Overloaded
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_RECORDER, Recorder, Span, dump_chrome, \
    recording
from repro.serve.batching import (BATCH, INTERACTIVE, SHED_RATE_LIMIT,
                                  BatchFormer, Barrier, Batch, LaneConfig,
                                  RateLimiter)
from repro.serve.engine import DiscoveryEngine

__all__ = ["AsyncDiscoveryServer", "DeadlineExceeded", "DiscoveryServer",
           "Overloaded"]


@dataclass
class _QueryJob:
    query: object
    future: Future
    optimize: bool
    deadline_s: float | None = None   # the caller's requested budget


@dataclass
class _MutationJob:
    op: str                       # 'add_table' | 'drop_table' | 'compact'
    args: tuple
    kwargs: dict
    future: Future


class DiscoveryServer:
    """Continuous-batching front tier (see module docstring).

    Parameters mirror the policy surface: ``max_batch`` bounds coalescing,
    ``interactive_window_s`` / ``batch_window_s`` are the per-lane windows,
    ``max_queue`` / ``batch_max_queue`` bound the lanes (backpressure),
    ``rate`` / ``burst`` / ``per_tenant`` configure token buckets
    (``rate=None``: unlimited), ``optimize`` / ``fused`` set the engine
    defaults.  ``start=False`` leaves the dispatcher parked (deterministic
    queue tests); ``now`` injects the clock for admission decisions.

    Observability: all serving telemetry lives in ``self.metrics`` — the
    process registry when ``repro.obs`` is enabled (or an explicit
    ``metrics=`` registry), else a private one so :meth:`stats` always
    works.  ``trace=True`` turns on the per-request flight recorder: every
    response carries its span tree (``DiscoveryResponse.trace``), the last
    ``trace_capacity`` request trees are retained, and
    :meth:`dump_trace` exports them as Chrome trace-event JSON."""

    def __init__(self, engine, *, max_batch: int = 16,
                 interactive_window_s: float = 0.002,
                 batch_window_s: float = 0.010,
                 max_queue: int = 256, batch_max_queue: int = 1024,
                 mutation_max_queue: int = 256,
                 rate: float | None = None, burst: float | None = None,
                 per_tenant: dict | None = None,
                 optimize: bool = True, fused: bool = True,
                 start: bool = True, now=time.monotonic,
                 trace: bool = False, trace_capacity: int = 256,
                 metrics: MetricsRegistry | None = None,
                 deadline_margin_s: float = 0.0):
        self.engine = engine if isinstance(engine, DiscoveryEngine) \
            else DiscoveryEngine(engine)
        self.optimize, self.fused = optimize, fused
        self._now = now
        self._former = BatchFormer(
            max_batch=max_batch,
            lanes={INTERACTIVE: LaneConfig(interactive_window_s, max_queue),
                   BATCH: LaneConfig(batch_window_s, batch_max_queue)},
            mutation_max_queue=mutation_max_queue)
        self._limiter = RateLimiter(rate, burst, per_tenant, now=now)
        #: subtracted from every request deadline so the cull happens while
        #: there is still time to *not* dispatch — covers batch-formation
        #: latency between the cull decision and the engine call
        self.deadline_margin_s = float(deadline_margin_s)
        self._cond = threading.Condition()
        self._engine_lock = threading.Lock()
        self._stopping = False
        #: dispatcher sleep state (guarded by _cond): None while it is
        #: processing or between polls, else the absolute deadline it sleeps
        #: toward (inf for an idle wait).  submit uses it to wake the
        #: dispatcher only when an arrival changes its plan.
        self._sleep_deadline: float | None = None
        self.metrics = metrics if metrics is not None else (
            obs.registry() if obs.enabled() else MetricsRegistry(now=now))
        self._trace = trace
        #: flight recorder: span trees of the most recent requests
        self._flight: deque = deque(maxlen=trace_capacity)
        # pre-bound hot-path instruments (one dict lookup saved per submit)
        self._m_submitted = self.metrics.counter("server.submitted")
        self._thread: threading.Thread | None = None
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self):
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stopping = False
        self._thread = threading.Thread(target=self._loop,
                                        name="discovery-server", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float | None = 30.0):
        """Stop the dispatcher; with ``drain`` (default) every admitted
        request is served first — futures never dangle."""
        with self._cond:
            self._stopping = True
            if not drain:
                while True:
                    work = self._former.poll(float("inf"))
                    if work is None:
                        break
                    reqs = work.requests + work.expired \
                        if isinstance(work, Batch) else [work.request]
                    for p in reqs:
                        p.payload.future.cancel()
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # ------------------------------------------------------------ admission
    def submit(self, query, *, lane: str = INTERACTIVE,
               tenant: str = "default", optimize: bool | None = None,
               deadline_s: float | None = None) -> Future:
        """Admit one query; returns a Future resolving to a
        ``DiscoveryResponse`` or, when shed, an :class:`Overloaded` (the
        future itself never raises for overload — shedding is a response,
        not an error).  ``deadline_s`` is a *relative* latency budget: if it
        passes while the request is still queued, the request is never
        dispatched and the future resolves to :class:`DeadlineExceeded`
        (minus ``deadline_margin_s`` of headroom for batch formation)."""
        fut: Future = Future()
        job = _QueryJob(query, fut,
                        self.optimize if optimize is None else optimize,
                        deadline_s)
        with self._cond:
            now = self._now()
            ok, retry = self._limiter.admit(tenant, now=now)
            if not ok:
                self.metrics.counter(
                    f"server.shed.{SHED_RATE_LIMIT}").inc()
                fut.set_result(Overloaded(SHED_RATE_LIMIT, lane, tenant,
                                          retry_after_s=retry))
                return fut
            cutoff = None if deadline_s is None \
                else now + deadline_s - self.deadline_margin_s
            pending, reason = self._former.submit(job, lane=lane,
                                                  tenant=tenant, now=now,
                                                  deadline_s=cutoff)
            if pending is None:
                self.metrics.counter(f"server.shed.{reason}").inc()
                fut.set_result(Overloaded(reason, lane, tenant))
                return fut
            self._m_submitted.inc()
            wake = now + self._former.lanes[lane].window_s
            self._wake(wake if cutoff is None else min(wake, cutoff))
        return fut

    def serve(self, query, **kw):
        """Synchronous convenience: submit + wait."""
        return self.submit(query, **kw).result()

    def _submit_mutation(self, op: str, *args, **kwargs) -> Future:
        fut: Future = Future()
        job = _MutationJob(op, args, kwargs, fut)
        with self._cond:
            now = self._now()
            pending, reason = self._former.submit(job, kind="mutation",
                                                  now=now)
            if pending is None:
                fut.set_result(Overloaded(reason, BatchFormer.MUTATION_LANE,
                                          "default"))
                return fut
            self._wake(now)           # a barrier cuts every window short
        return fut

    def _wake(self, deadline: float):
        """Wake the dispatcher only when this arrival changes its plan: it
        is sleeping AND (the arrival's window deadline is earlier than the
        one it sleeps toward, or a full batch is probably ready).  Waking on
        every submit would make the dispatcher rescan its queues once per
        admitted request — an O(depth) cost that caps goodput well below
        the fused engine's capacity at saturating offered load.  Caller
        holds ``_cond``."""
        sd = self._sleep_deadline
        if sd is None:                # processing: it re-polls on its own
            return
        if deadline < sd or \
                sum(self._former.depth().values()) >= self._former.max_batch:
            self._cond.notify()

    def add_table(self, table, name: str | None = None) -> Future:
        """Enqueue a barrier mutation; the future resolves to the table id
        once every earlier query has been served at the old epoch."""
        return self._submit_mutation("add_table", table, name=name)

    def drop_table(self, ref) -> Future:
        return self._submit_mutation("drop_table", ref)

    def compact(self, **kw) -> Future:
        return self._submit_mutation("compact", **kw)

    # ------------------------------------------------------------ dispatcher
    def _loop(self):
        while True:
            with self._cond:
                while True:
                    # when stopping, flush every open window (drain): poll
                    # at t=inf closes all of them, so no future dangles
                    now = float("inf") if self._stopping else self._now()
                    work = self._former.poll(now)
                    if work is not None:
                        break
                    if self._stopping:
                        return
                    deadline = self._former.next_deadline(self._now())
                    timeout = None if deadline is None \
                        else max(deadline - self._now(), 0.0)
                    self._sleep_deadline = float("inf") if deadline is None \
                        else deadline
                    self._cond.wait(timeout=timeout)
                    self._sleep_deadline = None
            if isinstance(work, Batch):
                if work.expired:
                    self._expire(work.expired)
                if work.requests:
                    self._run_batch(work)
            else:
                self._run_barrier(work)

    def _epoch_barrier(self):
        """Pin one consistent epoch for a whole engine call: hold the
        LiveLake mutation barrier so nothing (server mutations run on this
        same thread; direct user mutations run anywhere) can move the store
        epoch while a batch is in flight."""
        live = self.engine.live
        return live.barrier() if live is not None else nullcontext()

    def _expire(self, expired: list):
        """Resolve deadline-culled requests with a typed
        :class:`DeadlineExceeded` — they were never dispatched, so no device
        work was wasted on answers nobody is waiting for."""
        now = self._now()
        m = self.metrics.counter("server.deadline_exceeded")
        for p in expired:
            m.inc()
            job = p.payload
            if not job.future.done():
                job.future.set_result(DeadlineExceeded(
                    p.lane, p.tenant, deadline_s=job.deadline_s,
                    waited_s=max(now - p.enqueue_s, 0.0)))

    def _run_batch(self, batch: Batch):
        start = self._now()
        jobs = [p.payload for p in batch.requests]
        reg = self.metrics
        rec = Recorder(now=self._now) if self._trace else NULL_RECORDER
        try:
            with recording(rec), \
                    rec.span("batch", tid="dispatcher",
                             requests=len(jobs)) as bspan:
                with contextlib.ExitStack() as stack:
                    # pin_epoch measures lock + mutation-barrier wait; the
                    # barrier stays held for the whole dispatch below
                    with rec.span("pin_epoch"):
                        stack.enter_context(self._engine_lock)
                        stack.enter_context(self._epoch_barrier())
                    responses: list = [None] * len(jobs)
                    # per-request optimize overrides partition the batch;
                    # each partition is still one fused serve_many call
                    by_opt: dict = {}
                    for i, job in enumerate(jobs):
                        by_opt.setdefault(job.optimize, []).append(i)
                    for opt, idxs in by_opt.items():
                        out = self.engine.serve_many(
                            [jobs[i].query for i in idxs], optimize=opt,
                            fused=self.fused)
                        for i, resp in zip(idxs, out):
                            responses[i] = resp
        except BaseException as e:                   # noqa: BLE001
            reg.counter("server.batch_errors").inc()
            for job in jobs:
                if not job.future.done():
                    job.future.set_exception(e)
            return
        end = self._now()
        launches = max(r.launches for r in responses)
        ndeg = sum(1 for r in responses if getattr(r, "degraded", False))
        if ndeg:
            reg.counter("server.degraded").inc(ndeg)
        reg.counter("server.served").inc(len(jobs))
        reg.counter("server.batches").inc()
        reg.counter("server.launches").inc(launches)
        reg.gauge("server.launches_last_batch").set(launches)
        reg.histogram("server.batch_size", lo=1.0).observe(len(jobs))
        reg.histogram("server.batch_seconds").observe(end - start)
        for d_lane, d in self._former.depth().items():
            reg.gauge(f"server.queue_depth.{d_lane}").set(d)
        for p, job, resp in zip(batch.requests, jobs, responses):
            resp.queue_seconds = max(start - p.enqueue_s, 0.0)
            resp.batch_size = len(batch.requests)
            reg.histogram(f"server.queue_seconds.{p.lane}").observe(
                resp.queue_seconds)
            reg.histogram(f"server.e2e_seconds.{p.lane}").observe(
                max(end - p.enqueue_s, 0.0))
            if self._trace:
                # per-request tree: its own queue wait, then the (shared)
                # batch subtree — chrome_trace emits shared subtrees once.
                # queue + batch are contiguous wall-clock intervals, so the
                # root's children tile its whole [enqueue, end] extent.
                root = Span("request", t0=min(p.enqueue_s, start), t1=end,
                            tid=f"req-{p.seq}",
                            attrs={"lane": p.lane, "tenant": p.tenant,
                                   "batch_size": len(batch.requests)})
                root.children.append(
                    Span("queue", t0=root.t0, t1=start, tid=root.tid))
                root.children.append(bspan)
                resp.trace = root
                self._flight.append(root)
            if not job.future.cancelled():
                job.future.set_result(resp)

    def _run_barrier(self, barrier: Barrier):
        job = barrier.request.payload
        t0 = self._now()
        try:
            with self._engine_lock:
                out = getattr(self.engine, job.op)(*job.args, **job.kwargs)
        except BaseException as e:                   # noqa: BLE001
            self.metrics.counter("server.mutation_errors").inc()
            if not job.future.done():
                job.future.set_exception(e)
            return
        self.metrics.counter("server.mutations").inc()
        self.metrics.histogram("server.mutation_seconds").observe(
            self._now() - t0)
        if not job.future.cancelled():
            job.future.set_result(out)

    # ------------------------------------------------------------ inspection
    @property
    def session(self):
        return self.engine.session

    def stats(self) -> dict:
        """Serving telemetry: queue depth and occupancy per lane, shed
        counts by reason/lane/tenant, batch-size histogram, aggregate
        launches per batch, mutation counters.  A thin reader: all serving
        counters live in ``self.metrics`` (admission/queue-shape state stays
        in the BatchFormer/RateLimiter, which own those decisions)."""
        with self._cond:
            f = self._former
            s = f.stats
            reg = self.metrics
            depth = f.depth()
            occupancy = {
                name: {"depth": depth[name], "max_queue": cfg.max_queue,
                       "utilization": depth[name] / cfg.max_queue}
                for name, cfg in f.lanes.items()}
            rate_sheds = sum(self._limiter.sheds.values())
            queue_sheds = sum(s.shed.values())
            batches = max(s.batches, 1)
            launches_total = int(reg.counter("server.launches").value)
            return {
                "running": self._thread is not None
                and self._thread.is_alive(),
                "served": int(reg.counter("server.served").value),
                "queue_depth": depth,
                "lane_occupancy": occupancy,
                "shed": {SHED_RATE_LIMIT: rate_sheds, **s.shed,
                         "total": rate_sheds + queue_sheds,
                         "by_lane": {k: dict(v)
                                     for k, v in s.shed_by_lane.items()},
                         "by_tenant": dict(self._limiter.sheds)},
                "batches": {"formed": s.batches,
                            "requests": s.batched_requests,
                            "mean_size": s.batched_requests / batches,
                            "size_hist": {str(k): v for k, v in
                                          sorted(s.batch_size_hist.items())}},
                "launches": {"total": launches_total,
                             "per_batch_mean": launches_total / batches,
                             "last_batch": int(reg.gauge(
                                 "server.launches_last_batch").value)},
                "mutations": {"executed": int(reg.counter(
                                  "server.mutations").value),
                              "pending": depth[f.MUTATION_LANE]},
                "deadline_exceeded": s.expired,
                "degraded": int(reg.counter("server.degraded").value),
            }

    def dump_trace(self, path):
        """Export the flight recorder (the last ``trace_capacity`` request
        span trees) as Chrome trace-event JSON loadable in Perfetto /
        ``chrome://tracing``; returns ``path``."""
        with self._cond:
            roots = list(self._flight)
        return dump_chrome(roots, path)

    def explain(self, query, **kw):
        """``session.explain`` with the server's stats attached (rendered as
        the ``== server ==`` section).  Takes the engine lock: the explain
        runs between batches, never concurrently with one."""
        with self._engine_lock, self._epoch_barrier():
            return self.session.explain(query, server=self.stats(), **kw)


class AsyncDiscoveryServer:
    """Asyncio façade over :class:`DiscoveryServer`: the same thread-based
    queue underneath, awaited via ``asyncio.wrap_future``::

        async with AsyncDiscoveryServer(engine) as server:
            resp = await server.serve(expr, tenant="alice")

    Wraps an existing server or constructs one from the same kwargs."""

    def __init__(self, engine_or_server, **kw):
        self.server = engine_or_server \
            if isinstance(engine_or_server, DiscoveryServer) \
            else DiscoveryServer(engine_or_server, **kw)

    async def serve(self, query, **kw):
        import asyncio
        return await asyncio.wrap_future(self.server.submit(query, **kw))

    async def add_table(self, table, name: str | None = None):
        import asyncio
        return await asyncio.wrap_future(self.server.add_table(table,
                                                               name=name))

    async def drop_table(self, ref):
        import asyncio
        return await asyncio.wrap_future(self.server.drop_table(ref))

    async def compact(self, **kw):
        import asyncio
        return await asyncio.wrap_future(self.server.compact(**kw))

    def stats(self) -> dict:
        return self.server.stats()

    async def __aenter__(self):
        self.server.start()
        return self

    async def __aexit__(self, *exc):
        self.server.stop()
