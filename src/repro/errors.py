"""Typed fault hierarchy for the serving and durability layers.

One catchable base — :class:`BlendFault` — under every typed failure the
system can hand back instead of crashing or serving garbage:

* :class:`Overloaded` — admission control shed the request (rate limit or
  bounded-queue backpressure); carries ``retry_after_s`` for clients.
* :class:`DeadlineExceeded` — the request's deadline passed while it was
  still queued; it was never executed (the serving tier enforces deadlines
  at dispatch admission, so stale work is dropped, not computed).
* :class:`CorruptSnapshot` — a snapshot failed its format / version /
  checksum validation; ``store/snapshot.py`` falls back to the previous
  good generation instead of serving a torn or bit-flipped index.
* :class:`WalReplayError` — mid-log corruption in the write-ahead log
  (valid records exist *after* the bad one, so this is damage, not a torn
  tail; torn tails are silently truncated — see ``store/wal.py``).

``Overloaded`` and ``DeadlineExceeded`` double as *response values*: the
server resolves futures with them rather than raising (shedding is policy,
not an error), and their ``ok=False`` field lets call sites branch without
isinstance checks.  Being exceptions too, a client that prefers raising can
``raise resp``.  ``CorruptSnapshot`` and ``WalReplayError`` additionally
subclass ``ValueError`` so pre-existing ``except ValueError`` callers (and
the version-check contract of older snapshots) keep working.

Old import paths stay valid: ``repro.serve.server.Overloaded`` re-exports
from here.
"""
from __future__ import annotations

from dataclasses import dataclass


class BlendFault(Exception):
    """Common base for every typed serving/durability fault."""


@dataclass
class Overloaded(BlendFault):
    """Typed rejection: the admission controller shed this request instead
    of queueing it unboundedly.  ``reason`` is ``'rate_limit'`` (tenant
    bucket empty; retry after ``retry_after_s``) or ``'queue_full'`` (lane
    backpressure).  ``ok`` distinguishes it from DiscoveryResponse without
    isinstance checks at call sites that only care about success."""
    reason: str
    lane: str
    tenant: str
    retry_after_s: float | None = None
    ok: bool = False


@dataclass
class DeadlineExceeded(BlendFault):
    """Typed rejection: the request's deadline passed while it was queued.
    It never reached the engine — deadline enforcement happens when a batch
    forms, so expired work is dropped before any device dispatch.
    ``waited_s`` is how long it sat queued before expiring."""
    lane: str
    tenant: str
    deadline_s: float | None = None
    waited_s: float = 0.0
    ok: bool = False


class CorruptSnapshot(BlendFault, ValueError):
    """A snapshot failed validation: wrong format, unsupported version,
    missing/truncated arrays, or a per-array checksum mismatch.  The loader
    falls back to the previous retained generation; this propagates only
    when no good generation remains."""


class WalReplayError(BlendFault, ValueError):
    """Mid-log WAL corruption: a record failed its magic/CRC check but
    valid records follow it, so truncating would silently drop acknowledged
    mutations.  (A bad *tail* with nothing valid after it is a torn write
    and is truncated without error.)"""
