"""Span-based tracing and the per-query flight recorder.

A :class:`Span` is one named wall-clock interval with attributes and
children; a :class:`Recorder` builds a span tree through nested
``with rec.span("name"):`` blocks.  The serving stack threads the active
recorder through :func:`recording` (a contextvar), so deep layers —
``core/fused.py``'s per-kind group launches, per-shard probes and the DAG
merge program, ``serve/engine.py``'s drain and host transfer — attach
their spans without any signature plumbing: they call :func:`current`,
which returns the :data:`NULL_RECORDER` no-op singleton unless something
upstream is recording.

The **flight recorder** view: ``DiscoveryServer(trace=True)`` keeps a ring
buffer of per-request span trees (``DiscoveryResponse.trace`` carries each
request's own root), covering submit -> queue wait -> batch formation ->
epoch pin -> per-kind fused dispatch -> per-shard probe -> cross-shard
merge -> drain -> host transfer.  ``server.dump_trace(path)`` exports the
buffer as Chrome trace-event JSON (:func:`chrome_trace`) loadable in
Perfetto / ``chrome://tracing``.

Tracing is observation only: no span ever synchronizes the device, so
enabling it changes no ids and no scores (parity-tested).  Span *durations*
on the dispatch path therefore measure host-side enqueue time unless
synchronized timing is opted into (``repro.obs.set_sync_timing`` — see the
tradeoff note there); the span *tree* is contiguous wall-clock either way,
which is what makes queue + batch sum to end-to-end latency.

Clocks are injectable (``Recorder(now=...)``) so nesting/ordering tests run
on a fake clock with exact expected timestamps.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import time
from dataclasses import dataclass, field


@dataclass
class Span:
    """One named interval.  ``t0``/``t1`` are seconds on the recorder's
    clock (``t1`` None while open); ``tid`` names the Chrome-trace track
    (inherited from the parent when unset)."""
    name: str
    t0: float
    t1: float | None = None
    attrs: dict = field(default_factory=dict)
    children: list = field(default_factory=list)
    tid: str | None = None

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def set(self, key: str, value):
        """Attach one attribute (no-op on the null span)."""
        self.attrs[key] = value
        return self

    def walk(self):
        """Yield this span and every descendant, depth-first."""
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str):
        """First descendant (or self) with ``name``, else None."""
        for s in self.walk():
            if s.name == name:
                return s
        return None

    def render(self, indent: int = 0) -> str:
        """ASCII tree with millisecond durations (examples / debugging)."""
        pad = "  " * indent
        attrs = "".join(f" {k}={v}" for k, v in self.attrs.items())
        lines = [f"{pad}{self.name:<{max(28 - 2 * indent, 1)}s} "
                 f"{self.duration * 1e3:9.3f} ms{attrs}"]
        for c in self.children:
            lines.append(c.render(indent + 1))
        return "\n".join(lines)


class Recorder:
    """Builds span trees (see module docstring).  ``roots`` holds the
    top-level spans in creation order."""

    enabled = True

    def __init__(self, now=time.perf_counter):
        self.now = now
        self.roots: list = []
        self._stack: list = []

    def _attach(self, span: Span):
        if self._stack:
            self._stack[-1].children.append(span)
        else:
            self.roots.append(span)

    @contextlib.contextmanager
    def span(self, name: str, tid: str | None = None, **attrs):
        s = Span(name=name, t0=self.now(), attrs=attrs, tid=tid)
        self._attach(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            self._stack.pop()
            s.t1 = self.now()

    def record(self, name: str, t0: float, t1: float,
               tid: str | None = None, **attrs) -> Span:
        """Attach one pre-measured interval (e.g. queue wait, whose start
        predates the recorder) under the currently open span."""
        s = Span(name=name, t0=t0, t1=t1, attrs=attrs, tid=tid)
        self._attach(s)
        return s


class _NullSpan:
    """Shared inert span yielded by the null recorder's contexts."""
    name = "null"
    t0 = 0.0
    t1 = 0.0
    duration = 0.0
    tid = None
    children = ()

    def set(self, key, value):
        return self

    def walk(self):
        return iter(())

    def find(self, name):
        return None

    def render(self, indent: int = 0) -> str:
        return ""


class _NullSpanCtx:
    _span = _NullSpan()

    def __enter__(self):
        return self._span

    def __exit__(self, *exc):
        return False


class NullRecorder:
    """The disabled recorder: ``span`` is a reusable no-op context."""

    enabled = False
    roots: list = []
    _ctx = _NullSpanCtx()

    def span(self, name: str, tid: str | None = None, **attrs):
        return self._ctx

    def record(self, name: str, t0: float, t1: float,
               tid: str | None = None, **attrs):
        return _NullSpanCtx._span


NULL_RECORDER = NullRecorder()

#: the active recorder for this thread/task (contextvar: each thread that
#: never calls ``recording`` sees the null recorder)
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_recorder", default=NULL_RECORDER)


def current():
    """The active recorder (the no-op singleton unless inside
    :func:`recording`)."""
    return _ACTIVE.get()


@contextlib.contextmanager
def recording(recorder):
    """Make ``recorder`` the active recorder for the dynamic extent."""
    token = _ACTIVE.set(recorder)
    try:
        yield recorder
    finally:
        _ACTIVE.reset(token)


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------

#: single logical process for the serving stack in exported traces
_PID = 1


def chrome_trace(roots, process_name: str = "blend-serve") -> dict:
    """Flatten span trees into the Chrome trace-event JSON format
    (https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
    one complete (``"ph": "X"``) event per span, microsecond timestamps
    relative to the earliest span, plus metadata (``"ph": "M"``) events
    naming the process and tracks.

    Spans shared between trees (a batch subtree referenced by every request
    it served) are emitted exactly once, keyed by identity — Perfetto then
    shows one dispatcher track plus one track per request."""
    roots = list(roots)
    origin = min((s.t0 for s in roots), default=0.0)
    events = [{"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
               "args": {"name": process_name}}]
    seen: set = set()
    tids: dict = {}

    def tid_index(tid: str) -> int:
        if tid not in tids:
            tids[tid] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                           "tid": tids[tid], "args": {"name": tid}})
        return tids[tid]

    def emit(span, inherited_tid: str):
        if id(span) in seen:
            return
        seen.add(id(span))
        tid = span.tid or inherited_tid
        t1 = span.t1 if span.t1 is not None else span.t0
        events.append({
            "name": span.name, "ph": "X", "pid": _PID,
            "tid": tid_index(tid),
            "ts": (span.t0 - origin) * 1e6,
            "dur": max(t1 - span.t0, 0.0) * 1e6,
            "args": {k: v for k, v in span.attrs.items()
                     if isinstance(v, (str, int, float, bool))},
        })
        for c in span.children:
            emit(c, tid)

    for i, root in enumerate(roots):
        emit(root, root.tid or f"trace-{i}")
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_chrome(roots, path, process_name: str = "blend-serve"):
    """Write :func:`chrome_trace` JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(roots, process_name=process_name), f)
    return path
