"""Process-local metrics: counters, gauges, and log-bucketed histograms.

Every layer of the serving stack (front tier, executor, query cache, live
store, sharded dispatch) records into one :class:`MetricsRegistry` instead
of keeping its own ad-hoc counters, so "why was this query slow" has a
single answer surface: ``registry.snapshot()`` (and ``session.explain``'s
``== metrics ==`` section, which is just a reader of it).

Design constraints, in order:

* **near-zero cost when disabled** — the package-level accessor
  (``repro.obs.registry()``) returns the :data:`NULL_REGISTRY` no-op
  singleton unless observability was enabled, so instrumented hot paths pay
  one attribute call that does nothing;
* **clock-injectable** — like ``serve/batching.py``'s ``BatchFormer``,
  every timing surface takes ``now=`` so unit tests drive histograms and
  timers with a fake clock (tests/test_obs.py);
* **bounded memory** — histograms are log-bucketed (geometric bucket
  edges), so a latency distribution spanning six orders of magnitude costs
  a fixed ~64 ints, and percentile snapshots (p50/p95/p99) read straight
  off the cumulative bucket counts.

Percentiles are bucket-resolution: a reported quantile is the geometric
midpoint of the bucket containing it, i.e. exact to within a factor of
``sqrt(growth)`` (default growth 2.0 -> ~1.41x).  ``count``/``sum``/
``min``/``max`` are exact.

Nothing here is hard-synchronized: increments are GIL-atomic enough for
telemetry (a lost update under extreme thread races skews a counter by one,
never corrupts state), and metric *creation* is locked so concurrent first
touches of one name agree on the instrument.
"""
from __future__ import annotations

import math
import threading
import time


class Counter:
    """Monotone event count (``inc`` only)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n


class Gauge:
    """Point-in-time level (``set``/``inc``/``dec``): queue depth, resident
    bytes, segment count, compaction debt."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def inc(self, n: float = 1.0):
        self.value += n

    def dec(self, n: float = 1.0):
        self.value -= n


class Histogram:
    """Log-bucketed distribution with percentile snapshots.

    Bucket ``i >= 1`` covers ``[lo * growth**(i-1), lo * growth**i)``;
    bucket 0 holds everything below ``lo`` (including zeros/negatives,
    which a wall-clock duration can produce on coarse clocks).  Values at
    or above the top edge clamp into the last bucket — ``max`` still
    reports them exactly.
    """

    __slots__ = ("name", "lo", "growth", "n_buckets", "buckets", "count",
                 "sum", "min", "max", "_log_lo", "_log_growth")

    def __init__(self, name: str, lo: float = 1e-6, growth: float = 2.0,
                 n_buckets: int = 64):
        if lo <= 0 or growth <= 1.0 or n_buckets < 2:
            raise ValueError("need lo > 0, growth > 1, n_buckets >= 2")
        self.name = name
        self.lo = lo
        self.growth = growth
        self.n_buckets = n_buckets
        self.buckets = [0] * n_buckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._log_lo = math.log(lo)
        self._log_growth = math.log(growth)

    def bucket_index(self, v: float) -> int:
        if v < self.lo:
            return 0
        i = 1 + int((math.log(v) - self._log_lo) / self._log_growth)
        return min(i, self.n_buckets - 1)

    def bucket_edges(self, i: int) -> tuple:
        """(lower, upper) value edges of bucket ``i`` (bucket 0's lower
        edge is 0)."""
        if i <= 0:
            return (0.0, self.lo)
        return (self.lo * self.growth ** (i - 1), self.lo * self.growth ** i)

    def observe(self, v: float):
        v = float(v)
        self.buckets[self.bucket_index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Bucket-resolution quantile: the geometric midpoint of the bucket
        containing the ``q``-th percentile observation (0 with no data)."""
        if self.count == 0:
            return 0.0
        target = max(q / 100.0 * self.count, 1.0)
        seen = 0
        for i, n in enumerate(self.buckets):
            seen += n
            if seen >= target:
                lo, hi = self.bucket_edges(i)
                if i == 0:
                    return min(self.lo, self.max)
                # clamp into the observed range so single-value
                # distributions report that value exactly
                return min(max(math.sqrt(lo * hi), self.min), self.max)
        return self.max

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count, "min": self.min,
                "max": self.max, "p50": self.percentile(50),
                "p95": self.percentile(95), "p99": self.percentile(99)}


class _Timer:
    """Context manager recording elapsed ``now()`` seconds into a
    histogram on exit."""

    __slots__ = ("_hist", "_now", "_t0")

    def __init__(self, hist: Histogram, now):
        self._hist = hist
        self._now = now

    def __enter__(self):
        self._t0 = self._now()
        return self

    def __exit__(self, *exc):
        self._hist.observe(self._now() - self._t0)
        return False


class MetricsRegistry:
    """One process-local home for every metric (see module docstring).

    Instruments are created on first touch and memoized by name; touching a
    name as two different kinds raises (one name, one meaning)."""

    def __init__(self, now=time.perf_counter):
        self._now = now
        self._metrics: dict = {}
        self._lock = threading.Lock()
        #: real registries answer True so call sites can skip expensive
        #: *derivations* (not recording) when observability is off
        self.enabled = True

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name, **kw)
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {type(m).__name__}, "
                            f"not a {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, lo: float = 1e-6, growth: float = 2.0,
                  n_buckets: int = 64) -> Histogram:
        return self._get(name, Histogram, lo=lo, growth=growth,
                         n_buckets=n_buckets)

    def timer(self, name: str) -> _Timer:
        """``with registry.timer("store.add_table_seconds"): ...``"""
        return _Timer(self.histogram(name), self._now)

    # -------------------------------------------------------------- reading
    def snapshot(self) -> dict:
        """``{"counters": {...}, "gauges": {...}, "histograms": {...}}`` —
        plain JSON-serializable values (histograms as their snapshot
        dicts), name-sorted for stable rendering."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.snapshot()
        return out

    def render(self) -> str:
        """Human-readable snapshot (examples / ``explain``)."""
        snap = self.snapshot()
        lines = []
        for name, v in snap["counters"].items():
            lines.append(f"  {name:<40s} {v:,.0f}")
        for name, v in snap["gauges"].items():
            lines.append(f"  {name:<40s} {v:,.1f}")
        for name, h in snap["histograms"].items():
            if "seconds" in name:
                lines.append(
                    f"  {name:<40s} n={h['count']:<7d} "
                    f"p50={h['p50'] * 1e3:9.3f}ms "
                    f"p95={h['p95'] * 1e3:9.3f}ms "
                    f"p99={h['p99'] * 1e3:9.3f}ms "
                    f"max={h['max'] * 1e3:9.3f}ms")
            else:
                lines.append(
                    f"  {name:<40s} n={h['count']:<7d} "
                    f"p50={h['p50']:9.2f} p95={h['p95']:9.2f} "
                    f"p99={h['p99']:9.2f} max={h['max']:9.2f}")
        return "\n".join(lines) if lines else "  (no metrics recorded)"

    def reset(self):
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# the disabled path: no-op singletons (one shared instance of each, so the
# instrumented hot paths allocate nothing when observability is off)
# ---------------------------------------------------------------------------

class _NullCounter:
    name = "null"
    value = 0.0

    def inc(self, n: float = 1.0):
        pass


class _NullGauge:
    name = "null"
    value = 0.0

    def set(self, v: float):
        pass

    def inc(self, n: float = 1.0):
        pass

    def dec(self, n: float = 1.0):
        pass


class _NullHistogram:
    name = "null"
    count = 0

    def observe(self, v: float):
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def snapshot(self) -> dict:
        return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0, "max": 0.0,
                "p50": 0.0, "p95": 0.0, "p99": 0.0}


class _NullTimer:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class NullRegistry:
    """The disabled registry: every accessor returns a shared no-op."""

    enabled = False
    _counter = _NullCounter()
    _gauge = _NullGauge()
    _hist = _NullHistogram()
    _timer = _NullTimer()

    def counter(self, name: str) -> _NullCounter:
        return self._counter

    def gauge(self, name: str) -> _NullGauge:
        return self._gauge

    def histogram(self, name: str, **kw) -> _NullHistogram:
        return self._hist

    def timer(self, name: str) -> _NullTimer:
        return self._timer

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def render(self) -> str:
        return "  (observability disabled)"

    def reset(self):
        pass


NULL_REGISTRY = NullRegistry()
