"""Unified observability for the serving stack: metrics + span tracing.

One switch, three surfaces::

    from repro import obs

    reg = obs.enable()                  # install a real MetricsRegistry
    ...serve traffic...
    print(reg.render())                 # counters / gauges / p50-p95-p99
    obs.disable()                       # back to the no-op singleton

* **metrics** (``obs/metrics.py``) — every layer records counters, gauges
  and log-bucketed latency histograms into ``obs.registry()``.  Disabled
  (the default), that accessor returns a no-op singleton, so instrumented
  hot paths cost one dynamic call that does nothing.
* **tracing** (``obs/trace.py``) — span trees threaded through a contextvar
  (``obs.trace.recording``); ``DiscoveryServer(trace=True)`` turns them
  into a per-request flight recorder exportable as Chrome trace-event JSON
  (``server.dump_trace``).  Tracing works with metrics disabled and vice
  versa.
* **synchronized timing** (:func:`set_sync_timing`) — opt-in accuracy mode
  for the executor's per-node timings.  JAX dispatch is asynchronous, so a
  default timing measures *enqueue* cost, not device compute: a seeker that
  launches in 40us and computes for 4ms reports 40us.  With sync timing on,
  the executor calls ``block_until_ready`` after each seeker / fused group
  / DAG program before reading the clock, so ``ExecInfo.node_seconds`` and
  the trace spans measure real compute — at the price of serializing
  dispatch (pipelining across nodes and batched requests is lost, so
  end-to-end latency degrades; use it in benchmarks and offline traces,
  never in production serving).  Results are bit-identical either way.
"""
from __future__ import annotations

import time

from repro.obs import trace  # noqa: F401  (re-export: obs.trace.recording)
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, NULL_REGISTRY, NullRegistry)
from repro.obs.trace import (NULL_RECORDER, Recorder, Span,  # noqa: F401
                             chrome_trace, dump_chrome, recording)

_registry = NULL_REGISTRY
_sync_timing = False


def enable(registry: MetricsRegistry | None = None, *,
           sync_timing: bool | None = None,
           now=time.perf_counter) -> MetricsRegistry:
    """Install (and return) the process-local registry.  A fresh registry
    is created unless one is passed; ``sync_timing`` optionally flips the
    synchronized-timing mode in the same call."""
    global _registry
    _registry = registry if registry is not None \
        else MetricsRegistry(now=now)
    if sync_timing is not None:
        set_sync_timing(sync_timing)
    return _registry


def disable():
    """Back to the no-op singleton (also clears sync timing)."""
    global _registry
    _registry = NULL_REGISTRY
    set_sync_timing(False)


def enabled() -> bool:
    return _registry is not NULL_REGISTRY


def registry():
    """The active registry — the no-op singleton unless :func:`enable` was
    called.  Instrumented code calls this unconditionally."""
    return _registry


def set_sync_timing(flag: bool):
    """Opt in/out of synchronized per-node timing (see module docstring:
    accurate device timings, serialized dispatch)."""
    global _sync_timing
    _sync_timing = bool(flag)


def sync_timing() -> bool:
    return _sync_timing
