"""Learning-based seeker cost estimation (the paper's ML optimizer).

One ridge regression per seeker type on the paper's three features:
cardinality of Q, number of columns in Q, and the average frequency of Q's
values in the lake (for MC: product of per-column average frequencies).
Trained offline on measured runtimes of randomly sampled queries; predicting
is part of the online optimization step.
"""
from __future__ import annotations

import time

import numpy as np

SEEKER_TYPES = ("KW", "SC", "MC", "C")
# Rule-based ranking (Rules 1-3): KW always first, MC always last, SC over C.
RULE_RANK = {"KW": 0, "SC": 1, "C": 2, "MC": 3}


def features(card: float, n_cols: float, avg_freq: float) -> np.ndarray:
    return np.array([1.0, np.log1p(card), float(n_cols), np.log1p(avg_freq)])


class CostModel:
    def __init__(self):
        self.weights: dict[str, np.ndarray] = {}

    def fit(self, kind: str, X: np.ndarray, y: np.ndarray, l2: float = 1e-3):
        A = X.T @ X + l2 * np.eye(X.shape[1])
        self.weights[kind] = np.linalg.solve(A, X.T @ y)

    def predict(self, kind: str, card, n_cols, avg_freq) -> float:
        w = self.weights.get(kind)
        if w is None:
            return float(card)          # fallback: bigger queries are slower
        return float(features(card, n_cols, avg_freq) @ w)

    def trained(self, kind: str) -> bool:
        return kind in self.weights


def train_cost_model(executor, lake, n_samples: int = 60, seed: int = 0,
                     kinds=("SC", "KW", "MC", "C")) -> CostModel:
    """Sample random queries from the lake, execute each seeker standalone,
    and fit per-type regressions on the measured runtimes."""
    from repro.core.plan import Seekers

    rng = np.random.default_rng(seed)
    model = CostModel()
    for kind in kinds:
        X, y = [], []
        for _ in range(n_samples):
            t = lake.tables[int(rng.integers(0, lake.n_tables))]
            n = int(rng.integers(3, max(4, min(30, t.n_rows))))
            rows = rng.choice(t.n_rows, n, replace=False)
            if kind in ("SC", "KW"):
                vals = [t.columns[0][r] for r in rows]
                spec = (Seekers.SC(vals, k=10) if kind == "SC"
                        else Seekers.KW(vals, k=10))
            elif kind == "MC":
                if t.n_cols < 2:
                    continue
                tups = [(t.columns[0][r], t.columns[1][r]) for r in rows]
                spec = Seekers.MC(tups, k=10)
            else:
                num_cols = [c for c in range(t.n_cols)
                            if executor.index.quadrant is not None]
                vals = [t.columns[0][r] for r in rows]
                tgt = list(np.round(rng.normal(0, 1, n), 4))
                spec = Seekers.Correlation(vals, tgt, k=10)
            stats = executor.seeker_stats(spec)
            t0 = time.perf_counter()
            executor.run_seeker(spec)
            dt = time.perf_counter() - t0
            X.append(features(*stats))
            y.append(dt)
        if X:
            model.fit(kind, np.stack(X), np.array(y))
    return model
