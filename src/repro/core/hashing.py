"""Stable value hashing + XASH-style superkeys (offline index build, numpy).

Cell values (strings / ints / floats) are mapped to u32 via FNV-1a — the TPU
adaptation of BLEND's varchar CellValue column (no string type on device).
Superkeys are 64-bit XASH-style row digests: each cell contributes a single
bit chosen by its hash, rotated by its column position, OR-ed across the row
(MATE's alignment-aware bloom filter, [arXiv:2205.01600]-style adaptation).
"""
from __future__ import annotations

import numpy as np

FNV_OFFSET = np.uint32(2166136261)
FNV_PRIME = np.uint32(16777619)
MISSING = np.uint32(0xFFFFFFFF)    # reserved sentinel (never a real hash)


def fnv1a_bytes(data: bytes) -> int:
    h = 2166136261
    for b in data:
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h if h != 0xFFFFFFFF else 0


def hash_value(v) -> int:
    """Canonical value hash.  Floats that are integral hash like ints so
    joins across int/float columns behave (paper: numeric join keys)."""
    if v is None:
        return int(MISSING)
    if isinstance(v, float) and v.is_integer():
        v = int(v)
    if isinstance(v, (bool, np.bool_)):
        v = int(v)
    if isinstance(v, (int, np.integer)):
        return fnv1a_bytes(str(int(v)).encode())
    if isinstance(v, (float, np.floating)):
        return fnv1a_bytes(repr(float(v)).encode())
    return fnv1a_bytes(str(v).encode())


def hash_array(values) -> np.ndarray:
    """Vectorized hash of a 1-D object/str/num array -> u32."""
    out = np.empty(len(values), np.uint32)
    for i, v in enumerate(values):
        out[i] = hash_value(v)
    return out


def rotl64(x: np.ndarray, r) -> np.ndarray:
    x = x.astype(np.uint64)
    r = np.asarray(r, np.uint64) % np.uint64(64)
    left = np.left_shift(x, r)
    right = np.right_shift(x, (np.uint64(64) - r) % np.uint64(64))
    # r == 0: right shift by 64 is UB-ish; mask it out
    return np.where(r == 0, x, left | right).astype(np.uint64)


def cell_bit(h: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Bit pattern a cell contributes to its row superkey."""
    h = h.astype(np.uint64)
    base = np.left_shift(np.uint64(1), h % np.uint64(64))
    return rotl64(base, (col.astype(np.uint64) * np.uint64(11)))


def row_superkey(hashes: np.ndarray, cols: np.ndarray) -> np.uint64:
    """OR of the cell bits of one row (hashes/cols aligned 1-D arrays)."""
    bits = cell_bit(hashes, cols)
    out = np.uint64(0)
    for b in bits:
        out |= b
    return out


def superkeys_for_rows(hashes, cols, row_ids, n_rows) -> np.ndarray:
    """Vectorized per-row OR: returns u64[n_rows]."""
    bits = cell_bit(np.asarray(hashes), np.asarray(cols))
    out = np.zeros(n_rows, np.uint64)
    np.bitwise_or.at(out, np.asarray(row_ids), bits)
    return out


def split_u64(x: np.ndarray):
    """u64 -> (lo u32, hi u32) for TPU-friendly storage."""
    x = x.astype(np.uint64)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32), \
        (x >> np.uint64(32)).astype(np.uint32)
