"""BLEND's two-phase plan optimizer (Section VII-B).

Four steps on the plan DAG:
1. **EG identification** — seekers feeding the same *Intersection* combiner
   form an execution group (the only reorderable combiner: Difference is
   non-commutative; Union/Counter gain nothing from ordering).
2. **EG ordering** — topological over the hyper-DAG (handled by the executor's
   dependency-driven traversal).
3. **Operator ranking** — rule-based across types (KW ≺ SC ≺ C ≺ MC, Rules
   1-3) and the learned cost model within a type.
4. **Query rewriting** — the surviving-table mask of each executed seeker is
   threaded into the next seeker (Intersection: ``allowed=mask``;
   Difference: subtrahend restricted to the minuend's tables; Counter/Union:
   no rewriting), mirroring the paper's predicate injection.

Statistics are segment-aware on live lakes: ``stats_fn`` (the executor's
``seeker_stats``) sums per-segment ``host_counts`` with tombstoned postings
excluded (``live_only=True``), so the ranking reflects the live lake even
while dropped tables still occupy probe-window slots awaiting compaction.
Match *capacities*, by contrast, are sized from the tombstone-inclusive
counts — a masked posting fills a window slot all the same.

Theorem 1 (output preservation) is tested property-style in
tests/test_optimizer.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.cost_model import RULE_RANK, CostModel
from repro.core.plan import Plan, SeekerSpec


@dataclass
class ExecutionGroup:
    combiner: str                 # combiner node name
    seekers: list                 # ordered seeker node names


@dataclass
class ExecutionPlan:
    plan: Plan
    groups: dict = field(default_factory=dict)   # combiner name -> EG
    ranked: dict = field(default_factory=dict)   # seeker name -> rank index


def identify_groups(plan: Plan):
    """EGs: seeker-only dep sets of Intersection combiners."""
    groups = {}
    for node in plan.nodes.values():
        if node.is_seeker:
            continue
        if node.spec.kind != "intersect":
            continue
        seekers = [d for d in node.deps if plan.nodes[d].is_seeker]
        if len(seekers) >= 2:
            groups[node.name] = ExecutionGroup(node.name, seekers)
    return groups


def rank_seekers(plan: Plan, names, stats_fn, cost_model: CostModel | None):
    """Order seeker nodes by (rule rank, learned cost estimate)."""

    def key(name):
        spec: SeekerSpec = plan.nodes[name].spec
        rule = RULE_RANK[spec.kind]
        if cost_model is not None and cost_model.trained(spec.kind):
            est = cost_model.predict(spec.kind, *stats_fn(spec))
        else:
            est = stats_fn(spec)[0]           # fallback: |Q|
        return (rule, est)

    return sorted(names, key=key)


def optimize(plan: Plan, stats_fn, cost_model: CostModel | None = None):
    """Returns an ExecutionPlan with ranked execution groups."""
    plan.validate()
    ep = ExecutionPlan(plan=plan, groups=identify_groups(plan))
    for eg in ep.groups.values():
        eg.seekers = rank_seekers(plan, eg.seekers, stats_fn, cost_model)
        for i, s in enumerate(eg.seekers):
            ep.ranked[s] = i
    return ep
