"""Plan API: declarative discovery-task definition (the paper's Listing 4).

    plan = Plan()
    plan.add('kw', Seekers.KW(keywords, k=10))
    for col in example_cols:
        plan.add(col, Seekers.SC(values, k=100))
    plan.add('counter', Combiners.Counter(k=10), example_cols)
    plan.add('union', Combiners.Union(k=40), ['kw', 'counter'])

A plan is a DAG of seeker / combiner nodes; the grammar is validated at add
time (expression ::= seeker(Q) | combiner(expression+)).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class SeekerSpec:
    kind: str                    # 'SC' | 'KW' | 'MC' | 'C'
    k: int
    values: tuple = ()           # SC/KW: values; MC: tuples; C: join keys
    target: tuple = ()           # C: numeric target values
    h: int = 256                 # C: sketch sample size (query-time!)
    sampling: str = "conv"       # C: 'conv' | 'rand'

    @property
    def n_cols(self) -> int:
        if self.kind == "MC":
            return len(self.values[0]) if self.values else 0
        return 2 if self.kind == "C" else 1


@dataclass(frozen=True)
class CombinerSpec:
    kind: str                    # 'intersect' | 'union' | 'difference' | 'counter'
    k: int


class Seekers:
    @staticmethod
    def SC(values, k=10):
        return SeekerSpec("SC", k, tuple(values))

    @staticmethod
    def KW(keywords, k=10):
        return SeekerSpec("KW", k, tuple(keywords))

    @staticmethod
    def MC(tuples, k=10):
        return SeekerSpec("MC", k, tuple(tuple(t) for t in tuples))

    @staticmethod
    def Correlation(join_values, target_values, k=10, h=256, sampling="conv"):
        return SeekerSpec("C", k, tuple(join_values), tuple(target_values),
                          h, sampling)


class Combiners:
    @staticmethod
    def Intersect(k=10):
        return CombinerSpec("intersect", k)

    @staticmethod
    def Union(k=10):
        return CombinerSpec("union", k)

    @staticmethod
    def Difference(k=10):
        return CombinerSpec("difference", k)

    @staticmethod
    def Counter(k=10):
        return CombinerSpec("counter", k)


@dataclass
class Node:
    name: str
    spec: object
    deps: list = field(default_factory=list)

    @property
    def is_seeker(self) -> bool:
        return isinstance(self.spec, SeekerSpec)


class Plan:
    """A DAG of named seeker/combiner nodes; the last added node (or an
    explicit ``output``) is the plan result."""

    def __init__(self):
        self.nodes: dict[str, Node] = {}
        self.order: list[str] = []
        self.output: str | None = None

    def add(self, name: str, spec, deps=None):
        if name in self.nodes:
            raise ValueError(f"duplicate node {name!r}")
        deps = list(deps) if deps else []
        if isinstance(spec, SeekerSpec):
            if deps:
                raise ValueError("seekers take no deps (grammar: seeker(Q))")
        elif isinstance(spec, CombinerSpec):
            if len(deps) < 2:
                raise ValueError("combiners need >= 2 inputs")
            if spec.kind == "difference" and len(deps) != 2:
                raise ValueError("difference takes exactly 2 inputs")
            missing = [d for d in deps if d not in self.nodes]
            if missing:
                raise ValueError(f"unknown deps {missing}")
        else:
            raise TypeError(spec)
        self.nodes[name] = Node(name, spec, deps)
        self.order.append(name)
        self.output = name
        return self

    def seekers(self):
        return [n for n in self.nodes.values() if n.is_seeker]

    def copy(self) -> "Plan":
        """Shallow structural copy (nodes are immutable-by-convention; the
        dict/order/output skeleton is duplicated so pruning a copy never
        mutates the original)."""
        p = Plan()
        p.nodes = dict(self.nodes)
        p.order = list(self.order)
        p.output = self.output
        return p

    def reachable(self, root: str | None = None) -> set:
        """Node names reachable from ``root`` (default: the plan output)
        through dep edges.  Shared by ``validate`` and the BlendQL
        rewriter's dead-subtree pruning (query/rules.py)."""
        root = self.output if root is None else root
        if root is None:
            return set()
        seen: set = set()
        stack = [root]
        while stack:
            name = stack.pop()
            if name in seen:
                continue
            seen.add(name)
            stack.extend(self.nodes[name].deps)
        return seen

    def prune_unreachable(self) -> list:
        """Remove nodes unreachable from the output; returns their names."""
        keep = self.reachable()
        removed = [n for n in self.order if n not in keep]
        if removed:
            self.nodes = {n: v for n, v in self.nodes.items() if n in keep}
            self.order = [n for n in self.order if n in keep]
        return removed

    def validate(self):
        # acyclicity is by construction (deps must pre-exist); check that
        # every node is reachable from the output — a dead subtree means the
        # plan author wired a dep list wrong (or wants prune_unreachable())
        if self.output is None:
            raise ValueError("empty plan")
        reach = self.reachable()
        dead = [n for n in self.order if n not in reach]
        if dead:
            raise ValueError(
                f"nodes unreachable from output {self.output!r}: {dead} "
                f"(Plan.prune_unreachable() drops them)")
        return True

    def consumers(self, name: str):
        return [n for n in self.nodes.values() if name in n.deps]
