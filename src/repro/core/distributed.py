"""Distributed BLEND: hash-partitioned index shards + shard_map seekers.

The unified index is sharded across *every* mesh axis (pod x data x model —
a lake index is pure capacity; there is no 'model' in discovery).  Because
the postings are globally sorted by hash, contiguous shards are hash ranges:
a probe runs entirely shard-local and per-table score vectors are combined
with one ``psum`` — the same "push compute to the data" layering the paper
gets from its in-DB pushdown.  Cross-shard joins (the MC validation and the
correlation row-join) all-gather only the *candidate rowkeys* (tiny) and
re-reduce membership with a second psum.

``dryrun_discovery()`` lowers a representative multi-seeker plan over a
Gittables-scale index (1.4B postings) on the production mesh — the
blend-discovery dry-run cell.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import seekers as seek
from repro.core.match import probe_sorted, sorted_member

IDX_KEYS_MAIN = ("hash", "table", "col", "row", "sk_lo", "sk_hi", "quadrant",
                 "rank_conv", "rank_rand")
IDX_KEYS_NUM = ("num_rowkey", "num_table", "num_col", "num_quadrant",
                "num_rank_conv", "num_rank_rand")


def index_specs(mesh, n_postings: int, n_numeric: int):
    """Sharding specs for the device-array dict: every array sharded on its
    posting dim across all mesh axes."""
    axes = tuple(mesh.axis_names)
    return {k: NamedSharding(mesh, P(axes)) for k in IDX_KEYS_MAIN + IDX_KEYS_NUM}


def shard_device_index(index, mesh):
    """Place a host index's device arrays onto the mesh (padding the posting
    count to the device count).

    Accepts a ``UnifiedIndex`` or a LiveLake ``SegmentStore``: each shard's
    local segment list is derived from the store's merged live view
    (tombstones garbage-collected), so the distributed seekers — which probe
    shard-local contiguous hash ranges — never see delta fragmentation.
    Mutations re-shard through the same path (re-place after each epoch)."""
    if hasattr(index, "segments"):        # SegmentStore -> compacted view
        index = index.merged_index()
    dev = index.device_arrays()
    n_dev = mesh.size
    out = {}
    for k, v in dev.items():
        pad = (-v.shape[0]) % n_dev
        if pad:
            if k == "hash":          # sentinel: never matches a real hash
                fill = jnp.full((1,), 0xFFFFFFFF, v.dtype)
            elif k == "num_rowkey":  # sorted sentinel at the end
                fill = jnp.full((1,), jnp.iinfo(jnp.int32).max, v.dtype)
            else:
                fill = jnp.zeros_like(v[-1:])
            v = jnp.concatenate([v] + [fill] * pad)
        out[k] = jax.device_put(v, NamedSharding(mesh, P(tuple(mesh.axis_names))))
    return out


def _linear_shard_index(mesh, axes):
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _boundary_duplicate(mesh, axes, idx, q_hash, q_mask, with_col: bool):
    """Correction for hash runs straddling a shard boundary: if this shard's
    first posting continues the previous shard's last (same hash[,table,col])
    and that hash is queried, the distinct-count counted it twice."""
    h0, t0, c0 = idx["hash"][0], idx["table"][0], idx["col"][0]
    last = jnp.stack([idx["hash"][-1].astype(jnp.int32),
                      idx["table"][-1], idx["col"][-1]])
    gathered = jax.lax.all_gather(last, axes, tiled=False).reshape(-1, 3)
    lin = _linear_shard_index(mesh, axes)
    prev = gathered[jnp.maximum(lin - 1, 0)]
    same = (prev[0] == h0.astype(jnp.int32)) & (prev[1] == t0) & (lin > 0)
    if with_col:
        same &= prev[2] == c0
    queried = jnp.any((q_hash == h0) & q_mask)
    return same & queried, t0, c0


def make_distributed_sc(mesh, *, m_cap, n_tables, max_cols):
    axes = tuple(mesh.axis_names)
    idx_specs = {k: P(axes) for k in IDX_KEYS_MAIN + IDX_KEYS_NUM}

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(idx_specs, P(), P()), out_specs=P(),
                       check_rep=False)
    def run(idx, q_hash, q_mask):
        pidx, valid, ovf = probe_sorted(idx["hash"], q_hash, q_mask,
                                                m_cap)
        t = idx["table"][pidx]
        c = idx["col"][pidx]
        contrib = valid & seek._first_occurrence(t, c)
        flat = (t * max_cols + c).reshape(-1)
        tc = jnp.zeros(n_tables * max_cols, jnp.float32).at[flat].add(
            contrib.reshape(-1).astype(jnp.float32), mode="drop")
        dup, t0, c0 = _boundary_duplicate(mesh, axes, idx, q_hash, q_mask, True)
        tc = tc.at[t0 * max_cols + c0].add(-dup.astype(jnp.float32))
        tc = jax.lax.psum(tc, axes)
        return tc.reshape(n_tables, max_cols).max(axis=1), jax.lax.psum(ovf, axes)

    return jax.jit(run)


def make_distributed_kw(mesh, *, m_cap, n_tables):
    axes = tuple(mesh.axis_names)
    idx_specs = {k: P(axes) for k in IDX_KEYS_MAIN + IDX_KEYS_NUM}

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(idx_specs, P(), P()), out_specs=P(),
                       check_rep=False)
    def run(idx, q_hash, q_mask):
        pidx, valid, ovf = probe_sorted(idx["hash"], q_hash, q_mask,
                                                m_cap)
        t = idx["table"][pidx]
        contrib = valid & seek._first_occurrence(t)
        scores = jnp.zeros(n_tables, jnp.float32).at[t.reshape(-1)].add(
            contrib.reshape(-1).astype(jnp.float32), mode="drop")
        dup, t0, _ = _boundary_duplicate(mesh, axes, idx, q_hash, q_mask, False)
        scores = scores.at[t0].add(-dup.astype(jnp.float32))
        return jax.lax.psum(scores, axes), jax.lax.psum(ovf, axes)

    return jax.jit(run)


def make_distributed_c(mesh, *, m_cap, row_cap, n_tables, max_cols, h_sample,
                       row_stride, sampling="conv"):
    """Correlation seeker: local join-side probe -> all-gather candidate
    (rowkey, join_col, qbit) triples -> every shard joins its local numeric
    postings -> psum the per-(t,cj,cn) agree/count segments."""
    axes = tuple(mesh.axis_names)
    idx_specs = {k: P(axes) for k in IDX_KEYS_MAIN + IDX_KEYS_NUM}

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(idx_specs, P(), P(), P()), out_specs=P(),
                       check_rep=False)
    def run(idx, qj_hash, q_mask, q_bit):
        pidx, valid, ovf = probe_sorted(idx["hash"], qj_hash, q_mask,
                                                m_cap)
        t = idx["table"][pidx]
        r = idx["row"][pidx]
        cj = idx["col"][pidx]
        rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)
        rowkey = jnp.where(valid, rowkey, -1).reshape(-1)
        cjf = cj.reshape(-1)
        qbf = jnp.broadcast_to(q_bit[:, None], pidx.shape).reshape(-1)
        # globalize candidates: [S, nq*m_cap] (tiny vs the index)
        g_rk = jax.lax.all_gather(rowkey, axes, tiled=False).reshape(-1)
        g_cj = jax.lax.all_gather(cjf, axes, tiled=False).reshape(-1)
        g_qb = jax.lax.all_gather(qbf, axes, tiled=False).reshape(-1)
        # local numeric join
        nlo = jnp.searchsorted(idx["num_rowkey"], g_rk, side="left")
        nhi = jnp.searchsorted(idx["num_rowkey"], g_rk, side="right")
        nidx = nlo[:, None] + jnp.arange(row_cap)[None, :]
        nvalid = (nidx < nhi[:, None]) & (g_rk >= 0)[:, None]
        nidx = jnp.clip(nidx, 0, idx["num_rowkey"].shape[0] - 1)
        ntab = idx["num_table"][nidx]
        ncol = idx["num_col"][nidx]
        nquad = idx["num_quadrant"][nidx]
        rank = idx["num_rank_conv" if sampling == "conv"
                   else "num_rank_rand"][nidx]
        nvalid &= rank < h_sample
        agree = (nquad == g_qb[:, None]) & nvalid
        key = ((ntab * max_cols + g_cj[:, None]) * max_cols + ncol).reshape(-1)
        dim = n_tables * max_cols * max_cols
        n_all = jnp.zeros(dim, jnp.float32).at[key].add(
            nvalid.reshape(-1).astype(jnp.float32), mode="drop")
        n_agree = jnp.zeros(dim, jnp.float32).at[key].add(
            agree.reshape(-1).astype(jnp.float32), mode="drop")
        n_all = jax.lax.psum(n_all, axes)
        n_agree = jax.lax.psum(n_agree, axes)
        qcr = jnp.abs(2.0 * n_agree - n_all) / jnp.maximum(n_all, 1.0)
        qcr = jnp.where(n_all >= 3, qcr, 0.0)
        return qcr.reshape(n_tables, -1).max(axis=1), jax.lax.psum(ovf, axes)

    return jax.jit(run)


def make_distributed_mc(mesh, *, m_cap, n_tables, n_cols, row_stride):
    """MC: local initiator probe + bloom -> all-gather candidate rowkeys ->
    every shard checks membership of its local postings of each tuple column
    -> psum membership -> replicated scoring."""
    axes = tuple(mesh.axis_names)
    idx_specs = {k: P(axes) for k in IDX_KEYS_MAIN + IDX_KEYS_NUM}

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(idx_specs, P(), P(), P(), P()), out_specs=P(),
                       check_rep=False)
    def run(idx, tuple_hashes, init_col, qk_lo, qk_hi):
        nt = tuple_hashes.shape[0]
        h0 = jnp.take_along_axis(tuple_hashes, init_col[:, None], 1)[:, 0]
        q_mask = jnp.ones((nt,), bool)
        pidx, valid, ovf = probe_sorted(idx["hash"], h0, q_mask, m_cap)
        t = idx["table"][pidx]
        r = idx["row"][pidx]
        bloom = ((idx["sk_lo"][pidx] & qk_lo[:, None]) == qk_lo[:, None]) & \
                ((idx["sk_hi"][pidx] & qk_hi[:, None]) == qk_hi[:, None])
        valid &= bloom
        rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)
        rowkey = jnp.where(valid, rowkey, -1)                   # [nt, m_cap]
        # globalize candidates per tuple: [S*m_cap] per tuple
        g_rk = jax.lax.all_gather(rowkey, axes, tiled=False)    # [S, nt, m_cap]
        g_rk = jnp.moveaxis(g_rk, 0, 1).reshape(nt, -1)         # [nt, S*m_cap]
        # local membership of each tuple column at the candidate rows
        members = []
        for j in range(n_cols):
            pj, vj, _ = probe_sorted(idx["hash"], tuple_hashes[:, j],
                                             q_mask, m_cap)
            rkj = idx["table"][pj].astype(jnp.int32) * row_stride + \
                idx["row"][pj].astype(jnp.int32)
            rkj = jnp.sort(jnp.where(vj, rkj, jnp.iinfo(jnp.int32).max), axis=1)
            hit = sorted_member(rkj, g_rk)
            members.append(jax.lax.psum(hit.astype(jnp.int32), axes) > 0)
        ok = g_rk >= 0
        for j in range(n_cols):
            ok &= members[j] | (init_col == j)[:, None]
        tt = jnp.where(g_rk >= 0, g_rk // row_stride, 0)
        per_tt = jnp.zeros((nt * n_tables,), jnp.float32).at[
            (jnp.arange(nt)[:, None] * n_tables + tt).reshape(-1)].max(
            ok.reshape(-1).astype(jnp.float32), mode="drop")
        scores = per_tt.reshape(nt, n_tables).sum(axis=0)
        return scores, jax.lax.psum(ovf, axes)

    return jax.jit(run)


# --------------------------------------------------------------------------
# the blend-discovery dry-run cell (lake scale, production mesh)
# --------------------------------------------------------------------------

GITTABLES_SCALE = dict(n_postings=1_400_000_000, n_numeric=350_000_000,
                       n_tables=1_500_000, max_cols=8, row_stride=1 << 8)


def dryrun_discovery(multi_pod: bool = False, nq: int = 1024, m_cap: int = 64,
                     n_tuples: int = 256, n_cols: int = 2, row_cap: int = 8):
    """Lower + compile the distributed seekers over a Gittables-scale index
    (ShapeDtypeStructs, no allocation) on the production mesh."""
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    sc = GITTABLES_SCALE
    n_dev = mesh.size
    npad = ((sc["n_postings"] + n_dev - 1) // n_dev) * n_dev
    nnum = ((sc["n_numeric"] + n_dev - 1) // n_dev) * n_dev
    sds = jax.ShapeDtypeStruct
    idx = {"hash": sds((npad,), jnp.uint32),
           "table": sds((npad,), jnp.int32),
           "col": sds((npad,), jnp.int32),
           "row": sds((npad,), jnp.int32),
           "sk_lo": sds((npad,), jnp.uint32),
           "sk_hi": sds((npad,), jnp.uint32),
           "quadrant": sds((npad,), jnp.int8),
           "rank_conv": sds((npad,), jnp.int32),
           "rank_rand": sds((npad,), jnp.int32),
           "num_rowkey": sds((nnum,), jnp.int32),
           "num_table": sds((nnum,), jnp.int32),
           "num_col": sds((nnum,), jnp.int32),
           "num_quadrant": sds((nnum,), jnp.int8),
           "num_rank_conv": sds((nnum,), jnp.int32),
           "num_rank_rand": sds((nnum,), jnp.int32)}

    kw = dict(n_tables=sc["n_tables"], max_cols=sc["max_cols"])
    fns = {
        "sc": (make_distributed_sc(mesh, m_cap=m_cap, **kw),
               (idx, sds((nq,), jnp.uint32), sds((nq,), jnp.bool_))),
        "mc": (make_distributed_mc(mesh, m_cap=m_cap, n_tables=sc["n_tables"],
                                   n_cols=n_cols, row_stride=sc["row_stride"]),
               (idx, sds((n_tuples, n_cols), jnp.uint32),
                sds((n_tuples,), jnp.int32), sds((n_tuples,), jnp.uint32),
                sds((n_tuples,), jnp.uint32))),
        "c": (make_distributed_c(mesh, m_cap=m_cap, row_cap=row_cap,
                                 h_sample=256, row_stride=sc["row_stride"],
                                 **kw),
              (idx, sds((nq,), jnp.uint32), sds((nq,), jnp.bool_),
               sds((nq,), jnp.int8))),
    }
    rec = {"arch": "blend-discovery",
           "mesh": "pod2x16x16" if multi_pod else "pod16x16",
           "chips": mesh.size, "scale": sc, "status": "ok", "seekers": {}}
    idx_sharding = index_specs(mesh, npad, nnum)
    for name, (fn, args) in fns.items():
        t0 = time.time()
        with mesh:
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        text = compiled.as_text()
        analysis = hlo_analysis.analyze(text)
        mem = compiled.memory_analysis()
        terms = hlo_analysis.roofline_terms(analysis, chips=mesh.size)
        rec["seekers"][name] = {
            "compile_s": round(time.time() - t0, 2),
            "memory_gb_per_device": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                 mem.output_size_in_bytes) / 1e9, 3),
            "hlo": analysis, "roofline": terms,
        }
    return rec

def make_distributed_c_topk(mesh, *, m_cap, row_cap, n_tables, max_cols,
                            h_sample, row_stride, k=64, sampling="conv"):
    """§Perf variant of the correlation seeker: instead of psum-ing the dense
    [n_tables x max_cols^2] QCR segments to every device (2x full-buffer
    all-reduce), reduce-scatter the segments, score the local slice, take a
    per-shard top-k and all-gather only the winners.  Halves the collective
    bytes and removes the replicated dense scoring."""
    axes = tuple(mesh.axis_names)
    idx_specs = {k2: P(axes) for k2 in IDX_KEYS_MAIN + IDX_KEYS_NUM}
    n_dev = mesh.size
    dim = n_tables * max_cols * max_cols
    dim_pad = ((dim + n_dev - 1) // n_dev) * n_dev

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(idx_specs, P(), P(), P()), out_specs=(P(), P()),
                       check_rep=False)
    def run(idx, qj_hash, q_mask, q_bit):
        pidx, valid, ovf = probe_sorted(idx["hash"], qj_hash, q_mask,
                                                m_cap)
        t = idx["table"][pidx]
        r = idx["row"][pidx]
        cj = idx["col"][pidx]
        rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)
        rowkey = jnp.where(valid, rowkey, -1).reshape(-1)
        g_rk = jax.lax.all_gather(rowkey, axes, tiled=False).reshape(-1)
        g_cj = jax.lax.all_gather(cj.reshape(-1), axes, tiled=False).reshape(-1)
        qbf = jnp.broadcast_to(q_bit[:, None], pidx.shape).reshape(-1)
        g_qb = jax.lax.all_gather(qbf, axes, tiled=False).reshape(-1)
        nlo = jnp.searchsorted(idx["num_rowkey"], g_rk, side="left")
        nhi = jnp.searchsorted(idx["num_rowkey"], g_rk, side="right")
        nidx = nlo[:, None] + jnp.arange(row_cap)[None, :]
        nvalid = (nidx < nhi[:, None]) & (g_rk >= 0)[:, None]
        nidx = jnp.clip(nidx, 0, idx["num_rowkey"].shape[0] - 1)
        ntab = idx["num_table"][nidx]
        ncol = idx["num_col"][nidx]
        nquad = idx["num_quadrant"][nidx]
        rank = idx["num_rank_conv" if sampling == "conv"
                   else "num_rank_rand"][nidx]
        nvalid &= rank < h_sample
        agree = (nquad == g_qb[:, None]) & nvalid
        key = ((ntab * max_cols + g_cj[:, None]) * max_cols + ncol).reshape(-1)
        n_all = jnp.zeros(dim_pad, jnp.float32).at[key].add(
            nvalid.reshape(-1).astype(jnp.float32), mode="drop")
        n_agree = jnp.zeros(dim_pad, jnp.float32).at[key].add(
            agree.reshape(-1).astype(jnp.float32), mode="drop")
        # reduce-scatter the segment sums: each shard owns dim_pad/n_dev keys
        n_all = jax.lax.psum_scatter(n_all, axes, scatter_dimension=0,
                                     tiled=True)
        n_agree = jax.lax.psum_scatter(n_agree, axes, scatter_dimension=0,
                                       tiled=True)
        qcr = jnp.abs(2.0 * n_agree - n_all) / jnp.maximum(n_all, 1.0)
        qcr = jnp.where(n_all >= 3, qcr, 0.0)
        vals, loc = jax.lax.top_k(qcr, k)                # local winners
        lin = _linear_shard_index(mesh, axes)
        gids = (lin * (dim_pad // n_dev) + loc) // (max_cols * max_cols)
        g_vals = jax.lax.all_gather(vals, axes, tiled=True)   # [n_dev*k]
        g_ids = jax.lax.all_gather(gids, axes, tiled=True)
        best, bloc = jax.lax.top_k(g_vals, k)
        return g_ids[bloc], best

    return jax.jit(run)
