"""Data-lake containers + seeded synthetic lake generators with ground truth.

Tables hold columns as python lists / numpy arrays of mixed values (strings,
ints, floats, None).  Generators mirror the paper's benchmark settings:
joinable lakes (JOSIE / Fig 5), multi-column joinable rows (MATE / Table V),
unionable clusters (Starmie / Table VI), correlation lakes (QCR / Table VII),
and imputation scenarios (Table III).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Table:
    name: str
    columns: list            # list of 1-D value sequences (same length)
    col_names: list = field(default_factory=list)

    def __post_init__(self):
        if not self.col_names:
            self.col_names = [f"c{i}" for i in range(len(self.columns))]

    @property
    def n_rows(self) -> int:
        return len(self.columns[0]) if self.columns else 0

    @property
    def n_cols(self) -> int:
        return len(self.columns)

    def row(self, r: int):
        return [c[r] for c in self.columns]


@dataclass
class DataLake:
    tables: list

    @property
    def n_tables(self) -> int:
        return len(self.tables)

    def __getitem__(self, i: int) -> Table:
        return self.tables[i]

    def stats(self) -> dict:
        return {"tables": self.n_tables,
                "columns": sum(t.n_cols for t in self.tables),
                "rows": sum(t.n_rows for t in self.tables)}


def _vocab(rng, size):
    return [f"tok_{i}" for i in range(size)]


def synthetic_lake(n_tables=100, rows=40, cols=4, vocab=2000, seed=0,
                   numeric_cols=1) -> DataLake:
    """Generic lake: categorical columns from a shared vocabulary + numeric
    columns (so every seeker has work to do)."""
    rng = np.random.default_rng(seed)
    voc = _vocab(rng, vocab)
    tables = []
    for t in range(n_tables):
        nr = int(rng.integers(max(4, rows // 2), rows + 1))
        columns = []
        for c in range(cols - numeric_cols):
            columns.append([voc[i] for i in rng.integers(0, vocab, nr)])
        for c in range(numeric_cols):
            columns.append(list(np.round(rng.normal(0, 10, nr), 3)))
        tables.append(Table(f"t{t}", columns))
    return DataLake(tables)


def joinable_lake(n_tables=200, rows=50, vocab=5000, overlap_levels=10, seed=0):
    """Lake with controlled single-column overlap against a query column.

    Returns (lake, query_values, ground_truth) where ground_truth[t] = number
    of distinct query values appearing in some single column of table t.
    """
    rng = np.random.default_rng(seed)
    voc = _vocab(rng, vocab)
    q_size = 40
    query = [voc[i] for i in rng.choice(vocab, q_size, replace=False)]
    tables, truth = [], np.zeros(n_tables, np.int32)
    for t in range(n_tables):
        n_overlap = int(rng.integers(0, min(q_size, overlap_levels * 4)))
        chosen = list(rng.choice(q_size, n_overlap, replace=False))
        col = [query[i] for i in chosen]
        col += [voc[i] for i in rng.integers(0, vocab, rows - len(col))]
        rng.shuffle(col)
        other = [voc[i] for i in rng.integers(0, vocab, rows)]
        num = list(np.round(rng.normal(0, 5, rows), 3))
        tables.append(Table(f"t{t}", [col, other, num]))
        truth[t] = n_overlap
    return DataLake(tables), query, truth


def mc_joinable_lake(n_tables=80, rows=60, vocab=4000, seed=0, n_cols=2):
    """Lake for multi-column join: some tables contain aligned query tuples,
    others contain the same values misaligned (MATE's FP source).

    Returns (lake, query_tuples, truth) where truth[t] = number of query
    tuples exactly joinable with a row of table t (aligned).
    """
    rng = np.random.default_rng(seed)
    voc = _vocab(rng, vocab)
    n_q = 20
    q_tuples = [tuple(voc[i] for i in rng.choice(vocab, n_cols, replace=False))
                for _ in range(n_q)]
    tables, truth = [], np.zeros(n_tables, np.int32)
    for t in range(n_tables):
        cols = [[voc[i] for i in rng.integers(0, vocab, rows)]
                for _ in range(n_cols + 1)]
        mode = t % 3
        n_hit = int(rng.integers(0, n_q // 2))
        rows_idx = rng.choice(rows, n_hit, replace=False)
        hits = rng.choice(n_q, n_hit, replace=False)
        if mode in (0, 1):    # aligned: tuple values in the same row
            for r, qi in zip(rows_idx, hits):
                for c in range(n_cols):
                    cols[c][r] = q_tuples[qi][c]
            truth[t] = n_hit
        else:                 # misaligned: values present but in different rows
            for r, qi in zip(rows_idx, hits):
                for c in range(n_cols):
                    cols[c][(r + c + 1) % rows] = q_tuples[qi][c]
            truth[t] = 0
        tables.append(Table(f"t{t}", cols))
    return DataLake(tables), q_tuples, truth


def unionable_lake(n_clusters=10, per_cluster=8, rows=40, seed=0):
    """Clusters of unionable tables: tables in a cluster share column domains.

    Returns (lake, cluster_of_table) — tables with the same cluster id are
    the union-search ground truth for each other.
    """
    rng = np.random.default_rng(seed)
    tables, labels = [], []
    for c in range(n_clusters):
        domains = []
        for d in range(3):
            base = [f"cl{c}_d{d}_v{i}" for i in range(60)]
            domains.append(base)
        for j in range(per_cluster):
            columns = [list(rng.choice(dom, rows)) for dom in domains]
            tables.append(Table(f"cl{c}_t{j}", columns))
            labels.append(c)
    order = rng.permutation(len(tables))
    tables = [tables[i] for i in order]
    labels = [labels[i] for i in order]
    return DataLake(tables), np.array(labels)


def correlation_lake(n_tables=60, rows=80, seed=0, numeric_join_keys=False):
    """Lake for correlation discovery: tables join with the query on a key
    column; one numeric column correlates with the query target with a known
    coefficient.

    Returns (lake, join_values, target_values, truth_corr[t]).
    """
    rng = np.random.default_rng(seed)
    n_keys = rows
    if numeric_join_keys:
        keys = list(range(1000, 1000 + n_keys))
    else:
        keys = [f"key_{i}" for i in range(n_keys)]
    target = rng.normal(0, 1, n_keys)
    tables, truth = [], np.zeros(n_tables, np.float64)
    for t in range(n_tables):
        rho = float(rng.uniform(-1, 1))
        noise = rng.normal(0, 1, n_keys)
        y = rho * target + np.sqrt(max(1 - rho ** 2, 1e-9)) * noise
        perm = rng.permutation(n_keys)
        cols = [[keys[i] for i in perm],
                list(np.round(y[perm], 5)),
                list(rng.normal(50, 20, n_keys).round(3))]
        tables.append(Table(f"t{t}", cols, ["key", "corr_col", "noise_col"]))
        truth[t] = abs(np.corrcoef(target, y)[0, 1])
    return DataLake(tables), keys, list(np.round(target, 5)), truth
