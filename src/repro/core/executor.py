"""Plan executor: optimized (EG ordering + mask threading) and naive (B-NO).

The executor owns a ``MatchEngine`` (device index + probe backends), hashes
query values through a cross-query memo cache, and runs the plan DAG.
``optimize=False`` reproduces the paper's B-NO configuration: same seekers
and combiners, random/insertion seeker order, no intermediate-result
threading.

Serving is retrace-free: match capacities are quantized to a small fixed
ladder and query counts are padded to powers of two, so re-running any plan
shape with new values of the same capacity bucket hits the jit cache (zero
new traces — asserted against ``seekers.TRACE_COUNTS``).  ``sync=False``
dispatches seekers without host synchronization (no ``block_until_ready``,
no data-dependent compaction stages) for batched serving
(serve/engine.py ``serve_many``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import combiners as comb
from repro.core import seekers as seek
from repro.core.cost_model import CostModel
from repro.core.hashing import MISSING, hash_value, row_superkey, split_u64
from repro.core.index import UnifiedIndex
from repro.core.match import MatchEngine
from repro.core.optimizer import optimize as optimize_plan
from repro.core.plan import Plan, SeekerSpec
from repro.core import sketch as sk

# the match-capacity ladder: every seeker launch uses one of these static
# capacities, so the jit cache holds at most len(CAP_LADDER) variants per
# (seeker, query-pad) shape instead of one per observed match count — and a
# coarse ladder keeps the bucket stable across draws from the same workload
CAP_LADDER = (32, 128, 512, 1024)
PAD_SENTINEL = MISSING                    # reserved: never a real cell hash


@dataclass
class OverflowSlice:
    """A lazy view into a fused group's stacked overflow vector: ``rows``
    are this plan's seekers' rows in ``vec``.  Materializing the slice at
    dispatch time would cost one tiny device gather per seeker; deferring
    it to the ``ExecInfo.overflow`` read keeps the fused dispatch path free
    of per-node device ops.  On a sharded lake ``vec`` is a *tuple* of
    per-shard vectors (overflow sums across shards, like scores)."""
    vec: object                   # [n_seekers_p] device vector, or a tuple
    rows: list                    # this plan's row indices into vec


@dataclass
class ExecInfo:
    optimized: bool
    node_seconds: dict = field(default_factory=dict)
    order: list = field(default_factory=list)
    overflow_parts: list = field(default_factory=list)
    # query-cache accounting (serve/cache.py): seeker nodes served from the
    # subplan cache (``cached_nodes``) vs actually dispatched
    # (``seeker_runs``).  Telemetry only — ``serve_many`` excludes exact
    # result-cache hits (CacheInfo.status == 'hit') from its drain
    # denominator; a partial request dispatches combiner work even at zero
    # seeker runs, so it keeps its share.
    cached_nodes: list = field(default_factory=list)
    seeker_runs: int = 0
    #: memoized ``overflow`` total (None until first read / batch fetch)
    _overflow: int | None = None
    # device-program dispatch count: every jitted seeker call (compaction
    # stages included) and every combiner node counts one on the unfused
    # path; the fused path counts its group launches + the single DAG
    # program — ``n_groups + 1``, which is ``n_kinds + 1`` unless same-kind
    # seekers differ in static shape args (MC n_cols, C h/sampling)
    launches: int = 0
    # sharded graceful degradation: indices of shards whose fused probe
    # failed twice (initial + one retry on a rebuilt engine) and were
    # zero-substituted out of the merge — the response is flagged degraded
    # (serve/engine.py DiscoveryResponse) instead of erroring the batch
    failed_shards: list = field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return sum(self.node_seconds.values())

    @property
    def overflow(self) -> int:
        # reading this synchronizes on the dispatched seekers; all parts are
        # fetched in ONE device transfer (a part may be a per-seeker scalar
        # or a fused group's stacked OverflowSlice)
        if self._overflow is None:
            ExecInfo.materialize_overflow([self])
        return self._overflow

    @staticmethod
    def materialize_overflow(infos):
        """Resolve many infos' overflow totals in ONE device transfer,
        deduping shared vectors (a fused group's stacked overflow vector is
        shared by every plan in a serve_many batch).  Per-response fetches
        are a measurable share of the warm batched serving path."""
        todo = [i for i in infos if i._overflow is None]
        vecs: dict = {}
        for i in todo:
            for p in i.overflow_parts:
                v = p.vec if isinstance(p, OverflowSlice) else p
                vecs.setdefault(id(v), v)
        raw = jax.device_get(list(vecs.values())) if vecs else []
        host = {k: np.asarray(a) for k, a in zip(vecs, raw)}
        for i in todo:
            total = 0
            for p in i.overflow_parts:
                if isinstance(p, OverflowSlice):
                    # sharded slice: vec is [n_shards, n_seekers_p]
                    total += int(host[id(p.vec)][..., p.rows].sum())
                else:
                    total += int(host[id(p)].sum())
            i._overflow = total


def _pow2_at_least(n: int, lo: int = 8, hi: int = 1024) -> int:
    m = lo
    while m < min(n, hi):
        m *= 2
    return m


class Executor:
    """Runs plans over a ``UnifiedIndex`` or a LiveLake ``SegmentStore``.

    A store carries an ``epoch`` counter that every mutation bumps; the
    executor compares it lazily at query entry and rebuilds its MatchEngine
    when stale — so a Session over a live lake always observes a consistent
    epoch without any mutation hook into the executor.  (The value-hash
    memo survives refreshes: it is a pure function of cell values, not of
    the index.)"""

    def __init__(self, index: UnifiedIndex, m_cap_max: int = 1024,
                 row_cap: int = 8, backend: str = "sorted",
                 interpret: bool = False, bucket_width: int | None = None):
        self.index = index
        self.backend = backend
        self.interpret = interpret
        self.bucket_width = bucket_width
        self._engine_epoch = None
        self._build_engine()
        self.m_cap_max = m_cap_max
        self.row_cap = row_cap
        rungs = {min(c, m_cap_max) for c in CAP_LADDER}
        if m_cap_max > max(CAP_LADDER):
            rungs.add(m_cap_max)        # honor caps above the default ladder
        self.cap_ladder = tuple(sorted(rungs))
        self._hash_cache: dict = {}
        self._hash_cache_max = 1 << 20
        self._in_plan = False
        #: approximate tier: dense sketch packs, memoized per (epoch,
        #: geometry) — rebuilt lazily like the MatchEngine, never mid-query
        self._sketch_views_memo = None

    # ---------------------------------------------------------- live engine
    def _build_engine(self):
        idx = self.index
        if hasattr(idx, "segments"):       # LiveLake SegmentStore
            if self.bucket_width is not None:
                raise ValueError(
                    "bucket_width is not configurable on a live store: "
                    "each segment sizes its own lossless bucket layout")
            self.engine = MatchEngine.from_store(idx, backend=self.backend,
                                                 interpret=self.interpret)
            self._engine_epoch = idx.epoch
        else:
            self.engine = MatchEngine.from_index(
                idx, backend=self.backend, interpret=self.interpret,
                bucket_width=self.bucket_width)
        self.dev = self.engine.dev          # back-compat alias
        self.n_tables = idx.n_tables
        self.max_cols = idx.max_cols

    def refresh(self):
        """Pick up index mutations: rebuild the engine iff the store epoch
        moved (no-op for a static UnifiedIndex and for unchanged epochs).
        The value-hash memo survives: ``hash_value`` is a pure function of
        the cell value, independent of index epoch."""
        ep = getattr(self.index, "epoch", None)
        if ep is not None and ep != self._engine_epoch:
            self._build_engine()

    # ------------------------------------------------------------------ util
    def _hash_many(self, values) -> np.ndarray:
        """Memoized value hashing (shared across queries / plans).  The memo
        is bounded: a long-lived serving executor seeing an unbounded stream
        of distinct values evicts the oldest half (dict insertion order)
        instead of wiping everything — a full clear stampedes every hot
        value through a re-hash on the next request."""
        vals = list(values)
        out = np.empty(len(vals), np.uint32)
        cache = self._hash_cache
        if len(cache) > self._hash_cache_max:
            for k in list(cache)[:len(cache) // 2]:
                del cache[k]
        for i, v in enumerate(vals):
            h = cache.get(v)
            if h is None:
                h = hash_value(v)
                cache[v] = h
            out[i] = h
        return out

    def _hashed(self, values) -> np.ndarray:
        """Hash + dedupe (SQL IN (...) set semantics)."""
        return np.unique(self._hash_many(values))

    @staticmethod
    def _pad_queries(h: np.ndarray, lo: int = 16):
        """Pad a hashed query array to the power-of-two shape ladder so any
        query set of the same capacity bucket reuses the compiled seeker."""
        n = len(h)
        width = _pow2_at_least(max(n, 1), lo=lo, hi=1 << 30)
        hp = np.full(width, PAD_SENTINEL, np.uint32)
        hp[:n] = h
        mask = np.zeros(width, bool)
        mask[:n] = True
        return jnp.asarray(hp), jnp.asarray(mask)

    def _stat_counts(self, h: np.ndarray) -> np.ndarray:
        """Planner-statistics counts: on a live store, tombstoned postings
        are excluded (they contribute no results, only probe-window slots),
        so seeker ranking reflects the live lake."""
        if hasattr(self.index, "segments"):
            return self.index.host_counts(h, live_only=True)
        return self.index.host_counts(h)

    def seeker_stats(self, spec: SeekerSpec):
        """(cardinality, n_cols, avg value frequency) — the cost features."""
        if spec.kind == "MC":
            freqs = []
            for c in range(spec.n_cols):
                h = self._hashed([t[c] for t in spec.values])
                freqs.append(self._stat_counts(h).mean())
            avg = float(np.prod(freqs))
            return (float(len(spec.values)), float(spec.n_cols), avg)
        h = self._hashed(spec.values)
        avg = float(self._stat_counts(h).mean()) if len(h) else 0.0
        return (float(len(spec.values)), float(spec.n_cols), avg)

    def _quantize_cap(self, need: int) -> int:
        for c in self.cap_ladder:
            if need <= c:
                return c
        return self.cap_ladder[-1]

    def _mcap_for(self, hashes: np.ndarray) -> int:
        counts = self.index.host_counts(hashes)
        return self._quantize_cap(int(counts.max(initial=1)))

    # ----------------------------------------------------------- sketch tier
    def _sketch_sources(self):
        """[(sketch_map, alive_mask, device)] — one entry per device pack.
        The sharded executor overrides this with one entry per shard; the
        base executor serves one pack on the default device."""
        idx = self.index
        if hasattr(idx, "sketch_map"):            # LiveLake SegmentStore
            return [(idx.sketch_map(), None, None)]
        return [(getattr(idx, "sketches", None) or {}, None, None)]

    def sketch_views(self):
        """Sorted sketch-posting views (core/sketch.py ``SketchView``),
        memoized per (epoch, geometry): probe cost is O(|Q| log + matches)
        — independent of posting count AND of table count — and the view
        only rebuilds when the index epoch or capacity changes, so probes
        never re-sort across repeated queries."""
        key = (getattr(self.index, "epoch", None), self.n_tables,
               self.max_cols)
        memo = self._sketch_views_memo
        if memo is None or memo[0] != key:
            cfg = getattr(self.index, "sketch_config", None) \
                or sk.SketchConfig()
            views = [sk.build_view(m, self.n_tables, self.max_cols, cfg,
                                   alive=alive)
                     for m, alive, _dev in self._sketch_sources()]
            self._sketch_views_memo = (key, views)
        return self._sketch_views_memo[1]

    def sketch_probe(self, spec: SeekerSpec,
                     confidence: float = 0.95) -> sk.SketchProbeResult:
        """Estimate one seeker's per-table scores from the sketch tier.

        Runs the host probe on every view (per shard on a sharded lake) and
        merges with one elementwise sum — each table's slots are nonzero on
        exactly one view, so the merge is exact and the 1-vs-N shard
        results are bit-identical.  MC has no sketch estimator (raises
        ValueError; the session falls back to the exact path)."""
        if not self._in_plan:
            self.refresh()
        t0 = time.perf_counter()
        from repro.obs import trace as otrace
        rec = otrace.current()
        views = self.sketch_views()

        def dispatch(make):
            outs = []
            for i, view in enumerate(views):
                with rec.span("sketch.probe.pack", kind=spec.kind, pack=i):
                    outs.append(make(view))
            return [sum(parts) for parts in zip(*outs)]

        if spec.kind in ("SC", "KW"):
            # distinct query hashes: the exact seekers are COUNT(DISTINCT)
            h = np.unique(self._hashed(spec.values))
            # a table score is a max over per-column intervals: Bonferroni
            # the per-column confidence so the max's interval holds jointly
            comparisons = self.max_cols if spec.kind == "SC" else 1
            z = sk.z_for(confidence, comparisons)
            level = "col" if spec.kind == "SC" else "tbl"
            lo, hi, est, ci_lo, ci_hi = dispatch(
                lambda v: v.containment(h, z, level=level))
            out = sk.SketchProbeResult(
                kind=spec.kind, estimator="kmv-bottomk", est=est,
                bound_lo=lo, bound_hi=hi, ci_lo=ci_lo, ci_hi=ci_hi,
                sound=True)
        elif spec.kind == "C":
            pairs = list(dict.fromkeys(zip(spec.values, spec.target)))
            h = self._hash_many([p[0] for p in pairs])
            tgt = np.array([float(p[1]) for p in pairs])
            qbit = (tgt >= tgt.mean()).astype(np.int8)
            # dedupe join hashes keeping the first pair's quadrant bit (the
            # exact seeker probes in first-occurrence order too)
            hu, first = np.unique(h, return_index=True)
            qb = qbit[first]
            # the score is a max over (join col, numeric col) pairs
            z = sk.z_for(confidence, self.max_cols ** 2)

            def make(view):
                est, lo, hi, support = view.correlation(
                    hu, qb, z, min_support=sk.SAMPLE_MIN_SUPPORT)
                # sound join gate: zero containment upper bound over the
                # join values => the table cannot join => exact score is 0
                _, cont_hi, _, _, _ = view.containment(hu, 0.0, level="col")
                return est, lo, hi, support, cont_hi

            est, ci_lo, ci_hi, support, cont_hi = dispatch(make)
            impossible = cont_hi <= 0
            # joinable but unseen in the sample: report the uninformative
            # interval instead of a falsely tight one
            no_est = (support <= 0) & ~impossible
            est = np.where(support > 0, est, 0.0).astype(np.float32)
            ci_lo = np.where(support > 0, ci_lo, 0.0).astype(np.float32)
            ci_hi = np.where(impossible, 0.0,
                             np.where(no_est, 1.0, ci_hi)).astype(np.float32)
            out = sk.SketchProbeResult(
                kind="C", estimator="sample-qcr", est=est, bound_lo=ci_lo,
                bound_hi=ci_hi, ci_lo=ci_lo, ci_hi=ci_hi, sound=False,
                impossible=impossible)
        else:
            raise ValueError(
                f"no sketch estimator for seeker kind {spec.kind!r}")
        out.seconds = time.perf_counter() - t0
        out.launches = 0                 # host-side probe: no device programs
        reg = obs.registry()
        reg.counter("approx.sketch_probes").inc()
        reg.histogram("approx.probe_seconds").observe(out.seconds)
        return out

    # --------------------------------------------------------------- seekers
    def run_seeker(self, spec: SeekerSpec, allowed=None,
                   sync: bool = True) -> comb.ResultSet:
        if not self._in_plan:   # a running plan already pinned its epoch
            self.refresh()
        self._last_launches = 1
        if spec.kind in ("SC", "KW"):
            h = self._hashed(spec.values)
            m_cap = self._mcap_for(h)
            qh, qm = self._pad_queries(h)
            fn = seek.sc_seeker if spec.kind == "SC" else seek.kw_seeker
            kw = dict(m_cap=m_cap, n_tables=self.n_tables)
            if spec.kind == "SC":
                kw["max_cols"] = self.max_cols
            scores, ovf = fn(self.engine, qh, qm, allowed=allowed, **kw)
        elif spec.kind == "MC":
            values = list(dict.fromkeys(spec.values))   # dedupe tuples
            nt = len(values)
            n_cols = spec.n_cols
            th = np.stack([self._hash_many([t[c] for t in values])
                           for c in range(n_cols)], axis=1)       # [nt, n_cols]
            counts = np.stack([self.index.host_counts(th[:, c])
                               for c in range(n_cols)], axis=1)
            init_col = np.argmin(counts, axis=1).astype(np.int32)
            qks = np.array([row_superkey(th[i], np.zeros(n_cols, np.int64))
                            for i in range(nt)], np.uint64)
            qk_lo, qk_hi = split_u64(qks)
            m_cap = self._quantize_cap(int(counts.max(initial=1)))
            # pad the tuple batch onto the shape ladder
            ntp = _pow2_at_least(max(nt, 1), lo=8, hi=1 << 30)
            pad = ntp - nt
            th = np.pad(th, ((0, pad), (0, 0)))
            init_col = np.pad(init_col, (0, pad))
            qk_lo, qk_hi = np.pad(qk_lo, (0, pad)), np.pad(qk_hi, (0, pad))
            tmask = np.zeros(ntp, bool)
            tmask[:nt] = True
            args = (self.engine, jnp.asarray(th), jnp.asarray(init_col),
                    jnp.asarray(qk_lo), jnp.asarray(qk_hi))
            if sync:
                # stage 1: survivor counts after predicate + bloom -> the
                # stage-2 validation runs with compacted candidate buffers
                # (this is where the threaded 'WHERE TableId IN (IR)'
                # actually shrinks work)
                self._last_launches = 2
                surv = seek.mc_survivor_counts(*args, m_cap=m_cap,
                                               allowed=allowed,
                                               tuple_mask=jnp.asarray(tmask))
                m_cap2 = self._quantize_cap(int(jnp.max(surv)))
                scores, _rows, ovf = seek.mc_seeker_compact(
                    *args, m_cap=m_cap, m_cap2=min(m_cap2, m_cap),
                    n_tables=self.n_tables, n_cols=n_cols,
                    row_stride=self.index.row_stride, allowed=allowed,
                    tuple_mask=jnp.asarray(tmask))
            else:
                # async dispatch: skip the data-dependent compaction stage
                # (its capacity pick is a host sync); validate at full m_cap
                scores, _rows, ovf = seek.mc_seeker(
                    *args, m_cap=m_cap, n_tables=self.n_tables,
                    n_cols=n_cols, row_stride=self.index.row_stride,
                    allowed=allowed, tuple_mask=jnp.asarray(tmask))
        elif spec.kind == "C":
            pairs = list(dict.fromkeys(zip(spec.values, spec.target)))
            h = self._hash_many([p[0] for p in pairs])
            tgt = np.array([float(p[1]) for p in pairs])
            qbit = (tgt >= tgt.mean()).astype(np.int8)            # k0/k1 split
            m_cap = self._mcap_for(h)
            qh, qm = self._pad_queries(h)
            qbit = np.pad(qbit, (0, qh.shape[0] - len(qbit)))
            kw = dict(m_cap=m_cap, row_cap=self.row_cap,
                      n_tables=self.n_tables, max_cols=self.max_cols,
                      h_sample=spec.h, sampling=spec.sampling,
                      row_stride=self.index.row_stride, allowed=allowed)
            if allowed is not None and sync:
                # two-stage: compact the join side to the surviving postings
                self._last_launches = 2
                surv = int(seek.c_survivor_counts(self.engine, qh, qm,
                                                  m_cap=m_cap,
                                                  allowed=allowed))
                cap2 = _pow2_at_least(max(surv, 1),
                                      hi=int(qh.shape[0]) * m_cap)
                scores, ovf = seek.c_seeker_compact(self.engine, qh, qm,
                                                    jnp.asarray(qbit),
                                                    cap2=cap2, **kw)
            else:
                scores, ovf = seek.c_seeker(self.engine, qh, qm,
                                            jnp.asarray(qbit), **kw)
        else:
            raise ValueError(spec.kind)
        if sync:
            scores.block_until_ready()
        self._last_overflow = ovf
        return comb.topk_result(scores, spec.k)

    # ------------------------------------------------------------------ plan
    def run(self, plan: Plan, optimize: bool = True,
            cost_model: CostModel | None = None, sync: bool = True,
            cache=None, fused: bool = False):
        """Execute ``plan``.  ``cache`` is an optional query-cache handle
        (duck-typed ``seeker_key``/``get_seeker``/``put_seeker`` — see
        serve/cache.py): unrestricted seeker runs are served from and stored
        into its subplan level, short-circuiting ``run_seeker``.  Seekers
        that would run under a threaded optimizer mask still execute, so a
        partially-cached plan is bit-identical to a cold run.

        ``fused=True`` routes through core/fused.py: all same-kind seekers
        dispatch as one batched device program and the combiner DAG compiles
        to a single jitted program, so the plan executes in
        ``~n_kinds + 1`` launches (``ExecInfo.launches``) instead of one
        per node — bit-identical to the unfused walk."""
        self.refresh()          # one consistent epoch for the whole plan
        self._in_plan = True    # nested run_seeker calls must not re-refresh
        try:
            if fused:
                from repro.core.fused import run_fused
                rs, info = run_fused(self, [plan], optimize=optimize,
                                     cost_model=cost_model, cache=cache)[0]
                if sync:
                    rs.scores.block_until_ready()
                return rs, info
            return self._run(plan, optimize, cost_model, sync, cache)
        finally:
            self._in_plan = False

    def run_many(self, plans, optimize: bool = True,
                 cost_model: CostModel | None = None, sync: bool = True,
                 cache=None):
        """Fused batch execution: same-kind seekers are batched *across all
        plans* into shared device launches (serve/engine.py ``serve_many``'s
        fused mode).  Returns [(ResultSet, ExecInfo)] aligned with
        ``plans``; with ``sync=False`` nothing synchronizes — the caller
        drains the device once."""
        from repro.core.fused import run_fused
        self.refresh()
        self._in_plan = True
        try:
            out = run_fused(self, list(plans), optimize=optimize,
                            cost_model=cost_model, cache=cache)
        finally:
            self._in_plan = False
        if sync:
            jax.block_until_ready([rs.scores for rs, _ in out])
        return out

    def _run(self, plan: Plan, optimize: bool, cost_model, sync: bool,
             cache=None):
        info = ExecInfo(optimized=optimize)
        ep = optimize_plan(plan, self.seeker_stats, cost_model) if optimize \
            else None
        memo: dict[str, comb.ResultSet] = {}
        # synchronized-timing mode (repro.obs.set_sync_timing): per-node
        # timings measure device compute, not async-dispatch enqueue —
        # each node blocks before its clock read, serializing the pipeline
        sync_time = obs.sync_timing()

        def timed_seeker(name, spec, allowed=None):
            t0 = time.perf_counter()
            hit = None
            key = None
            if cache is not None and allowed is None:
                key = cache.seeker_key(spec)
                hit = cache.get_seeker(key)
            if hit is not None:
                rs = hit.result
                info.overflow_parts.append(hit.overflow)
                info.cached_nodes.append(name)
            else:
                rs = self.run_seeker(spec, allowed=allowed, sync=sync)
                if sync_time and not sync:
                    jax.block_until_ready(rs.scores)
                info.seeker_runs += 1
                info.launches += self._last_launches
                info.overflow_parts.append(self._last_overflow)
                if key is not None:
                    cache.put_seeker(key, rs, self._last_overflow,
                                     self.n_tables)
            info.node_seconds[name] = time.perf_counter() - t0
            info.order.append(name)
            return rs

        def eval_node(name: str) -> comb.ResultSet:
            if name in memo:
                return memo[name]
            node = plan.nodes[name]
            if node.is_seeker:
                rs = timed_seeker(name, node.spec)
            else:
                kind = node.spec.kind
                k = node.spec.k
                if optimize and ep is not None and name in ep.groups:
                    rs = self._run_group(plan, ep.groups[name], node, info,
                                         timed_seeker, eval_node, memo)
                elif kind == "difference":
                    a = eval_node(node.deps[0])
                    b_node = plan.nodes[node.deps[1]]
                    if optimize and b_node.is_seeker and \
                            len(plan.consumers(b_node.name)) == 1 and \
                            b_node.name not in memo:
                        # rewriting: restrict the subtrahend to the minuend's
                        # tables (WHERE TableId IN (IR_a))
                        b = timed_seeker(b_node.name, b_node.spec,
                                         allowed=a.mask)
                        memo[b_node.name] = b
                    else:
                        b = eval_node(node.deps[1])
                    t0 = time.perf_counter()
                    rs = comb.difference(a, b, k)
                    if sync_time:
                        jax.block_until_ready(rs.scores)
                    info.node_seconds[name] = time.perf_counter() - t0
                    info.order.append(name)
                    info.launches += 1
                else:
                    deps = [eval_node(d) for d in node.deps]
                    t0 = time.perf_counter()
                    if kind == "intersect":
                        rs = comb.intersect(deps, k)
                    elif kind == "union":
                        rs = comb.union(deps, k)
                    elif kind == "counter":
                        rs = comb.counter(deps, k)
                    else:
                        raise ValueError(kind)
                    if sync_time:
                        jax.block_until_ready(rs.scores)
                    info.node_seconds[name] = time.perf_counter() - t0
                    info.order.append(name)
                    info.launches += 1
            memo[name] = rs
            return rs

        result = eval_node(plan.output)
        reg = obs.registry()
        reg.counter("exec.plans").inc()
        reg.counter("exec.launches").inc(info.launches)
        reg.counter("exec.seeker_runs").inc(info.seeker_runs)
        reg.histogram("exec.plan_seconds").observe(info.total_seconds)
        return result, info

    def _run_group(self, plan, eg, combiner_node, info, timed_seeker,
                   eval_node, memo):
        """Ranked execution-group run with mask threading (Intersection)."""
        results = []
        allowed = None
        for sname in eg.seekers:
            if sname in memo:
                # shared seeker (>= 2 consumers, hash-consed subtree): it was
                # executed unrestricted once already — reuse, don't re-probe
                rs = memo[sname]
            else:
                exclusive = len(plan.consumers(sname)) == 1
                rs = timed_seeker(sname, plan.nodes[sname].spec,
                                  allowed=allowed if exclusive else None)
                memo[sname] = rs
            results.append(rs)
            allowed = rs.mask if allowed is None else (allowed & rs.mask)
        # non-seeker deps of the combiner are evaluated normally
        for dep in combiner_node.deps:
            if dep not in eg.seekers:
                results.append(eval_node(dep))
        t0 = time.perf_counter()
        rs = comb.intersect(results, combiner_node.spec.k)
        if obs.sync_timing():
            jax.block_until_ready(rs.scores)
        info.node_seconds[combiner_node.name] = time.perf_counter() - t0
        info.order.append(combiner_node.name)
        info.launches += 1
        return rs
