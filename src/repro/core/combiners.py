"""Combiners: TPU-native set algebra over dense per-table result vectors.

A seeker's result set is (scores f32 [n_tables], mask bool [n_tables]) with
the mask holding its top-k selection — combiners are elementwise AND / OR /
ANDNOT / + over these vectors, which is exactly the representation that makes
set ops free on a vector machine (the paper's combiners are SQL set ops).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ResultSet:
    scores: jnp.ndarray          # f32 [n_tables]
    mask: jnp.ndarray            # bool [n_tables]

    @staticmethod
    def rank(s, m):
        """Rank host-side (scores, mask) arrays: selected ids, score desc.
        The single ranking implementation — ``ids`` and batched response
        materialization (serve_many) both route through it, so they cannot
        diverge."""
        ids = np.nonzero(m)[0]
        return ids[np.argsort(-s[ids], kind="stable")]

    def ids(self):
        """Selected table ids sorted by score desc (host-side; scores and
        mask come back in a single device transfer)."""
        s, m = (np.asarray(a) for a in
                jax.device_get((self.scores, self.mask)))
        return self.rank(s, m)


def topk_result(scores, k: int) -> ResultSet:
    """Select the top-k positive-score tables into a ResultSet."""
    k = min(k, scores.shape[0])
    vals, ids = jax.lax.top_k(scores, k)
    keep = vals > 0
    mask = jnp.zeros(scores.shape[0], bool).at[ids].set(keep)
    return ResultSet(scores=jnp.where(mask, scores, 0.0), mask=mask)


def intersect(results, k: int | None = None) -> ResultSet:
    mask = results[0].mask
    scores = results[0].scores
    for r in results[1:]:
        mask = mask & r.mask
        scores = scores + r.scores
    scores = jnp.where(mask, scores, 0.0)
    return _maybe_topk(scores, mask, k)


def union(results, k: int | None = None) -> ResultSet:
    mask = results[0].mask
    scores = results[0].scores
    for r in results[1:]:
        mask = mask | r.mask
        scores = jnp.maximum(scores, r.scores)
    scores = jnp.where(mask, scores, 0.0)
    return _maybe_topk(scores, mask, k)


def difference(a: ResultSet, b: ResultSet, k: int | None = None) -> ResultSet:
    mask = a.mask & ~b.mask
    scores = jnp.where(mask, a.scores, 0.0)
    return _maybe_topk(scores, mask, k)


def counter(results, k: int | None = None) -> ResultSet:
    """Count occurrences of each table across the input sets, rank by count
    (the paper's union-search aggregator)."""
    counts = jnp.zeros_like(results[0].scores)
    for r in results:
        counts = counts + r.mask.astype(jnp.float32)
    mask = counts > 0
    return _maybe_topk(counts, mask, k)


def _maybe_topk(scores, mask, k):
    if k is None:
        return ResultSet(scores=scores, mask=mask)
    rs = topk_result(scores, k)
    return rs
