"""Sketch tier: per-table KMV / MinHash / row-sample sketches with error
bounds — the approximate discovery path behind ``Session.query(approx=...)``.

At millions-of-tables scale, exact probing of every segment is wasted work
for exploratory queries.  This module adds a fixed-size summary per table
that answers the seekers' questions *approximately*, with confidence
intervals, so the executor can rank top-k candidates from sketches and
escalate only the contended boundary of the ranking to the exact path
(Correlation Sketches' accuracy-for-latency contract).

Determinism contract
--------------------
A table's sketch is a **pure function of its posting arrays, the store seed
and the SketchConfig** — never of build order, table id, or segment layout:

* KMV / MinHash summarize the set of distinct ``cell_hash`` values of a
  column (order-free by construction);
* the row sample picks the ``samples`` rows with the smallest splitmix64
  key derived from the row's cell hashes and the seed (content-addressed
  bottom-k sampling — the same discipline as the index's per-(table name,
  column) ``rank_rand`` seeding: independent of build order);
* MinHash permutation parameters derive from the seed alone.

Therefore an L0 delta segment, a compaction merge, a snapshot reload and a
from-scratch rebuild all produce **bit-identical sketches** for the same
live table — the LiveLake parity suite extends to the sketch tier for free.

Estimators and their bounds
---------------------------
* **Containment (SC/KW)** — bottom-k KMV with *deterministically sound*
  bounds.  The sketch keeps the K smallest distinct hashes of a column;
  every distinct hash ``<= tau`` (the K-th smallest) is therefore retained,
  so membership of a query hash at or below tau is **exact**.  Writing
  ``matched`` for exact hits and ``n_above`` for query hashes above tau:
  ``lo = matched <= true <= matched + n_above = hi`` always holds, and the
  statistical CI (binomial extrapolation of the below-tau match rate) is
  clipped into ``[lo, hi]``.  A column with fewer than K distinct values is
  summarized losslessly — its interval is a point and the "estimate" is the
  exact engine score.
* **Correlation (C)** — the QCR agreement probability is estimated from the
  row sample joined against the query keys (a correlation-sketch estimate):
  binomial CI on ``p = P(quadrant agrees | row joins)`` transferred through
  ``|2p - 1|``.  These bounds hold *at the stated confidence*, not
  deterministically, so ``epsilon=0`` always escalates C to the exact path;
  the one sound fact used at ``epsilon=0`` is that a table whose join-side
  containment upper bound is zero cannot join at all (score exactly 0).
* **MC** has no sketch estimator — approx MC falls back to the exact path.
* ``kmv_union_size`` / ``minhash_jaccard`` are the classic distinct-union
  and Jaccard estimators over the same sketches (library surface, used by
  the statistical-coverage suite and the examples).

Probe execution
---------------
Probes run host-side over a **sorted sketch-posting view** (``SketchView``,
epoch-memoized like the device packs of the exact tier): all retained KMV
values of all columns are flattened into one sorted array with their
(table, col) owner, and a query is ``|Q| + matches`` binary searches plus
one scatter — the same shape as the exact probe, but over K-sized column
summaries instead of full posting lists, so probe cost is independent of
row count and proportional to sketch matches.  (A dense jitted formulation
— broadcast binary search over ``[tables, cols, K]`` — was tried first and
is gather-bound: XLA:CPU gathers cost ~10ns/lane, which at 100k columns is
hundreds of milliseconds for work the sorted view does in ~1ms.)  Probes
are dispatched per shard like exact probes and merged with one elementwise
sum — each table's slots are nonzero on exactly one shard, so 1-vs-N-shard
results are bit-identical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.hashing import MISSING

__all__ = [
    "SketchConfig", "TableSketch", "SketchView", "SketchProbeResult",
    "ApproxParams", "ApproxInfo", "sketch_tables", "build_view",
    "z_for", "kmv_union_size", "minhash_jaccard", "escalation_set",
]

DEFAULT_KMV_K = 128
DEFAULT_MINHASH_M = 32
DEFAULT_SAMPLES = 64

#: sample-side support floor mirroring the exact seekers' QCR min_support
SAMPLE_MIN_SUPPORT = 3


@dataclass(frozen=True)
class SketchConfig:
    """Sketch geometry.  Part of the index identity: two stores only produce
    bit-identical sketches under the same config (snapshot manifests carry
    it; ``from_dict`` restores it)."""
    k: int = DEFAULT_KMV_K            # KMV bottom-k size (power of two)
    minhash_m: int = DEFAULT_MINHASH_M
    samples: int = DEFAULT_SAMPLES    # row-sample size per table

    def as_dict(self) -> dict:
        return {"k": self.k, "minhash_m": self.minhash_m,
                "samples": self.samples}

    @classmethod
    def from_dict(cls, d) -> "SketchConfig":
        return cls(k=int(d["k"]), minhash_m=int(d["minhash_m"]),
                   samples=int(d["samples"]))


@dataclass(eq=False)
class TableSketch:
    """Fixed-size summary of one table (see module docstring)."""
    kmv: np.ndarray          # u32 [n_cols, K] sorted asc; MISSING pad
    kmv_m: np.ndarray        # i32 [n_cols] retained distinct count per col
    tbl_kmv: np.ndarray      # u32 [K] table-level KMV (distinct anywhere)
    tbl_m: int               # retained count of tbl_kmv
    minhash: np.ndarray      # u32 [n_cols, M]
    samp_rows: np.ndarray    # i32 [s] sampled row ids (key order)
    samp_hash: np.ndarray    # u32 [s, n_cols] cell hash at (row, col)
    samp_quad: np.ndarray    # i8  [s, n_cols] quadrant at (row, col)
    n_rows: int
    n_cols: int

    def nbytes(self) -> int:
        return (self.kmv.nbytes + self.kmv_m.nbytes + self.tbl_kmv.nbytes +
                self.minhash.nbytes + self.samp_rows.nbytes +
                self.samp_hash.nbytes + self.samp_quad.nbytes)


# --------------------------------------------------------------------------
# construction (host-side numpy; pure function of posting arrays + seed)
# --------------------------------------------------------------------------

_U64 = np.uint64


def _splitmix64(x: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):       # u64 wraparound is the point
        x = (x + _U64(0x9E3779B97F4A7C15))
        x = (x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)
        return x ^ (x >> _U64(31))


_MINHASH_PARAMS: dict = {}


def _minhash_params(seed: int, m: int):
    """Global (a, b) multiply-shift parameters, derived from the seed alone
    so every table of every segment uses the same permutations."""
    got = _MINHASH_PARAMS.get((seed, m))
    if got is None:
        rng = np.random.default_rng([seed, 0x6D696E68])     # 'minh'
        a = rng.integers(1, 2 ** 62, size=m, dtype=np.uint64) * _U64(2) \
            + _U64(1)                                        # odd multipliers
        b = rng.integers(0, 2 ** 62, size=m, dtype=np.uint64)
        got = _MINHASH_PARAMS[(seed, m)] = (a, b)
    return got


def _row_sample_keys(hashes2d: np.ndarray, seed: int) -> np.ndarray:
    """Content-addressed row keys: splitmix64 folded over the row's cell
    hashes.  Independent of table id and build order; ties (identical rows)
    break by row id in the caller's stable argsort."""
    nc, nr = hashes2d.shape
    acc = np.full(nr, _splitmix64(np.asarray(
        seed & 0xFFFFFFFFFFFFFFFF, np.uint64)), np.uint64)
    for c in range(nc):
        acc = _splitmix64(
            acc ^ (hashes2d[c].astype(np.uint64) +
                   _U64((0x9E3779B97F4A7C15 * (c + 1)) &
                        0xFFFFFFFFFFFFFFFF)))
    return acc


def sketch_tables(parts: dict, seed: int = 0,
                  config: SketchConfig | None = None) -> dict:
    """Per-table sketches from (unsorted OK) posting arrays.

    ``parts`` is a posting dict (``core.index.POSTING_KEYS`` layout); the
    arrays are canonically re-ordered by (table, col, row) internally, so
    the result is identical no matter which segment/merge order produced
    them.  Returns ``{global_table_id: TableSketch}`` — tables with no
    postings (zero columns) are absent, exactly as they are invisible to
    the exact seekers."""
    cfg = config or SketchConfig()
    K, M, S = cfg.k, cfg.minhash_m, cfg.samples
    ch, tid = np.asarray(parts["cell_hash"]), np.asarray(parts["table_id"])
    cid, rid = np.asarray(parts["col_id"]), np.asarray(parts["row_id"])
    quad = np.asarray(parts["quadrant"])
    out: dict = {}
    if not len(ch):
        return out
    order = np.lexsort((rid, cid, tid))
    ch, tid, cid, rid, quad = (a[order] for a in (ch, tid, cid, rid, quad))
    bounds = np.flatnonzero(np.diff(tid)) + 1
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [len(tid)]])
    a_mh, b_mh = _minhash_params(seed, M)
    for s0, s1 in zip(starts, ends):
        t = int(tid[s0])
        nc = int(cid[s1 - 1]) + 1
        nr = (s1 - s0) // nc
        # LiveLake invariant: a table's postings are complete per column
        # (every cell posted), so the canonical order is a dense grid
        hashes2d = ch[s0:s1].reshape(nc, nr)
        quads2d = quad[s0:s1].reshape(nc, nr)
        kmv = np.full((nc, K), MISSING, np.uint32)
        kmv_m = np.zeros(nc, np.int32)
        minhash = np.zeros((nc, M), np.uint32)
        for c in range(nc):
            u = np.unique(hashes2d[c])
            m = min(len(u), K)
            kmv[c, :m] = u[:m]
            kmv_m[c] = m
            perm = (a_mh[None, :] * u.astype(np.uint64)[:, None] + b_mh)
            minhash[c] = (perm.min(axis=0) >> _U64(32)).astype(np.uint32)
        ut = np.unique(hashes2d)
        tm = min(len(ut), K)
        tbl_kmv = np.full(K, MISSING, np.uint32)
        tbl_kmv[:tm] = ut[:tm]
        keys = _row_sample_keys(hashes2d, seed)
        sel = np.argsort(keys, kind="stable")[: min(S, nr)]
        out[t] = TableSketch(
            kmv=kmv, kmv_m=kmv_m, tbl_kmv=tbl_kmv, tbl_m=tm,
            minhash=minhash, samp_rows=sel.astype(np.int32),
            samp_hash=hashes2d[:, sel].T.copy(),
            samp_quad=quads2d[:, sel].T.copy(), n_rows=nr, n_cols=nc)
    return out


# --------------------------------------------------------------------------
# sorted sketch-posting view (executor-side, epoch-memoized by the caller)
# --------------------------------------------------------------------------

@dataclass(eq=False)
class SketchView:
    """Sketches flattened into sorted host-side posting arrays.

    Three mini posting lists mirror the exact index's layout, but over
    fixed-size summaries: column-level KMV values (SC), table-level KMV
    values (KW), and sampled cell hashes (C).  A probe binary-searches the
    query hashes into the sorted array and scatter-counts the matched
    owners, so probe cost scales with matches — not tables x cols x K.

    Dead/absent table slots simply have no postings and a ``tau`` of
    MISSING (everything counts as "below tau", ``n_above == 0``), so every
    bound degenerates to the exact score 0 and per-shard views sum exactly.
    """
    # column-level KMV postings: slot = t * max_cols + c
    col_hash: np.ndarray        # u32 [Nc] sorted retained values
    col_owner: np.ndarray       # i32 [Nc]
    col_tau_order: np.ndarray   # i64 [T * max_cols] argsort of tau
    col_tau_sorted: np.ndarray  # u32 [T * max_cols]
    # table-level KMV postings: slot = t
    tbl_hash: np.ndarray        # u32 [Nt] sorted
    tbl_owner: np.ndarray       # i32 [Nt]
    tbl_tau_order: np.ndarray   # i64 [T]
    tbl_tau_sorted: np.ndarray  # u32 [T]
    # row-sample postings: every sampled cell, sorted by hash
    samp_hash: np.ndarray       # u32 [Ns] sorted
    samp_tbl: np.ndarray        # i32 [Ns]
    samp_row: np.ndarray        # i32 [Ns] sample-slot index (not row id)
    samp_col: np.ndarray        # i32 [Ns]
    samp_quad: np.ndarray       # i8  [T, S, max_cols]; -1 pads
    config: SketchConfig
    n_tables: int
    max_cols: int

    # ---------------------------------------------------------- containment
    def containment(self, qh: np.ndarray, z: float, level: str = "col"):
        """Bottom-k containment bounds per table, maxed over columns
        (``level="col"``, the SC score shape) or against the table-level
        KMV (``level="tbl"``, KW).  ``qh`` must be sorted distinct u32
        (``np.unique`` output) — the exact seekers are COUNT(DISTINCT), so
        distinct-counting *is* the exact semantics.  Returns five f32
        [n_tables] arrays ``(bound_lo, bound_hi, est, ci_lo, ci_hi)`` with
        ``bound_lo <= exact <= bound_hi`` deterministic and ``[ci_lo,
        ci_hi]`` the Wilson interval at the confidence behind ``z``."""
        if level == "col":
            hash_s, owner = self.col_hash, self.col_owner
            tau_order, tau_sorted = self.col_tau_order, self.col_tau_sorted
            n_slots, ncols = self.n_tables * self.max_cols, self.max_cols
        else:
            hash_s, owner = self.tbl_hash, self.tbl_owner
            tau_order, tau_sorted = self.tbl_tau_order, self.tbl_tau_sorted
            n_slots, ncols = self.n_tables, 1
        matched = _match_counts(hash_s, owner, n_slots, qh)
        m_below = _count_below(tau_order, tau_sorted, qh)
        outs = _containment_bounds(matched, m_below, float(len(qh)), z)
        return tuple(a.reshape(self.n_tables, ncols).max(axis=1)
                     for a in outs)

    # ---------------------------------------------------------- correlation
    def correlation(self, qh: np.ndarray, qbit: np.ndarray, z: float,
                    min_support: int):
        """Row-sample QCR estimate per table: binomial CI on the agreement
        probability over sampled joined rows, transferred through |2p - 1|
        and maxed over (join col, numeric col) pairs.  ``qh`` sorted
        distinct u32, ``qbit`` the query-side quadrant bit per hash.
        Returns f32 [n_tables] ``(est, ci_lo, ci_hi, support)`` with
        support = best pair's sampled join count (0 => no estimate)."""
        T, C = self.n_tables, self.max_cols
        lo = np.searchsorted(self.samp_hash, qh, side="left")
        hi = np.searchsorted(self.samp_hash, qh, side="right")
        counts = hi - lo
        zero = tuple(np.zeros(T, np.float32) for _ in range(4))
        if not counts.sum():
            return zero
        pos = np.concatenate([np.arange(l, h)
                              for l, h in zip(lo, hi) if h > l])
        qb_m = np.repeat(qbit, counts)
        t_m, s_m = self.samp_tbl[pos], self.samp_row[pos]
        c_m = self.samp_col[pos]
        quad_rows = self.samp_quad[t_m, s_m]           # [M, C]
        isnum = quad_rows >= 0
        agree = isnum & (quad_rows == qb_m[:, None])
        base = (t_m.astype(np.int64) * C + c_m) * C
        cell = (base[:, None] + np.arange(C, dtype=np.int64)[None, :])
        cell = cell.reshape(-1)
        n_all_flat = np.bincount(cell, weights=isnum.reshape(-1),
                                 minlength=T * C * C)
        n_agree_flat = np.bincount(cell, weights=agree.reshape(-1),
                                   minlength=T * C * C)
        # the Wilson math and the per-table max only touch the (join col,
        # num col) pairs that actually have enough sampled joins — a tiny
        # subset of the dense [T, C, C] grid
        ok = np.flatnonzero(n_all_flat >= min_support)
        if not ok.size:
            return zero
        n_all = n_all_flat[ok]
        p = n_agree_flat[ok] / n_all
        est_pair = np.abs(2.0 * p - 1.0)
        # Wilson score interval on the agreement rate (Wald under-covers at
        # the small sampled-join counts min_support admits) + 0.5/n
        # continuity, transferred through |2p - 1|
        z2 = z * z
        dw = 1.0 + z2 / n_all
        center = (p + z2 / (2.0 * n_all)) / dw
        se_w = np.sqrt(p * (1.0 - p) / n_all
                       + z2 / (4.0 * n_all * n_all)) / dw
        half_p = z * se_w + 0.5 / n_all
        pl = np.clip(np.minimum(center - half_p, p), 0.0, 1.0)
        ph = np.clip(np.maximum(center + half_p, p), 0.0, 1.0)
        el = np.abs(2.0 * pl - 1.0)
        eh = np.abs(2.0 * ph - 1.0)
        spans_half = (pl <= 0.5) & (ph >= 0.5)
        lo_pair = np.where(spans_half, 0.0, np.minimum(el, eh))
        hi_pair = np.maximum(el, eh)
        t_ok = (ok // (C * C)).astype(np.int64)
        out = []
        for vals in (est_pair, lo_pair, hi_pair, n_all):
            acc = np.zeros(T, np.float64)
            np.maximum.at(acc, t_ok, vals)
            out.append(acc.astype(np.float32))
        return tuple(out)


def build_view(sketches: dict, n_tables: int, max_cols: int,
               config: SketchConfig, alive=None) -> SketchView:
    """Flatten per-table sketches into the sorted posting view.  ``alive``
    masks out tombstoned tables (their segment sketches still exist but
    must not answer queries).  O(total sketch cells log) — paid once per
    index epoch, like the exact tier's device pack."""
    K, S = config.k, config.samples
    col_tau = np.full(n_tables * max_cols, MISSING, np.uint32)
    tbl_tau = np.full(n_tables, MISSING, np.uint32)
    samp_quad = np.full((n_tables, S, max_cols), -1, np.int8)
    col_h, col_o = [], []
    tbl_h, tbl_o = [], []
    sm_h, sm_t, sm_s, sm_c = [], [], [], []
    for t, sk in sketches.items():
        if t >= n_tables or (alive is not None and not alive[t]):
            continue
        nc = min(sk.n_cols, max_cols)
        for c in range(nc):
            m = int(sk.kmv_m[c])
            col_h.append(sk.kmv[c, :m])
            col_o.append(np.full(m, t * max_cols + c, np.int32))
            if m == K:                    # saturated: tau = K-th smallest
                col_tau[t * max_cols + c] = sk.kmv[c, K - 1]
        tbl_h.append(sk.tbl_kmv[:sk.tbl_m])
        tbl_o.append(np.full(sk.tbl_m, t, np.int32))
        if sk.tbl_m == K:
            tbl_tau[t] = sk.tbl_kmv[K - 1]
        s = len(sk.samp_rows)
        samp_quad[t, :s, :nc] = sk.samp_quad[:, :nc]
        sh = sk.samp_hash[:, :nc]                       # [s, nc]
        sm_h.append(sh.reshape(-1))
        sm_t.append(np.full(s * nc, t, np.int32))
        sm_s.append(np.repeat(np.arange(s, dtype=np.int32), nc))
        sm_c.append(np.tile(np.arange(nc, dtype=np.int32), s))

    def _sorted(hs, os):
        h = (np.concatenate(hs) if hs else np.empty(0, np.uint32))
        o = (np.concatenate(os) if os else np.empty(0, np.int32))
        order = np.argsort(h, kind="stable")
        return h[order], o[order]

    col_hash, col_owner = _sorted(col_h, col_o)
    tbl_hash, tbl_owner = _sorted(tbl_h, tbl_o)
    s_hash = (np.concatenate(sm_h) if sm_h else np.empty(0, np.uint32))
    s_order = np.argsort(s_hash, kind="stable")
    cat = lambda xs: (np.concatenate(xs) if xs       # noqa: E731
                      else np.empty(0, np.int32))
    col_tau_order = np.argsort(col_tau, kind="stable")
    tbl_tau_order = np.argsort(tbl_tau, kind="stable")
    return SketchView(
        col_hash=col_hash, col_owner=col_owner,
        col_tau_order=col_tau_order, col_tau_sorted=col_tau[col_tau_order],
        tbl_hash=tbl_hash, tbl_owner=tbl_owner,
        tbl_tau_order=tbl_tau_order, tbl_tau_sorted=tbl_tau[tbl_tau_order],
        samp_hash=s_hash[s_order], samp_tbl=cat(sm_t)[s_order],
        samp_row=cat(sm_s)[s_order], samp_col=cat(sm_c)[s_order],
        samp_quad=samp_quad, config=config, n_tables=n_tables,
        max_cols=max_cols)


# --------------------------------------------------------------------------
# normal quantile (no scipy): Acklam's rational approximation of Phi^-1
# --------------------------------------------------------------------------

_ACK_A = (-3.969683028665376e+01, 2.209460984245205e+02,
          -2.759285104469687e+02, 1.383577518672690e+02,
          -3.066479806614716e+01, 2.506628277459239e+00)
_ACK_B = (-5.447609879822406e+01, 1.615858368580409e+02,
          -1.556989798598866e+02, 6.680131188771972e+01,
          -1.328068155288572e+01)
_ACK_C = (-7.784894002430293e-03, -3.223964580411365e-01,
          -2.400758277161838e+00, -2.549732539343734e+00,
          4.374664141464968e+00, 2.938163982698783e+00)
_ACK_D = (7.784695709041462e-03, 3.224671290700398e-01,
          2.445134137142996e+00, 3.754408661907416e+00)


def _norm_ppf(p: float) -> float:
    if not 0.0 < p < 1.0:
        raise ValueError(f"quantile {p} outside (0, 1)")
    a, b, c, d = _ACK_A, _ACK_B, _ACK_C, _ACK_D
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q
                + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        return -_norm_ppf(1 - p)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r
            + a[5]) * q / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                            + b[4]) * r + 1)


def z_for(confidence: float, comparisons: int = 1) -> float:
    """Two-sided normal critical value at ``confidence``, Bonferroni-split
    over ``comparisons`` simultaneous intervals (a table score is a max over
    columns / column pairs, so its per-component intervals must hold
    jointly)."""
    if not 0.0 < confidence < 1.0:
        raise ValueError(f"confidence {confidence} outside (0, 1)")
    alpha = (1.0 - confidence) / max(comparisons, 1)
    return _norm_ppf(1.0 - alpha / 2.0)


# --------------------------------------------------------------------------
# host probe primitives (binary search + scatter over the sorted view)
# --------------------------------------------------------------------------

def _match_counts(hash_sorted: np.ndarray, owner: np.ndarray, n_slots: int,
                  qh: np.ndarray) -> np.ndarray:
    """matched[slot] = |Q ∩ retained(slot)| for sorted distinct ``qh``:
    2|Q| binary searches into the posting array, then one bincount over the
    matched owners — O(|Q| log N + matches)."""
    lo = np.searchsorted(hash_sorted, qh, side="left")
    hi = np.searchsorted(hash_sorted, qh, side="right")
    if not (hi - lo).sum():
        return np.zeros(n_slots, np.float64)
    pos = np.concatenate([np.arange(l, h) for l, h in zip(lo, hi) if h > l])
    return np.bincount(owner[pos], minlength=n_slots).astype(np.float64)


def _count_below(tau_order: np.ndarray, tau_sorted: np.ndarray,
                 qh: np.ndarray) -> np.ndarray:
    """m_below[slot] = |{q in Q : q <= tau[slot]}| for every slot at once
    without a per-slot search: bucket the |Q| query hashes into the sorted
    tau array, histogram, cumulative-sum, unsort — O(|Q| log S + S)."""
    S = tau_sorted.shape[0]
    p = np.searchsorted(tau_sorted, qh, side="left")
    below_sorted = np.cumsum(np.bincount(p, minlength=S + 1)[:S])
    m_below = np.empty(S, np.float64)
    m_below[tau_order] = below_sorted
    return m_below


def _containment_bounds(matched: np.ndarray, m_below: np.ndarray,
                        nq_real: float, z: float):
    """Per-slot containment bounds from the sound match/below-tau counts.

    Returns (bound_lo, bound_hi, est, ci_lo, ci_hi) f32 arrays:
    ``bound_lo = matched <= true <= matched + n_above = bound_hi``
    deterministically; ``[ci_lo, ci_hi]`` is the binomial-extrapolation
    interval clipped into those sound bounds.  A slot whose sketch is
    lossless (``n_above == 0``) has the point interval [matched, matched];
    the Wilson math only runs on the saturated subset, which keeps the
    probe cheap when most columns fit inside K."""
    n_above_all = nq_real - m_below
    lo32 = matched.astype(np.float32)
    hi32 = (matched + n_above_all).astype(np.float32)
    est32, ci_lo32, ci_hi32 = lo32.copy(), lo32.copy(), lo32.copy()
    sat = np.flatnonzero(n_above_all > 0)
    if sat.size:
        m, n_above = matched[sat], n_above_all[sat]
        denom = np.maximum(m_below[sat], 1.0)
        p = m / denom
        est = m + p * n_above
        # Wilson score interval on the below-tau containment rate (the
        # plain Wald interval under-covers badly at the m_below ~ tens this
        # regime produces), plus the binomial realization noise of the
        # above-tau count itself — the truth fluctuates around p * n_above
        # even at known p
        z2 = z * z
        dw = 1.0 + z2 / denom
        center = (p + z2 / (2.0 * denom)) / dw
        se_w = np.sqrt(p * (1.0 - p) / denom
                       + z2 / (4.0 * denom * denom)) / dw
        half = z * np.sqrt(se_w * se_w * n_above * n_above
                           + center * (1.0 - center) * n_above) + 1.0
        mid = m + center * n_above
        est32[sat] = est.astype(np.float32)
        ci_lo32[sat] = np.clip(np.minimum(mid - half, est),
                               m, m + n_above).astype(np.float32)
        ci_hi32[sat] = np.clip(np.maximum(mid + half, est),
                               m, m + n_above).astype(np.float32)
    return lo32, hi32, est32, ci_lo32, ci_hi32


# --------------------------------------------------------------------------
# library estimators over raw sketches (coverage suite / examples)
# --------------------------------------------------------------------------

def kmv_union_size(kmv_a: np.ndarray, m_a: int, kmv_b: np.ndarray, m_b: int,
                   k: int, confidence: float = 0.95):
    """Distinct-count estimate of the union of two sketched value sets.

    Merging two bottom-k KMV sketches yields the bottom-k sketch of the
    union; if both inputs retained every distinct hash the union size is
    exact (zero-width interval), otherwise the classic (K-1)/tau estimator
    with relative standard error ~ 1/sqrt(K-2) at the stated confidence.
    Returns ``(est, ci_lo, ci_hi)``."""
    merged = np.unique(np.concatenate([kmv_a[:m_a], kmv_b[:m_b]]))
    exact = m_a < k and m_b < k        # both sides losslessly summarized
    n_seen = len(merged)
    if exact or n_seen < k:
        return float(n_seen), float(n_seen), float(n_seen)
    tau = float(merged[k - 1]) + 1.0
    est = (k - 1) / (tau / 2.0 ** 32)
    rel = z_for(confidence) / math.sqrt(max(k - 2, 1))
    lo = max(float(n_seen), est * (1.0 - rel))
    return est, lo, est * (1.0 + rel) + 1.0


def minhash_jaccard(sig_a: np.ndarray, sig_b: np.ndarray,
                    confidence: float = 0.95):
    """Jaccard similarity from MinHash signatures: collision-rate estimate
    with a binomial CI over the M independent permutations.  Returns
    ``(est, ci_lo, ci_hi)``."""
    sig_a, sig_b = np.asarray(sig_a), np.asarray(sig_b)
    m = len(sig_a)
    p = float(np.mean(sig_a == sig_b))
    half = z_for(confidence) * math.sqrt(p * (1.0 - p) / m) + 0.5 / m
    return p, max(0.0, p - half), min(1.0, p + half)


# --------------------------------------------------------------------------
# approx query surface: params, probe result, escalation rule
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ApproxParams:
    """The epsilon/confidence contract of ``Session.query(approx=...)``.

    * ``epsilon`` — ranking tolerance.  A top-k contender whose interval is
      wider than epsilon (relative to its upper bound for the count-valued
      SC/KW estimators, absolute for the [0,1]-valued correlation score)
      escalates to the exact path.  ``epsilon=0`` therefore returns ids
      bit-identical to the exact path.
    * ``confidence`` — nominal coverage of the reported per-hit intervals
      (and, for C, of the escalation bounds themselves)."""
    epsilon: float = 0.05
    confidence: float = 0.95

    def key(self) -> tuple:
        return (round(float(self.epsilon), 12),
                round(float(self.confidence), 12))

    @classmethod
    def of(cls, approx) -> "ApproxParams | None":
        """Normalize the ``approx=`` argument: False/None -> None, True ->
        defaults, a dict/ApproxParams -> explicit parameters."""
        if approx is None or approx is False:
            return None
        if approx is True:
            return cls()
        if isinstance(approx, cls):
            return approx
        if isinstance(approx, dict):
            unknown = set(approx) - {"epsilon", "confidence"}
            if unknown:
                raise ValueError(f"unknown approx parameters {sorted(unknown)}"
                                 f" (expected epsilon/confidence)")
            return cls(epsilon=float(approx.get("epsilon", 0.05)),
                       confidence=float(approx.get("confidence", 0.95)))
        raise TypeError(f"approx must be bool/dict/ApproxParams, "
                        f"got {type(approx)!r}")


@dataclass
class SketchProbeResult:
    """Host-side per-table estimates of one seeker's scores.

    ``bound_lo <= exact <= bound_hi`` holds deterministically for SC/KW and
    at the stated confidence for C (``sound=False``); ``[ci_lo, ci_hi]`` is
    the reported interval at the stated confidence."""
    kind: str
    estimator: str
    est: np.ndarray          # f32 [n_tables]
    bound_lo: np.ndarray
    bound_hi: np.ndarray
    ci_lo: np.ndarray
    ci_hi: np.ndarray
    sound: bool
    seconds: float = 0.0
    launches: int = 0        # device-program dispatches (0: host-side probe)
    #: C only: sound join-impossibility mask (containment upper bound == 0)
    impossible: np.ndarray | None = None


@dataclass
class ApproxInfo:
    """What the approximate path did for one query (``QueryResult.approx``,
    surfaced through ``DiscoveryResponse.approx``)."""
    params: ApproxParams
    kind: str
    estimator: str
    escalated: int            # tables resolved on the exact path
    candidates: int           # tables whose upper bound reached the top-k bar
    threshold: float          # the k-th largest lower bound
    est: np.ndarray = field(repr=False, default=None)
    ci_lo: np.ndarray = field(repr=False, default=None)
    ci_hi: np.ndarray = field(repr=False, default=None)
    escalated_ids: list = field(default_factory=list)
    fallback: str | None = None    # why the exact path ran wholesale
    probe_seconds: float = 0.0

    def interval(self, table_id: int) -> tuple:
        """(estimate, ci_lo, ci_hi) for one table id."""
        t = int(table_id)
        return (float(self.est[t]), float(self.ci_lo[t]),
                float(self.ci_hi[t]))

    def as_dict(self, ids=None) -> dict:
        d = {"epsilon": self.params.epsilon,
             "confidence": self.params.confidence, "kind": self.kind,
             "estimator": self.estimator, "escalated": self.escalated,
             "candidates": self.candidates, "threshold": self.threshold,
             "fallback": self.fallback,
             "probe_seconds": self.probe_seconds}
        if ids is not None and self.est is not None:
            d["estimates"] = {int(t): {"est": float(self.est[int(t)]),
                                       "ci_lo": float(self.ci_lo[int(t)]),
                                       "ci_hi": float(self.ci_hi[int(t)])}
                              for t in ids}
        return d


def escalation_set(probe: SketchProbeResult, k: int,
                   params: ApproxParams) -> tuple:
    """The contended boundary of the ranking: table ids to resolve exactly.

    ``T`` = k-th largest lower bound.  A table escalates iff its upper
    bound reaches ``T`` (it could displace the provisional top-k) AND its
    interval is wider than epsilon.  With ``epsilon > 0`` the contract is
    statistical, so the bounds are the confidence intervals; with
    ``epsilon=0`` the deterministic bounds take over (for SC/KW the sound
    sandwich, for C the sound [0, possible] envelope) and every
    non-degenerate contender escalates, which makes the final ids
    bit-identical to the exact path (non-contenders are provably below the
    bar; degenerate intervals ARE the exact score).  Returns
    ``(escalate_ids, candidates, threshold)``."""
    eps = float(params.epsilon)
    if eps > 0:
        lo, hi = probe.ci_lo, probe.ci_hi
    elif probe.sound:
        lo, hi = probe.bound_lo, probe.bound_hi
    else:
        lo = np.zeros_like(probe.bound_lo)
        hi = np.where(probe.impossible, 0.0, 1.0).astype(np.float32)
    n = len(lo)
    kk = min(max(k, 1), n)
    thresh = float(np.partition(lo, n - kk)[n - kk])
    width = hi - lo
    if probe.kind == "C":
        wide = width > eps                       # absolute: QCR lives in [0,1]
    else:
        wide = width > eps * np.maximum(hi, 1.0)  # relative: count-valued
    esc = (hi >= thresh) & (hi > 0) & wide
    cand = int(np.count_nonzero((hi >= thresh) & (hi > 0)))
    return np.flatnonzero(esc), cand, thresh
