"""Fused plan execution: batched same-kind seeker dispatch + whole-DAG
device compilation.

The unfused executor pays one device program per seeker node (two for the
compaction stages) plus a Python re-entry between every combiner — on deep
discovery DAGs launch overhead, not probe work, dominates warm-path latency.
The fused path collapses a plan (or a whole ``serve_many`` batch) to
``n_kinds + 1`` launches:

1. **Batched seeker dispatch** — all same-kind seekers, across every plan in
   the batch, are concatenated into one padded query array with per-row
   seeker ids and per-row (ladder-quantized) capacities, probed once through
   ``MatchEngine.probe_capped`` and grouped-by into a stacked
   ``[n_seekers, n_tables]`` score matrix (seekers.py ``*_seeker_seg``).
   Capacity lookups batch into ONE ``host_counts`` call over every seeker's
   hashes.
2. **Whole-DAG device compilation** — the post-seeker combiner DAG
   (top-k / intersect / union / difference / counter / optimizer mask
   threading) is elementwise over ``[n_tables]`` vectors, so the entire DAG
   lowers to one jitted program keyed on the (static, hashable) instruction
   list derived from the plan topology.  Zero intermediate host syncs.

Bit-identity with the unfused executor rests on two invariants:

* per-seeker probe windows under ``probe_capped`` hold exactly the postings
  a dedicated launch at that seeker's capacity would hold, and every seeker
  score is a sum / max of 0-or-1 float contributions (or a QCR ratio of such
  sums), so the stacked rows equal the dedicated launches bit-for-bit;
* a seeker run under the optimizer's threaded ``allowed`` mask equals
  ``where(allowed, unrestricted_scores, 0)`` followed by the same top-k —
  the mask is constant per table and is ANDed into contributions *before* a
  per-table group-by — so mask threading moves into the DAG program, where
  the masks live on device, and the batched seekers all run unrestricted.

Query-cache composition: seekers served from the subplan cache drop out of
the batch entirely — their cached (scores, mask) vectors are fed to the DAG
program as extra inputs.  As in the unfused path, only unrestricted runs are
served from or stored into the cache, so partial hits stay bit-identical to
a cold run.

Retrace-freedom: the batch query width, the tuple-block width, the seeker
count and the shared capacity window are all quantized onto power-of-two /
capacity ladders, and the DAG program is keyed on plan topology — re-running
any plan shape with new values of the same buckets is zero-trace
(``seekers.TRACE_COUNTS``-asserted in tests/test_fused.py).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs
from repro.core import seekers as seek
from repro.core.combiners import ResultSet
from repro.obs import trace as otrace
from repro.core.executor import (ExecInfo, OverflowSlice, PAD_SENTINEL,
                                 _pow2_at_least)
from repro.core.hashing import row_superkey, split_u64
from repro.core.optimizer import optimize as optimize_plan


@dataclass
class _Task:
    """One pending (unrestricted) seeker dispatch in the fused batch."""
    plan_idx: int
    name: str
    spec: object
    instr_idx: int                    # its placeholder slot in the plan prog
    # hashed query payload (filled by _hash_tasks)
    h: np.ndarray | None = None      # SC/KW/C: hashed values
    qbit: np.ndarray | None = None   # C: k0/k1 split bits
    th: np.ndarray | None = None     # MC: [nt, n_cols] hashed tuples
    init_col: np.ndarray | None = None
    qk_lo: np.ndarray | None = None
    qk_hi: np.ndarray | None = None
    nt: int = 0                      # MC: deduped tuple count
    m_cap: int = 0                   # this seeker's capacity-ladder rung
    #: sharded lakes: per-shard capacity rungs from per-shard counts — a
    #: shard probes only its own postings, so its window can be (much)
    #: smaller than the global rung; exact as long as no shard overflows
    shard_caps: tuple = ()
    group_key: tuple = ()
    row: int = -1                    # row in the group's stacked output
    head: object = None              # canonical task for this spec: dupes
    #                                  share its hashes, batch row and scores


@dataclass
class _PlanProg:
    """A plan compiled to a linear DAG program + its pending seeker batch."""
    instrs: list = field(default_factory=list)
    order: list = field(default_factory=list)        # ExecInfo.order parity
    tasks: list = field(default_factory=list)        # _Task, traversal order
    cached: list = field(default_factory=list)       # CachedSeeker hits
    cached_names: list = field(default_factory=list)
    cache_puts: list = field(default_factory=list)   # (key, reg, task)
    out_reg: int = 0


def _group_key(spec) -> tuple:
    """Seekers sharing a key share one device program: the kind plus every
    per-seeker *static* argument of its segmented kernel."""
    if spec.kind == "MC":
        return ("MC", spec.n_cols)
    if spec.kind == "C":
        return ("C", spec.h, spec.sampling)
    return (spec.kind,)


# --------------------------------------------------------------------------
# plan -> linear DAG program (mirrors Executor._run's traversal exactly,
# including the memoization, EG mask threading, the difference-subtrahend
# rewrite and the subplan-cache consultation order)
# --------------------------------------------------------------------------

def _compile_plan(plan, optimize, ep, cache, plan_idx) -> _PlanProg:
    pr = _PlanProg()
    reg_of: dict[str, int] = {}

    def emit(ins) -> int:
        pr.instrs.append(ins)
        return len(pr.instrs) - 1

    def seeker_node(name, spec, allowed_reg) -> int:
        # mirrors timed_seeker: cache serves/stores unrestricted runs only
        key = cache.seeker_key(spec) \
            if cache is not None and allowed_reg is None else None
        if key is not None:
            hit = cache.get_seeker(key)
            if hit is not None:
                reg = emit(("cached", len(pr.cached)))
                pr.cached.append(hit)
                pr.cached_names.append(name)
                pr.order.append(name)
                return reg
        task = _Task(plan_idx=plan_idx, name=name, spec=spec,
                     instr_idx=len(pr.instrs))
        # the task's ordinal within the plan is stable across batch
        # compositions; its batch row is resolved through the traced
        # ``rows`` vector at run time, so reshuffled batches reuse the
        # compiled DAG program
        reg = emit(("seeker", None, len(pr.tasks), spec.k,
                    -1 if allowed_reg is None else allowed_reg))
        pr.tasks.append(task)
        if key is not None:
            pr.cache_puts.append((key, reg, task))
        pr.order.append(name)
        return reg

    def run_group(eg, combiner_node) -> int:
        results = []
        allowed = None
        for sname in eg.seekers:
            if sname in reg_of:
                r = reg_of[sname]
            else:
                exclusive = len(plan.consumers(sname)) == 1
                r = seeker_node(sname, plan.nodes[sname].spec,
                                allowed if exclusive else None)
                reg_of[sname] = r
            results.append(r)
            allowed = r if allowed is None else emit(("maskand", allowed, r))
        for dep in combiner_node.deps:
            if dep not in eg.seekers:
                results.append(eval_node(dep))
        reg = emit(("intersect", tuple(results), combiner_node.spec.k))
        pr.order.append(combiner_node.name)
        return reg

    def eval_node(name: str) -> int:
        if name in reg_of:
            return reg_of[name]
        node = plan.nodes[name]
        if node.is_seeker:
            reg = seeker_node(name, node.spec, None)
        else:
            kind = node.spec.kind
            k = node.spec.k
            if optimize and ep is not None and name in ep.groups:
                reg = run_group(ep.groups[name], node)
            elif kind == "difference":
                a = eval_node(node.deps[0])
                b_node = plan.nodes[node.deps[1]]
                if optimize and b_node.is_seeker and \
                        len(plan.consumers(b_node.name)) == 1 and \
                        b_node.name not in reg_of:
                    b = seeker_node(b_node.name, b_node.spec, a)
                    reg_of[b_node.name] = b
                else:
                    b = eval_node(node.deps[1])
                reg = emit(("difference", a, b, k))
                pr.order.append(name)
            else:
                deps = tuple(eval_node(d) for d in node.deps)
                reg = emit((kind, deps, k))
                pr.order.append(name)
        reg_of[name] = reg
        return reg

    pr.out_reg = eval_node(plan.output)
    return pr


# --------------------------------------------------------------------------
# batched hashing + ONE host_counts call for every capacity pick
# --------------------------------------------------------------------------

def _hash_tasks(ex, tasks):
    """Hash every pending seeker's query values (through the executor's
    memoized value-hash cache) and pick every capacity from one batched
    ``host_counts`` lookup over the concatenated hash arrays."""
    reqs = []
    for t in tasks:
        spec = t.spec
        if spec.kind in ("SC", "KW"):
            t.h = ex._hashed(spec.values)
            reqs.append(t.h)
        elif spec.kind == "C":
            pairs = list(dict.fromkeys(zip(spec.values, spec.target)))
            t.h = ex._hash_many([p[0] for p in pairs])
            tgt = np.array([float(p[1]) for p in pairs])
            t.qbit = (tgt >= tgt.mean()).astype(np.int8) if len(tgt) \
                else np.zeros(0, np.int8)
            reqs.append(t.h)
        else:                                       # MC
            values = list(dict.fromkeys(spec.values))
            t.nt = len(values)
            n_cols = spec.n_cols
            t.th = np.stack([ex._hash_many([v[c] for v in values])
                             for c in range(n_cols)], axis=1) if values \
                else np.zeros((0, n_cols), np.uint32)
            qks = np.array([row_superkey(t.th[i], np.zeros(n_cols, np.int64))
                            for i in range(t.nt)], np.uint64)
            t.qk_lo, t.qk_hi = split_u64(qks)
            reqs.append(t.th.reshape(-1))
    if not tasks:
        return
    lens = np.array([len(r) for r in reqs], np.int64)
    offs = np.concatenate([[0], np.cumsum(lens)])
    all_h = np.concatenate(reqs) if offs[-1] else np.zeros(0, np.uint32)
    n_shards = getattr(ex, "n_shards", 0)
    if n_shards:
        # per-shard counts in the same ONE batched lookup: global capacities
        # (and the MC initiator-column pick) come from the summed counts —
        # identical to a 1-shard run — while each shard's probe window sizes
        # to its own counts (a shard only holds its own tables' postings)
        per = ex.index.host_counts(all_h, per_shard=True)
        counts = per.sum(axis=0)
    else:
        counts = ex.index.host_counts(all_h)
    for i, t in enumerate(tasks):
        c = counts[offs[i]:offs[i + 1]]
        if t.spec.kind == "MC":
            cm = c.reshape(t.nt, t.spec.n_cols) if t.nt \
                else np.zeros((0, t.spec.n_cols), np.int64)
            t.init_col = np.argmin(cm, axis=1).astype(np.int32) if t.nt \
                else np.zeros(0, np.int32)
            t.m_cap = ex._quantize_cap(int(cm.max(initial=1)))
        else:
            t.m_cap = ex._quantize_cap(int(c.max(initial=1)))
        if n_shards:
            t.shard_caps = tuple(
                ex._quantize_cap(int(per[s, offs[i]:offs[i + 1]]
                                     .max(initial=1)))
                for s in range(n_shards))


# --------------------------------------------------------------------------
# group batch assembly + launch
# --------------------------------------------------------------------------

def _pow2(n: int, lo: int) -> int:
    return _pow2_at_least(max(n, 1), lo=lo, hi=1 << 30)


def _launch_group(ex, key, tasks, failed=None):
    """Dispatch one seeker group as a single device program.  Returns
    (scores [n_seekers_p, n_tables], overflow [n_seekers_p]) — both lazy.
    ``tasks`` are the deduped head tasks of the group (run_fused collapses
    identical specs before hashing).

    Sharded executors (``ex.engines``) dispatch the same batched program
    once per shard — same query operands, per-shard capacity windows — and
    return *tuples* of per-shard (scores, overflow).  Each shard holds
    whole tables, so summing the per-shard matrices (inside ``_run_dag``)
    is exact: every table slot is nonzero on exactly one shard.  The whole
    per-shard fan-out is ONE logical launch (ExecInfo.launches).

    Graceful degradation: a shard probe that raises is retried once on a
    freshly rebuilt shard engine (``ex.reset_shard``); a second failure
    drops the shard from this launch — its (scores, overflow) are
    zero-substituted, which the exact merge treats as "no tables here" —
    and its index lands in ``failed`` so the response is flagged degraded
    rather than silently partial."""
    for i, t in enumerate(tasks):
        t.row = i
    kind = key[0]
    # lo=8: a serving batch's per-kind seeker count varies with every batch
    # composition; padding the stacked output to at least 8 rows collapses
    # nsp (and with it the DAG program's group-matrix input shapes) onto a
    # couple of buckets, so reshuffled batches stop retracing.  The padding
    # itself is dead rows in a [nsp, n_tables] matrix — negligible next to
    # the probe work, and single-plan latency is unaffected (measured).
    nsp = _pow2(len(tasks), lo=8)
    spans = []

    def fill_caps(caps, shard):
        m_cap = 1
        for (off, n), t in zip(spans, tasks):
            c = t.m_cap if shard is None else t.shard_caps[shard]
            caps[off:off + n] = c
            m_cap = max(m_cap, c)
        return m_cap

    if kind == "MC":
        n_cols = key[1]
        total = sum(t.nt for t in tasks)
        width = _pow2(total, lo=8)
        th = np.zeros((width, n_cols), np.uint32)
        init = np.zeros(width, np.int32)
        qlo = np.zeros(width, np.uint32)
        qhi = np.zeros(width, np.uint32)
        seg = np.zeros(width, np.int32)
        tmask = np.zeros(width, bool)
        off = 0
        for i, t in enumerate(tasks):
            n = t.nt
            th[off:off + n] = t.th
            init[off:off + n] = t.init_col
            qlo[off:off + n] = t.qk_lo
            qhi[off:off + n] = t.qk_hi
            seg[off:off + n] = i
            tmask[off:off + n] = True
            spans.append((off, n))
            off += n

        # numpy operands go straight into the jitted call: jit's own
        # device_put of the whole operand list is much cheaper than
        # per-array jnp.asarray round-trips on the hot path (and, being
        # uncommitted, they follow each shard engine to its device)
        def dispatch(eng, caps, m_cap):
            return seek.mc_seeker_seg(
                eng, th, init, qlo, qhi, seg, caps,
                m_cap=m_cap, n_seekers=nsp, n_tables=ex.n_tables,
                n_cols=n_cols, row_stride=ex.index.row_stride,
                tuple_mask=tmask)
    else:
        total = sum(len(t.h) for t in tasks)
        width = _pow2(total, lo=16)
        qh = np.full(width, PAD_SENTINEL, np.uint32)
        qm = np.zeros(width, bool)
        seg = np.zeros(width, np.int32)
        qb = np.zeros(width, np.int8)
        off = 0
        for i, t in enumerate(tasks):
            n = len(t.h)
            qh[off:off + n] = t.h
            qm[off:off + n] = True
            seg[off:off + n] = i
            if kind == "C":
                qb[off:off + n] = t.qbit
            spans.append((off, n))
            off += n

        def dispatch(eng, caps, m_cap):
            if kind == "SC":
                return seek.sc_seeker_seg(eng, qh, qm, seg, caps,
                                          m_cap=m_cap, n_seekers=nsp,
                                          n_tables=ex.n_tables,
                                          max_cols=ex.max_cols)
            if kind == "KW":
                return seek.kw_seeker_seg(eng, qh, qm, seg, caps,
                                          m_cap=m_cap, n_seekers=nsp,
                                          n_tables=ex.n_tables)
            return seek.c_seeker_seg(eng, qh, qm, qb, seg, caps,
                                     m_cap=m_cap, row_cap=ex.row_cap,
                                     n_seekers=nsp, n_tables=ex.n_tables,
                                     max_cols=ex.max_cols, h_sample=key[1],
                                     sampling=key[2],
                                     row_stride=ex.index.row_stride)

    engines = getattr(ex, "engines", None)
    rec = otrace.current()
    mreg = obs.registry()
    sync_time = obs.sync_timing()
    if engines is None:
        caps = np.zeros(width, np.int32)
        m_cap = fill_caps(caps, None)
        with rec.span("shard:0", m_cap=m_cap, seekers=len(tasks)):
            t0 = time.perf_counter()
            sc, ov = dispatch(ex.engine, caps, m_cap)
            if sync_time:
                jax.block_until_ready(sc)
            mreg.histogram("shard.probe_seconds.0").observe(
                time.perf_counter() - t0)
        return sc, ov
    scores, ovf = [], []
    shard_s = []
    for s, eng in enumerate(engines):
        caps = np.zeros(width, np.int32)
        m_cap = fill_caps(caps, s)
        with rec.span(f"shard:{s}", m_cap=m_cap, seekers=len(tasks)):
            t0 = time.perf_counter()
            try:
                faults.checkpoint(f"shard.probe.{s}")
                sc, ov = dispatch(eng, caps, m_cap)
                if sync_time:
                    jax.block_until_ready(sc)
            except Exception:                        # noqa: BLE001
                # InjectedCrash (BaseException) deliberately passes through:
                # a simulated kill -9 must not be absorbed as a shard retry
                mreg.counter("shard.failures").inc()
                try:
                    eng = ex.reset_shard(s)
                    faults.checkpoint(f"shard.probe.{s}")
                    sc, ov = dispatch(eng, caps, m_cap)
                    if sync_time:
                        jax.block_until_ready(sc)
                    mreg.counter("shard.retries").inc()
                except Exception:                    # noqa: BLE001
                    # rebuilt engine failed too: drop the shard from the
                    # merge — zeros are exactly "no tables live here"
                    mreg.counter("shard.dropped").inc()
                    if failed is not None:
                        failed.add(s)
                    sc = jnp.zeros((nsp, ex.n_tables), jnp.float32)
                    ov = jnp.zeros(nsp, jnp.int32)
            dt = time.perf_counter() - t0
        shard_s.append(dt)
        mreg.histogram(f"shard.probe_seconds.{s}").observe(dt)
        # stage results on the merge device so the single DAG program
        # consumes them without implicit cross-device transfers
        scores.append(jax.device_put(sc, ex.merge_device))
        ovf.append(jax.device_put(ov, ex.merge_device))
    # shard skew for this launch: slowest / mean probe time (1.0 = level).
    # Only meaningful under synchronized timing — async it measures
    # enqueue skew, which is still a leading indicator of a hot shard.
    mean_s = sum(shard_s) / len(shard_s)
    if mean_s > 0:
        mreg.gauge("shard.imbalance").set(max(shard_s) / mean_s)
    return tuple(scores), tuple(ovf)


# --------------------------------------------------------------------------
# the whole-DAG device program
# --------------------------------------------------------------------------

def _topk(scores, k: int):
    """Mirrors combiners.topk_result on raw (scores, mask) pairs."""
    k = min(k, scores.shape[0])
    vals, ids = jax.lax.top_k(scores, k)
    keep = vals > 0
    mask = jnp.zeros(scores.shape[0], bool).at[ids].set(keep)
    return jnp.where(mask, scores, 0.0), mask


def _maybe_topk(scores, mask, k):
    """Mirrors combiners._maybe_topk: ``k=None`` keeps the combiner's own
    mask (no cut) — the same contract legacy cut-free plans rely on."""
    scores = jnp.where(mask, scores, 0.0)
    if k is None:
        return scores, mask
    return _topk(scores, k)


@functools.partial(jax.jit, static_argnames=("prog",))
def _run_dag(group_scores, rows, cached_scores, cached_masks, *, prog):
    """Execute one plan's compiled instruction list in a single device
    program.  ``group_scores`` is the tuple of stacked seeker score matrices
    this plan consumes and ``rows`` the traced vector mapping each seeker
    ordinal to its batch row — traced so a reshuffled serve_many batch of
    the same plan shapes reuses the compiled program.  Every op mirrors its
    combiners.py counterpart exactly (same op order, same top-k), so
    outputs are bit-identical to the node-at-a-time walk."""
    seek._mark_trace("DAG")
    regs = []
    for ins in prog:
        op = ins[0]
        if op == "seeker":
            _, gi, j, k, allowed = ins
            gs = group_scores[gi]
            if isinstance(gs, tuple):
                # sharded group: sum the per-shard score matrices' rows —
                # exact in f32 (each table slot is nonzero on exactly one
                # shard; the rest contribute literal zeros).  This is the
                # whole cross-shard merge epilogue: it fuses into the one
                # DAG program, costing no extra launch.
                s = gs[0][rows[j]]
                for m in gs[1:]:
                    s = s + m[rows[j]]
            else:
                s = gs[rows[j]]
            if allowed >= 0:
                s = jnp.where(regs[allowed][1], s, 0.0)
            regs.append(_topk(s, k))
        elif op == "cached":
            regs.append((cached_scores[ins[1]], cached_masks[ins[1]]))
        elif op == "maskand":
            regs.append((regs[ins[1]][0], regs[ins[1]][1] & regs[ins[2]][1]))
        elif op == "intersect":
            _, deps, k = ins
            scores, mask = regs[deps[0]]
            for d in deps[1:]:
                mask = mask & regs[d][1]
                scores = scores + regs[d][0]
            regs.append(_maybe_topk(scores, mask, k))
        elif op == "union":
            _, deps, k = ins
            scores, mask = regs[deps[0]]
            for d in deps[1:]:
                mask = mask | regs[d][1]
                scores = jnp.maximum(scores, regs[d][0])
            regs.append(_maybe_topk(scores, mask, k))
        elif op == "difference":
            _, a, b, k = ins
            mask = regs[a][1] & ~regs[b][1]
            regs.append(_maybe_topk(regs[a][0], mask, k))
        elif op == "counter":
            _, deps, k = ins
            counts = jnp.zeros_like(regs[deps[0]][0])
            for d in deps:
                counts = counts + regs[d][1].astype(jnp.float32)
            regs.append(_maybe_topk(counts, counts > 0, k))
        else:
            raise ValueError(op)
    return tuple(regs)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _empty_cached(n_tables: int):
    """Shared zero-width placeholder inputs for plans with no cached
    seekers — built eagerly once per table count instead of dispatching two
    ``jnp.zeros`` device programs per plan per batch (a measurable share of
    the warm serve_many hot path)."""
    return (jnp.zeros((0, n_tables), jnp.float32),
            jnp.zeros((0, n_tables), bool))


def run_fused(ex, plans, optimize=True, cost_model=None, cache=None):
    """Execute ``plans`` (one or a whole serve_many batch) on the fused
    path; returns [(ResultSet, ExecInfo)] aligned with ``plans``.  The
    caller (Executor.run / Executor.run_many) owns engine refresh and the
    final drain."""
    eps = [optimize_plan(p, ex.seeker_stats, cost_model) if optimize
           else None for p in plans]
    progs = [_compile_plan(p, optimize, e, cache, i)
             for i, (p, e) in enumerate(zip(plans, eps))]

    tasks = [t for pr in progs for t in pr.tasks]
    # identical seekers (same frozen spec — e.g. a hot subtree shared
    # across a serve_many batch, where per-request cache lookups all happen
    # before any put) collapse onto one head task BEFORE hashing: same spec
    # means same hashes, capacity rung and scores, so dupes share the
    # head's batch row and pay no host work
    heads: dict = {}
    for t in tasks:
        t.head = heads.setdefault(t.spec, t)
    _hash_tasks(ex, list(heads.values()))

    groups: dict[tuple, list] = {}
    for h in heads.values():
        h.group_key = _group_key(h.spec)
        groups.setdefault(h.group_key, []).append(h)
    group_out: dict[tuple, tuple] = {}
    launch_seconds: dict[tuple, float] = {}
    failed_shards: set = set()
    rec = otrace.current()
    mreg = obs.registry()
    for key in sorted(groups):
        kind_name = "/".join(str(p) for p in key)
        # compile-vs-execute split: a launch that bumped TRACE_COUNTS paid
        # a jit trace+compile; steady-state launches must land in
        # exec.probe_seconds only (retrace-freedom made observable)
        tr0 = sum(seek.TRACE_COUNTS.values())
        t0 = time.perf_counter()
        with rec.span("probe:" + kind_name, seekers=len(groups[key])) as sp:
            group_out[key] = _launch_group(ex, key, groups[key],
                                           failed=failed_shards)
        dt = time.perf_counter() - t0
        launch_seconds[key] = dt
        if sum(seek.TRACE_COUNTS.values()) > tr0:
            sp.set("compiled", True)
            mreg.counter("exec.compiles").inc()
            mreg.histogram("exec.compile_seconds").observe(dt)
        else:
            mreg.histogram("exec.probe_seconds").observe(dt)
    group_plans: dict[tuple, set] = {}
    for t in tasks:                    # dupes adopt their head's placement
        t.group_key = t.head.group_key
        t.row = t.head.row
        group_plans.setdefault(t.group_key, set()).add(t.plan_idx)

    out = []
    for pr, plan in zip(progs, plans):
        plan_keys = sorted({t.group_key for t in pr.tasks})
        key_idx = {k: i for i, k in enumerate(plan_keys)}
        for t in pr.tasks:
            ins = pr.instrs[t.instr_idx]
            pr.instrs[t.instr_idx] = ("seeker", key_idx[t.group_key],
                                      ins[2], ins[3], ins[4])
        rows = np.array([t.row for t in pr.tasks], np.int32)
        gs = tuple(group_out[k][0] for k in plan_keys)
        if pr.cached:
            cs = jnp.stack([c.result.scores for c in pr.cached])
            cm = jnp.stack([c.result.mask for c in pr.cached])
        else:
            cs, cm = _empty_cached(ex.n_tables)
        # the DAG program is the cross-shard merge + the whole combiner tree
        tr0 = sum(seek.TRACE_COUNTS.values())
        t0 = time.perf_counter()
        with rec.span("merge", instrs=len(pr.instrs)) as sp:
            regs = _run_dag(gs, rows, cs, cm, prog=tuple(pr.instrs))
            if obs.sync_timing():
                jax.block_until_ready(regs[pr.out_reg][0])
        dag_s = time.perf_counter() - t0
        if sum(seek.TRACE_COUNTS.values()) > tr0:
            sp.set("compiled", True)
            mreg.counter("exec.compiles").inc()
            mreg.histogram("exec.compile_seconds").observe(dag_s)
        else:
            mreg.histogram("exec.dag_seconds").observe(dag_s)

        info = ExecInfo(optimized=optimize)
        info.order = pr.order
        info.cached_nodes = pr.cached_names
        info.seeker_runs = len(pr.tasks)
        # every plan in the batch shares the group launches, so a dropped
        # shard degrades every response formed from them
        info.failed_shards = sorted(failed_shards)
        # one launch per seeker group + the DAG program; groups == kinds
        # unless same-kind seekers differ in static shape args (MC n_cols,
        # C h/sampling), each of which is its own device program
        info.launches = len(plan_keys) + 1
        info.node_seconds["fused:dag"] = dag_s
        for key in plan_keys:
            # a serve_many group launch is shared across plans; attribute an
            # equal share so per-request node_seconds stay additive (+= so
            # two same-kind groups, e.g. MC n_cols=2 and n_cols=3, don't
            # overwrite each other)
            name = "fused:" + "/".join(str(p) for p in key)
            info.node_seconds[name] = info.node_seconds.get(name, 0.0) + \
                launch_seconds[key] / len(group_plans[key])
        info.overflow_parts.extend(c.overflow for c in pr.cached)
        for key in plan_keys:
            rows = [t.row for t in pr.tasks if t.group_key == key]
            info.overflow_parts.append(OverflowSlice(group_out[key][1],
                                                     rows))
        if cache is not None:
            for ckey, reg, task in pr.cache_puts:
                cache.put_seeker(ckey, ResultSet(scores=regs[reg][0],
                                                 mask=regs[reg][1]),
                                 OverflowSlice(group_out[task.group_key][1],
                                               [task.row]),
                                 ex.n_tables)
        out.append((ResultSet(scores=regs[pr.out_reg][0],
                              mask=regs[pr.out_reg][1]), info))
    mreg.counter("exec.plans").inc(len(out))
    # physical device programs this call: one per group + one DAG per plan
    # (per-plan ExecInfo.launches attributes shared group launches to every
    # consumer, so summing those would overcount)
    mreg.counter("exec.launches").inc(len(groups) + len(progs))
    mreg.counter("exec.seeker_runs").inc(len(tasks))
    return out
