"""The unified BLEND index: one columnar fact table serving all seekers.

AllTables(CellValue, TableId, ColumnId, RowId, SuperKey, Quadrant) from the
paper becomes a struct-of-arrays sorted by (cell_hash, table, col, row):

* ``cell_hash``      u32 — FNV-1a of the cell value (string-free TPU layout)
* ``table_id/col_id/row_id`` i32 — the DataXFormer inverted-index columns
* ``superkey lo/hi`` u32x2 — XASH-style 64-bit row bloom digest (MATE)
* ``quadrant``       i8  — 1/0 = numeric >= / < column mean, -1 = non-numeric
                     (our in-DB QCR reformulation: one boolean per cell
                     instead of the baseline's per-column-pair sketches)
* ``rank_conv/rank_rand`` i32 — position of the posting within its
                     (table, column) group in RowId order / in a seeded
                     shuffle — realizing the paper's convenience vs random
                     h-sampling entirely inside the index.

Auxiliary views derived from the same arrays (not separate indexes):
* bucket offsets over the top ``bucket_bits`` hash bits (the B-tree analogue;
  also the layout the Pallas ``bucket_probe`` kernel consumes),
* a numeric-postings permutation sorted by (table, row) — the join side of
  the correlation seeker,
* an optional AoS (row-store) interleave for the PostgreSQL-vs-column-store
  comparison of Fig 5.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import hashing
from repro.core.lake import DataLake
from repro.core.sketch import SketchConfig, sketch_tables

def _ceil_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


def validate_row_stride(n_tables: int, row_stride: int, max_rows: int = 0):
    """Rowkey soundness guard: ``rowkey = table * row_stride + row`` must be
    collision-free and fit int32.  A stride smaller than the longest table
    silently aliases rowkeys across tables and corrupts the MC validation and
    correlation joins — reject it loudly instead."""
    if max_rows > row_stride:
        raise ValueError(
            f"row_stride={row_stride} is smaller than the longest table "
            f"({max_rows} rows): rowkeys would alias across tables and "
            f"corrupt MC/correlation joins; widen the stride (build_index "
            f"auto-widens; pass row_stride >= {_ceil_pow2(max_rows)})")
    if n_tables * row_stride >= 2 ** 31:
        raise ValueError(
            f"int32 rowkey overflow: {n_tables} tables * row_stride="
            f"{row_stride} exceeds 2^31; shard the lake "
            f"(see dist/shard.py)")


def _is_numeric_col(values) -> bool:
    seen = False
    for v in values:
        if v is None:
            continue
        if isinstance(v, (bool, str)):
            return False
        if not isinstance(v, (int, float, np.integer, np.floating)):
            return False
        seen = True
    return seen


@dataclass
class UnifiedIndex:
    cell_hash: np.ndarray        # u32 [N] sorted
    table_id: np.ndarray         # i32 [N]
    col_id: np.ndarray           # i32 [N]
    row_id: np.ndarray           # i32 [N]
    superkey_lo: np.ndarray      # u32 [N]
    superkey_hi: np.ndarray      # u32 [N]
    quadrant: np.ndarray         # i8  [N]
    rank_conv: np.ndarray        # i32 [N]
    rank_rand: np.ndarray        # i32 [N]
    # numeric-by-row view (indices into the arrays above)
    num_perm: np.ndarray         # i32 [M] numeric postings by (table,row)
    num_rowkey: np.ndarray       # i32 [M] sorted rowkeys of num_perm
    # metadata
    n_tables: int
    max_cols: int
    bucket_bits: int
    bucket_offsets: np.ndarray   # i64 [2^bits + 1]
    table_rows: np.ndarray       # i32 [n_tables]
    # rowkey = table * row_stride + row.  No silent default: a stride smaller
    # than the longest table aliases rowkeys across tables (validated by
    # ``validate_row_stride`` at build time; build_index auto-widens).
    row_stride: int
    # approximate tier: {table_id: core.sketch.TableSketch} built from the
    # same posting arrays (see core/sketch.py for the determinism contract)
    sketches: dict = field(default_factory=dict, compare=False)
    sketch_config: SketchConfig = field(default_factory=SketchConfig,
                                        compare=False)

    @property
    def n_postings(self) -> int:
        return len(self.cell_hash)

    def storage_bytes(self) -> int:
        core = sum(a.nbytes for a in (
            self.cell_hash, self.table_id, self.col_id, self.row_id,
            self.superkey_lo, self.superkey_hi, self.quadrant,
            self.rank_conv, self.rank_rand))
        views = self.num_perm.nbytes + self.num_rowkey.nbytes + \
            self.bucket_offsets.nbytes
        return core + views

    def device_arrays(self):
        """The jnp-side dict the seekers consume."""
        import jax.numpy as jnp
        return {
            "hash": jnp.asarray(self.cell_hash),
            "table": jnp.asarray(self.table_id),
            "col": jnp.asarray(self.col_id),
            "row": jnp.asarray(self.row_id),
            "sk_lo": jnp.asarray(self.superkey_lo),
            "sk_hi": jnp.asarray(self.superkey_hi),
            "quadrant": jnp.asarray(self.quadrant),
            "rank_conv": jnp.asarray(self.rank_conv),
            "rank_rand": jnp.asarray(self.rank_rand),
            "num_rowkey": jnp.asarray(self.num_rowkey),
            "num_table": jnp.asarray(self.table_id[self.num_perm]),
            "num_col": jnp.asarray(self.col_id[self.num_perm]),
            "num_quadrant": jnp.asarray(self.quadrant[self.num_perm]),
            "num_rank_conv": jnp.asarray(self.rank_conv[self.num_perm]),
            "num_rank_rand": jnp.asarray(self.rank_rand[self.num_perm]),
        }

    def host_counts(self, q_hashes: np.ndarray) -> np.ndarray:
        """Match counts per query hash (planner statistics, O(|Q| log N))."""
        lo = np.searchsorted(self.cell_hash, q_hashes, side="left")
        hi = np.searchsorted(self.cell_hash, q_hashes, side="right")
        return (hi - lo).astype(np.int64)

    def padded_buckets(self, width: int):
        """Padded radix-bucket layout for the Pallas probe kernel: returns
        (bucket_hashes u32 [2^bits, width], bucket_payload i32 [...],
        overflow_count).  Fully vectorized: one scatter over the postings
        instead of a Python loop over 2^bits buckets."""
        nb = 1 << self.bucket_bits
        bh = np.full((nb, width), hashing.MISSING, np.uint32)
        bp = np.full((nb, width), -1, np.int32)
        shift = 32 - self.bucket_bits
        buckets = (self.cell_hash >> shift).astype(np.int64)
        # position of each posting within its bucket
        starts = self.bucket_offsets[:-1]
        pos = np.arange(self.n_postings, dtype=np.int64) - starts[buckets]
        keep = pos < width
        counts = np.diff(self.bucket_offsets)
        overflow = int(np.maximum(counts - width, 0).sum())
        bh[buckets[keep], pos[keep]] = self.cell_hash[keep]
        bp[buckets[keep], pos[keep]] = np.nonzero(keep)[0].astype(np.int32)
        return bh, bp, overflow

    def max_bucket_count(self) -> int:
        """Largest bucket population (the lossless probe-kernel width)."""
        return int(np.diff(self.bucket_offsets).max(initial=0))

    def aos_view(self) -> np.ndarray:
        """Row-store interleave (hash,t,c,r,sk_lo,sk_hi,quadrant) i64-packed
        into an int32 [N, 7] matrix — the 'PostgreSQL layout' of Fig 5."""
        out = np.empty((self.n_postings, 7), np.int32)
        out[:, 0] = self.cell_hash.view(np.int32)
        out[:, 1] = self.table_id
        out[:, 2] = self.col_id
        out[:, 3] = self.row_id
        out[:, 4] = self.superkey_lo.view(np.int32)
        out[:, 5] = self.superkey_hi.view(np.int32)
        out[:, 6] = self.quadrant
        return out


POSTING_KEYS = ("cell_hash", "table_id", "col_id", "row_id", "superkey_lo",
                "superkey_hi", "quadrant", "rank_conv", "rank_rand")


def table_postings(table, tid: int, *, seed: int = 0,
                   with_quadrants: bool = True) -> dict:
    """Unsorted posting arrays for one table (dict over ``POSTING_KEYS``).

    Shared by ``build_index`` and the LiveLake segment builder
    (store/segments.py), so an incrementally-built segment holds exactly the
    arrays a from-scratch rebuild would produce.  ``rank_rand`` is therefore
    seeded per (table name, column) — not from one build-wide RNG stream —
    so the shuffle a column gets is independent of build order.
    """
    nr, nc = table.n_rows, table.n_cols
    col_hashes, col_quads, col_rand = [], [], []
    for c, col in enumerate(table.columns):
        col_hashes.append(hashing.hash_array(col))
        if with_quadrants and _is_numeric_col(col):
            vals = np.array([float(v) for v in col])
            col_quads.append((vals >= vals.mean()).astype(np.int8))
        else:
            col_quads.append(np.full(nr, -1, np.int8))
        rng = np.random.default_rng(
            [seed, hashing.fnv1a_bytes(str(table.name).encode()), c])
        col_rand.append(rng.permutation(nr).astype(np.int32))
    # row superkeys: OR of position-independent cell bits (MATE-style
    # bloom; alignment is verified exactly at query time)
    if nc:
        all_h = np.concatenate(col_hashes)
        all_r = np.tile(np.arange(nr), nc)
        sk = hashing.superkeys_for_rows(all_h, np.zeros_like(all_h), all_r, nr)
    else:
        sk = np.zeros(0, np.uint64)
    lo32, hi32 = hashing.split_u64(sk)
    n = nr * nc
    return {
        "cell_hash": np.concatenate(col_hashes) if nc
        else np.zeros(0, np.uint32),
        "table_id": np.full(n, tid, np.int32),
        "col_id": np.repeat(np.arange(nc, dtype=np.int32), nr),
        "row_id": np.tile(np.arange(nr, dtype=np.int32), nc),
        "superkey_lo": np.tile(lo32, nc),
        "superkey_hi": np.tile(hi32, nc),
        "quadrant": np.concatenate(col_quads) if nc else np.zeros(0, np.int8),
        "rank_conv": np.tile(np.arange(nr, dtype=np.int32), nc),
        "rank_rand": np.concatenate(col_rand) if nc else np.zeros(0, np.int32),
    }


_POSTING_DTYPES = {"cell_hash": np.uint32, "quadrant": np.int8,
                   "superkey_lo": np.uint32, "superkey_hi": np.uint32}


def concat_postings(per_table: list) -> dict:
    """Concatenate per-table posting dicts (empty-safe)."""
    return {k: np.concatenate([p[k] for p in per_table]) if per_table
            else np.zeros(0, _POSTING_DTYPES.get(k, np.int32))
            for k in POSTING_KEYS}


def sort_postings(parts: dict) -> dict:
    """Lexsort concatenated posting arrays by (cell_hash, table, col, row)."""
    order = np.lexsort((parts["row_id"], parts["col_id"], parts["table_id"],
                        parts["cell_hash"]))
    return {k: v[order] for k, v in parts.items()}


def bucket_offsets_for(cell_hash: np.ndarray, bucket_bits: int) -> np.ndarray:
    """Offsets of the radix buckets over the top ``bucket_bits`` hash bits."""
    nb = 1 << bucket_bits
    shift = 32 - bucket_bits
    return np.searchsorted(
        (cell_hash >> shift).astype(np.uint32),
        np.arange(nb + 1, dtype=np.uint32), side="left").astype(np.int64)


def numeric_view(parts: dict, row_stride: int):
    """(num_perm, num_rowkey) — numeric postings permuted to (table, row)
    order.  The permutation itself is stride-independent (any collision-free
    stride induces the same (table, row) order), so widening the stride only
    recomputes ``num_rowkey`` values."""
    numeric = np.nonzero(parts["quadrant"] >= 0)[0]
    rowkey = parts["table_id"][numeric].astype(np.int64) * row_stride + \
        parts["row_id"][numeric].astype(np.int64)
    np_order = np.argsort(rowkey, kind="stable")
    return numeric[np_order].astype(np.int32), \
        rowkey[np_order].astype(np.int32)


def build_index(lake: DataLake, bucket_bits: int = 12, seed: int = 0,
                with_quadrants: bool = True,
                row_stride: int | None = None,
                sketch_config: SketchConfig | None = None) -> UnifiedIndex:
    max_cols = 1
    table_rows = np.zeros(max(lake.n_tables, 1), np.int32)
    per_table = []
    for t, table in enumerate(lake.tables):
        max_cols = max(max_cols, table.n_cols)
        table_rows[t] = table.n_rows
        per_table.append(table_postings(table, t, seed=seed,
                                        with_quadrants=with_quadrants))
    parts = concat_postings(per_table)
    parts = sort_postings(parts)

    max_rows = int(table_rows.max(initial=1))
    row_stride = max(_ceil_pow2(max_rows), row_stride or 0)
    validate_row_stride(lake.n_tables, row_stride, max_rows)

    bucket_offsets = bucket_offsets_for(parts["cell_hash"], bucket_bits)
    num_perm, num_rowkey = numeric_view(parts, row_stride)

    cell_hash, table_id, col_id, row_id = (
        parts["cell_hash"], parts["table_id"], parts["col_id"],
        parts["row_id"])
    superkey_lo, superkey_hi = parts["superkey_lo"], parts["superkey_hi"]
    quadrant = parts["quadrant"]
    rank_conv, rank_rand = parts["rank_conv"], parts["rank_rand"]

    sketch_config = sketch_config or SketchConfig()
    return UnifiedIndex(
        cell_hash=cell_hash, table_id=table_id, col_id=col_id, row_id=row_id,
        superkey_lo=superkey_lo, superkey_hi=superkey_hi, quadrant=quadrant,
        rank_conv=rank_conv, rank_rand=rank_rand,
        num_perm=num_perm, num_rowkey=num_rowkey,
        n_tables=lake.n_tables, max_cols=max_cols, bucket_bits=bucket_bits,
        bucket_offsets=bucket_offsets, table_rows=table_rows,
        row_stride=row_stride,
        sketches=sketch_tables(parts, seed=seed, config=sketch_config),
        sketch_config=sketch_config)
