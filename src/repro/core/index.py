"""The unified BLEND index: one columnar fact table serving all seekers.

AllTables(CellValue, TableId, ColumnId, RowId, SuperKey, Quadrant) from the
paper becomes a struct-of-arrays sorted by (cell_hash, table, col, row):

* ``cell_hash``      u32 — FNV-1a of the cell value (string-free TPU layout)
* ``table_id/col_id/row_id`` i32 — the DataXFormer inverted-index columns
* ``superkey lo/hi`` u32x2 — XASH-style 64-bit row bloom digest (MATE)
* ``quadrant``       i8  — 1/0 = numeric >= / < column mean, -1 = non-numeric
                     (our in-DB QCR reformulation: one boolean per cell
                     instead of the baseline's per-column-pair sketches)
* ``rank_conv/rank_rand`` i32 — position of the posting within its
                     (table, column) group in RowId order / in a seeded
                     shuffle — realizing the paper's convenience vs random
                     h-sampling entirely inside the index.

Auxiliary views derived from the same arrays (not separate indexes):
* bucket offsets over the top ``bucket_bits`` hash bits (the B-tree analogue;
  also the layout the Pallas ``bucket_probe`` kernel consumes),
* a numeric-postings permutation sorted by (table, row) — the join side of
  the correlation seeker,
* an optional AoS (row-store) interleave for the PostgreSQL-vs-column-store
  comparison of Fig 5.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import hashing
from repro.core.lake import DataLake

def _ceil_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


def _is_numeric_col(values) -> bool:
    seen = False
    for v in values:
        if v is None:
            continue
        if isinstance(v, (bool, str)):
            return False
        if not isinstance(v, (int, float, np.integer, np.floating)):
            return False
        seen = True
    return seen


@dataclass
class UnifiedIndex:
    cell_hash: np.ndarray        # u32 [N] sorted
    table_id: np.ndarray         # i32 [N]
    col_id: np.ndarray           # i32 [N]
    row_id: np.ndarray           # i32 [N]
    superkey_lo: np.ndarray      # u32 [N]
    superkey_hi: np.ndarray      # u32 [N]
    quadrant: np.ndarray         # i8  [N]
    rank_conv: np.ndarray        # i32 [N]
    rank_rand: np.ndarray        # i32 [N]
    # numeric-by-row view (indices into the arrays above)
    num_perm: np.ndarray         # i32 [M] numeric postings by (table,row)
    num_rowkey: np.ndarray       # i32 [M] sorted rowkeys of num_perm
    # metadata
    n_tables: int
    max_cols: int
    bucket_bits: int
    bucket_offsets: np.ndarray   # i64 [2^bits + 1]
    table_rows: np.ndarray       # i32 [n_tables]
    row_stride: int = 1 << 22    # rowkey = table * row_stride + row

    @property
    def n_postings(self) -> int:
        return len(self.cell_hash)

    def storage_bytes(self) -> int:
        core = sum(a.nbytes for a in (
            self.cell_hash, self.table_id, self.col_id, self.row_id,
            self.superkey_lo, self.superkey_hi, self.quadrant,
            self.rank_conv, self.rank_rand))
        views = self.num_perm.nbytes + self.num_rowkey.nbytes + \
            self.bucket_offsets.nbytes
        return core + views

    def device_arrays(self):
        """The jnp-side dict the seekers consume."""
        import jax.numpy as jnp
        return {
            "hash": jnp.asarray(self.cell_hash),
            "table": jnp.asarray(self.table_id),
            "col": jnp.asarray(self.col_id),
            "row": jnp.asarray(self.row_id),
            "sk_lo": jnp.asarray(self.superkey_lo),
            "sk_hi": jnp.asarray(self.superkey_hi),
            "quadrant": jnp.asarray(self.quadrant),
            "rank_conv": jnp.asarray(self.rank_conv),
            "rank_rand": jnp.asarray(self.rank_rand),
            "num_rowkey": jnp.asarray(self.num_rowkey),
            "num_table": jnp.asarray(self.table_id[self.num_perm]),
            "num_col": jnp.asarray(self.col_id[self.num_perm]),
            "num_quadrant": jnp.asarray(self.quadrant[self.num_perm]),
            "num_rank_conv": jnp.asarray(self.rank_conv[self.num_perm]),
            "num_rank_rand": jnp.asarray(self.rank_rand[self.num_perm]),
        }

    def host_counts(self, q_hashes: np.ndarray) -> np.ndarray:
        """Match counts per query hash (planner statistics, O(|Q| log N))."""
        lo = np.searchsorted(self.cell_hash, q_hashes, side="left")
        hi = np.searchsorted(self.cell_hash, q_hashes, side="right")
        return (hi - lo).astype(np.int64)

    def padded_buckets(self, width: int):
        """Padded radix-bucket layout for the Pallas probe kernel: returns
        (bucket_hashes u32 [2^bits, width], bucket_payload i32 [...],
        overflow_count).  Fully vectorized: one scatter over the postings
        instead of a Python loop over 2^bits buckets."""
        nb = 1 << self.bucket_bits
        bh = np.full((nb, width), hashing.MISSING, np.uint32)
        bp = np.full((nb, width), -1, np.int32)
        shift = 32 - self.bucket_bits
        buckets = (self.cell_hash >> shift).astype(np.int64)
        # position of each posting within its bucket
        starts = self.bucket_offsets[:-1]
        pos = np.arange(self.n_postings, dtype=np.int64) - starts[buckets]
        keep = pos < width
        counts = np.diff(self.bucket_offsets)
        overflow = int(np.maximum(counts - width, 0).sum())
        bh[buckets[keep], pos[keep]] = self.cell_hash[keep]
        bp[buckets[keep], pos[keep]] = np.nonzero(keep)[0].astype(np.int32)
        return bh, bp, overflow

    def max_bucket_count(self) -> int:
        """Largest bucket population (the lossless probe-kernel width)."""
        return int(np.diff(self.bucket_offsets).max(initial=0))

    def aos_view(self) -> np.ndarray:
        """Row-store interleave (hash,t,c,r,sk_lo,sk_hi,quadrant) i64-packed
        into an int32 [N, 7] matrix — the 'PostgreSQL layout' of Fig 5."""
        out = np.empty((self.n_postings, 7), np.int32)
        out[:, 0] = self.cell_hash.view(np.int32)
        out[:, 1] = self.table_id
        out[:, 2] = self.col_id
        out[:, 3] = self.row_id
        out[:, 4] = self.superkey_lo.view(np.int32)
        out[:, 5] = self.superkey_hi.view(np.int32)
        out[:, 6] = self.quadrant
        return out


def build_index(lake: DataLake, bucket_bits: int = 12, seed: int = 0,
                with_quadrants: bool = True) -> UnifiedIndex:
    rng = np.random.default_rng(seed)
    hashes, tids, cids, rids = [], [], [], []
    sk_lo, sk_hi, quads = [], [], []
    r_conv, r_rand = [], []
    max_cols = 1
    table_rows = np.zeros(lake.n_tables, np.int32)

    for t, table in enumerate(lake.tables):
        nr, nc = table.n_rows, table.n_cols
        max_cols = max(max_cols, nc)
        table_rows[t] = nr
        col_hashes = []
        col_quads = []
        for c, col in enumerate(table.columns):
            h = hashing.hash_array(col)
            col_hashes.append(h)
            if with_quadrants and _is_numeric_col(col):
                vals = np.array([float(v) for v in col])
                q = (vals >= vals.mean()).astype(np.int8)
            else:
                q = np.full(nr, -1, np.int8)
            col_quads.append(q)
        # row superkeys: OR of position-independent cell bits (MATE-style
        # bloom; alignment is verified exactly at query time)
        all_h = np.concatenate(col_hashes)
        all_r = np.tile(np.arange(nr), nc)
        sk = hashing.superkeys_for_rows(all_h, np.zeros_like(all_h), all_r, nr)
        lo32, hi32 = hashing.split_u64(sk)
        for c in range(nc):
            hashes.append(col_hashes[c])
            tids.append(np.full(nr, t, np.int32))
            cids.append(np.full(nr, c, np.int32))
            rids.append(np.arange(nr, dtype=np.int32))
            sk_lo.append(lo32)
            sk_hi.append(hi32)
            quads.append(col_quads[c])
            r_conv.append(np.arange(nr, dtype=np.int32))
            r_rand.append(rng.permutation(nr).astype(np.int32))

    cell_hash = np.concatenate(hashes)
    table_id = np.concatenate(tids)
    col_id = np.concatenate(cids)
    row_id = np.concatenate(rids)
    superkey_lo = np.concatenate(sk_lo)
    superkey_hi = np.concatenate(sk_hi)
    quadrant = np.concatenate(quads)
    rank_conv = np.concatenate(r_conv)
    rank_rand = np.concatenate(r_rand)

    order = np.lexsort((row_id, col_id, table_id, cell_hash))
    cell_hash, table_id, col_id, row_id = (cell_hash[order], table_id[order],
                                           col_id[order], row_id[order])
    superkey_lo, superkey_hi = superkey_lo[order], superkey_hi[order]
    quadrant = quadrant[order]
    rank_conv, rank_rand = rank_conv[order], rank_rand[order]

    nb = 1 << bucket_bits
    shift = 32 - bucket_bits
    bucket_offsets = np.searchsorted(
        (cell_hash >> shift).astype(np.uint32), np.arange(nb + 1, dtype=np.uint32),
        side="left").astype(np.int64)

    numeric = np.nonzero(quadrant >= 0)[0]
    row_stride = _ceil_pow2(int(table_rows.max(initial=1)))
    rowkey = table_id[numeric].astype(np.int64) * row_stride + \
        row_id[numeric].astype(np.int64)
    assert lake.n_tables * row_stride < 2 ** 31, \
        "int32 rowkey overflow: shard the lake (see core/distributed.py)"
    np_order = np.argsort(rowkey, kind="stable")
    num_perm = numeric[np_order].astype(np.int32)
    num_rowkey = rowkey[np_order].astype(np.int32)

    return UnifiedIndex(
        cell_hash=cell_hash, table_id=table_id, col_id=col_id, row_id=row_id,
        superkey_lo=superkey_lo, superkey_hi=superkey_hi, quadrant=quadrant,
        rank_conv=rank_conv, rank_rand=rank_rand,
        num_perm=num_perm, num_rowkey=num_rowkey,
        n_tables=lake.n_tables, max_cols=max_cols, bucket_bits=bucket_bits,
        bucket_offsets=bucket_offsets, table_rows=table_rows,
        row_stride=row_stride)
