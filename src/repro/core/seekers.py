"""The four BLEND seekers as static-shaped, jittable scan programs.

Every seeker maps (MatchEngine, hashed query) -> dense per-table scores
[n_tables] (the TPU-native result-set representation; combiners are
elementwise set algebra over these vectors).  ``allowed`` is the optimizer's
threaded intermediate-result mask — the TPU analogue of the paper's
``WHERE TableId IN (...)`` query rewriting: postings from dead tables are
zeroed *before* the expensive group-by / validation stages.

All probing goes through ``MatchEngine.probe`` (core/match.py): the engine
owns the device index and selects the searchsorted or Pallas bucket-probe
backend; seekers never touch the raw hash array.  The MC bloom stage and the
correlation scoring epilogue likewise route through the superkey_filter and
qcr_score kernel packages via the engine.

Static capacities (``m_cap`` matches per value, ``row_cap`` numeric cells per
row) keep shapes jit-stable; overflows are counted and surfaced, never
silently dropped.  ``TRACE_COUNTS`` increments once per jit trace of each
seeker — the executor's retrace-free contract is asserted against it.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro import obs

TRACE_COUNTS = collections.Counter()


def _mark_trace(kind: str):
    """Python-side effect: runs once per jit trace, never per call.  Also
    bridged into the metrics registry (``exec.retraces``), so a serving
    tier with observability enabled sees compile churn without reaching
    into this module's counter."""
    TRACE_COUNTS[kind] += 1
    reg = obs.registry()
    reg.counter("exec.retraces").inc()
    reg.counter(f"exec.retraces.{kind}").inc()


def _first_occurrence(*keys, valid=None):
    """Mask of first occurrence of a key combo along axis 1.

    Inputs are sorted within each valid run.  With a segment-fanned probe
    window ([nq, n_segments * m_cap]) a valid run can directly follow another
    segment's garbage tail whose clipped gather happens to repeat the same
    key — passing ``valid`` masks keys to a sentinel first so run boundaries
    always register as a change (a table's postings live in exactly one
    segment, so a key never spans two valid runs)."""
    first = None
    for k in keys:
        if valid is not None:
            k = jnp.where(valid, k, -1)
        prev = jnp.concatenate([jnp.full_like(k[:, :1], -1), k[:, :-1]], axis=1)
        f = k != prev
        first = f if first is None else (first | f)
    return first


# --------------------------------------------------------------------------
# SC seeker — single-column join discovery (Listing 1)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_cap", "n_tables", "max_cols"))
def sc_seeker(engine, q_hash, q_mask, *, m_cap, n_tables, max_cols,
              allowed=None):
    """COUNT(DISTINCT CellValue) GROUP BY (TableId, ColumnId); table score =
    best column.  Returns (scores f32 [n_tables], overflow)."""
    _mark_trace("SC")
    idx = engine.dev
    pidx, valid, ovf = engine.probe(q_hash, q_mask, m_cap)
    t = idx["table"][pidx]
    c = idx["col"][pidx]
    contrib = valid & _first_occurrence(t, c, valid=valid)
    if allowed is not None:
        contrib &= allowed[t]
    flat = (t * max_cols + c).reshape(-1)
    scores_tc = jnp.zeros(n_tables * max_cols, jnp.float32).at[flat].add(
        contrib.reshape(-1).astype(jnp.float32), mode="drop")
    return scores_tc.reshape(n_tables, max_cols).max(axis=1), ovf


# --------------------------------------------------------------------------
# KW seeker — keyword search (SC without the ColumnId group key)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_cap", "n_tables"))
def kw_seeker(engine, q_hash, q_mask, *, m_cap, n_tables, allowed=None):
    _mark_trace("KW")
    idx = engine.dev
    pidx, valid, ovf = engine.probe(q_hash, q_mask, m_cap)
    t = idx["table"][pidx]
    contrib = valid & _first_occurrence(t, valid=valid)
    if allowed is not None:
        contrib &= allowed[t]
    scores = jnp.zeros(n_tables, jnp.float32).at[t.reshape(-1)].add(
        contrib.reshape(-1).astype(jnp.float32), mode="drop")
    return scores, ovf


# --------------------------------------------------------------------------
# MC seeker — multi-column join discovery (MATE-style, Listing 2)
# --------------------------------------------------------------------------

def _tuple_mask_or_ones(tuple_mask, nt):
    return jnp.ones((nt,), bool) if tuple_mask is None else tuple_mask


@functools.partial(jax.jit, static_argnames=("m_cap", "n_tables", "n_cols",
                                             "use_superkey", "row_stride"))
def mc_seeker(engine, tuple_hashes, init_col, qk_lo, qk_hi, *, m_cap,
              n_tables, n_cols, row_stride=1 << 22, use_superkey=True,
              allowed=None, tuple_mask=None):
    """tuple_hashes: [nt, n_cols] hashed query tuples; init_col: [nt] index of
    the least-frequent (initiator) value; qk_lo/hi: [nt] query superkeys;
    tuple_mask: [nt] optional validity of (padded) tuples.

    Phase 1: probe the initiator value -> candidate rows.
    Phase 2: XASH superkey bloom filter  ((row_sk & q_sk) == q_sk).
    Phase 3: exact validation — every other column value must occur in the
             same (table, row).
    Returns (scores = matched-tuple count per table, row_counts = candidate
    rows that survive per table (Table V TP metric), overflow)."""
    _mark_trace("MC")
    idx = engine.dev
    nt = tuple_hashes.shape[0]
    h0 = jnp.take_along_axis(tuple_hashes, init_col[:, None], 1)[:, 0]
    q_mask = _tuple_mask_or_ones(tuple_mask, nt)
    pidx, valid, ovf = engine.probe(h0, q_mask, m_cap)
    t = idx["table"][pidx]
    r = idx["row"][pidx]
    if allowed is not None:
        valid &= allowed[t]
    if use_superkey:
        valid &= engine.bloom(pidx, qk_lo, qk_hi)
    rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)

    ok = valid
    for j in range(n_cols):                       # static, small
        hj = tuple_hashes[:, j]
        pj, vj, _ = engine.probe(hj, q_mask, m_cap)
        tj = idx["table"][pj]
        rj = idx["row"][pj]
        rkj = tj.astype(jnp.int32) * row_stride + rj.astype(jnp.int32)
        rkj = jnp.where(vj, rkj, -1)
        member = jnp.any(rowkey[:, :, None] == rkj[:, None, :], axis=-1)
        ok &= member | (init_col == j)[:, None]
    # matched-tuple count per table (dedupe: one tuple counts once per table)
    per_tt = jnp.zeros((nt * n_tables,), jnp.float32).at[
        (jnp.arange(nt)[:, None] * n_tables + t).reshape(-1)].max(
        ok.reshape(-1).astype(jnp.float32), mode="drop")
    scores = per_tt.reshape(nt, n_tables).sum(axis=0)
    row_counts = jnp.zeros(n_tables, jnp.float32).at[t.reshape(-1)].add(
        ok.reshape(-1).astype(jnp.float32), mode="drop")
    return scores, row_counts, ovf


# --------------------------------------------------------------------------
# Segmented (fused-batch) seeker variants — core/fused.py dispatches all
# same-kind seekers of a plan (or of a whole serve_many batch) as ONE device
# program: the padded query arrays are concatenated with per-row seeker ids
# (``seg_id``) and per-row match capacities (``row_caps``, each seeker's own
# ladder rung), probing goes through ``MatchEngine.probe_capped``, and the
# group-by keys are prefixed with the seeker id so one scatter produces a
# stacked [n_seekers, n_tables] score matrix.  Per-seeker contributions are
# exactly the ones a dedicated launch would have produced (same valid
# windows, same 0/1 integer sums), so each row of the stack is bit-identical
# to the unfused seeker's output.  ``n_seekers`` is quantized to a power of
# two by the caller so the batch stays retrace-free.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_cap", "n_seekers", "n_tables",
                                             "max_cols"))
def sc_seeker_seg(engine, q_hash, q_mask, seg_id, row_caps, *, m_cap,
                  n_seekers, n_tables, max_cols):
    """Batched ``sc_seeker``: one probe over the concatenated query rows,
    one group-by into [n_seekers, n_tables].  Returns (scores, overflow
    [n_seekers])."""
    _mark_trace("SC_seg")
    idx = engine.dev
    pidx, valid, ovf_rows = engine.probe_capped(q_hash, q_mask, m_cap,
                                                row_caps)
    t = idx["table"][pidx]
    c = idx["col"][pidx]
    contrib = valid & _first_occurrence(t, c, valid=valid)
    flat = ((seg_id[:, None] * n_tables + t) * max_cols + c).reshape(-1)
    scores = jnp.zeros(n_seekers * n_tables * max_cols, jnp.float32).at[
        flat].add(contrib.reshape(-1).astype(jnp.float32), mode="drop")
    ovf = jnp.zeros(n_seekers, ovf_rows.dtype).at[seg_id].add(ovf_rows,
                                                              mode="drop")
    return scores.reshape(n_seekers, n_tables, max_cols).max(axis=2), ovf


@functools.partial(jax.jit, static_argnames=("m_cap", "n_seekers",
                                             "n_tables"))
def kw_seeker_seg(engine, q_hash, q_mask, seg_id, row_caps, *, m_cap,
                  n_seekers, n_tables):
    """Batched ``kw_seeker`` (SC without the ColumnId group key)."""
    _mark_trace("KW_seg")
    idx = engine.dev
    pidx, valid, ovf_rows = engine.probe_capped(q_hash, q_mask, m_cap,
                                                row_caps)
    t = idx["table"][pidx]
    contrib = valid & _first_occurrence(t, valid=valid)
    flat = (seg_id[:, None] * n_tables + t).reshape(-1)
    scores = jnp.zeros(n_seekers * n_tables, jnp.float32).at[flat].add(
        contrib.reshape(-1).astype(jnp.float32), mode="drop")
    ovf = jnp.zeros(n_seekers, ovf_rows.dtype).at[seg_id].add(ovf_rows,
                                                              mode="drop")
    return scores.reshape(n_seekers, n_tables), ovf


@functools.partial(jax.jit, static_argnames=("m_cap", "n_seekers", "n_tables",
                                             "n_cols", "use_superkey",
                                             "row_stride"))
def mc_seeker_seg(engine, tuple_hashes, init_col, qk_lo, qk_hi, seg_id,
                  row_caps, *, m_cap, n_seekers, n_tables, n_cols,
                  row_stride=1 << 22, use_superkey=True, tuple_mask=None):
    """Batched ``mc_seeker`` over the concatenated tuple blocks of all
    same-width (``n_cols``) MC seekers; ``seg_id`` is per tuple.  The
    matched-tuple counts are segment-summed by seeker after the per-tuple
    dedupe, so each stacked row equals the dedicated launch's scores."""
    _mark_trace("MC_seg")
    idx = engine.dev
    nt = tuple_hashes.shape[0]
    h0 = jnp.take_along_axis(tuple_hashes, init_col[:, None], 1)[:, 0]
    q_mask = _tuple_mask_or_ones(tuple_mask, nt)
    pidx, valid, ovf_rows = engine.probe_capped(h0, q_mask, m_cap, row_caps)
    t = idx["table"][pidx]
    r = idx["row"][pidx]
    if use_superkey:
        valid &= engine.bloom(pidx, qk_lo, qk_hi)
    rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)

    ok = valid
    for j in range(n_cols):                       # static, small
        hj = tuple_hashes[:, j]
        pj, vj, _ = engine.probe_capped(hj, q_mask, m_cap, row_caps)
        tj = idx["table"][pj]
        rj = idx["row"][pj]
        rkj = tj.astype(jnp.int32) * row_stride + rj.astype(jnp.int32)
        rkj = jnp.where(vj, rkj, -1)
        member = jnp.any(rowkey[:, :, None] == rkj[:, None, :], axis=-1)
        ok &= member | (init_col == j)[:, None]
    per_tt = jnp.zeros((nt * n_tables,), jnp.float32).at[
        (jnp.arange(nt)[:, None] * n_tables + t).reshape(-1)].max(
        ok.reshape(-1).astype(jnp.float32), mode="drop")
    scores = jnp.zeros((n_seekers, n_tables), jnp.float32).at[seg_id].add(
        per_tt.reshape(nt, n_tables), mode="drop")
    ovf = jnp.zeros(n_seekers, ovf_rows.dtype).at[seg_id].add(ovf_rows,
                                                              mode="drop")
    return scores, ovf


@functools.partial(jax.jit, static_argnames=("m_cap", "row_cap", "n_seekers",
                                             "n_tables", "max_cols",
                                             "h_sample", "sampling",
                                             "min_support", "row_stride"))
def c_seeker_seg(engine, qj_hash, q_mask, q_bit, seg_id, row_caps, *, m_cap,
                 row_cap, n_seekers, n_tables, max_cols, h_sample,
                 row_stride=1 << 22, sampling="conv", min_support=3):
    """Batched ``c_seeker``: the QCR group-by key is prefixed with the
    seeker id of the originating join posting, so the per-(table, join_col,
    num_col) segment sums — and hence every QCR ratio — are computed from
    exactly the contributions the dedicated launch would have seen."""
    _mark_trace("C_seg")
    idx = engine.dev
    pidx, valid, ovf_rows = engine.probe_capped(qj_hash, q_mask, m_cap,
                                                row_caps)
    t = idx["table"][pidx]
    r = idx["row"][pidx]
    cj = idx["col"][pidx]
    rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)
    rk_flat = rowkey.reshape(-1)

    nidx, nvalid = engine.rowjoin(rk_flat, valid.reshape(-1), row_cap)

    ntab = idx["num_table"][nidx]
    ncol = idx["num_col"][nidx]
    nquad = idx["num_quadrant"][nidx]
    rank = idx["num_rank_conv" if sampling == "conv" else "num_rank_rand"][nidx]
    nvalid &= rank < h_sample

    qb = jnp.broadcast_to(q_bit[:, None], pidx.shape).reshape(-1)[:, None]
    agree = (nquad == qb) & nvalid

    segf = jnp.broadcast_to(seg_id[:, None], pidx.shape).reshape(-1)
    dim = n_tables * max_cols * max_cols
    key = segf[:, None] * dim + \
        (ntab * max_cols + cj.reshape(-1)[:, None]) * max_cols + ncol
    key = key.reshape(-1)
    n_all = jnp.zeros(n_seekers * dim, jnp.float32).at[key].add(
        nvalid.reshape(-1).astype(jnp.float32), mode="drop")
    n_agree = jnp.zeros(n_seekers * dim, jnp.float32).at[key].add(
        agree.reshape(-1).astype(jnp.float32), mode="drop")
    qcr = engine.qcr(n_agree, n_all, min_support)
    ovf = jnp.zeros(n_seekers, ovf_rows.dtype).at[seg_id].add(ovf_rows,
                                                              mode="drop")
    return qcr.reshape(n_seekers, n_tables, max_cols * max_cols).max(axis=2), \
        ovf


# --------------------------------------------------------------------------
# MC capacity compaction — the TPU analogue of the paper's query rewriting.
# The threaded predicate can't shrink a static-shape scan by itself; instead
# the executor measures the survivor count (stage 1) and re-launches the
# expensive validation with compacted candidate buffers (stage 2).  This is
# where "WHERE TableId IN (IR)" actually reduces work on a vector machine.
# --------------------------------------------------------------------------

def _mc_candidates(engine, tuple_hashes, init_col, qk_lo, qk_hi, m_cap,
                   use_superkey, allowed, tuple_mask):
    idx = engine.dev
    nt = tuple_hashes.shape[0]
    h0 = jnp.take_along_axis(tuple_hashes, init_col[:, None], 1)[:, 0]
    q_mask = _tuple_mask_or_ones(tuple_mask, nt)
    pidx, valid, ovf = engine.probe(h0, q_mask, m_cap)
    t = idx["table"][pidx]
    r = idx["row"][pidx]
    if allowed is not None:
        valid &= allowed[t]
    if use_superkey:
        valid &= engine.bloom(pidx, qk_lo, qk_hi)
    return t, r, valid, ovf, q_mask


@functools.partial(jax.jit, static_argnames=("m_cap", "use_superkey"))
def mc_survivor_counts(engine, tuple_hashes, init_col, qk_lo, qk_hi, *, m_cap,
                       use_superkey=True, allowed=None, tuple_mask=None):
    """Stage 1: candidates per tuple surviving the threaded predicate +
    bloom prune (the planner picks the stage-2 capacity from the max)."""
    _mark_trace("MC_stage1")
    _, _, valid, _, _ = _mc_candidates(engine, tuple_hashes, init_col, qk_lo,
                                       qk_hi, m_cap, use_superkey, allowed,
                                       tuple_mask)
    return jnp.sum(valid, axis=1)


@functools.partial(jax.jit, static_argnames=("m_cap", "m_cap2", "n_tables",
                                             "n_cols", "use_superkey",
                                             "row_stride"))
def mc_seeker_compact(engine, tuple_hashes, init_col, qk_lo, qk_hi, *, m_cap,
                      m_cap2, n_tables, n_cols, row_stride=1 << 22,
                      use_superkey=True, allowed=None, tuple_mask=None):
    """Stage 2: exact validation over compacted [nt, m_cap2] candidates
    (m_cap2 << m_cap when the predicate filters hard)."""
    _mark_trace("MC_stage2")
    idx = engine.dev
    nt = tuple_hashes.shape[0]
    t, r, valid, ovf, q_mask = _mc_candidates(engine, tuple_hashes, init_col,
                                              qk_lo, qk_hi, m_cap,
                                              use_superkey, allowed,
                                              tuple_mask)
    # compact: move surviving candidates to the front, take m_cap2
    order = jnp.argsort(~valid, axis=1, stable=True)[:, :m_cap2]
    t = jnp.take_along_axis(t, order, axis=1)
    r = jnp.take_along_axis(r, order, axis=1)
    valid = jnp.take_along_axis(valid, order, axis=1)
    rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)

    ok = valid
    for j in range(n_cols):
        hj = tuple_hashes[:, j]
        pj, vj, _ = engine.probe(hj, q_mask, m_cap)
        tj = idx["table"][pj]
        rj = idx["row"][pj]
        rkj = tj.astype(jnp.int32) * row_stride + rj.astype(jnp.int32)
        rkj = jnp.sort(jnp.where(vj, rkj, jnp.iinfo(jnp.int32).max), axis=1)
        member = engine.member(rkj, rowkey)
        ok &= member | (init_col == j)[:, None]
    per_tt = jnp.zeros((nt * n_tables,), jnp.float32).at[
        (jnp.arange(nt)[:, None] * n_tables + t).reshape(-1)].max(
        ok.reshape(-1).astype(jnp.float32), mode="drop")
    scores = per_tt.reshape(nt, n_tables).sum(axis=0)
    row_counts = jnp.zeros(n_tables, jnp.float32).at[t.reshape(-1)].add(
        ok.reshape(-1).astype(jnp.float32), mode="drop")
    return scores, row_counts, ovf


# --------------------------------------------------------------------------
# Correlation seeker — QCR in one pass (Listing 3)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_cap", "row_cap", "n_tables",
                                             "max_cols", "h_sample", "sampling",
                                             "min_support", "row_stride"))
def c_seeker(engine, qj_hash, q_mask, q_bit, *, m_cap, row_cap, n_tables,
             max_cols, h_sample, row_stride=1 << 22, sampling="conv",
             min_support=3, allowed=None):
    """qj_hash: hashed join-key values; q_bit[i] = 1 iff the query target for
    key i is >= the target mean (the paper's k0/k1 split, done at parse time).

    QCR = (2*(n_I + n_III) - N) / N  computed per (table, join_col, num_col)
    triple via two segment-sums; table score = max |QCR| over triples with
    N >= min_support.  h-sampling filters the numeric side by the indexed
    convenience/random rank (sketch size chosen at query time)."""
    _mark_trace("C")
    idx = engine.dev
    pidx, valid, ovf = engine.probe(qj_hash, q_mask, m_cap)
    t = idx["table"][pidx]
    r = idx["row"][pidx]
    cj = idx["col"][pidx]
    rowkey = t.astype(jnp.int32) * row_stride + r.astype(jnp.int32)
    rk_flat = rowkey.reshape(-1)

    nidx, nvalid = engine.rowjoin(rk_flat, valid.reshape(-1), row_cap)

    ntab = idx["num_table"][nidx]
    ncol = idx["num_col"][nidx]
    nquad = idx["num_quadrant"][nidx]
    rank = idx["num_rank_conv" if sampling == "conv" else "num_rank_rand"][nidx]
    nvalid &= rank < h_sample
    if allowed is not None:
        nvalid &= allowed[ntab]

    qb = jnp.broadcast_to(q_bit[:, None], pidx.shape).reshape(-1)[:, None]
    agree = (nquad == qb) & nvalid

    key = ((ntab * max_cols + cj.reshape(-1)[:, None]) * max_cols + ncol)
    key = key.reshape(-1)
    dim = n_tables * max_cols * max_cols
    n_all = jnp.zeros(dim, jnp.float32).at[key].add(
        nvalid.reshape(-1).astype(jnp.float32), mode="drop")
    n_agree = jnp.zeros(dim, jnp.float32).at[key].add(
        agree.reshape(-1).astype(jnp.float32), mode="drop")
    qcr = engine.qcr(n_agree, n_all, min_support)
    return qcr.reshape(n_tables, -1).max(axis=1), ovf


@functools.partial(jax.jit, static_argnames=("m_cap",))
def c_survivor_counts(engine, qj_hash, q_mask, *, m_cap, allowed=None):
    """Stage 1 for the compacted correlation seeker: join-side matches that
    survive the threaded predicate."""
    _mark_trace("C_stage1")
    pidx, valid, _ = engine.probe(qj_hash, q_mask, m_cap)
    if allowed is not None:
        valid &= allowed[engine.dev["table"][pidx]]
    return jnp.sum(valid)


@functools.partial(jax.jit, static_argnames=("m_cap", "cap2", "row_cap",
                                             "n_tables", "max_cols",
                                             "h_sample", "sampling",
                                             "min_support", "row_stride"))
def c_seeker_compact(engine, qj_hash, q_mask, q_bit, *, m_cap, cap2, row_cap,
                     n_tables, max_cols, h_sample, row_stride=1 << 22,
                     sampling="conv", min_support=3, allowed=None):
    """Stage 2: the numeric row-join + QCR scoring runs over the compacted
    [cap2] surviving join-side postings instead of [nq*m_cap]."""
    _mark_trace("C_stage2")
    idx = engine.dev
    pidx, valid, ovf = engine.probe(qj_hash, q_mask, m_cap)
    t = idx["table"][pidx]
    if allowed is not None:
        valid &= allowed[t]
    rowkey = (t.astype(jnp.int32) * row_stride +
              idx["row"][pidx].astype(jnp.int32))
    cj = idx["col"][pidx]
    qb = jnp.broadcast_to(q_bit[:, None], pidx.shape)
    flat_valid = valid.reshape(-1)
    # fill_value must be out-of-band: filling with slot 0 would mark the pad
    # entries valid whenever slot 0 itself survives, double-counting its
    # postings cap2-surv times in the QCR segment sums
    (keep,) = jnp.nonzero(flat_valid, size=cap2, fill_value=-1)
    kv = keep >= 0
    keep = jnp.where(kv, keep, 0)
    rk = jnp.where(kv, rowkey.reshape(-1)[keep], -1)
    cjf = cj.reshape(-1)[keep]
    qbf = qb.reshape(-1)[keep]

    nidx, nvalid = engine.rowjoin(rk, kv & (rk >= 0), row_cap)
    ntab = idx["num_table"][nidx]
    ncol = idx["num_col"][nidx]
    nquad = idx["num_quadrant"][nidx]
    rank = idx["num_rank_conv" if sampling == "conv" else "num_rank_rand"][nidx]
    nvalid &= rank < h_sample
    agree = (nquad == qbf[:, None]) & nvalid
    key = ((ntab * max_cols + cjf[:, None]) * max_cols + ncol).reshape(-1)
    dim = n_tables * max_cols * max_cols
    n_all = jnp.zeros(dim, jnp.float32).at[key].add(
        nvalid.reshape(-1).astype(jnp.float32), mode="drop")
    n_agree = jnp.zeros(dim, jnp.float32).at[key].add(
        agree.reshape(-1).astype(jnp.float32), mode="drop")
    qcr = engine.qcr(n_agree, n_all, min_support)
    return qcr.reshape(n_tables, -1).max(axis=1), ovf
