"""MatchEngine: the unified probe layer every seeker routes through.

One object owns the device-resident index arrays, the padded radix-bucket
layout, and the low-level match primitives:

* ``probe(q_hash, q_mask, m_cap)`` -> (pidx, valid, overflow) — postings per
  query value, expanded to a static [nq, m_cap] window.  Two interchangeable
  backends: ``"sorted"`` (binary search over the globally hash-sorted
  postings) and ``"bucket"`` (the Pallas ``bucket_probe`` kernel over the
  padded radix-bucket table).  Seeker outputs are bit-identical across
  backends (parity-tested in tests/test_match_engine.py).
* ``rowjoin(rowkeys, mask, row_cap)`` — the numeric-postings-by-row probe of
  the correlation seeker (same expansion over ``num_rowkey``).
* ``bloom(...)`` — the MC seeker's XASH superkey containment stage, routed
  through the ``superkey_filter`` kernel package.
* ``qcr(n_agree, n_all)`` — the correlation seeker's scoring epilogue,
  routed through the ``qcr_score`` kernel package.
* ``member(sorted_keys, queries)`` — batched sorted-membership (the MC
  validation join).

The engine is a registered pytree: its arrays are leaves (so jitted seekers
close over nothing) and its configuration is static aux data (so switching
backend retraces, while re-querying with new values of the same padded shape
hits the jit cache — the retrace-free serving contract).

``probe_sorted`` is also exposed as a free function: the distributed
shard_map seekers (core/distributed.py) reuse the same primitive on their
shard-local array slices, where no engine object exists.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bucket_probe import ops as bucket_ops
from repro.kernels.qcr_score import ops as qcr_ops
from repro.kernels.superkey_filter import ops as sk_ops

BACKENDS = ("sorted", "bucket")


def probe_sorted(sorted_keys, queries, q_mask, cap):
    """Match range per query in a sorted key array, expanded to [nq, cap].

    Returns (pidx i32 [nq, cap] clipped gather indices, valid bool [nq, cap],
    overflow = matches beyond cap, summed)."""
    lo = jnp.searchsorted(sorted_keys, queries, side="left")
    hi = jnp.searchsorted(sorted_keys, queries, side="right")
    pidx = lo[:, None] + jnp.arange(cap)[None, :]
    valid = (pidx < hi[:, None]) & q_mask[:, None]
    pidx = jnp.clip(pidx, 0, sorted_keys.shape[0] - 1)
    overflow = jnp.sum(jnp.where(q_mask, jnp.maximum(hi - lo - cap, 0), 0))
    return pidx, valid, overflow


def sorted_member(sorted_keys, queries):
    """Batched membership: sorted_keys [B, M] row-sorted, queries [B, C] ->
    bool [B, C] (the MC validation join primitive)."""
    loc = jnp.clip(jax.vmap(jnp.searchsorted)(sorted_keys, queries),
                   0, sorted_keys.shape[1] - 1)
    return jnp.take_along_axis(sorted_keys, loc, axis=1) == queries


@dataclass(frozen=True)
class EngineConfig:
    """Static (hashable) part of a MatchEngine — the jit cache key."""
    backend: str
    interpret: bool
    bucket_bits: int
    bucket_width: int
    n_tables: int
    max_cols: int
    row_stride: int


class MatchEngine:
    """See module docstring.  Build with ``MatchEngine.from_index``."""

    def __init__(self, dev: dict, bucket_hashes, bucket_payload,
                 config: EngineConfig):
        self.dev = dev
        self.bucket_hashes = bucket_hashes
        self.bucket_payload = bucket_payload
        self.config = config

    # ------------------------------------------------------------- building
    @classmethod
    def from_index(cls, index, *, backend: str = "sorted",
                   interpret: bool = False, bucket_width: int | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        dev = index.device_arrays()
        bh = bp = None
        width = 0
        if backend == "bucket":
            # the layout must be lossless: a truncated bucket would drop
            # matches without any overflow accounting (the probe can only
            # count what the layout kept)
            need = max(index.max_bucket_count(), 1)
            if bucket_width is None:
                bucket_width = need
            elif bucket_width < need:
                raise ValueError(
                    f"bucket_width={bucket_width} is smaller than the "
                    f"fullest bucket ({need}): probing would silently drop "
                    f"matches; raise bucket_width or bucket_bits")
            width = ((bucket_width + 127) // 128) * 128   # TPU lane padding
            bh_np, bp_np, layout_overflow = index.padded_buckets(width)
            assert layout_overflow == 0
            bh, bp = jnp.asarray(bh_np), jnp.asarray(bp_np)
        cfg = EngineConfig(backend=backend, interpret=interpret,
                           bucket_bits=index.bucket_bits, bucket_width=width,
                           n_tables=index.n_tables, max_cols=index.max_cols,
                           row_stride=index.row_stride)
        return cls(dev, bh, bp, cfg)

    @property
    def backend(self) -> str:
        return self.config.backend

    # ------------------------------------------------------------ primitives
    def probe(self, q_hash, q_mask, m_cap: int):
        """Postings window per query hash: (pidx, valid, overflow)."""
        if self.config.backend == "sorted":
            return probe_sorted(self.dev["hash"], q_hash, q_mask, m_cap)
        nq = q_hash.shape[0]
        q_block = min(256, nq)
        hits = bucket_ops.probe(self.bucket_hashes, self.bucket_payload,
                                q_hash, self.config.bucket_bits,
                                use_kernel=True,
                                interpret=self.config.interpret,
                                q_block=q_block)          # [nq, W] payload|-1
        hit = hits >= 0
        count = jnp.sum(hit, axis=1)
        n = self.dev["hash"].shape[0]
        # postings are bucket-contiguous and hash-sorted, so the matched
        # payloads form the run [base, base + count): recover the window from
        # the min payload instead of compacting the hit matrix
        base = jnp.min(jnp.where(hit, hits, n), axis=1)
        pidx = base[:, None] + jnp.arange(m_cap)[None, :]
        valid = (jnp.arange(m_cap)[None, :] < count[:, None]) & q_mask[:, None]
        pidx = jnp.clip(pidx, 0, n - 1)
        overflow = jnp.sum(jnp.where(q_mask, jnp.maximum(count - m_cap, 0), 0))
        return pidx, valid, overflow

    def rowjoin(self, rowkeys, mask, row_cap: int):
        """Numeric-postings window per candidate rowkey: (nidx, nvalid)."""
        nidx, nvalid, _ = probe_sorted(self.dev["num_rowkey"], rowkeys, mask,
                                       row_cap)
        return nidx, nvalid

    def bloom(self, pidx, qk_lo, qk_hi):
        """XASH superkey containment of query digests in the candidate rows
        at ``pidx`` [nt, cap]: (row_sk & q_sk) == q_sk, via the
        superkey_filter kernel package."""
        cand_lo = self.dev["sk_lo"][pidx]
        cand_hi = self.dev["sk_hi"][pidx]
        return sk_ops.filter_candidates(
            cand_lo, cand_hi, qk_lo, qk_hi,
            use_kernel=self.config.backend == "bucket",
            interpret=self.config.interpret)

    def qcr(self, n_agree, n_all, min_support: int = 3):
        """QCR epilogue |2a - n| / n with the support floor, via the
        qcr_score kernel package."""
        return qcr_ops.score_segments(
            n_agree, n_all, min_support=min_support,
            use_kernel=self.config.backend == "bucket",
            interpret=self.config.interpret)

    def member(self, sorted_keys, queries):
        return sorted_member(sorted_keys, queries)


def _engine_flatten(e: MatchEngine):
    return ((e.dev, e.bucket_hashes, e.bucket_payload), e.config)


def _engine_unflatten(aux, children):
    dev, bh, bp = children
    return MatchEngine(dev, bh, bp, aux)


jax.tree_util.register_pytree_node(MatchEngine, _engine_flatten,
                                   _engine_unflatten)
