"""MatchEngine: the unified probe layer every seeker routes through.

One object owns the device-resident index arrays, the padded radix-bucket
layouts, and the low-level match primitives.  Since the LiveLake subsystem
(repro/store) the engine is *segment-aware*: the resident index is an ordered
list of immutable sorted segments (one large base + small L0 deltas) and

* ``probe(q_hash, q_mask, m_cap)`` fans out over the segments — each segment
  has its own sorted run, padded-bucket layout and capacity-ladder entry —
  and concatenates the per-segment posting windows along the match axis, so
  seekers see one ``[nq, n_segments * m_cap]`` window and stay unchanged.
* tombstone masks (dropped tables) are applied to ``valid`` inside ``probe``
  / ``rowjoin``, *before* any group-by stage, so mutation parity with a
  from-scratch rebuild holds bit-exactly.

Two interchangeable probe backends: ``"sorted"`` (binary search over each
segment's hash-sorted run) and ``"bucket"`` (the Pallas ``bucket_probe``
kernel over each segment's padded radix-bucket table).  Seeker outputs are
bit-identical across backends (parity-tested in tests/test_match_engine.py)
and across mutation histories (tests/test_livelake.py).

* ``rowjoin(rowkeys, mask, row_cap)`` — the numeric-postings-by-row probe of
  the correlation seeker (same fan-out over per-segment ``num_rowkey`` runs).
* ``bloom(...)`` — the MC seeker's XASH superkey containment stage, routed
  through the ``superkey_filter`` kernel package.
* ``qcr(n_agree, n_all)`` — the correlation seeker's scoring epilogue,
  routed through the ``qcr_score`` kernel package.
* ``member(sorted_keys, queries)`` — batched sorted-membership (the MC
  validation join).

The engine is a registered pytree: its arrays are leaves (so jitted seekers
close over nothing) and its configuration — including the static per-segment
bounds — is hashable aux data.  Segments are length-padded onto a power-of-
two ladder (store/segments.py), so a mutation that lands in an already-seen
segment topology re-uses the compiled seekers (zero new traces — the
retrace-free serving contract extends to live lakes).

A sharded lake (dist/shard.py) builds one engine per shard with
``from_store(..., device=...)``, pinning each shard's concatenated arrays to
its own mesh device; the fused executor then dispatches the same jitted
seekers per shard and sums the score matrices on the merge device.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bucket_probe import ops as bucket_ops
from repro.kernels.qcr_score import ops as qcr_ops
from repro.kernels.superkey_filter import ops as sk_ops

BACKENDS = ("sorted", "bucket")


def probe_sorted(sorted_keys, queries, q_mask, cap):
    """Match range per query in a sorted key array, expanded to [nq, cap].

    Returns (pidx i32 [nq, cap] clipped gather indices, valid bool [nq, cap],
    overflow = matches beyond cap, summed)."""
    lo = jnp.searchsorted(sorted_keys, queries, side="left")
    hi = jnp.searchsorted(sorted_keys, queries, side="right")
    pidx = lo[:, None] + jnp.arange(cap)[None, :]
    valid = (pidx < hi[:, None]) & q_mask[:, None]
    pidx = jnp.clip(pidx, 0, sorted_keys.shape[0] - 1)
    overflow = jnp.sum(jnp.where(q_mask, jnp.maximum(hi - lo - cap, 0), 0))
    return pidx, valid, overflow


def probe_sorted_bounded(sorted_keys, n_real: int, queries, q_mask, cap):
    """``probe_sorted`` over a length-padded sorted run: only the first
    ``n_real`` keys are live postings; the tail is sort-stable sentinel
    padding that must never match (clamping lo/hi to ``n_real`` keeps even
    queries that equal the sentinel from touching it)."""
    lo = jnp.minimum(jnp.searchsorted(sorted_keys, queries, side="left"),
                     n_real)
    hi = jnp.minimum(jnp.searchsorted(sorted_keys, queries, side="right"),
                     n_real)
    pidx = lo[:, None] + jnp.arange(cap)[None, :]
    valid = (pidx < hi[:, None]) & q_mask[:, None]
    pidx = jnp.clip(pidx, 0, sorted_keys.shape[0] - 1)
    overflow = jnp.sum(jnp.where(q_mask, jnp.maximum(hi - lo - cap, 0), 0))
    return pidx, valid, overflow


def probe_sorted_capped(sorted_keys, n_real: int, queries, q_mask, cap,
                        row_caps):
    """``probe_sorted_bounded`` with *per-row* match capacities.

    The fused executor (core/fused.py) batches seekers with different
    (ladder-quantized) capacities into one launch: ``cap`` is the static
    window width (the group maximum) while ``row_caps[i] <= cap`` restricts
    row ``i`` to its own seeker's capacity, so per-seeker scores and
    overflow stay bit-identical to a dedicated launch at that capacity.
    Overflow is returned per row (callers segment-sum it by seeker)."""
    lo = jnp.minimum(jnp.searchsorted(sorted_keys, queries, side="left"),
                     n_real)
    hi = jnp.minimum(jnp.searchsorted(sorted_keys, queries, side="right"),
                     n_real)
    lane = jnp.arange(cap)[None, :]
    pidx = lo[:, None] + lane
    valid = (pidx < hi[:, None]) & (lane < row_caps[:, None]) & q_mask[:, None]
    pidx = jnp.clip(pidx, 0, sorted_keys.shape[0] - 1)
    ovf_rows = jnp.where(q_mask, jnp.maximum(hi - lo - row_caps, 0), 0)
    return pidx, valid, ovf_rows


def sorted_member(sorted_keys, queries):
    """Batched membership: sorted_keys [B, M] row-sorted, queries [B, C] ->
    bool [B, C] (the MC validation join primitive)."""
    loc = jnp.clip(jax.vmap(jnp.searchsorted)(sorted_keys, queries),
                   0, sorted_keys.shape[1] - 1)
    return jnp.take_along_axis(sorted_keys, loc, axis=1) == queries


@dataclass(frozen=True)
class EngineConfig:
    """Static (hashable) part of a MatchEngine — the jit cache key.

    ``seg_bounds`` / ``num_bounds`` are per-segment ``(start, length,
    n_real)`` triples into the concatenated device arrays: ``start`` is the
    segment's offset, ``length`` its padded extent (the slice shape the trace
    specializes on), ``n_real`` the live-posting count within it."""
    backend: str
    interpret: bool
    bucket_bits: int
    bucket_widths: tuple          # per segment; () on the sorted backend
    seg_bounds: tuple             # ((start, length, n_real), ...)
    num_bounds: tuple             # ((start, length, n_real), ...)
    n_tables: int
    max_cols: int
    row_stride: int


class MatchEngine:
    """See module docstring.  Build with ``MatchEngine.from_index`` (one
    static segment) or ``MatchEngine.from_store`` (LiveLake segments)."""

    def __init__(self, dev: dict, bucket_hashes, bucket_payload,
                 config: EngineConfig, alive=None):
        self.dev = dev                      # concatenated per-segment arrays
        self.bucket_hashes = bucket_hashes  # tuple of [2^bits, W_i] per seg
        self.bucket_payload = bucket_payload
        self.alive = alive                  # bool [n_tables] tombstone mask
        self.config = config

    # ------------------------------------------------------------- building
    @classmethod
    def from_index(cls, index, *, backend: str = "sorted",
                   interpret: bool = False, bucket_width: int | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        dev = index.device_arrays()
        bh = bp = None
        widths = ()
        if backend == "bucket":
            # the layout must be lossless: a truncated bucket would drop
            # matches without any overflow accounting (the probe can only
            # count what the layout kept)
            need = max(index.max_bucket_count(), 1)
            if bucket_width is None:
                bucket_width = need
            elif bucket_width < need:
                raise ValueError(
                    f"bucket_width={bucket_width} is smaller than the "
                    f"fullest bucket ({need}): probing would silently drop "
                    f"matches; raise bucket_width or bucket_bits")
            width = ((bucket_width + 127) // 128) * 128   # TPU lane padding
            bh_np, bp_np, layout_overflow = index.padded_buckets(width)
            assert layout_overflow == 0
            bh, bp = (jnp.asarray(bh_np),), (jnp.asarray(bp_np),)
            widths = (width,)
        n = index.n_postings
        m = len(index.num_rowkey)
        cfg = EngineConfig(backend=backend, interpret=interpret,
                           bucket_bits=index.bucket_bits,
                           bucket_widths=widths,
                           seg_bounds=((0, n, n),),
                           num_bounds=((0, m, m),),
                           n_tables=index.n_tables, max_cols=index.max_cols,
                           row_stride=index.row_stride)
        return cls(dev, bh, bp, cfg)

    @classmethod
    def from_store(cls, store, *, backend: str = "sorted",
                   interpret: bool = False, device=None):
        """Engine over a LiveLake SegmentStore: per-segment device arrays are
        concatenated *on device* (host->device transfer is only ever the new
        segment — segment uploads are memoized on the immutable segments),
        and the per-segment bounds become static aux data.  ``device`` pins
        every array to one mesh device (sharded lakes build one engine per
        shard on its own device)."""
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        segs = store.segments
        seg_devs = [s.device_arrays(device) for s in segs]
        dev = {k: jnp.concatenate([d[k] for d in seg_devs])
               for k in seg_devs[0]}
        seg_bounds, num_bounds = [], []
        off = noff = 0
        for s in segs:
            seg_bounds.append((off, s.n_padded, s.n_real))
            num_bounds.append((noff, s.n_num_padded, s.n_num))
            off += s.n_padded
            noff += s.n_num_padded
        bh = bp = None
        widths = ()
        if backend == "bucket":
            bhs, bps, ws = [], [], []
            for (start, _, _), s in zip(seg_bounds, segs):
                width = ((max(s.max_bucket_count(), 1) + 127) // 128) * 128
                bh_i, bp_i = s.device_buckets(width, payload_offset=start,
                                              device=device)
                bhs.append(bh_i)
                bps.append(bp_i)
                ws.append(width)
            bh, bp, widths = tuple(bhs), tuple(bps), tuple(ws)
        cfg = EngineConfig(backend=backend, interpret=interpret,
                           bucket_bits=store.bucket_bits,
                           bucket_widths=widths,
                           seg_bounds=tuple(seg_bounds),
                           num_bounds=tuple(num_bounds),
                           n_tables=store.n_tables, max_cols=store.max_cols,
                           row_stride=store.row_stride)
        alive = jnp.asarray(store.alive) if device is None else \
            jax.device_put(np.asarray(store.alive), device)
        return cls(dev, bh, bp, cfg, alive=alive)

    @property
    def backend(self) -> str:
        return self.config.backend

    # ------------------------------------------------------------ primitives
    def _probe_segment(self, i: int, q_hash, q_mask, m_cap: int):
        """One segment's (pidx, valid, overflow) window, globally indexed."""
        start, length, n_real = self.config.seg_bounds[i]
        if self.config.backend == "sorted":
            keys = self.dev["hash"][start:start + length]
            pidx, valid, ovf = probe_sorted_bounded(keys, n_real, q_hash,
                                                    q_mask, m_cap)
            return pidx + start, valid, ovf
        nq = q_hash.shape[0]
        q_block = min(256, nq)
        hits = bucket_ops.probe(self.bucket_hashes[i], self.bucket_payload[i],
                                q_hash, self.config.bucket_bits,
                                use_kernel=True,
                                interpret=self.config.interpret,
                                q_block=q_block)          # [nq, W] payload|-1
        hit = hits >= 0
        count = jnp.sum(hit, axis=1)
        n = self.dev["hash"].shape[0]
        # postings are bucket-contiguous and hash-sorted within the segment,
        # so the matched (globally-offset) payloads form the run
        # [base, base + count): recover the window from the min payload
        # instead of compacting the hit matrix
        base = jnp.min(jnp.where(hit, hits, n), axis=1)
        pidx = base[:, None] + jnp.arange(m_cap)[None, :]
        valid = (jnp.arange(m_cap)[None, :] < count[:, None]) & q_mask[:, None]
        pidx = jnp.clip(pidx, 0, n - 1)
        overflow = jnp.sum(jnp.where(q_mask, jnp.maximum(count - m_cap, 0), 0))
        return pidx, valid, overflow

    def probe(self, q_hash, q_mask, m_cap: int):
        """Postings window per query hash: (pidx, valid, overflow), fanned
        out over the segments ([nq, n_segments * m_cap]) with tombstoned
        tables masked out of ``valid`` before any group-by stage.

        One uniform ``m_cap`` (sized from cross-segment total counts) is
        deliberate: per-segment caps would shrink the window when matches
        spread across segments, but each data-dependent cap combination
        would be its own jit-cache entry — fragmenting the capacity-ladder
        buckets that make mutation serving retrace-free.  Compaction, not
        cap tuning, is the mechanism that bounds the fan-out cost."""
        parts = [self._probe_segment(i, q_hash, q_mask, m_cap)
                 for i in range(len(self.config.seg_bounds))]
        if len(parts) == 1:
            pidx, valid, ovf = parts[0]
        else:
            pidx = jnp.concatenate([p for p, _, _ in parts], axis=1)
            valid = jnp.concatenate([v for _, v, _ in parts], axis=1)
            ovf = sum(o for _, _, o in parts)
        if self.alive is not None:
            valid &= self.alive[self.dev["table"][pidx]]
        return pidx, valid, ovf

    def _probe_segment_capped(self, i: int, q_hash, q_mask, m_cap: int,
                              row_caps):
        """``_probe_segment`` with per-row capacities (fused batching)."""
        start, length, n_real = self.config.seg_bounds[i]
        if self.config.backend == "sorted":
            keys = self.dev["hash"][start:start + length]
            pidx, valid, ovf = probe_sorted_capped(keys, n_real, q_hash,
                                                   q_mask, m_cap, row_caps)
            return pidx + start, valid, ovf
        nq = q_hash.shape[0]
        q_block = min(256, nq)
        hits = bucket_ops.probe(self.bucket_hashes[i], self.bucket_payload[i],
                                q_hash, self.config.bucket_bits,
                                use_kernel=True,
                                interpret=self.config.interpret,
                                q_block=q_block)
        hit = hits >= 0
        count = jnp.sum(hit, axis=1)
        n = self.dev["hash"].shape[0]
        base = jnp.min(jnp.where(hit, hits, n), axis=1)
        lane = jnp.arange(m_cap)[None, :]
        pidx = base[:, None] + lane
        valid = (lane < count[:, None]) & (lane < row_caps[:, None]) & \
            q_mask[:, None]
        pidx = jnp.clip(pidx, 0, n - 1)
        ovf_rows = jnp.where(q_mask, jnp.maximum(count - row_caps, 0), 0)
        return pidx, valid, ovf_rows

    def probe_capped(self, q_hash, q_mask, m_cap: int, row_caps):
        """``probe`` with per-row match capacities: the fused executor
        concatenates several seekers' padded query arrays into one batch and
        probes them in a single launch; ``row_caps`` carries each row's own
        (ladder-quantized) capacity so every seeker sees exactly the match
        window its dedicated launch would have seen.  Returns per-row
        overflow instead of a batch total, so callers can segment-sum it
        back into per-seeker overflow counters."""
        parts = [self._probe_segment_capped(i, q_hash, q_mask, m_cap,
                                            row_caps)
                 for i in range(len(self.config.seg_bounds))]
        if len(parts) == 1:
            pidx, valid, ovf_rows = parts[0]
        else:
            pidx = jnp.concatenate([p for p, _, _ in parts], axis=1)
            valid = jnp.concatenate([v for _, v, _ in parts], axis=1)
            ovf_rows = sum(o for _, _, o in parts)
        if self.alive is not None:
            valid &= self.alive[self.dev["table"][pidx]]
        return pidx, valid, ovf_rows

    def rowjoin(self, rowkeys, mask, row_cap: int):
        """Numeric-postings window per candidate rowkey: (nidx, nvalid),
        fanned out over the per-segment (table, row)-sorted runs."""
        parts = []
        for start, length, n_real in self.config.num_bounds:
            keys = self.dev["num_rowkey"][start:start + length]
            nidx, nvalid, _ = probe_sorted_bounded(keys, n_real, rowkeys,
                                                   mask, row_cap)
            parts.append((nidx + start, nvalid))
        if len(parts) == 1:
            nidx, nvalid = parts[0]
        else:
            nidx = jnp.concatenate([p for p, _ in parts], axis=1)
            nvalid = jnp.concatenate([v for _, v in parts], axis=1)
        if self.alive is not None:
            nvalid &= self.alive[self.dev["num_table"][nidx]]
        return nidx, nvalid

    def bloom(self, pidx, qk_lo, qk_hi):
        """XASH superkey containment of query digests in the candidate rows
        at ``pidx`` [nt, cap]: (row_sk & q_sk) == q_sk, via the
        superkey_filter kernel package."""
        cand_lo = self.dev["sk_lo"][pidx]
        cand_hi = self.dev["sk_hi"][pidx]
        return sk_ops.filter_candidates(
            cand_lo, cand_hi, qk_lo, qk_hi,
            use_kernel=self.config.backend == "bucket",
            interpret=self.config.interpret)

    def qcr(self, n_agree, n_all, min_support: int = 3):
        """QCR epilogue |2a - n| / n with the support floor, via the
        qcr_score kernel package."""
        return qcr_ops.score_segments(
            n_agree, n_all, min_support=min_support,
            use_kernel=self.config.backend == "bucket",
            interpret=self.config.interpret)

    def member(self, sorted_keys, queries):
        return sorted_member(sorted_keys, queries)


def _engine_flatten(e: MatchEngine):
    return ((e.dev, e.bucket_hashes, e.bucket_payload, e.alive), e.config)


def _engine_unflatten(aux, children):
    dev, bh, bp, alive = children
    return MatchEngine(dev, bh, bp, aux, alive=alive)


jax.tree_util.register_pytree_node(MatchEngine, _engine_flatten,
                                   _engine_unflatten)
