"""Standalone discovery systems + federated pipelines (the paper's baselines).

Each baseline owns its *own* index structures (the paper's storage argument —
Table VIII) and runs as an isolated system; complex tasks federate them with
application-level glue, which is exactly what BLEND's unified index +
optimizer beat in Table III.

* ``JosieLike``   — single-column join search: per-value posting lists keyed
                    by (table, column) sets (JOSIE's token->sets index).
* ``MateLike``    — multi-column join: its own inverted index + XASH column,
                    candidate fetch in the "DB" (vectorized) but row-by-row
                    exact validation in application code (the paper's noted
                    bottleneck), no intermediate-result filters.
* ``QcrLike``     — correlation sketch index: per (table, join_col, num_col)
                    pair, the h smallest-hash (key, quadrant) sketch entries,
                    materialized offline (fixed h — resizing requires
                    re-indexing, unlike BLEND's query-time h).
* ``UnionBaseline`` — per-column domain-signature overlap (Starmie stand-in:
                    no contrastive model offline, but the same evaluation
                    interface; documented as a syntactic proxy).
"""
from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core.hashing import hash_array, hash_value
from repro.core.lake import DataLake


class JosieLike:
    """Token -> list[(table, col)] posting dict; query = multiset overlap."""

    def __init__(self, lake: DataLake):
        self.postings: dict[int, set] = defaultdict(set)
        for t, tab in enumerate(lake.tables):
            for c, col in enumerate(tab.columns):
                for h in hash_array(col):
                    self.postings[int(h)].add((t, c))
        self.n_tables = lake.n_tables

    def storage_bytes(self) -> int:
        n = sum(len(v) for v in self.postings.values())
        return len(self.postings) * 12 + n * 8

    def query(self, values, k=10):
        scores = defaultdict(set)
        for v in values:
            for (t, c) in self.postings.get(hash_value(v), ()):
                scores[(t, c)].add(hash_value(v))
        table_best = defaultdict(int)
        for (t, c), s in scores.items():
            table_best[t] = max(table_best[t], len(s))
        ranked = sorted(table_best.items(), key=lambda kv: -kv[1])[:k]
        return [t for t, s in ranked if s > 0]


class MateLike:
    """Inverted index + XASH superkeys; app-level row validation."""

    def __init__(self, lake: DataLake):
        from repro.core.hashing import superkeys_for_rows
        self.lake = lake
        self.postings: dict[int, list] = defaultdict(list)
        self.rows: dict[tuple, list] = {}
        self.superkeys: dict[tuple, int] = {}
        for t, tab in enumerate(lake.tables):
            col_hashes = [hash_array(col) for col in tab.columns]
            all_h = np.concatenate(col_hashes)
            all_r = np.tile(np.arange(tab.n_rows), tab.n_cols)
            sks = superkeys_for_rows(all_h, np.zeros_like(all_h), all_r,
                                     tab.n_rows)
            for r in range(tab.n_rows):
                self.rows[(t, r)] = [int(ch[r]) for ch in col_hashes]
                self.superkeys[(t, r)] = int(sks[r])
            for c, ch in enumerate(col_hashes):
                for r, h in enumerate(ch):
                    self.postings[int(h)].append((t, c, r))

    def storage_bytes(self) -> int:
        n = sum(len(v) for v in self.postings.values())
        return len(self.postings) * 12 + n * 12 + len(self.superkeys) * 16

    def query(self, tuples, k=10, allowed=None, count_fps=False):
        """Returns (top-k table ids, n_validated_rows, tp, fp)."""
        from repro.core.hashing import row_superkey
        tp = fp = validated = 0
        matched = defaultdict(set)
        for qi, tup in enumerate(tuples):
            hs = np.array([hash_value(v) for v in tup], np.uint32)
            qk = int(row_superkey(hs, np.zeros(len(tup), np.int64)))
            # candidate rows from the first value's postings (no initiator
            # frequency optimization — that's BLEND's planner)
            cands = self.postings.get(int(hs[0]), ())
            seen = set()
            for (t, c, r) in cands:
                if (t, r) in seen:
                    continue
                seen.add((t, r))
                if allowed is not None and t not in allowed:
                    continue
                if (self.superkeys[(t, r)] & qk) != qk:
                    continue
                # application-level exact validation, row by row
                validated += 1
                row = self.rows[(t, r)]
                if all(int(h) in row for h in hs):
                    matched[t].add(qi)
                    tp += 1
                else:
                    fp += 1
        ranked = sorted(matched.items(), key=lambda kv: -len(kv[1]))[:k]
        return [t for t, _ in ranked], validated, tp, fp


class QcrLike:
    """Offline per-(table, join_col, num_col) sketches of the h smallest
    (hash(key), quadrant) pairs — fixed h at build time."""

    def __init__(self, lake: DataLake, h: int = 256):
        self.h = h
        self.sketches: dict[tuple, list] = {}
        for t, tab in enumerate(lake.tables):
            numeric = []
            for c, col in enumerate(tab.columns):
                try:
                    vals = np.array([float(v) for v in col])
                except (TypeError, ValueError):
                    continue
                numeric.append((c, vals >= vals.mean()))
            for cj, col in enumerate(tab.columns):
                if any(cj == c for c, _ in numeric):
                    continue     # baseline: categorical join keys only
                key_hashes = hash_array(col)
                order = np.argsort(key_hashes)[: self.h]
                for cn, quad in numeric:
                    self.sketches[(t, cj, cn)] = [
                        (int(key_hashes[i]), bool(quad[i])) for i in order]

    def storage_bytes(self) -> int:
        return sum(len(v) for v in self.sketches.values()) * 5 + \
            len(self.sketches) * 24

    def query(self, join_values, target_values, k=10, allowed=None):
        tgt = np.array([float(v) for v in target_values])
        qbit = tgt >= tgt.mean()
        qmap = {hash_value(v): bool(b) for v, b in zip(join_values, qbit)}
        scores = {}
        for (t, cj, cn), entries in self.sketches.items():
            if allowed is not None and t not in allowed:
                continue
            n = agree = 0
            for h, b in entries:
                if h in qmap:
                    n += 1
                    agree += int(qmap[h] == b)
            if n >= 3:
                qcr = abs(2 * agree - n) / n
                scores[t] = max(scores.get(t, 0.0), qcr)
        ranked = sorted(scores.items(), key=lambda kv: -kv[1])[:k]
        return [t for t, _ in ranked]


class UnionBaseline:
    """Per-table column domain signatures; union score = best greedy column
    matching overlap (syntactic Starmie stand-in)."""

    def __init__(self, lake: DataLake, sig_size: int = 64):
        self.sig_size = sig_size
        self.sigs = []
        for tab in lake.tables:
            cols = []
            for col in tab.columns:
                hs = sorted(int(h) for h in set(hash_array(col)))[:sig_size]
                cols.append(set(hs))
            self.sigs.append(cols)

    def storage_bytes(self) -> int:
        return sum(len(s) for cols in self.sigs for s in cols) * 8

    def query(self, table_idx: int, k=10):
        q_cols = self.sigs[table_idx]
        scores = []
        for t, cols in enumerate(self.sigs):
            if t == table_idx:
                scores.append(-1.0)
                continue
            total = 0.0
            used = set()
            for qc in q_cols:
                best, best_c = 0.0, None
                for c, cc in enumerate(cols):
                    if c in used or not qc or not cc:
                        continue
                    ov = len(qc & cc) / len(qc | cc)
                    if ov > best:
                        best, best_c = ov, c
                if best_c is not None:
                    used.add(best_c)
                    total += best
            scores.append(total)
        order = np.argsort(-np.array(scores))[:k]
        return [int(t) for t in order if scores[t] > 0]
