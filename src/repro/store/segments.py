"""LSM segments: the resident index as an ordered list of immutable runs.

A ``Segment`` is one hash-sorted posting run — exactly the arrays
``build_index`` produces, but (a) table ids are *global* (stable across
mutations, never renumbered by a merge), (b) every array is length-padded
onto a power-of-two ladder so segments of similar size share device shapes
(the jit-cache key), and (c) each segment carries its own bucket offsets,
padded-bucket layout and numeric (table, row) view.

Invariant: a table's postings live wholly inside exactly one segment.  That
keeps per-query match runs contiguous per segment (the seekers' adjacent-
dedupe stays exact) and lets ``drop_table`` of a single-table delta remove
the whole run instead of tombstoning it.

``SegmentStore`` is the mutable collection the executor talks to: it exposes
the same planner/statistics surface as ``UnifiedIndex`` (``host_counts``,
``row_stride``, ``n_tables``, ``storage_bytes``) plus the mutation API.
``n_tables`` is a padded *capacity* (slots), so adding a table within the
headroom keeps every seeker's static shape — and its jit cache — intact.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import hashing
from repro.core.index import (POSTING_KEYS, UnifiedIndex, _ceil_pow2,
                              bucket_offsets_for, concat_postings,
                              numeric_view, sort_postings, table_postings,
                              validate_row_stride)
from repro.core.sketch import SketchConfig, sketch_tables

SEG_PAD_MIN = 256          # smallest padded segment length (postings)
PAD_RANK = np.int32(2 ** 31 - 1)   # pad rank: never < any h_sample


def _pad_len(n: int, lo: int = SEG_PAD_MIN) -> int:
    return _ceil_pow2(max(n, lo))


def _pad_to(a: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full(n, fill, a.dtype)
    out[: len(a)] = a
    return out


@dataclass(eq=False)            # identity semantics: runs are unique objects
class Segment:
    """One immutable sorted posting run (see module docstring).

    Arrays are padded to ``n_padded`` / ``n_num_padded``; only the first
    ``n_real`` / ``n_num`` entries are live postings.  The hash pad sentinel
    (``hashing.MISSING``) sorts last, and probing clamps to ``n_real`` so a
    padded tail can never match (core/match.py ``probe_sorted_bounded``)."""
    cell_hash: np.ndarray        # u32 [n_padded] sorted; MISSING tail
    table_id: np.ndarray         # i32 [n_padded] global table ids
    col_id: np.ndarray
    row_id: np.ndarray
    superkey_lo: np.ndarray
    superkey_hi: np.ndarray
    quadrant: np.ndarray
    rank_conv: np.ndarray
    rank_rand: np.ndarray
    num_perm: np.ndarray         # i32 [n_num_padded] segment-local indices
    num_rowkey: np.ndarray       # i32 [n_num_padded] sorted; int32-max tail
    bucket_bits: int
    bucket_offsets: np.ndarray   # i64 [2^bits + 1] over the real prefix
    n_real: int
    n_num: int
    tables: tuple                # global table ids wholly contained here
    #: approximate tier: {global_table_id: core.sketch.TableSketch}, a pure
    #: function of the live posting arrays + store seed + SketchConfig — so
    #: deltas, merges, snapshot reloads and rebuilds carry identical sketches
    sketches: dict = field(default_factory=dict, repr=False, compare=False)
    #: memoized device uploads, keyed by target device (None = jax default) —
    #: a sharded lake pins each shard's segments to its own mesh device
    _dev: dict = field(default_factory=dict, repr=False, compare=False)
    _dev_buckets: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def n_padded(self) -> int:
        return len(self.cell_hash)

    @property
    def n_num_padded(self) -> int:
        return len(self.num_rowkey)

    def storage_bytes(self) -> int:
        core = sum(getattr(self, k).nbytes for k in POSTING_KEYS)
        return core + self.num_perm.nbytes + self.num_rowkey.nbytes + \
            self.bucket_offsets.nbytes

    # ---------------------------------------------------------------- device
    def device_arrays(self, device=None) -> dict:
        """The jnp-side dict slice this segment contributes to the engine's
        concatenated arrays.  Memoized per target device: a segment is
        immutable, so it is uploaded to each device at most once no matter
        how many engine refreshes it survives.  ``device=None`` uses the jax
        default device; a sharded lake passes each shard's mesh device."""
        if device not in self._dev:
            import jax
            import jax.numpy as jnp
            if device is None:
                put = jnp.asarray
            else:
                def put(a):
                    return jax.device_put(np.asarray(a), device)
            p = self.num_perm
            self._dev[device] = {
                "hash": put(self.cell_hash),
                "table": put(self.table_id),
                "col": put(self.col_id),
                "row": put(self.row_id),
                "sk_lo": put(self.superkey_lo),
                "sk_hi": put(self.superkey_hi),
                "quadrant": put(self.quadrant),
                "rank_conv": put(self.rank_conv),
                "rank_rand": put(self.rank_rand),
                "num_rowkey": put(self.num_rowkey),
                "num_table": put(self.table_id[p]),
                "num_col": put(self.col_id[p]),
                "num_quadrant": put(self.quadrant[p]),
                "num_rank_conv": put(
                    np.where(np.arange(len(p)) < self.n_num,
                             self.rank_conv[p], PAD_RANK)),
                "num_rank_rand": put(
                    np.where(np.arange(len(p)) < self.n_num,
                             self.rank_rand[p], PAD_RANK)),
            }
        return self._dev[device]

    def max_bucket_count(self) -> int:
        return int(np.diff(self.bucket_offsets).max(initial=0))

    def padded_buckets(self, width: int):
        """Padded radix-bucket layout over the *real* prefix (pad postings
        are invisible to the bucket kernel: their payload stays -1)."""
        nb = 1 << self.bucket_bits
        bh = np.full((nb, width), hashing.MISSING, np.uint32)
        bp = np.full((nb, width), -1, np.int32)
        n = self.n_real
        shift = 32 - self.bucket_bits
        buckets = (self.cell_hash[:n] >> shift).astype(np.int64)
        starts = self.bucket_offsets[:-1]
        pos = np.arange(n, dtype=np.int64) - starts[buckets]
        keep = pos < width
        counts = np.diff(self.bucket_offsets)
        overflow = int(np.maximum(counts - width, 0).sum())
        bh[buckets[keep], pos[keep]] = self.cell_hash[:n][keep]
        bp[buckets[keep], pos[keep]] = np.nonzero(keep)[0].astype(np.int32)
        return bh, bp, overflow

    def device_buckets(self, width: int, payload_offset: int = 0,
                       device=None):
        """Device-side (bucket_hashes, bucket_payload) with payloads offset
        into the engine's concatenated arrays; memoized per (width, offset,
        device)."""
        key = (width, payload_offset, device)
        if key not in self._dev_buckets:
            import jax
            import jax.numpy as jnp
            bh, bp, overflow = self.padded_buckets(width)
            assert overflow == 0, "segment bucket layout must be lossless"
            bp = np.where(bp >= 0, bp + payload_offset, -1).astype(np.int32)
            if device is None:
                self._dev_buckets[key] = (jnp.asarray(bh), jnp.asarray(bp))
            else:
                self._dev_buckets[key] = (jax.device_put(bh, device),
                                          jax.device_put(bp, device))
        return self._dev_buckets[key]

    # ------------------------------------------------------------- rekeying
    def with_row_stride(self, row_stride: int) -> "Segment":
        """Re-key the numeric view for a widened stride.  The (table, row)
        permutation is stride-invariant, so only ``num_rowkey`` values are
        recomputed — no re-sort, no re-upload of the posting arrays."""
        p = self.num_perm[: self.n_num]
        rk = self.table_id[p].astype(np.int64) * row_stride + \
            self.row_id[p].astype(np.int64)
        num_rowkey = _pad_to(rk.astype(np.int32), self.n_num_padded,
                             np.int32(2 ** 31 - 1))
        seg = Segment(
            cell_hash=self.cell_hash, table_id=self.table_id,
            col_id=self.col_id, row_id=self.row_id,
            superkey_lo=self.superkey_lo, superkey_hi=self.superkey_hi,
            quadrant=self.quadrant, rank_conv=self.rank_conv,
            rank_rand=self.rank_rand, num_perm=self.num_perm,
            num_rowkey=num_rowkey, bucket_bits=self.bucket_bits,
            bucket_offsets=self.bucket_offsets, n_real=self.n_real,
            n_num=self.n_num, tables=self.tables,
            sketches=self.sketches)    # stride doesn't touch cell content
        if self._dev:
            # only num_rowkey changed: carry the memoized uploads over so
            # widening never re-transfers the posting arrays
            import jax
            import jax.numpy as jnp
            for device, dev in self._dev.items():
                rk_dev = jnp.asarray(num_rowkey) if device is None else \
                    jax.device_put(num_rowkey, device)
                seg._dev[device] = dict(dev, num_rowkey=rk_dev)
        seg._dev_buckets = self._dev_buckets    # hash layout is unchanged
        return seg


def segment_from_arrays(parts: dict, *, bucket_bits: int, row_stride: int,
                        pad_min: int = SEG_PAD_MIN, seed: int = 0,
                        sketch_config: SketchConfig | None = None) -> Segment:
    """Sort + pad concatenated posting arrays into a Segment.

    Every segment-construction path (fresh build, L0 delta, compaction
    merge, snapshot reload) funnels through here, so the per-table sketches
    are computed in exactly one place — from the same posting arrays — and
    stay bit-identical across all of them."""
    parts = sort_postings(parts)
    sketches = sketch_tables(parts, seed=seed,
                             config=sketch_config or SketchConfig())
    n = len(parts["cell_hash"])
    bucket_offsets = bucket_offsets_for(parts["cell_hash"], bucket_bits)
    num_perm, num_rowkey = numeric_view(parts, row_stride)
    n_num = len(num_perm)
    np_ = _pad_len(n, pad_min)
    nnp = _pad_len(n_num, pad_min)
    tables = tuple(np.unique(parts["table_id"]).tolist())
    return Segment(
        cell_hash=_pad_to(parts["cell_hash"], np_, hashing.MISSING),
        table_id=_pad_to(parts["table_id"], np_, 0),
        col_id=_pad_to(parts["col_id"], np_, 0),
        row_id=_pad_to(parts["row_id"], np_, 0),
        superkey_lo=_pad_to(parts["superkey_lo"], np_, 0),
        superkey_hi=_pad_to(parts["superkey_hi"], np_, 0),
        quadrant=_pad_to(parts["quadrant"], np_, -1),
        rank_conv=_pad_to(parts["rank_conv"], np_, PAD_RANK),
        rank_rand=_pad_to(parts["rank_rand"], np_, PAD_RANK),
        num_perm=_pad_to(num_perm, nnp, 0),
        num_rowkey=_pad_to(num_rowkey, nnp, np.int32(2 ** 31 - 1)),
        bucket_bits=bucket_bits, bucket_offsets=bucket_offsets,
        n_real=n, n_num=n_num, tables=tables, sketches=sketches)


def build_segment(entries, *, bucket_bits: int, row_stride: int,
                  seed: int = 0, with_quadrants: bool = True,
                  pad_min: int = SEG_PAD_MIN,
                  sketch_config: SketchConfig | None = None) -> Segment:
    """Build one segment from ``entries`` = [(global_table_id, Table), ...].

    Uses the same per-table posting builder as ``build_index``
    (core/index.py ``table_postings``), so the arrays are bit-identical to
    the slice a from-scratch rebuild would hold for these tables."""
    parts = concat_postings([
        table_postings(tab, tid, seed=seed, with_quadrants=with_quadrants)
        for tid, tab in entries])
    return segment_from_arrays(parts, bucket_bits=bucket_bits,
                               row_stride=row_stride, pad_min=pad_min,
                               seed=seed, sketch_config=sketch_config)


class SegmentStore:
    """Mutable segmented index: base + L0 deltas + tombstones + epoch.

    Executor-facing surface (duck-typed with ``UnifiedIndex``):
    ``n_tables`` (slot capacity), ``max_cols`` (padded), ``row_stride``,
    ``host_counts``, ``n_postings``, ``storage_bytes``, ``epoch``.
    """

    #: slot-capacity headroom: adding this many tables never grows the
    #: score-vector shape (and therefore never retraces the seekers)
    MIN_HEADROOM = 8

    def __init__(self, lake=None, *, bucket_bits: int = 12, seed: int = 0,
                 with_quadrants: bool = True, entries=None,
                 table_names=None, table_cap: int | None = None,
                 row_stride: int | None = None,
                 max_cols: int | None = None,
                 sketch_config: SketchConfig | None = None):
        """Default path: index every table of ``lake`` under global ids
        ``0..n-1``.  Shard path (dist/shard.py): ``entries`` is an explicit
        ``[(global_id, Table), ...]`` subset and ``table_cap`` /
        ``row_stride`` / ``max_cols`` impose the *global* geometry, so every
        shard compiles seekers against identical static shapes and the
        per-shard score vectors sum into the global one slot-for-slot."""
        self.bucket_bits = bucket_bits
        self.seed = seed
        self.with_quadrants = with_quadrants
        self.sketch_config = sketch_config or SketchConfig()
        if entries is None:
            tables = list(lake.tables) if lake is not None else []
            entries = list(enumerate(tables))
            table_names = [t.name for t in tables]
        else:
            entries = list(entries)
            table_names = list(table_names or [])
        owned = [t for _, t in entries]
        n_slots = max(len(table_names),
                      max([g for g, _ in entries], default=-1) + 1)
        table_names += [None] * (n_slots - len(table_names))
        self.table_names = table_names
        self._max_cols_real = max([t.n_cols for t in owned], default=1)
        if max_cols is not None:
            self._max_cols_real = max(self._max_cols_real, max_cols)
        max_rows = max([t.n_rows for t in owned], default=1)
        self.row_stride = row_stride if row_stride is not None else \
            _ceil_pow2(max(max_rows, 1))
        self._table_cap = table_cap if table_cap is not None else \
            _ceil_pow2(max(n_slots + self.MIN_HEADROOM, 16))
        validate_row_stride(self._table_cap, self.row_stride, max_rows)
        self.alive = np.zeros(self._table_cap, bool)
        self.table_rows = np.zeros(self._table_cap, np.int32)
        for gid, tab in entries:
            self.alive[gid] = True
            self.table_rows[gid] = tab.n_rows
        #: ids whose postings are fully gone (safe to hand out again)
        self.free_ids: list = []
        #: dropped ids whose postings still sit tombstoned in some segment
        self.pending_dead: set = set()
        self.epoch = 0
        self.segments: list[Segment] = [build_segment(
            entries, bucket_bits=bucket_bits,
            row_stride=self.row_stride, seed=seed,
            with_quadrants=with_quadrants,
            sketch_config=self.sketch_config)]

    # -------------------------------------------------------------- geometry
    @property
    def n_tables(self) -> int:
        """Slot capacity — the static score-vector length seekers compile
        against (live tables + tombstoned slots + headroom)."""
        return self._table_cap

    @property
    def n_slots(self) -> int:
        return len(self.table_names)

    @property
    def max_cols(self) -> int:
        return _ceil_pow2(max(self._max_cols_real, 4))

    @property
    def n_postings(self) -> int:
        return sum(s.n_real for s in self.segments)

    @property
    def quadrant(self):
        # cost_model only truth-tests this attribute (UnifiedIndex duck type)
        return self.segments[0].quadrant if self.segments else None

    def live_ids(self) -> list:
        return [t for t in range(self.n_slots) if self.alive[t]]

    def storage_bytes(self) -> int:
        return sum(s.storage_bytes() for s in self.segments)

    def bump_epoch(self):
        self.epoch += 1

    def _ensure_nonempty(self):
        # the engine fans out over segments; keep at least one (possibly
        # empty) run so an emptied-out lake still serves (zero-score) queries
        if not self.segments:
            self.segments.append(build_segment(
                [], bucket_bits=self.bucket_bits,
                row_stride=self.row_stride, seed=self.seed,
                with_quadrants=self.with_quadrants,
                sketch_config=self.sketch_config))

    # ------------------------------------------------------------ statistics
    def host_counts(self, q_hashes: np.ndarray,
                    live_only: bool = False) -> np.ndarray:
        """Match counts per query hash summed over segments (planner
        statistics).  ``live_only=False`` (the default) includes tombstoned
        postings — they still occupy probe-window slots, so match capacities
        must cover them; ``live_only=True`` subtracts them for cost
        estimates (core/optimizer.py seeker ranking)."""
        q = np.asarray(q_hashes)
        total = np.zeros(len(q), np.int64)
        for seg in self.segments:
            keys = seg.cell_hash[: seg.n_real]
            lo = np.searchsorted(keys, q, side="left")
            hi = np.searchsorted(keys, q, side="right")
            total += hi - lo
            if live_only:
                dead = ~self.alive[seg.table_id[: seg.n_real]]
                if dead.any():
                    csum = np.concatenate([[0], np.cumsum(dead)])
                    total -= csum[hi] - csum[lo]
        return total

    def shape(self) -> dict:
        """Observable index shape (Session.explain): segment/posting layout,
        tombstones and epoch."""
        return {
            "mode": "live",
            "epoch": self.epoch,
            "segments": len(self.segments),
            "postings_per_segment": [s.n_real for s in self.segments],
            "tables_per_segment": [len(s.tables) for s in self.segments],
            "live_tables": int(self.alive.sum()),
            "tombstoned": sorted(
                self.table_names[t] for t in self.pending_dead),
            "table_slots": self._table_cap,
            "row_stride": self.row_stride,
            "postings": self.n_postings,
        }

    # ------------------------------------------------------------- mutations
    def _alloc_id(self, name: str) -> int:
        if self.free_ids:
            tid = self.free_ids.pop()
            self.table_names[tid] = name
            return tid
        tid = self.n_slots
        if tid >= self._table_cap:
            # validate the grown capacity before mutating any state, so a
            # rejected add leaves the store untouched
            validate_row_stride(self._table_cap * 2, self.row_stride)
            self._table_cap *= 2
            self.alive = _pad_to(self.alive, self._table_cap, False)
            self.table_rows = _pad_to(self.table_rows, self._table_cap, 0)
        self.table_names.append(name)
        return tid

    def grow_capacity(self, new_cap: int):
        """Grow the table-slot capacity to ``new_cap`` (a power of two).
        Changes the static score-vector length every seeker compiles
        against, so the epoch is bumped — a sharded lake must apply the
        same growth (and bump) on *every* shard to keep shapes aligned."""
        if new_cap <= self._table_cap:
            return
        validate_row_stride(new_cap, self.row_stride)
        self._table_cap = new_cap
        self.alive = _pad_to(self.alive, new_cap, False)
        self.table_rows = _pad_to(self.table_rows, new_cap, 0)
        self.bump_epoch()

    def _widen_stride(self, max_rows: int):
        stride = _ceil_pow2(max_rows)
        validate_row_stride(self._table_cap, stride, max_rows)
        self.segments = [s.with_row_stride(stride) for s in self.segments]
        self.row_stride = stride

    def resolve(self, ref) -> int:
        """Table reference (global id or name) -> live global id."""
        if isinstance(ref, str):
            matches = [t for t, n in enumerate(self.table_names)
                       if n == ref and self.alive[t]]
            if not matches:
                raise KeyError(f"no live table named {ref!r}")
            return matches[-1]
        tid = int(ref)
        if not (0 <= tid < self.n_slots and self.alive[tid]):
            raise KeyError(f"table id {tid} is not live")
        return tid

    def add_table(self, table, name: str | None = None,
                  tid: int | None = None) -> int:
        """Index one new table as an L0 delta segment; returns its global
        id.  No existing segment is touched (auto-widening the rowkey stride
        for an unusually long table re-keys, but never re-sorts, the
        numeric views).  ``tid`` pins the global id (sharded lakes allocate
        ids at the coordinator and route the table to one shard)."""
        name = table.name if name is None else name
        if table.n_rows > self.row_stride:
            self._widen_stride(table.n_rows)   # validates before allocating
        if tid is None:
            tid = self._alloc_id(name)
        else:
            if tid in self.free_ids:
                self.free_ids.remove(tid)
            if tid >= self._table_cap:
                cap = self._table_cap
                while tid >= cap:
                    cap *= 2
                self.grow_capacity(cap)
            if tid >= len(self.table_names):
                self.table_names += [None] * (tid + 1 -
                                              len(self.table_names))
            self.table_names[tid] = name
        self.alive[tid] = True
        self.table_rows[tid] = table.n_rows
        self._max_cols_real = max(self._max_cols_real, table.n_cols)
        self.segments.append(build_segment(
            [(tid, table)], bucket_bits=self.bucket_bits,
            row_stride=self.row_stride, seed=self.seed,
            with_quadrants=self.with_quadrants,
            sketch_config=self.sketch_config))
        self.bump_epoch()
        return tid

    def drop_table(self, ref) -> int:
        """Tombstone a table.  If it is the only live table of its segment,
        the whole run is removed (an LSM delete of the run) and the id is
        immediately reusable; otherwise its postings stay masked until the
        next compaction garbage-collects them."""
        tid = self.resolve(ref)
        self.alive[tid] = False
        self.table_rows[tid] = 0
        owner = next((s for s in self.segments if tid in s.tables), None)
        if owner is not None and not any(self.alive[t] for t in owner.tables):
            # every table of the run is dead: drop the run, free the slots
            self.segments.remove(owner)
            for t in owner.tables:
                self.pending_dead.discard(t)
                self.free_ids.append(t)
            self._ensure_nonempty()
        else:
            self.pending_dead.add(tid)
        self.bump_epoch()
        return tid

    def replace_segments(self, old: list, new: Segment | None):
        """Swap ``old`` segments for one merged segment (compaction commit).
        Tombstoned tables whose postings were dropped by the merge become
        free slots."""
        gone = {t for s in old for t in s.tables}
        if new is not None:
            gone -= set(new.tables)
        pos = min(self.segments.index(s) for s in old)
        self.segments = [s for s in self.segments if s not in old]
        if new is not None and new.n_real > 0:
            self.segments.insert(pos, new)
        for t in sorted(gone):
            if t in self.pending_dead:
                self.pending_dead.discard(t)
                self.free_ids.append(t)
        self._ensure_nonempty()
        self.bump_epoch()

    # ---------------------------------------------------------------- export
    def sketch_map(self) -> dict:
        """Live tables' sketches, unioned over segments.  A table's postings
        live wholly inside one segment (module invariant), so the union has
        no conflicts; tombstoned slots are dropped here."""
        out: dict = {}
        for seg in self.segments:
            for t, sk in seg.sketches.items():
                if self.alive[t]:
                    out[t] = sk
        return out

    def live_postings(self, segments=None) -> dict:
        """Concatenated live posting arrays (tombstones dropped, unsorted)
        of ``segments`` (default: all) — the one tombstone-GC collection
        path, shared by compaction merges, snapshots and the sharded lake
        loader (dist/shard.py)."""
        cols = {k: [] for k in POSTING_KEYS}
        for seg in (self.segments if segments is None else segments):
            keep = self.alive[seg.table_id[: seg.n_real]]
            for k in POSTING_KEYS:
                cols[k].append(getattr(seg, k)[: seg.n_real][keep])
        return {k: np.concatenate(v) if v else
                np.zeros(0, getattr(self.segments[0], k).dtype)
                for k, v in cols.items()}

    def merged_index(self) -> UnifiedIndex:
        """A compacted, tombstone-free ``UnifiedIndex`` view of the live
        postings (snapshot persistence consumes this; the store itself is
        not mutated)."""
        parts = sort_postings(self.live_postings())
        num_perm, num_rowkey = numeric_view(parts, self.row_stride)
        return UnifiedIndex(
            cell_hash=parts["cell_hash"], table_id=parts["table_id"],
            col_id=parts["col_id"], row_id=parts["row_id"],
            superkey_lo=parts["superkey_lo"],
            superkey_hi=parts["superkey_hi"], quadrant=parts["quadrant"],
            rank_conv=parts["rank_conv"], rank_rand=parts["rank_rand"],
            num_perm=num_perm, num_rowkey=num_rowkey,
            n_tables=self.n_tables, max_cols=self.max_cols,
            bucket_bits=self.bucket_bits,
            bucket_offsets=bucket_offsets_for(parts["cell_hash"],
                                              self.bucket_bits),
            table_rows=self.table_rows.copy(), row_stride=self.row_stride)
