"""Size-tiered compaction: merge L0 deltas into larger segments off the
hot path.

Probe cost grows linearly with the segment count (each query window fans out
over every segment), so mutations are cheap but queries slowly degrade as
deltas accumulate.  Compaction restores the single-run fast path:

* tiers are powers of two of the live-posting count; when a tier collects
  ``tier_fanout`` runs they merge into one (which lands in a higher tier) —
  the classic size-tiered LSM policy, so each posting is rewritten
  O(log(total) / log(fanout)) times over its lifetime;
* merging drops tombstoned postings (garbage collection) and rebuilds the
  merged segment's bucket offsets and numeric view; freed table slots become
  reusable;
* ``compact_store(store, full=True)`` merges everything into one base
  segment — the state snapshots persist (store/snapshot.py);
* ``maybe_compact`` is the auto-trigger ``LiveLake`` runs after each
  mutation once the segment count crosses ``CompactionPolicy.max_segments``.

Merged segments keep *global* table ids — results and tombstone masks stay
valid across compactions.  ``compact_store(..., reclaim_ids=True)``
additionally remaps table ids onto the dense range [0, n_live), rewriting
the posting arrays' table-id columns; it returns the old->new mapping so
callers can translate previously returned ids.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.store.segments import Segment, SegmentStore, segment_from_arrays


@dataclass(frozen=True)
class CompactionPolicy:
    """Knobs for the auto-trigger (see module docstring)."""
    max_segments: int = 8        # auto-compact when len(segments) exceeds
    tier_fanout: int = 4         # runs per size tier before they merge
    pad_min: int = 256           # padded-length floor for merged segments


def merge_segments(store: SegmentStore, segs: list,
                   pad_min: int = 256) -> Segment | None:
    """Merge ``segs`` into one segment, dropping tombstoned postings.
    Returns None when nothing live remains."""
    parts = store.live_postings(segments=segs)
    if not len(parts["cell_hash"]):
        return None
    return segment_from_arrays(parts, bucket_bits=store.bucket_bits,
                               row_stride=store.row_stride, pad_min=pad_min,
                               seed=store.seed,
                               sketch_config=store.sketch_config)


def _tier(seg: Segment) -> int:
    return max(int(np.log2(max(seg.n_real, 1))), 0)


def maybe_compact(store: SegmentStore,
                  policy: CompactionPolicy | None = None) -> bool:
    """Auto-trigger: while the segment count exceeds the policy threshold,
    merge the fullest size tier (falling back to the smallest runs when no
    tier has collected ``tier_fanout`` members).  Returns True if any merge
    ran."""
    policy = policy or CompactionPolicy()
    ran = False
    while len(store.segments) > policy.max_segments:
        tiers: dict[int, list] = {}
        for s in store.segments:
            tiers.setdefault(_tier(s), []).append(s)
        full = [runs for runs in tiers.values()
                if len(runs) >= policy.tier_fanout]
        if full:
            victims = max(full, key=len)[: policy.tier_fanout]
        else:
            by_size = sorted(store.segments, key=lambda s: s.n_real)
            victims = by_size[: max(policy.tier_fanout, 2)]
        if len(victims) < 2:
            break
        store.replace_segments(victims,
                               merge_segments(store, victims,
                                              policy.pad_min))
        ran = True
    return ran


def compact_store(store: SegmentStore, policy: CompactionPolicy | None = None,
                  full: bool = False, reclaim_ids: bool = False):
    """Explicit compaction.  ``full=True`` merges every segment into one
    base (always garbage-collecting tombstones); otherwise runs the tiered
    policy.  With ``reclaim_ids=True`` (implies full) table ids are remapped
    onto [0, n_live); returns the {old_id: new_id} mapping, else None."""
    if reclaim_ids:
        full = True
    if full:
        victims = list(store.segments)
        merged = merge_segments(store, victims,
                                (policy or CompactionPolicy()).pad_min)
        store.replace_segments(victims, merged)
    else:
        maybe_compact(store, policy or
                      CompactionPolicy(max_segments=1, tier_fanout=2))
    if not reclaim_ids:
        return None
    live = store.live_ids()
    remap = {old: new for new, old in enumerate(live)}
    lut = np.zeros(store.n_tables, np.int32)
    for old, new in remap.items():
        lut[old] = new
    for i, seg in enumerate(store.segments):
        tid = lut[seg.table_id]          # pad rows map to slot 0: masked out
        store.segments[i] = Segment(
            cell_hash=seg.cell_hash, table_id=tid, col_id=seg.col_id,
            row_id=seg.row_id, superkey_lo=seg.superkey_lo,
            superkey_hi=seg.superkey_hi, quadrant=seg.quadrant,
            rank_conv=seg.rank_conv, rank_rand=seg.rank_rand,
            num_perm=seg.num_perm, num_rowkey=seg.num_rowkey,
            bucket_bits=seg.bucket_bits, bucket_offsets=seg.bucket_offsets,
            n_real=seg.n_real, n_num=seg.n_num,
            tables=tuple(sorted(remap[t] for t in seg.tables)),
            # sketches are id-free summaries: remapping is a pure re-keying
            sketches={remap[t]: sk for t, sk in seg.sketches.items()
                      if t in remap},
        ).with_row_stride(store.row_stride)
    names = [store.table_names[old] for old in live]
    rows = np.zeros_like(store.table_rows)
    alive = np.zeros_like(store.alive)
    rows[: len(live)] = store.table_rows[live]
    alive[: len(live)] = True
    store.table_names = names
    store.table_rows = rows
    store.alive = alive
    store.free_ids = []
    store.pending_dead = set()
    store.bump_epoch()
    return remap
