"""Snapshot persistence: a compacted index as ``.npz`` + JSON manifest.

``save`` writes the store's live postings (tombstones garbage-collected, one
merged run) to ``<path>.npz`` and a versioned JSON manifest to
``<path>.json`` holding everything array-free: format version, epoch, lake
stats, table slots/names and the index geometry.  ``load`` restores a fully
queryable ``SegmentStore`` — a server restart skips indexing entirely and
goes straight to device upload (benchmarks/run_all.py records the
load-vs-rebuild speedup in BENCH_3.json).

The snapshot holds array data only; it does not carry the original Table
objects, so a restored store serves queries and accepts new mutations but
cannot re-derive raw cell values.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.index import POSTING_KEYS, _ceil_pow2
from repro.core.sketch import SketchConfig
from repro.store.segments import SegmentStore, segment_from_arrays

SNAPSHOT_FORMAT = "blend-livelake-snapshot"
SNAPSHOT_VERSION = 1


def _paths(path) -> tuple[Path, Path]:
    base = Path(path)
    if base.suffix in (".npz", ".json"):
        base = base.with_suffix("")
    return base.with_suffix(".npz"), base.with_suffix(".json")


def save(store: SegmentStore, path) -> Path:
    """Write the compacted live index; returns the manifest path."""
    npz_path, man_path = _paths(path)
    merged = store.merged_index()
    arrays = {k: getattr(merged, k) for k in POSTING_KEYS}
    n_slots = store.n_slots
    np.savez_compressed(
        npz_path, **arrays,
        table_rows=store.table_rows[:n_slots],
        alive=store.alive[:n_slots])
    manifest = {
        "format": SNAPSHOT_FORMAT,
        "version": SNAPSHOT_VERSION,
        "epoch": store.epoch,
        "bucket_bits": store.bucket_bits,
        "row_stride": store.row_stride,
        "seed": store.seed,
        "with_quadrants": store.with_quadrants,
        "sketch": store.sketch_config.as_dict(),
        "max_cols": store._max_cols_real,
        "table_names": list(store.table_names),
        "lake_stats": {
            "tables": int(store.alive.sum()),
            "slots": n_slots,
            "postings": int(merged.n_postings),
            "numeric_postings": int(len(merged.num_rowkey)),
        },
    }
    man_path.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
    return man_path


def load(path) -> SegmentStore:
    """Restore a queryable ``SegmentStore`` from ``save`` output (no
    re-indexing: no hashing, no superkeys — the saved arrays are re-padded
    into a single base segment; the stable re-sort of an already-sorted run
    is the only array pass)."""
    npz_path, man_path = _paths(path)
    manifest = json.loads(man_path.read_text())
    if manifest.get("format") != SNAPSHOT_FORMAT:
        raise ValueError(f"{man_path} is not a {SNAPSHOT_FORMAT} manifest")
    if manifest.get("version") != SNAPSHOT_VERSION:
        raise ValueError(
            f"snapshot version {manifest.get('version')} unsupported "
            f"(this build reads version {SNAPSHOT_VERSION})")
    with np.load(npz_path) as data:
        parts = {k: data[k] for k in POSTING_KEYS}
        table_rows = data["table_rows"]
        alive = data["alive"]

    store = SegmentStore.__new__(SegmentStore)
    store.bucket_bits = int(manifest["bucket_bits"])
    store.seed = int(manifest["seed"])
    store.with_quadrants = bool(manifest["with_quadrants"])
    # additive manifest key: pre-sketch snapshots load under the default
    # config (sketches are recomputed from the arrays, not persisted)
    store.sketch_config = (SketchConfig.from_dict(manifest["sketch"])
                           if "sketch" in manifest else SketchConfig())
    store.table_names = list(manifest["table_names"])
    store._max_cols_real = int(manifest["max_cols"])
    store.row_stride = int(manifest["row_stride"])
    n_slots = len(store.table_names)
    store._table_cap = _ceil_pow2(
        max(n_slots + SegmentStore.MIN_HEADROOM, 16))
    store.alive = np.zeros(store._table_cap, bool)
    store.alive[:n_slots] = alive
    store.table_rows = np.zeros(store._table_cap, np.int32)
    store.table_rows[:n_slots] = table_rows
    store.free_ids = [t for t in range(n_slots) if not alive[t]]
    store.pending_dead = set()
    store.epoch = int(manifest["epoch"])
    store.segments = [segment_from_arrays(
        parts, bucket_bits=store.bucket_bits, row_stride=store.row_stride,
        seed=store.seed, sketch_config=store.sketch_config)]
    return store
