"""Snapshot persistence: a compacted index as ``.npz`` + JSON manifest.

``save`` writes the store's live postings (tombstones garbage-collected, one
merged run) to ``<path>.npz`` and a versioned JSON manifest to
``<path>.json`` holding everything array-free: format version, epoch, lake
stats, table slots/names and the index geometry.  ``load`` restores a fully
queryable ``SegmentStore`` — a server restart skips indexing entirely and
goes straight to device upload (benchmarks/run_all.py records the
load-vs-rebuild speedup in BENCH_3.json).

Durability hardening (format version 2):

* **per-array checksums** — the manifest carries a crc32 per saved array;
  ``load`` verifies them, so a truncated or bit-flipped ``.npz`` raises a
  typed :class:`~repro.errors.CorruptSnapshot` instead of serving garbage;
* **atomic commit** — arrays and manifest are written to ``.tmp`` files and
  ``os.replace``d into place (manifest last: it is the commit point), so a
  crash mid-save never clobbers the previous good snapshot;
* **generation retention** — each save rotates the previous snapshot to
  ``<path>.npz.g1`` / ``.json.g1`` (up to ``retain`` generations);
  ``load`` falls back through generations on corruption and only raises
  when none validates;
* **WAL watermark** — ``wal_seq`` records the write-ahead-log position the
  snapshot covers, so ``LiveLake.recover`` replays exactly the suffix
  (store/wal.py);
* **sharded lakes** — a ``ShardedStore`` saves every shard's merged run
  into the *same* npz under ``s{i}:`` key prefixes plus one coordinator
  manifest (global geometry, per-shard epochs/names), keeping the
  two-rename commit atomic for the whole mesh.

Version-1 snapshots (no checksums, no ``wal_seq``, no pinned ``table_cap``)
still load; unsupported versions raise ``CorruptSnapshot`` (a
``ValueError``, preserving the old contract).

The snapshot holds array data only; it does not carry the original Table
objects, so a restored store serves queries and accepts new mutations but
cannot re-derive raw cell values.
"""
from __future__ import annotations

import json
import os
import zlib
from pathlib import Path

import numpy as np

from repro import faults, obs
from repro.core.index import POSTING_KEYS, _ceil_pow2
from repro.core.sketch import SketchConfig
from repro.errors import CorruptSnapshot
from repro.store.segments import SegmentStore, segment_from_arrays

SNAPSHOT_FORMAT = "blend-livelake-snapshot"
SNAPSHOT_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)
#: previous generations kept beside the current snapshot
RETAIN_GENERATIONS = 2


def _paths(path) -> tuple[Path, Path]:
    base = Path(path)
    if base.suffix in (".npz", ".json"):
        base = base.with_suffix("")
    return base.with_suffix(".npz"), base.with_suffix(".json")


def _gen_paths(path, g: int) -> tuple[Path, Path]:
    npz, man = _paths(path)
    if g == 0:
        return npz, man
    return Path(f"{npz}.g{g}"), Path(f"{man}.g{g}")


def _rotate(path, retain: int):
    """Shift generations one step: current -> .g1 -> .g2 ... (oldest
    dropped).  ``os.replace`` is atomic per file; a crash between renames
    leaves every touched generation intact under *some* name, which the
    fallback loader tolerates."""
    if retain <= 0:
        return
    oldest = _gen_paths(path, retain)
    for p in oldest:
        if p.exists():
            p.unlink()
    for g in range(retain - 1, -1, -1):
        for src, dst in zip(_gen_paths(path, g), _gen_paths(path, g + 1)):
            if src.exists():
                os.replace(src, dst)


def _checksums(arrays: dict) -> dict:
    return {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
            for k, v in arrays.items()}


def _store_arrays(store: SegmentStore, prefix: str = "") -> dict:
    merged = store.merged_index()
    arrays = {prefix + k: getattr(merged, k) for k in POSTING_KEYS}
    n_slots = store.n_slots
    arrays[prefix + "table_rows"] = store.table_rows[:n_slots]
    arrays[prefix + "alive"] = store.alive[:n_slots]
    return arrays


def _commit(path, arrays: dict, manifest: dict, retain: int) -> Path:
    """Write-temp-then-rename commit of one snapshot generation."""
    npz_path, man_path = _paths(path)
    tmp_npz = Path(f"{npz_path}.tmp")
    tmp_man = Path(f"{man_path}.tmp")
    manifest = dict(manifest, checksums=_checksums(arrays))
    faults.checkpoint("snapshot.write.pre")
    with open(tmp_npz, "wb") as f:
        np.savez_compressed(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(tmp_man, "w") as f:
        f.write(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
        f.flush()
        os.fsync(f.fileno())
    faults.checkpoint("snapshot.rename.pre")
    _rotate(path, retain)
    os.replace(tmp_npz, npz_path)
    os.replace(tmp_man, man_path)         # the commit point
    faults.checkpoint("snapshot.post")
    return man_path


def save(store, path, *, wal_seq: int = 0,
         retain: int = RETAIN_GENERATIONS) -> Path:
    """Write the compacted live index; returns the manifest path.  Accepts
    a single ``SegmentStore`` or a sharded coordinator (``.shards``)."""
    with obs.registry().timer("snapshot.save_seconds"):
        if hasattr(store, "shards"):
            return _save_sharded(store, path, wal_seq=wal_seq,
                                 retain=retain)
        arrays = _store_arrays(store)
        manifest = {
            "format": SNAPSHOT_FORMAT,
            "version": SNAPSHOT_VERSION,
            "epoch": store.epoch,
            "bucket_bits": store.bucket_bits,
            "row_stride": store.row_stride,
            "seed": store.seed,
            "with_quadrants": store.with_quadrants,
            "sketch": store.sketch_config.as_dict(),
            "max_cols": store._max_cols_real,
            "table_cap": store.n_tables,
            "table_names": list(store.table_names),
            "wal_seq": int(wal_seq),
            "lake_stats": {
                "tables": int(store.alive.sum()),
                "slots": store.n_slots,
                "postings": int(len(arrays["cell_hash"])),
            },
        }
        return _commit(path, arrays, manifest, retain)


def _save_sharded(store, path, *, wal_seq: int, retain: int) -> Path:
    arrays: dict = {}
    per_shard: list = []
    for i, s in enumerate(store.shards):
        arrays.update(_store_arrays(s, prefix=f"s{i}:"))
        per_shard.append({"epoch": s.epoch,
                          "table_names": list(s.table_names)})
    manifest = {
        "format": SNAPSHOT_FORMAT,
        "version": SNAPSHOT_VERSION,
        "shards": store.n_shards,
        "per_shard": per_shard,
        "epoch": list(store.epoch),
        "bucket_bits": store.bucket_bits,
        "row_stride": store.row_stride,
        "seed": store.shards[0].seed,
        "with_quadrants": store.shards[0].with_quadrants,
        "sketch": store.sketch_config.as_dict(),
        "max_cols": max(s._max_cols_real for s in store.shards),
        "table_cap": store.n_tables,
        "wal_seq": int(wal_seq),
        "lake_stats": {
            "tables": int(store.alive.sum()),
            "slots": store.n_slots,
            "postings": int(store.n_postings),
        },
    }
    return _commit(path, arrays, manifest, retain)


def _read_arrays(npz_path: Path, manifest: dict, keys: list) -> dict:
    """Load + checksum-verify the named arrays (v1 manifests carry no
    checksums and skip verification)."""
    try:
        with np.load(npz_path) as data:
            out = {k: data[k] for k in keys}
    except FileNotFoundError:
        raise
    except Exception as e:                       # truncated/bit-flipped zip
        raise CorruptSnapshot(f"{npz_path}: unreadable snapshot arrays "
                              f"({e})") from e
    sums = manifest.get("checksums")
    if sums is not None:
        for k, v in out.items():
            want = sums.get(k)
            got = zlib.crc32(np.ascontiguousarray(v).tobytes())
            if want is None or got != want:
                obs.registry().counter("snapshot.checksum_failures").inc()
                raise CorruptSnapshot(
                    f"{npz_path}: checksum mismatch on array {k!r} "
                    f"(expected {want}, got {got})")
    return out


def _new_store(manifest: dict, parts: dict, table_rows, alive,
               table_names: list, epoch: int) -> SegmentStore:
    """Rebuild one queryable ``SegmentStore`` from saved arrays (no
    re-indexing: no hashing, no superkeys — the saved arrays are re-padded
    into a single base segment; the stable re-sort of an already-sorted run
    is the only array pass)."""
    store = SegmentStore.__new__(SegmentStore)
    store.bucket_bits = int(manifest["bucket_bits"])
    store.seed = int(manifest["seed"])
    store.with_quadrants = bool(manifest["with_quadrants"])
    # additive manifest key: pre-sketch snapshots load under the default
    # config (sketches are recomputed from the arrays, not persisted)
    store.sketch_config = (SketchConfig.from_dict(manifest["sketch"])
                           if "sketch" in manifest else SketchConfig())
    store.table_names = list(table_names)
    store._max_cols_real = int(manifest["max_cols"])
    store.row_stride = int(manifest["row_stride"])
    n_slots = len(store.table_names)
    # v2 pins the padded slot capacity — the static score-vector length —
    # so recovery is shape-identical to the uninterrupted run; v1 recomputes
    store._table_cap = int(manifest["table_cap"]) if "table_cap" in manifest \
        else _ceil_pow2(max(n_slots + SegmentStore.MIN_HEADROOM, 16))
    store.alive = np.zeros(store._table_cap, bool)
    store.alive[:n_slots] = alive
    store.table_rows = np.zeros(store._table_cap, np.int32)
    store.table_rows[:n_slots] = table_rows
    store.free_ids = [t for t in range(n_slots) if not alive[t]]
    store.pending_dead = set()
    store.epoch = int(epoch)
    if len(parts["cell_hash"]):
        store.segments = [segment_from_arrays(
            parts, bucket_bits=store.bucket_bits,
            row_stride=store.row_stride, seed=store.seed,
            sketch_config=store.sketch_config)]
    else:
        store.segments = []
        store._ensure_nonempty()
    return store


def _load_one(path, g: int):
    npz_path, man_path = _gen_paths(path, g)
    try:
        manifest = json.loads(man_path.read_text())
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CorruptSnapshot(f"{man_path}: unreadable manifest "
                              f"({e})") from e
    if manifest.get("format") != SNAPSHOT_FORMAT:
        raise CorruptSnapshot(
            f"{man_path} is not a {SNAPSHOT_FORMAT} manifest")
    if manifest.get("version") not in SUPPORTED_VERSIONS:
        raise CorruptSnapshot(
            f"snapshot version {manifest.get('version')} unsupported "
            f"(this build reads versions {SUPPORTED_VERSIONS})")
    if manifest.get("shards"):
        store = _load_sharded(npz_path, manifest)
    else:
        keys = list(POSTING_KEYS) + ["table_rows", "alive"]
        data = _read_arrays(npz_path, manifest, keys)
        parts = {k: data[k] for k in POSTING_KEYS}
        store = _new_store(manifest, parts, data["table_rows"],
                           data["alive"], manifest["table_names"],
                           manifest["epoch"])
    #: the WAL watermark this snapshot covers (LiveLake.recover reads it)
    store.recovered_wal_seq = int(manifest.get("wal_seq", 0))
    return store


def _load_sharded(npz_path: Path, manifest: dict):
    from repro.dist.shard import ShardedStore, make_shard_mesh, shard_devices
    n = int(manifest["shards"])
    keys = [f"s{i}:{k}" for i in range(n)
            for k in list(POSTING_KEYS) + ["table_rows", "alive"]]
    data = _read_arrays(npz_path, manifest, keys)
    store = ShardedStore.__new__(ShardedStore)
    store.n_shards = n
    store.devices = shard_devices(n)
    store.mesh = make_shard_mesh(n)
    store.shards = []
    for i, sec in enumerate(manifest["per_shard"]):
        parts = {k: data[f"s{i}:{k}"] for k in POSTING_KEYS}
        store.shards.append(_new_store(
            manifest, parts, data[f"s{i}:table_rows"], data[f"s{i}:alive"],
            sec["table_names"], sec["epoch"]))
    # per-shard loaders mark every not-owned slot free; recompute globally
    # (a slot is free only if no shard holds it live) and park the free
    # list on shard 0 — the coordinator's _alloc_gid scans all shards
    n_slots = max((len(s.table_names) for s in store.shards), default=0)
    alive_any = np.zeros(n_slots, bool)
    for s in store.shards:
        alive_any[:s.n_slots] |= s.alive[:s.n_slots]
        s.free_ids = []
    store.shards[0].free_ids = [t for t in range(n_slots) if not alive_any[t]]
    return store


def load(path, *, fallback: bool = True):
    """Restore a queryable store from ``save`` output.  On a corrupt
    current snapshot, falls back through retained generations
    (``<path>.npz.g1`` ...) and raises the *first* error only when no
    generation validates.  Missing snapshot -> ``FileNotFoundError``."""
    with obs.registry().timer("snapshot.load_seconds"):
        first_err = None
        g = 0
        while True:
            try:
                store = _load_one(path, g)
                if g:
                    obs.registry().counter(
                        "snapshot.generation_fallbacks").inc()
                return store
            except FileNotFoundError as e:
                if g == 0 and _gen_paths(path, 1)[1].exists():
                    # crash mid-rotation: current gone, older ones remain
                    first_err = CorruptSnapshot(
                        f"current snapshot missing ({e})")
                elif first_err is not None:
                    raise first_err
                else:
                    raise
            except CorruptSnapshot as e:
                if first_err is None:
                    first_err = e
                if not fallback:
                    raise
            g += 1
