"""Write-ahead log for LiveLake mutations: checksummed, append-only,
torn-tail-truncating.

Recovery contract (store/live.py ``LiveLake.recover``): the durable state
of a live lake is *latest snapshot + WAL suffix*.  Every acknowledged
mutation (``add_table`` / ``drop_table`` / ``compact``) appends one record
**after** the in-memory apply and **before** the call returns, so

* a crash before the append loses only an *unacknowledged* mutation —
  the caller never saw it succeed, so snapshot+WAL replay is consistent;
* a crash mid-append leaves a **torn tail**: the record fails its CRC (or
  is short) and nothing valid follows it, so replay truncates it — the
  half-written mutation was likewise never acknowledged;
* a CRC failure with valid records *after* it is real corruption, not a
  torn write, and raises :class:`~repro.errors.WalReplayError` — silently
  truncating there would drop acknowledged mutations.

Record layout (little-endian)::

    u32 magic | u32 payload_len | u32 crc32(payload) | payload (JSON)

Each payload carries a monotone ``seq``; snapshot manifests store the
``wal_seq`` watermark at save time, so replay skips records the snapshot
already contains (the WAL is cleared after a successful snapshot, but the
watermark makes the crash-between-snapshot-and-clear window safe too).

Bit-identity: records log the *allocated* table id (and owning shard, for
sharded lakes) plus the post-mutation epoch, and replay pins all three —
recovered lakes answer queries with ids, scores AND epoch identical to the
uninterrupted run even though the recovered segment layout differs (segment
builds are bit-identical by construction; layout never changes scores).
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from contextlib import contextmanager
from pathlib import Path

import numpy as np

from repro import faults, obs
from repro.errors import WalReplayError

MAGIC = 0x424C5741                      # "BLWA"
_HEADER = struct.Struct("<III")         # magic, payload_len, crc32
#: sanity bound on one record's payload (a Table serialization is ~KBs;
#: anything past this is a corrupt length field, not a real record)
MAX_RECORD_BYTES = 1 << 28


def _json_default(v):
    """Normalize the rare non-JSON cell values exactly as core/hashing.py
    does before hashing (np scalars via bool/int/float, ``str`` fallback),
    so a logged Table *hashes identically* after the WAL round trip.
    Invoked lazily by ``json.dumps`` — plain str/float columns (the common
    case) serialize at C speed with no per-cell Python call."""
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    return str(v)


def _encode(record: dict) -> bytes:
    payload = json.dumps(record, sort_keys=True, separators=(",", ":"),
                         default=_json_default).encode()
    return _HEADER.pack(MAGIC, len(payload), zlib.crc32(payload)) + payload


def _valid_record_at(data: bytes, off: int) -> bool:
    if len(data) - off < _HEADER.size:
        return False
    magic, length, crc = _HEADER.unpack_from(data, off)
    if magic != MAGIC or length > MAX_RECORD_BYTES:
        return False
    start = off + _HEADER.size
    if len(data) - start < length:
        return False
    return zlib.crc32(data[start:start + length]) == crc


def _valid_record_after(data: bytes, start: int) -> bool:
    """Any fully valid record beginning at or after ``start``?  Scans for
    the magic byte pattern — distinguishes a torn tail (nothing valid
    follows) from mid-log corruption (something does)."""
    needle = struct.pack("<I", MAGIC)
    pos = data.find(needle, start)
    while pos != -1:
        if _valid_record_at(data, pos):
            return True
        pos = data.find(needle, pos + 1)
    return False


def scan(path) -> tuple[list, int, bool]:
    """Parse a WAL file.  Returns ``(records, good_bytes, torn)`` where
    ``good_bytes`` is the offset of the first bad byte (== file size when
    clean) and ``torn`` flags a truncatable tail.  Raises
    :class:`WalReplayError` on mid-log corruption."""
    path = Path(path)
    if not path.exists():
        return [], 0, False
    data = path.read_bytes()
    records: list = []
    off = 0
    while off < len(data):
        if not _valid_record_at(data, off):
            # bad header/body at off: torn tail unless a later record is
            # intact (then truncating would drop acknowledged mutations)
            if _valid_record_after(data, off + 1):
                raise WalReplayError(
                    f"{path}: corrupt WAL record at byte {off} with valid "
                    f"records after it — refusing to truncate mid-log")
            return records, off, True
        _, length, _ = _HEADER.unpack_from(data, off)
        start = off + _HEADER.size
        records.append(json.loads(data[start:start + length]))
        off = start + length
    return records, off, False


def recover_records(path) -> tuple[list, int]:
    """Scan + physically truncate a torn tail, so post-recovery appends
    never interleave with garbage.  Returns ``(records, next_seq_floor)``
    — the max seq seen (0 for an empty/missing log)."""
    records, good, torn = scan(path)
    if torn:
        obs.registry().counter("wal.torn_truncated").inc()
        with open(path, "r+b") as f:
            f.truncate(good)
    last = max((int(r.get("seq", 0)) for r in records), default=0)
    return records, last


class WriteAheadLog:
    """Append-only redo log (see module docstring).

    ``fsync=True`` (the default) makes every append durable before the
    mutation is acknowledged; ``fsync=False`` trades the crash-durability
    of the last few records for mutation throughput (data still survives a
    *process* crash — the OS holds the page cache — just not a host crash).

    ``preallocate=N`` allocates the file in N-byte extents up front (the
    etcd/InnoDB redo-log technique): the per-append durability barrier is
    then ``fdatasync`` on a file whose size and extent map never change, so
    no metadata journal commit rides on every acknowledged mutation.  Same
    guarantee, much cheaper — the extent map itself is fsynced once per
    chunk.  Replay treats the zero-filled tail beyond the last record like
    any torn tail: truncated, never replayed."""

    def __init__(self, path, *, fsync: bool = True, start_seq: int = 0,
                 preallocate: int = 0):
        self.path = Path(path)
        self.fsync = fsync
        self.preallocate = int(preallocate)
        self._fd: int | None = None
        self._off = 0                 # logical tail: next append lands here
        self._alloc = 0               # allocated bytes (>= _off)
        scanned = 0
        if self.path.exists() and self.path.stat().st_size:
            # recover_records truncates any torn tail, so after it the file
            # ends exactly at the last durable record
            _, scanned = recover_records(self.path)
            self._off = self.path.stat().st_size
        self._seq = max(int(start_seq), scanned)
        reg = obs.registry()
        self._m_appends = reg.counter("wal.appends")
        self._m_bytes = reg.counter("wal.bytes")
        self._m_fsyncs = reg.counter("wal.fsyncs")

    @property
    def seq(self) -> int:
        """Seq of the last appended (or scanned) record."""
        return self._seq

    def _file(self) -> int:
        if self._fd is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
            self._alloc = os.fstat(self._fd).st_size
        return self._fd

    def _ensure_capacity(self, fd: int, need: int):
        """Preallocate the next extent chunk (and durably commit the new
        extent map once) so per-append barriers are metadata-free."""
        if self._off + need <= self._alloc:
            return
        new = self._off + max(need, self.preallocate)
        try:
            os.posix_fallocate(fd, self._alloc, new - self._alloc)
        except OSError:                 # fs without fallocate: plain appends
            self.preallocate = 0
            return
        os.fsync(fd)
        self._alloc = new

    def append(self, record: dict) -> int:
        """Durably append one record; returns its seq.  The caller applies
        the mutation in memory *first* — a crash in here loses only the
        not-yet-acknowledged mutation."""
        faults.checkpoint("wal.append.pre")
        seq = self._seq + 1
        buf = _encode(dict(record, seq=seq))
        fd = self._file()
        if self.preallocate:
            self._ensure_capacity(fd, len(buf))
        frac = faults.torn_fraction("wal.append.torn")
        if frac is not None:
            # torn write: a seeded strict prefix of the record lands on
            # disk, then the "process" dies — replay must truncate it
            cut = min(len(buf) - 1, max(1, int(len(buf) * frac)))
            os.pwrite(fd, buf[:cut], self._off)
            os.fsync(fd)
            faults.crash_now("wal.append.torn")
        os.pwrite(fd, buf, self._off)
        self._off += len(buf)
        if self.fsync:
            # inside a preallocated extent the size/extent metadata never
            # changes, so fdatasync is a full durability barrier
            (os.fdatasync if self.preallocate else os.fsync)(fd)
            self._m_fsyncs.inc()
        self._seq = seq
        self._m_appends.inc()
        self._m_bytes.inc(len(buf))
        faults.checkpoint("wal.append.post")
        return seq

    def sync(self):
        """Durability barrier: make every appended record durable now."""
        fd = self._file()
        (os.fdatasync if self.preallocate else os.fsync)(fd)
        self._m_fsyncs.inc()

    @contextmanager
    def group(self):
        """Group commit: appends inside the block skip their per-record
        barrier; one :meth:`sync` at exit makes the whole group durable
        (amortizing the device flush across the batch).  The caller must
        not acknowledge any grouped mutation before the block exits — a
        crash inside it loses the unacknowledged suffix, exactly like a
        crash inside a single append."""
        if not self.fsync:
            yield self
            return
        self.fsync = False
        try:
            yield self
        finally:
            self.fsync = True
            self.sync()

    def clear(self):
        """Drop every record (a snapshot now covers them).  The seq counter
        keeps counting — snapshot watermarks stay comparable across
        clears."""
        fd = self._file()
        os.ftruncate(fd, 0)
        self._off = self._alloc = 0
        if self.fsync:
            os.fsync(fd)

    def close(self):
        if self._fd is not None:
            # drop any preallocated zero tail so the file ends at the last
            # record (replay would truncate it anyway)
            os.ftruncate(self._fd, self._off)
            os.close(self._fd)
            self._fd = None

    def __del__(self):
        # release the raw fd on GC (os.open fds are not auto-closed), but
        # WITHOUT close()'s tidy truncation: an abandoned log must look
        # exactly like a crashed process's — recovery handles the tail
        fd, self._fd = self._fd, None
        if fd is not None:
            try:
                os.close(fd)
            except (OSError, TypeError):
                pass

    def __repr__(self):
        return f"WriteAheadLog({str(self.path)!r}, seq={self._seq})"
