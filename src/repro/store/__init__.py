"""LiveLake: incremental index maintenance for evolving lakes.

The resident unified index becomes an ordered list of immutable sorted
segments — one large base plus small L0 deltas — in the LSM style:

* :mod:`repro.store.segments` — ``Segment`` (an immutable sorted posting
  run with its own bucket layout and padded capacity-ladder entry) and
  ``SegmentStore`` (the mutable, engine-facing collection: ``add_table`` /
  ``drop_table`` produce deltas and tombstones, never array rewrites).
* :mod:`repro.store.compact` — size-tiered compaction merging deltas into
  larger segments off the hot path.
* :mod:`repro.store.live` — the ``LiveLake`` facade wired into
  ``blend.connect(lake, live=True)``.
* :mod:`repro.store.snapshot` — versioned ``.npz`` + JSON-manifest
  persistence (checksummed, atomically committed, generation-retained) so
  a server restart skips indexing entirely.
* :mod:`repro.store.wal` — checksummed write-ahead log; snapshot + WAL
  replay (``LiveLake.recover``) survives a crash at any instruction with
  bit-identical query results.

Every mutation bumps the store epoch; executors rebuild their MatchEngine
lazily on the next query, and seeker outputs stay bit-identical to a
from-scratch rebuild of the mutated lake (tests/test_livelake.py).
"""
from repro.store.compact import CompactionPolicy, compact_store, maybe_compact
from repro.store.live import LiveLake
from repro.store.segments import Segment, SegmentStore, build_segment
from repro.store.wal import WriteAheadLog

__all__ = ["CompactionPolicy", "LiveLake", "Segment", "SegmentStore",
           "WriteAheadLog", "build_segment", "compact_store",
           "maybe_compact"]
