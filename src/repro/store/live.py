"""LiveLake: the mutable-lake facade over the segment store.

``blend.connect(lake, live=True)`` builds one of these and wires it into the
Session, so discovery queries keep flowing while the lake evolves::

    session = blend.connect(lake, live=True)
    tid = session.add_table(table)        # L0 delta, no rebuild
    session.query(blend.sc(values))       # observes the new table
    session.drop_table(tid)               # tombstone (or whole-run delete)
    session.compact()                     # merge deltas off the hot path
    session.snapshot("lake.snap")         # .npz + manifest for fast restart

Every mutation bumps the store epoch; executors notice on their next query
and refresh their MatchEngine (device-side concat of the memoized segment
uploads — the host only ever transfers the new delta).  Queries therefore
always observe a consistent epoch: a mutation never changes the index under
a dispatched plan.

``auto_compact`` runs the size-tiered policy (store/compact.py) after each
``add_table`` once the segment count crosses the policy threshold.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

from repro import obs
from repro.store.compact import CompactionPolicy, compact_store, maybe_compact
from repro.store.segments import SegmentStore
from repro.store import snapshot as snap


class LiveLake:
    """Mutable lake handle: tables in, tables out, index stays resident.

    Mutations are serialized under an internal reentrant barrier lock:
    concurrent ``add_table`` / ``drop_table`` / ``compact`` calls (a serving
    tier's mutation queue plus direct user calls) never interleave inside
    the store, and a reader holding :meth:`barrier` pins the epoch — the
    DiscoveryServer wraps each coalesced batch dispatch in it so every batch
    observes exactly one consistent index epoch."""

    def __init__(self, lake=None, *, bucket_bits: int = 12, seed: int = 0,
                 policy: CompactionPolicy | None = None,
                 auto_compact: bool = True, store: SegmentStore | None = None):
        self.store = store if store is not None else SegmentStore(
            lake, bucket_bits=bucket_bits, seed=seed)
        self.policy = policy or CompactionPolicy()
        self.auto_compact = auto_compact
        self._barrier = threading.RLock()
        #: tid -> Table registry for live tables (examples / parity tests;
        #: empty after ``restore`` — snapshots persist arrays, not cells)
        self.tables = {t: tab for t, tab in
                       enumerate(lake.tables)} if lake is not None else {}

    # ------------------------------------------------------------- mutations
    @property
    def epoch(self) -> int:
        return self.store.epoch

    @contextmanager
    def barrier(self):
        """Hold the mutation barrier: while the context is open the store
        epoch cannot move (mutations block), so a whole batch of queries
        dispatches against one consistent index.  Reentrant — a mutation
        running under the server's barrier does not deadlock itself."""
        with self._barrier:
            yield self

    def add_table(self, table, name: str | None = None) -> int:
        with self._barrier, obs.registry().timer("store.add_table_seconds"):
            tid = self.store.add_table(table, name=name)
            self.tables[tid] = table
            if self.auto_compact:
                if hasattr(self.store, "shards"):   # sharded: per-shard tiers
                    self.store.maybe_compact(self.policy)
                else:
                    maybe_compact(self.store, self.policy)
            self._note_shape()
            return tid

    def drop_table(self, ref) -> int:
        with self._barrier, obs.registry().timer("store.drop_table_seconds"):
            tid = self.store.drop_table(ref)
            self.tables.pop(tid, None)
            self._note_shape()
            return tid

    def compact(self, full: bool = True, reclaim_ids: bool = False):
        """Explicit compaction; with ``reclaim_ids`` returns the old->new
        table-id mapping (and re-keys the Table registry)."""
        with self._barrier, obs.registry().timer("store.compact_seconds"):
            if hasattr(self.store, "shards"):    # sharded: shard-local merges
                remap = self.store.compact(self.policy, full=full,
                                           reclaim_ids=reclaim_ids)
                self._note_shape()
                return remap
            remap = compact_store(self.store, self.policy, full=full,
                                  reclaim_ids=reclaim_ids)
            if remap is not None:
                self.tables = {remap[t]: tab for t, tab in
                               self.tables.items() if t in remap}
            self._note_shape()
            return remap

    def _note_shape(self):
        """Post-mutation store-shape gauges.  ``compaction_debt`` is how far
        the segment count sits past the policy threshold — a growing debt
        means mutations are outrunning (or auto-compaction is not keeping up
        with) the size-tiered merge."""
        reg = obs.registry()
        if not reg.enabled:
            return
        s = self.store
        n_seg = len(s.segments)
        n_shards = len(s.shards) if hasattr(s, "shards") else 1
        reg.gauge("store.segments").set(n_seg)
        reg.gauge("store.postings").set(s.n_postings)
        reg.gauge("store.tombstones").set(len(s.pending_dead))
        reg.gauge("store.live_tables").set(len(s.live_ids()))
        reg.gauge("store.compaction_debt").set(
            max(0, n_seg - self.policy.max_segments * n_shards))

    # ----------------------------------------------------------- persistence
    def snapshot(self, path):
        """Save the compacted live index; returns the manifest path."""
        with self._barrier:
            return self._snapshot(path)

    def _snapshot(self, path):
        if hasattr(self.store, "shards"):
            raise NotImplementedError(
                "snapshots of sharded lakes are not supported yet: "
                "snapshot each shard's lake separately or open the lake "
                "unsharded")
        return snap.save(self.store, path)

    @classmethod
    def restore(cls, path, *, policy: CompactionPolicy | None = None,
                auto_compact: bool = True) -> "LiveLake":
        return cls(store=snap.load(path), policy=policy,
                   auto_compact=auto_compact)

    # ------------------------------------------------------------ inspection
    def cache_key(self) -> tuple:
        """``(epoch, store fingerprint)`` — the query-cache invalidation key
        (query/fingerprint.py).  Every mutation above bumps the epoch, so a
        QueryCache validated against this key drops its result/seeker levels
        before the next query can observe the mutated index."""
        from repro.query.fingerprint import index_epoch_key
        return index_epoch_key(self.store)

    def live_ids(self) -> list:
        return self.store.live_ids()

    def shape(self) -> dict:
        return self.store.shape()

    def __repr__(self):
        s = self.store
        return (f"LiveLake(tables={int(s.alive.sum())}, "
                f"segments={len(s.segments)}, postings={s.n_postings}, "
                f"epoch={s.epoch})")
