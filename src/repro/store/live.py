"""LiveLake: the mutable-lake facade over the segment store.

``blend.connect(lake, live=True)`` builds one of these and wires it into the
Session, so discovery queries keep flowing while the lake evolves::

    session = blend.connect(lake, live=True)
    tid = session.add_table(table)        # L0 delta, no rebuild
    session.query(blend.sc(values))       # observes the new table
    session.drop_table(tid)               # tombstone (or whole-run delete)
    session.compact()                     # merge deltas off the hot path
    session.snapshot("lake.snap")         # .npz + manifest for fast restart

Every mutation bumps the store epoch; executors notice on their next query
and refresh their MatchEngine (device-side concat of the memoized segment
uploads — the host only ever transfers the new delta).  Queries therefore
always observe a consistent epoch: a mutation never changes the index under
a dispatched plan.

``auto_compact`` runs the size-tiered policy (store/compact.py) after each
``add_table`` once the segment count crosses the policy threshold.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

from repro import faults, obs
from repro.core.lake import Table
from repro.errors import WalReplayError
from repro.store.compact import CompactionPolicy, compact_store, maybe_compact
from repro.store.segments import SegmentStore
from repro.store import snapshot as snap
from repro.store import wal as walmod


def _pack_table(t: Table) -> dict:
    """WAL-record form of a Table.  Columns go in raw: the WAL encoder's
    ``default=`` hook (store/wal.py ``_json_default``) normalizes exotic
    cell values lazily so they hash identically after the round trip —
    keeping the append hot path free of per-cell Python work."""
    return {"name": t.name,
            "columns": [list(col) for col in t.columns],
            "col_names": list(t.col_names)}


def _unpack_table(d: dict) -> Table:
    return Table(d["name"], d["columns"], list(d["col_names"]))


class LiveLake:
    """Mutable lake handle: tables in, tables out, index stays resident.

    Mutations are serialized under an internal reentrant barrier lock:
    concurrent ``add_table`` / ``drop_table`` / ``compact`` calls (a serving
    tier's mutation queue plus direct user calls) never interleave inside
    the store, and a reader holding :meth:`barrier` pins the epoch — the
    DiscoveryServer wraps each coalesced batch dispatch in it so every batch
    observes exactly one consistent index epoch."""

    def __init__(self, lake=None, *, bucket_bits: int = 12, seed: int = 0,
                 policy: CompactionPolicy | None = None,
                 auto_compact: bool = True, store: SegmentStore | None = None,
                 wal=None):
        self.store = store if store is not None else SegmentStore(
            lake, bucket_bits=bucket_bits, seed=seed)
        self.policy = policy or CompactionPolicy()
        self.auto_compact = auto_compact
        self._barrier = threading.RLock()
        #: tid -> Table registry for live tables (examples / parity tests;
        #: empty after ``restore`` — snapshots persist arrays, not cells)
        self.tables = {t: tab for t, tab in
                       enumerate(lake.tables)} if lake is not None else {}
        #: write-ahead log (path or WriteAheadLog) — when set, every
        #: acknowledged mutation is durably logged; the WAL only covers
        #: *mutations*, so a lake opened non-empty needs one snapshot before
        #: its initial tables are recoverable
        if wal is not None and not hasattr(wal, "append"):
            wal = walmod.WriteAheadLog(wal)
        self.wal = wal

    # ------------------------------------------------------------- mutations
    @property
    def epoch(self) -> int:
        return self.store.epoch

    @contextmanager
    def barrier(self):
        """Hold the mutation barrier: while the context is open the store
        epoch cannot move (mutations block), so a whole batch of queries
        dispatches against one consistent index.  Reentrant — a mutation
        running under the server's barrier does not deadlock itself."""
        with self._barrier:
            yield self

    def add_table(self, table, name: str | None = None, *,
                  tid: int | None = None, shard: int | None = None) -> int:
        """Add one table (L0 delta).  ``tid`` / ``shard`` pin the allocated
        id and destination shard — used by WAL replay so recovery reproduces
        the uninterrupted run's placement exactly."""
        with self._barrier, obs.registry().timer("store.add_table_seconds"):
            faults.checkpoint("store.add.pre")
            sharded = hasattr(self.store, "shards")
            if sharded:
                tid = self.store.add_table(table, name=name, tid=tid,
                                           shard=shard)
            else:
                tid = self.store.add_table(table, name=name, tid=tid)
            self.tables[tid] = table
            if self.auto_compact:
                if sharded:                         # sharded: per-shard tiers
                    self.store.maybe_compact(self.policy)
                else:
                    maybe_compact(self.store, self.policy)
            self._note_shape()
            self._log("add_table", {
                "table": _pack_table(table), "name": name, "tid": tid,
                "shard": self.store.owner_of(tid) if sharded else None})
            faults.checkpoint("store.add.post")
            return tid

    def add_tables(self, tables, names=None) -> list:
        """Bulk ingest with WAL group commit: every table is applied and
        logged like :meth:`add_table`, but the durability barrier runs once
        for the whole batch (the ack — this returning — waits for it).  The
        redo records are identical to N single adds, so recovery replays a
        grouped batch exactly like an ungrouped one."""
        names = list(names) if names is not None else [None] * len(tables)
        with self._barrier:
            if self.wal is not None:
                with self.wal.group():
                    return [self.add_table(t, name=n)
                            for t, n in zip(tables, names)]
            return [self.add_table(t, name=n) for t, n in zip(tables, names)]

    def drop_table(self, ref) -> int:
        with self._barrier, obs.registry().timer("store.drop_table_seconds"):
            faults.checkpoint("store.drop.pre")
            tid = self.store.drop_table(ref)
            self.tables.pop(tid, None)
            self._note_shape()
            self._log("drop_table", {"tid": tid})
            faults.checkpoint("store.drop.post")
            return tid

    def compact(self, full: bool = True, reclaim_ids: bool = False):
        """Explicit compaction; with ``reclaim_ids`` returns the old->new
        table-id mapping (and re-keys the Table registry)."""
        with self._barrier, obs.registry().timer("store.compact_seconds"):
            faults.checkpoint("store.compact.pre")
            if hasattr(self.store, "shards"):    # sharded: shard-local merges
                remap = self.store.compact(self.policy, full=full,
                                           reclaim_ids=reclaim_ids)
            else:
                remap = compact_store(self.store, self.policy, full=full,
                                      reclaim_ids=reclaim_ids)
                if remap is not None:
                    self.tables = {remap[t]: tab for t, tab in
                                   self.tables.items() if t in remap}
            self._note_shape()
            self._log("compact", {"full": bool(full),
                                  "reclaim_ids": bool(reclaim_ids)})
            faults.checkpoint("store.compact.post")
            return remap

    # -------------------------------------------------------------- WAL redo
    def _log(self, op: str, payload: dict):
        """Append one redo record *after* the in-memory apply, *before* the
        mutation call returns (see store/wal.py for the recovery contract).
        ``epoch`` is the post-mutation epoch — replay forces it, because the
        recovered segment layout (one merged base from the snapshot) makes
        auto-compaction trigger at different times than the uninterrupted
        run even though scores are layout-independent."""
        if self.wal is None:
            return
        epoch = self.store.epoch
        rec = {"op": op, **payload,
               "epoch": list(epoch) if isinstance(epoch, tuple) else epoch}
        self.wal.append(rec)

    def _apply_record(self, rec: dict):
        op = rec.get("op")
        if op == "add_table":
            self.add_table(_unpack_table(rec["table"]), name=rec.get("name"),
                           tid=rec["tid"], shard=rec.get("shard"))
        elif op == "drop_table":
            self.drop_table(rec["tid"])
        elif op == "compact":
            self.compact(full=rec.get("full", True),
                         reclaim_ids=rec.get("reclaim_ids", False))
        else:
            raise WalReplayError(f"unknown WAL op {op!r}")
        self._force_epoch(rec["epoch"])

    def _force_epoch(self, epoch):
        if hasattr(self.store, "shards"):
            for s, e in zip(self.store.shards, epoch):
                s.epoch = int(e)
        else:
            self.store.epoch = int(epoch)

    @classmethod
    def recover(cls, path=None, *, wal=None,
                policy: CompactionPolicy | None = None,
                auto_compact: bool = True, shards: int | None = None,
                fsync: bool = True) -> "LiveLake":
        """Rebuild a live lake from durable state: the latest good snapshot
        generation (if ``path`` is given and exists) plus a replay of every
        WAL record past the snapshot's ``wal_seq`` watermark.  Torn WAL
        tails are truncated before replay; the returned lake keeps logging
        to ``wal`` with the seq counter continued, so its next snapshot's
        watermark stays comparable.  The recovered lake answers queries with
        ids, scores and epoch bit-identical to the uninterrupted run."""
        reg = obs.registry()
        with reg.timer("store.recover_seconds"):
            store = None
            watermark = 0
            if path is not None:
                try:
                    store = snap.load(path)
                except FileNotFoundError:
                    store = None            # cold start: WAL-only recovery
                else:
                    watermark = getattr(store, "recovered_wal_seq", 0)
            if store is None and shards:
                from repro.dist.shard import ShardedStore
                store = ShardedStore(None, n_shards=shards)
            lake = cls(None, policy=policy, auto_compact=auto_compact,
                       store=store)
            replayed = 0
            last = 0
            if wal is not None:
                records, last = walmod.recover_records(wal)
                for r in records:
                    if int(r.get("seq", 0)) <= watermark:
                        continue
                    lake._apply_record(r)
                    replayed += 1
                lake.wal = walmod.WriteAheadLog(
                    wal, fsync=fsync, start_seq=max(last, watermark))
            reg.counter("wal.records_replayed").inc(replayed)
            return lake

    def _note_shape(self):
        """Post-mutation store-shape gauges.  ``compaction_debt`` is how far
        the segment count sits past the policy threshold — a growing debt
        means mutations are outrunning (or auto-compaction is not keeping up
        with) the size-tiered merge."""
        reg = obs.registry()
        if not reg.enabled:
            return
        s = self.store
        n_seg = len(s.segments)
        n_shards = len(s.shards) if hasattr(s, "shards") else 1
        reg.gauge("store.segments").set(n_seg)
        reg.gauge("store.postings").set(s.n_postings)
        reg.gauge("store.tombstones").set(len(s.pending_dead))
        reg.gauge("store.live_tables").set(len(s.live_ids()))
        reg.gauge("store.compaction_debt").set(
            max(0, n_seg - self.policy.max_segments * n_shards))

    # ----------------------------------------------------------- persistence
    def snapshot(self, path):
        """Save the compacted live index; returns the manifest path."""
        with self._barrier:
            return self._snapshot(path)

    def _snapshot(self, path):
        seq = self.wal.seq if self.wal is not None else 0
        out = snap.save(self.store, path, wal_seq=seq)
        if self.wal is not None:
            # records up to ``seq`` are covered by the snapshot; clear()
            # keeps the seq counter running so the watermark stays valid
            # even if we crash between the rename and this truncate
            self.wal.clear()
        return out

    @classmethod
    def restore(cls, path, *, policy: CompactionPolicy | None = None,
                auto_compact: bool = True, wal=None) -> "LiveLake":
        return cls(store=snap.load(path), policy=policy,
                   auto_compact=auto_compact, wal=wal)

    # ------------------------------------------------------------ inspection
    def cache_key(self) -> tuple:
        """``(epoch, store fingerprint)`` — the query-cache invalidation key
        (query/fingerprint.py).  Every mutation above bumps the epoch, so a
        QueryCache validated against this key drops its result/seeker levels
        before the next query can observe the mutated index."""
        from repro.query.fingerprint import index_epoch_key
        return index_epoch_key(self.store)

    def live_ids(self) -> list:
        return self.store.live_ids()

    def shape(self) -> dict:
        return self.store.shape()

    def __repr__(self):
        s = self.store
        return (f"LiveLake(tables={int(s.alive.sum())}, "
                f"segments={len(s.segments)}, postings={s.n_postings}, "
                f"epoch={s.epoch})")
