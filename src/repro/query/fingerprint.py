"""Canonical fingerprints: the content-addressed identity of a query.

The query cache (serve/cache.py) keys on *semantic* identity, not on object
identity or source text: ``a & b`` and ``b & a`` must hit the same entry, and
a query written fluently, as BlendQL text, or as a legacy ``Plan`` must all
resolve to one fingerprint when they describe the same work.  Three layers:

* ``fingerprint_spec``  — one seeker leaf.  Query values are rendered through
  the same canonicalization as ``core.hashing.hash_value`` (integral floats
  join like ints) and reduced to the executor's set semantics: SC/KW values
  sort + dedupe; MC tuples dedupe raw, then sort (a tuple's values are
  position-independent in the row-membership validation, so within-tuple
  order is canonicalized away too); C pairs dedupe in written order only —
  the k0/k1 target-mean split is pair-order-sensitive at the ulp level.
* ``fingerprint_expr`` / ``fingerprint_plan`` — the DAG.  Children of
  order-blind combiners are sorted by child fingerprint — union and counter
  at any arity, intersect only at two inputs (``_order_blind``: a permuted
  >= 3-ary f32 score sum can differ by an ulp, so those spellings keep their
  own entries); ``difference`` stays ordered.  Duplicate children are kept:
  a legacy plan that sums a seeker twice is *not* the same computation as
  the folded expression.  Expressions are fingerprinted post-rewrite
  (``rules.canonical_expr``), so nesting differences the flatten rule
  removes never split cache entries.
* ``index_epoch_key`` — the invalidation key ``(epoch, index fingerprint)``:
  any LiveLake mutation bumps the epoch, and the fingerprint pins the cache
  to one resident store so a cache handle can never serve ids from a
  different index object.

Hashes are blake2b over stable literal renderings — never Python ``hash``,
which is salted per process for strings.
"""
from __future__ import annotations

import hashlib
import itertools

import numpy as np

from repro.core.plan import Plan, SeekerSpec
from repro.query import logical as L

_KIND_OF = {L.And: "intersect", L.Or: "union", L.Sub: "difference",
            L.Counter: "counter"}


def _order_blind(kind: str, n_kids: int) -> bool:
    """Is this combiner's result *bit*-independent of its input order?
    Union (elementwise max) and counter (sums of 0/1 mask floats) are exact
    at any arity.  Intersect sums f32 scores sequentially: commutative at 2
    inputs, but at >= 3 a permutation re-associates the sum and fractional
    (QCR) scores can move by an ulp — those spellings must NOT share a cache
    entry, or a hit could differ from that spelling's own cold run."""
    if kind in ("union", "counter"):
        return True
    return kind == "intersect" and n_kids <= 2


def _h(*parts) -> str:
    d = hashlib.blake2b(digest_size=16)
    for p in parts:
        d.update(str(p).encode())
        d.update(b"\x1f")
    return d.hexdigest()


def _literal(v) -> str:
    """Stable literal form of one query value, canonicalized the way
    ``hash_value`` canonicalizes (2.0 joins like 2, bools like ints, numpy
    scalars like their Python equivalents)."""
    if v is None:
        return "none"
    if isinstance(v, (bool, np.bool_)):
        v = int(v)
    elif isinstance(v, np.integer):
        v = int(v)
    elif isinstance(v, np.floating):
        v = float(v)
    elif isinstance(v, str) and type(v) is not str:
        v = str(v)                       # np.str_ and other str subclasses
    if isinstance(v, float) and v.is_integer():
        v = int(v)
    return f"{type(v).__name__}:{v!r}"


def fingerprint_spec(spec: SeekerSpec) -> str:
    """Content hash of one seeker leaf under the executor's set semantics."""
    if spec.kind == "MC":
        # dedupe raw tuples (executor: dict.fromkeys), then canonicalize:
        # within-tuple order is position-independent, the tuple *multiset*
        # is not (two permuted duplicates score twice)
        tuples = list(dict.fromkeys(spec.values))
        q = sorted("|".join(sorted(_literal(v) for v in t)) for t in tuples)
        return _h("seek", "MC", spec.k, *q)
    if spec.kind == "C":
        # pairs dedupe in written order but are NOT sorted: the executor's
        # k0/k1 split thresholds on tgt.mean(), and an f64 mean over permuted
        # pairs can move by an ulp and flip a boundary qbit — permuted corr
        # spellings are different computations and keep their own entries
        pairs = list(dict.fromkeys(zip(spec.values, spec.target)))
        q = [f"{_literal(a)}->{_literal(b)}" for a, b in pairs]
        return _h("seek", "C", spec.k, spec.h, spec.sampling, *q)
    # SC / KW: plain IN (...) set semantics
    q = sorted({_literal(v) for v in spec.values})
    return _h("seek", spec.kind, spec.k, *q)


def fingerprint_expr(e: L.Expr) -> str:
    """Content hash of a logical expression DAG (hash-consed or not — shared
    and duplicated-but-equal subtrees fingerprint identically).  Canonical
    caching should fingerprint the *rewritten* tree (``fingerprint_query``)
    so flatten/fold normalization is already applied."""
    memo: dict = {}

    def fp(n: L.Expr) -> str:
        got = memo.get(n)
        if got is not None:
            return got
        if isinstance(n, L.Seek):
            f = fingerprint_spec(n.spec())
        else:
            kids = [fp(c) for c in n.children()]
            kind = _KIND_OF[type(n)]
            if _order_blind(kind, len(kids)):
                kids = sorted(kids)
            k = n.k if n.k is not None else L.UNCUT
            f = _h("comb", kind, k, *kids)
        memo[n] = f
        return f

    return fp(e)


def fingerprint_query(e: L.Expr, top: int | None = None) -> str:
    """Normalize through the rewrite rules, then fingerprint — the canonical
    query identity (``(a & b).fingerprint() == (b & a).fingerprint()``,
    nested vs flat AND chains collapse, duplicate siblings fold)."""
    from repro.query.rules import canonical_expr
    return fingerprint_expr(canonical_expr(e, top=top))


def fingerprint_plan(plan: Plan) -> str:
    """Content hash of a physical plan DAG from its output node.  Produces
    the same digest as ``fingerprint_expr`` on the expression it was lowered
    from (combiners with ``k=None`` lower to ``UNCUT``), so legacy plans and
    BlendQL expressions share cache entries."""
    memo: dict = {}

    def fp(name: str) -> str:
        got = memo.get(name)
        if got is not None:
            return got
        node = plan.nodes[name]
        if node.is_seeker:
            f = fingerprint_spec(node.spec)
        else:
            kids = [fp(d) for d in node.deps]
            if _order_blind(node.spec.kind, len(kids)):
                kids = sorted(kids)
            f = _h("comb", node.spec.kind, node.spec.k, *kids)
        memo[name] = f
        return f

    if plan.output is None:
        raise ValueError("cannot fingerprint an empty plan")
    return fp(plan.output)


_NONCES = itertools.count(1)


def object_nonce(obj) -> int:
    """Process-unique identity stamp for one object (index, cost model...).
    ``id()`` is not enough: CPython reuses freed addresses, so a shared
    QueryCache could match a dead object's key against a same-shaped
    successor — a nonce lives exactly as long as the object and is never
    reused.  Falls back to ``id`` for objects that refuse attributes."""
    n = getattr(obj, "_cache_nonce", None)
    if n is None:
        n = next(_NONCES)
        try:
            obj._cache_nonce = n
        except AttributeError:
            return id(obj)
    return n


def index_fingerprint(index) -> str:
    """Identity of the resident index object (static ``UnifiedIndex`` or a
    LiveLake ``SegmentStore``).  Together with the epoch this is the cache
    invalidation key: same process, same store, same epoch — anything else
    never matches."""
    kind = "store" if hasattr(index, "segments") else "static"
    return _h(kind, object_nonce(index), index.n_tables, index.n_postings,
              index.row_stride)


def index_epoch_key(index) -> tuple:
    """``(epoch, index fingerprint)`` — every LiveLake mutation
    (``add_table`` / ``drop_table`` / ``compact``) bumps the epoch, so a
    cache validated against this key can never serve stale table ids.
    Static indexes are immutable: epoch pinned to 0."""
    return (getattr(index, "epoch", 0), index_fingerprint(index))
