"""Lowering: logical IR -> the existing physical ``Plan`` DAG.

The physical executor (core/executor.py) and optimizer (core/optimizer.py)
stay the backend unchanged — lowering just emits ``Plan.add`` calls.  Node
names are deterministic (``sc0, and1, ...`` in post-order), and emission is
memoized per interned IR node: after the rewriter's hash-consing, a subtree
shared by two branches becomes ONE plan node, which the executor's per-name
memo then runs exactly once.
"""
from __future__ import annotations

from repro.core.plan import CombinerSpec, Plan
from repro.query import logical as L

_KINDS = {L.And: "intersect", L.Or: "union", L.Sub: "difference",
          L.Counter: "counter"}


def lower(e: L.Expr) -> tuple[Plan, dict]:
    """Emit a physical plan for ``e``.  Returns ``(plan, node_of)`` where
    ``node_of`` maps each IR node to its plan-node name.  Combiners with
    ``k=None`` lower cut-free (``UNCUT``); a seeker root keeps its own k."""
    plan = Plan()
    node_of: dict = {}
    counts: dict = {}

    def name_for(tag: str) -> str:
        i = counts.get(tag, 0)
        counts[tag] = i + 1
        return f"{tag}{i}"

    def emit(n: L.Expr) -> str:
        got = node_of.get(n)
        if got is not None:
            return got
        if isinstance(n, L.Seek):
            name = name_for(n.kind.lower())
            plan.add(name, n.spec())
        else:
            deps = [emit(c) for c in n.children()]
            kind = _KINDS[type(n)]
            k = n.k if n.k is not None else L.UNCUT
            name = name_for(kind)
            plan.add(name, CombinerSpec(kind, k), deps)
        node_of[n] = name
        return name

    out = emit(e)
    plan.output = out
    return plan, node_of
