"""The unified Session API: ``blend.connect(lake) -> Session``.

A Session owns the resident unified index + executor and compiles BlendQL
(fluent expressions or SQL strings) through the full stack::

    parse/IR -> rewrite (rules.py) -> lower (lower.py) -> Plan
             -> optimize + execute (core/optimizer.py, core/executor.py)

``session.query`` and ``session.sql`` return a ``QueryResult``;
``session.explain`` additionally renders the logical tree, the applied
rewrite rules, the ranked physical order and per-node timings.  Legacy
physical ``Plan`` objects are accepted everywhere an expression is — the
old ``Plan.add`` frontend keeps working on top of the same entry point.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.cost_model import CostModel
from repro.core.executor import ExecInfo, Executor
from repro.core.index import build_index
from repro.core.optimizer import optimize as optimize_plan
from repro.core.plan import Plan
from repro.core.sketch import ApproxParams
from repro.query import logical as L
from repro.query.lower import lower
from repro.query.parse import parse
from repro.query.rules import prune_dead_nodes, rewrite


@dataclass
class Compiled:
    """Output of the logical pipeline, ready for (repeated) execution."""
    plan: Plan
    logical: L.Expr | None            # rewritten IR (None for legacy plans)
    raw: L.Expr | None                # IR as written, pre-rewrite
    applied_rules: list = field(default_factory=list)
    node_of: dict = field(default_factory=dict)   # IR node -> plan-node name


@dataclass
class QueryResult:
    result: object                    # core.combiners.ResultSet (device-side)
    info: ExecInfo
    compiled: Compiled
    seconds: float
    _ids: list | None = None
    cache: object | None = None       # serve.cache.CacheInfo (None: cache off)
    _entry: object | None = None      # backing CachedResult on cache hits
    #: core.sketch.ApproxInfo when the query ran with ``approx=`` (estimates,
    #: intervals, escalation accounting); None on the exact path
    approx: object | None = None

    @property
    def scores(self):
        """Dense f32 [n_tables] score vector (device array — reading it from
        the host synchronizes; serve_many drains the device first)."""
        return self.result.scores

    @property
    def ids(self) -> list:
        """Ranked table ids, score-descending (materialized lazily so a
        ``sync=False`` dispatch stays host-synchronization-free; a cache hit
        writes the list back into its entry so later hits skip the sort)."""
        if self._ids is None:
            self._ids = [int(t) for t in self.result.ids()]
            if self._entry is not None and self._entry.ids is None:
                self._entry.ids = self._ids
        return self._ids

    def materialize(self, scores_np, mask_np) -> list:
        """Install ids from already-fetched host arrays (``serve_many``
        fetches a whole batch's (scores, mask) pairs in one transfer).
        Ranking goes through ``ResultSet.rank`` — the same code path the
        lazy ``ids`` property uses — and the cache-entry write-back
        semantics match it exactly."""
        if self._ids is None:
            from repro.core.combiners import ResultSet
            self._ids = [int(t) for t in ResultSet.rank(scores_np, mask_np)]
            if self._entry is not None and self._entry.ids is None:
                self._entry.ids = self._ids
        return self._ids

    @property
    def applied_rules(self):
        return self.compiled.applied_rules

    def __iter__(self):
        return iter(self.ids)


@dataclass
class Explain:
    logical_tree: str
    applied_rules: list
    physical_order: dict              # intersect node -> ranked seeker names
    exec_order: list                  # actual execution order (ExecInfo)
    node_seconds: dict
    overflow: int
    ids: list
    launches: int = 0                 # device-program dispatches (ExecInfo)
    index_shape: dict = field(default_factory=dict)   # live-lake observability
    cache: dict = field(default_factory=dict)         # query-cache telemetry
    server: dict = field(default_factory=dict)        # front-tier telemetry
    metrics: dict = field(default_factory=dict)       # obs registry snapshot

    def __str__(self):
        lines = ["== logical plan =="]
        lines += [self.logical_tree]
        lines.append("== rewrite rules applied ==")
        lines += [f"  - {r}" for r in self.applied_rules] or ["  (none)"]
        if self.index_shape:
            s = self.index_shape
            lines.append("== index ==")
            if s.get("shards"):
                mesh = "x".join(str(d) for d in s["mesh_shape"])
                lines.append(f"  mode: {s['mode']}   mesh: {mesh} "
                             f"({s['shards']} shards)   "
                             f"epoch: {s['epoch']}")
                for p in s["per_shard"]:
                    lines.append(f"  shard {p['shard']}: "
                                 f"segments: {p['segments']}   "
                                 f"postings: {p['postings']}   "
                                 f"tables: {p['live_tables']}   "
                                 f"tombstones: {p['tombstones']}   "
                                 f"[{p['device']}]")
            else:
                lines.append(f"  mode: {s['mode']}   epoch: {s['epoch']}   "
                             f"segments: {s['segments']}")
                lines.append(
                    f"  postings/segment: {s['postings_per_segment']}")
            lines.append(f"  live tables: {s['live_tables']}"
                         + (f"   tombstoned: {s['tombstoned']}"
                            if s["tombstoned"] else ""))
        if self.cache:
            c = self.cache
            lines.append("== cache ==")
            lines.append(f"  status: {c['status']}   "
                         f"seekers: {c['seekers_run']} run / "
                         f"{c['seekers_cached']} cached   "
                         f"epoch: {c['epoch']}")
            lines.append(f"  entries: {c['entries']}   bytes: {c['bytes']}   "
                         f"evictions: {c['evictions']}   "
                         f"invalidations: {c['invalidations']}")
        if self.server:
            s = self.server
            depth = s["queue_depth"]
            lines.append("== server ==")
            lines.append(
                "  queue depth: "
                + "   ".join(f"{k}: {v}" for k, v in depth.items()))
            occ = s["lane_occupancy"]
            lines.append(
                "  lane occupancy: "
                + "   ".join(f"{k}: {v['depth']}/{v['max_queue']}"
                             for k, v in occ.items()))
            lines.append(f"  served: {s['served']}   "
                         f"shed: {s['shed']['total']} "
                         f"(rate_limit: {s['shed'].get('rate_limit', 0)}, "
                         f"queue_full: {s['shed'].get('queue_full', 0)})")
            lines.append(f"  batches: {s['batches']['formed']}   "
                         f"mean size: {s['batches']['mean_size']:.2f}   "
                         f"launches/batch: "
                         f"{s['launches']['per_batch_mean']:.2f}")
        if self.metrics:
            m = self.metrics
            lines.append("== metrics ==")
            for name, v in m.get("counters", {}).items():
                lines.append(f"  {name:<40s} {v:,.0f}")
            for name, v in m.get("gauges", {}).items():
                lines.append(f"  {name:<40s} {v:,.1f}")
            for name, h in m.get("histograms", {}).items():
                scale, unit = (1e3, "ms") if "seconds" in name \
                    else (1.0, "")
                lines.append(f"  {name:<40s} n={h['count']:<7d} "
                             f"p50={h['p50'] * scale:9.3f}{unit} "
                             f"p95={h['p95'] * scale:9.3f}{unit} "
                             f"p99={h['p99'] * scale:9.3f}{unit}")
        lines.append("== physical order (ranked execution groups) ==")
        if self.physical_order:
            for comb, seekers in self.physical_order.items():
                lines.append(f"  {comb}: {' -> '.join(seekers)}")
        else:
            lines.append("  (no reorderable intersection groups)")
        if self.exec_order:
            lines.append("== execution ==")
            lines.append(f"  order: {' -> '.join(self.exec_order)}")
            for name in self.exec_order:
                if name in self.node_seconds:
                    lines.append(f"  {name:<14s} "
                                 f"{self.node_seconds[name]*1e3:8.2f} ms")
            lines.append(f"  launches: {self.launches}")
            lines.append(f"  overflow: {self.overflow}")
            lines.append(f"  top tables: {list(self.ids)[:10]}")
        return "\n".join(lines)


class Session:
    """A connection to one lake: resident index, compiled-seeker cache,
    cost model, and the BlendQL compile pipeline.  Over a live lake
    (``connect(lake, live=True)``) the Session additionally exposes the
    mutation API — ``add_table`` / ``drop_table`` / ``compact`` /
    ``snapshot`` — and ``explain`` reports the index shape (segments,
    postings, tombstones, epoch).  With ``connect(lake, cache=True)`` the
    Session also owns a semantic QueryCache (serve/cache.py): plan, result
    and seeker levels keyed on canonical fingerprints and invalidated by
    ``(epoch, index fingerprint)``."""

    def __init__(self, executor: Executor, lake=None,
                 cost_model: CostModel | None = None, live=None, cache=None):
        self.executor = executor
        self.lake = lake
        self.cost_model = cost_model
        self.live = live                  # LiveLake handle or None
        self.cache = cache                # serve.cache.QueryCache or None
        self._plan_memo = {}              # cache-off compile memo (bounded)

    @property
    def index(self):
        return self.executor.index

    def _cache_config(self) -> tuple:
        """The execution-identity part of the cache key: entries produced
        under different executor opts (capacity ladder, probe backend) or a
        different cost model (seeker ranking -> f32 sum order) are different
        computations and must never cross-serve (serve/cache.py begin)."""
        from repro.query.fingerprint import object_nonce
        ex = self.executor
        return (ex.backend, ex.interpret, ex.m_cap_max, ex.row_cap,
                ex.bucket_width, getattr(ex, "n_shards", 0),
                object_nonce(self.cost_model)
                if self.cost_model is not None else 0)

    # ------------------------------------------------------------ mutations
    def _require_live(self):
        if self.live is None:
            raise RuntimeError("this session is static; open one with "
                               "blend.connect(lake, live=True) to mutate")
        return self.live

    def add_table(self, table, name: str | None = None) -> int:
        """Index one new table without a rebuild; returns its table id."""
        return self._require_live().add_table(table, name=name)

    def add_tables(self, tables, names=None) -> list:
        """Bulk ingest: one WAL group commit covers the whole batch."""
        return self._require_live().add_tables(tables, names=names)

    def drop_table(self, ref) -> int:
        """Drop a table (id or name): tombstoned, or whole-run removed."""
        return self._require_live().drop_table(ref)

    def compact(self, full: bool = True, reclaim_ids: bool = False):
        """Merge delta segments off the hot path (store/compact.py)."""
        return self._require_live().compact(full=full,
                                            reclaim_ids=reclaim_ids)

    def snapshot(self, path):
        """Persist the compacted index; reload with ``blend.restore``."""
        return self._require_live().snapshot(path)

    def index_shape(self) -> dict:
        """Observable index layout (also rendered by ``explain``)."""
        idx = self.executor.index
        if hasattr(idx, "shape"):
            return idx.shape()
        return {"mode": "static", "epoch": 0, "segments": 1,
                "postings_per_segment": [idx.n_postings],
                "tables_per_segment": [idx.n_tables],
                "live_tables": idx.n_tables, "tombstoned": [],
                "table_slots": idx.n_tables, "row_stride": idx.row_stride,
                "postings": idx.n_postings}

    # ---------------------------------------------------------------- compile
    def compile(self, q, top: int | None = None) -> Compiled:
        """Expression / BlendQL string / legacy Plan -> Compiled.  With the
        query cache enabled, compiled plans are memoized by query content
        (strings and expressions are hashable; compilation is
        index-independent, so plan entries survive epoch changes)."""
        plan_key = None
        if isinstance(q, (str, L.Expr)):
            plan_key = (q, top)
            got = self.cache.get_plan(plan_key) if self.cache is not None \
                else self._plan_memo.get(plan_key)
            if got is not None:
                return got
        if isinstance(q, str):
            q = parse(q)
        if isinstance(q, Plan):
            # legacy frontend: dead-subtree pruning is the only safe rewrite;
            # prune a copy so the caller-owned Plan is never mutated
            plan = q.copy()
            removed = prune_dead_nodes(plan)
            applied = ["prune_dead_nodes"] if removed else []
            return Compiled(plan=plan, logical=None, raw=None,
                            applied_rules=applied)
        if not isinstance(q, L.Expr):
            raise TypeError(f"cannot compile {type(q)!r}: expected a BlendQL "
                            f"expression, SQL string, or Plan")
        rewritten = rewrite(q, top=top)
        plan, node_of = lower(rewritten.expr)
        prune_dead_nodes(plan)        # lowering emits none; shared traversal
        compiled = Compiled(plan=plan, logical=rewritten.expr, raw=q,
                            applied_rules=list(rewritten.applied),
                            node_of=node_of)
        if plan_key is not None:
            if self.cache is not None:
                self.cache.put_plan(plan_key, compiled)
            else:
                # compilation is index-independent (same contract the cache
                # path relies on), so a cache-off session can still memoize
                # hot-query plans — this keeps rewrite+lower off the warm
                # serving path.  FIFO-bounded: serving mixes are small.
                if len(self._plan_memo) >= 512:
                    self._plan_memo.pop(next(iter(self._plan_memo)))
                self._plan_memo[plan_key] = compiled
        return compiled

    # ---------------------------------------------------------------- execute
    def query(self, q, top: int | None = None, optimize: bool = True,
              sync: bool = True, fused: bool = False,
              approx=False) -> QueryResult:
        """Compile + execute; ``top`` overrides/sets the root result limit.

        With the query cache enabled (``connect(lake, cache=True)``) the
        request is first validated against the ``(epoch, index fingerprint)``
        key, then served from the exact-result cache when the canonical plan
        fingerprint matches; otherwise the executor runs with the subplan
        cache, which short-circuits unrestricted seeker runs (a 'partial'
        hit).  Results are bit-identical to a cold run in every case.

        ``fused=True`` executes on the fused path (core/fused.py): batched
        same-kind seeker dispatch + a single whole-DAG device program,
        ``ExecInfo.launches <= n_kinds + 1`` — bit-identical results.

        ``approx=True`` (or ``{"epsilon": .., "confidence": ..}`` /
        an ``ApproxParams``) answers from the sketch tier (core/sketch.py):
        per-table estimates with confidence intervals replace the exact
        probe, and only the contended boundary of the top-k ranking — tables
        whose interval both reaches the k-th-place threshold and is wider
        than ``epsilon`` — escalates to the exact path.  At ``epsilon=0``
        the returned ids are identical to the exact query's.  The result's
        ``approx`` field carries the estimates, intervals and escalation
        accounting."""
        compiled = q if isinstance(q, Compiled) else self.compile(q, top=top)
        params = ApproxParams.of(approx)
        if params is not None:
            return self._query_approx(compiled, params, optimize=optimize,
                                      sync=sync, fused=fused)
        cache = self.cache
        t0 = time.perf_counter()
        if cache is None:
            rs, info = self.executor.run(compiled.plan, optimize=optimize,
                                         cost_model=self.cost_model,
                                         sync=sync, fused=fused)
            return QueryResult(result=rs, info=info, compiled=compiled,
                               seconds=time.perf_counter() - t0)
        cache.begin(self.executor.index, self._cache_config())
        rkey = cache.result_key(compiled.plan, optimize)
        entry = cache.get_result(rkey)
        if entry is not None:
            return self._hit_result(entry, compiled, sync,
                                    time.perf_counter() - t0)
        rs, info = self.executor.run(compiled.plan, optimize=optimize,
                                     cost_model=self.cost_model, sync=sync,
                                     cache=cache, fused=fused)
        return self._record_result(rkey, rs, info, compiled,
                                   time.perf_counter() - t0)

    def _hit_result(self, entry, compiled, sync, seconds) -> QueryResult:
        """Serve one exact-result cache hit (shared by query/query_many)."""
        cache = self.cache
        cache.note("hit")
        # ids materialize through the lazy property (written back into
        # the entry): a sync=False hit on an entry stored earlier in the
        # same undrained batch must not block the dispatch loop
        if sync and entry.ids is None:
            entry.ids = [int(t) for t in entry.result.ids()]
        return QueryResult(result=entry.result, info=entry.info,
                           compiled=compiled, seconds=seconds,
                           _ids=entry.ids, cache=cache.request_info("hit"),
                           _entry=entry)

    def _record_result(self, rkey, rs, info, compiled,
                       seconds) -> QueryResult:
        """Store one executed result into the cache and wrap it (shared by
        query/query_many)."""
        cache = self.cache
        from repro.serve.cache import CachedResult   # lazy: avoids a cycle
        cache.put_result(rkey, CachedResult(result=rs, info=info,
                                            plan_nodes=len(
                                                compiled.plan.nodes)),
                         n_tables=self.executor.n_tables)
        status = "partial" if info.cached_nodes else "miss"
        cache.note(status)
        cinfo = cache.request_info(status,
                                   seekers_cached=len(info.cached_nodes),
                                   seekers_run=info.seeker_runs)
        return QueryResult(result=rs, info=info, compiled=compiled,
                           seconds=seconds, cache=cinfo)

    # ----------------------------------------------------------------- approx
    def _query_approx(self, compiled, params, *, optimize, sync,
                      fused) -> QueryResult:
        """Sketch-tier execution (``query(approx=...)``).

        Single-seeker SC/KW/C plans answer from the per-table sketch
        estimates; the escalation set (core/sketch.py) is the contended
        boundary of the ranking — when it is non-empty the exact plan runs
        (through the normal cached path, so the work is shared with exact
        queries) and its ResultSet is returned wholesale, which makes the
        ``epsilon=0`` identity guarantee trivial on that branch.  Multi-node
        plans and MC seekers have no sketch estimator and fall back to exact
        with ``approx.fallback`` set.  Approx results are cached under their
        own key (plan fingerprint + epsilon/confidence + kind), never
        cross-served with exact entries."""
        from repro import obs
        from repro.core import sketch as sk
        from repro.obs import trace as otrace

        t0 = time.perf_counter()
        plan = compiled.plan
        out_node = plan.nodes[plan.output]
        cache = self.cache
        rkey = None
        if cache is not None:
            cache.begin(self.executor.index, self._cache_config())
            rkey = cache.result_key(plan, optimize, approx=params.key())
            entry = cache.get_result(rkey)
            if entry is not None:
                res = self._hit_result(entry, compiled, sync,
                                       time.perf_counter() - t0)
                res.approx = getattr(entry, "approx", None)
                return res
        reg = obs.registry() if obs.enabled() else None
        if reg is not None:
            reg.counter("approx.queries").inc()
        fallback = None
        if not (len(plan.nodes) == 1 and out_node.is_seeker):
            fallback = "multi-node-plan"
        elif out_node.spec.kind == "MC":
            fallback = "mc-no-estimator"
        if fallback is not None:
            if reg is not None:
                reg.counter("approx.fallbacks").inc()
            ainfo = sk.ApproxInfo(
                params=params,
                kind=out_node.spec.kind if out_node.is_seeker else "plan",
                estimator="exact-fallback", escalated=0, candidates=0,
                threshold=0.0, fallback=fallback)
            return self._exact_for_approx(compiled, ainfo, rkey,
                                          optimize, sync, fused, t0)
        spec = out_node.spec
        with otrace.current().span("approx.query", kind=spec.kind):
            probe = self.executor.sketch_probe(spec, params.confidence)
            esc, candidates, thresh = sk.escalation_set(probe, spec.k, params)
        ainfo = sk.ApproxInfo(
            params=params, kind=spec.kind, estimator=probe.estimator,
            escalated=len(esc), candidates=candidates, threshold=thresh,
            est=probe.est, ci_lo=probe.ci_lo, ci_hi=probe.ci_hi,
            escalated_ids=[int(t) for t in esc],
            probe_seconds=probe.seconds)
        if reg is not None:
            reg.counter("approx.candidates").inc(candidates)
            reg.counter("approx.escalated_tables").inc(len(esc))
        if len(esc):
            if reg is not None:
                reg.counter("approx.escalations").inc()
            return self._exact_for_approx(compiled, ainfo, rkey,
                                          optimize, sync, fused, t0)
        import jax.numpy as jnp

        from repro.core import combiners as comb
        rs = comb.topk_result(jnp.asarray(probe.est, jnp.float32), spec.k)
        if sync:
            rs.scores.block_until_ready()
        # the probe is host-side (0 launches); the top-k select is 1 program
        info = ExecInfo(optimized=optimize, launches=probe.launches + 1)
        info.node_seconds[plan.output] = probe.seconds
        info.order.append(plan.output)
        seconds = time.perf_counter() - t0
        if cache is None:
            return QueryResult(result=rs, info=info, compiled=compiled,
                               seconds=seconds, approx=ainfo)
        from repro.serve.cache import CachedResult
        cache.put_result(rkey, CachedResult(result=rs, info=info,
                                            plan_nodes=len(plan.nodes),
                                            approx=ainfo),
                         n_tables=self.executor.n_tables)
        cache.note("miss")
        return QueryResult(result=rs, info=info, compiled=compiled,
                           seconds=seconds,
                           cache=cache.request_info("miss"), approx=ainfo)

    def _exact_for_approx(self, compiled, ainfo, rkey, optimize, sync,
                          fused, t0) -> QueryResult:
        """Resolve an approx request on the exact path (escalation or
        fallback): the exact run goes through ``query`` so it lands in — and
        can be served from — the plain exact-result cache, then the same
        ResultSet is also recorded under the approx key with its ApproxInfo
        so repeat approx requests hit directly."""
        eres = self.query(compiled, optimize=optimize, sync=sync, fused=fused)
        if self.cache is not None and rkey is not None:
            from repro.serve.cache import CachedResult
            self.cache.put_result(
                rkey, CachedResult(result=eres.result, info=eres.info,
                                   plan_nodes=len(compiled.plan.nodes),
                                   ids=eres._ids, approx=ainfo),
                n_tables=self.executor.n_tables)
        return QueryResult(result=eres.result, info=eres.info,
                           compiled=compiled,
                           seconds=time.perf_counter() - t0, _ids=eres._ids,
                           cache=eres.cache, _entry=eres._entry,
                           approx=ainfo)

    def sql(self, text: str, optimize: bool = True,
            sync: bool = True) -> QueryResult:
        """Execute one BlendQL statement."""
        return self.query(text, optimize=optimize, sync=sync)

    def query_many(self, queries, top: int | None = None,
                   optimize: bool = True, sync: bool = True,
                   fused: bool = True) -> list:
        """Execute a batch of queries; with ``fused=True`` (the default)
        same-kind seekers are batched *across all requests* into shared
        device launches (``Executor.run_many``), so a heterogeneous batch
        executes in about one launch per seeker kind plus one tiny DAG
        program per request.  Query-cache semantics match ``query``:
        exact-result hits short-circuit before the executor, subplan hits
        drop their seekers out of the fused batch, and every result is
        bit-identical to a sequential cold run.

        Each result's ``seconds`` is its own compile/lookup time plus an
        equal share of the batch's host-side dispatch (exact hits pay no
        share)."""
        cache = self.cache
        if cache is not None:
            cache.begin(self.executor.index, self._cache_config())
        results: list = [None] * len(queries)
        pending: list = []                     # (index, Compiled, result key)
        for i, q in enumerate(queries):
            t0 = time.perf_counter()
            comp = q if isinstance(q, Compiled) else self.compile(q, top=top)
            if cache is None:
                pending.append((i, comp, None, time.perf_counter() - t0))
                continue
            rkey = cache.result_key(comp.plan, optimize)
            entry = cache.get_result(rkey)
            if entry is not None:
                results[i] = self._hit_result(entry, comp, sync,
                                              time.perf_counter() - t0)
            else:
                pending.append((i, comp, rkey, time.perf_counter() - t0))
        if not pending:
            return results
        if not fused:
            for i, comp, _, _ in pending:
                results[i] = self.query(comp, optimize=optimize, sync=sync)
            return results
        t0 = time.perf_counter()
        outs = self.executor.run_many([c.plan for _, c, _, _ in pending],
                                      optimize=optimize,
                                      cost_model=self.cost_model, sync=sync,
                                      cache=cache)
        share = (time.perf_counter() - t0) / len(pending)
        for (i, comp, rkey, own_s), (rs, info) in zip(pending, outs):
            if cache is not None:
                results[i] = self._record_result(rkey, rs, info, comp,
                                                 own_s + share)
            else:
                results[i] = QueryResult(result=rs, info=info, compiled=comp,
                                         seconds=own_s + share)
        return results

    # ---------------------------------------------------------------- explain
    def explain(self, q, top: int | None = None, optimize: bool = True,
                execute: bool = True, fused: bool = False,
                server: dict | None = None) -> Explain:
        """Compile (and by default run) ``q``; returns the full transcript:
        rendered logical tree, applied rewrite rules, ranked physical order,
        and per-node timings from the actual execution.  ``fused=True``
        executes on the fused path — the transcript's ``launches`` line then
        shows the collapsed dispatch count (<= n_kinds + 1).  ``server=``
        attaches front-tier telemetry (``DiscoveryServer.stats()``) rendered
        as the ``== server ==`` section — queue depth, lane occupancy, shed
        counts, launches per batch.  With ``repro.obs`` enabled the
        transcript also carries the process metrics snapshot (``== metrics
        ==``): explain is a thin reader of the registry, not a second
        bookkeeping path."""
        compiled = q if isinstance(q, Compiled) else self.compile(q, top=top)
        if compiled.logical is not None:
            tree = compiled.logical.render()
        else:
            tree = "\n".join(
                f"{name}: {node.spec}" for name, node in
                compiled.plan.nodes.items())
        ranked = {}
        if optimize:
            ep = optimize_plan(compiled.plan, self.executor.seeker_stats,
                               self.cost_model)
            ranked = {name: list(eg.seekers) for name, eg in ep.groups.items()}
        info = ExecInfo(optimized=optimize)
        ids: list = []
        cache_info: dict = {}
        if execute:
            res = self.query(compiled, optimize=optimize, fused=fused)
            info, ids = res.info, res.ids
            if res.cache is not None:
                cache_info = res.cache.as_dict()
        from repro import obs
        return Explain(logical_tree=tree,
                       applied_rules=list(compiled.applied_rules),
                       physical_order=ranked, exec_order=list(info.order),
                       node_seconds=dict(info.node_seconds),
                       overflow=info.overflow if execute else 0, ids=ids,
                       launches=info.launches,
                       index_shape=self.index_shape(), cache=cache_info,
                       server=dict(server) if server else {},
                       metrics=obs.registry().snapshot()
                       if obs.enabled() else {})


def _make_cache(cache):
    """``cache=`` argument -> QueryCache | None: False/None disables, True
    uses the default byte budget, an int is the budget, a QueryCache
    instance is used as-is (lazy import: serve/ sits above query/)."""
    if not cache:
        return None
    from repro.serve.cache import QueryCache
    if isinstance(cache, QueryCache):
        return cache
    if cache is True:
        return QueryCache()
    return QueryCache(max_bytes=int(cache))


def connect(lake, cost_model: CostModel | None = None, live: bool = False,
            cache=False, shards: int | None = None, wal=None,
            **executor_opts) -> Session:
    """Open a discovery session on a lake: builds the unified index and the
    executor (kwargs forwarded: ``backend=``, ``interpret=``, ``m_cap_max=``,
    ...), returning the Session handle that serves queries.

    With ``live=True`` the index is built as a LiveLake segment store
    (repro/store): the session gains ``add_table`` / ``drop_table`` /
    ``compact`` / ``snapshot`` and queries keep serving — bit-identically to
    a from-scratch rebuild — while the lake evolves.  ``lake`` may also be
    an existing ``LiveLake`` handle.

    ``shards=N`` partitions the store across N devices along the table axis
    (dist/shard.py): queries execute as fused per-shard probes plus one
    cross-shard merge, bit-identical to an unsharded session; combine with
    ``live=True`` for shard-local mutations (``add_table`` routes to the
    least-loaded shard).

    ``cache=True`` (or a byte budget / QueryCache instance) enables the
    semantic query cache (serve/cache.py): repeated or subtree-sharing
    queries are served from compiled-plan, exact-result, and per-seeker
    caches, all invalidated by the store epoch so mutations never serve
    stale ids.

    ``wal=`` (a path or ``store.wal.WriteAheadLog``; requires ``live=True``)
    durably logs every acknowledged mutation so the lake survives crashes:
    reopen with :func:`recover` to replay snapshot + WAL bit-identically."""
    qc = _make_cache(cache)
    if wal is not None and not live:
        raise ValueError("wal= requires live=True (the WAL logs mutations)")
    if shards:
        from repro.dist.shard import ShardedExecutor, ShardedStore
        from repro.store.live import LiveLake
        if isinstance(lake, LiveLake):
            raise TypeError("pass the raw lake (not a LiveLake) with "
                            "shards=: the store must be built sharded")
        store = ShardedStore(lake, n_shards=shards)
        executor = ShardedExecutor(store, **executor_opts)
        ll = LiveLake(lake, store=store, wal=wal) if live else None
        return Session(executor, lake=lake, cost_model=cost_model,
                       live=ll, cache=qc)
    if live:
        from repro.store.live import LiveLake
        if isinstance(lake, LiveLake):
            ll = lake
            if wal is not None:
                raise ValueError("pass wal= when the LiveLake is built, "
                                 "not when wrapping an existing one")
        else:
            ll = LiveLake(lake, wal=wal)
        executor = Executor(ll.store, **executor_opts)
        return Session(executor, lake=None if lake is ll else lake,
                       cost_model=cost_model, live=ll, cache=qc)
    executor = Executor(build_index(lake), **executor_opts)
    return Session(executor, lake=lake, cost_model=cost_model, cache=qc)


def restore(path, cost_model: CostModel | None = None, cache=False,
            **executor_opts) -> Session:
    """Open a live session from a snapshot (store/snapshot.py) — no
    re-indexing: the server restart path."""
    from repro.store.live import LiveLake
    ll = LiveLake.restore(path)
    executor = Executor(ll.store, **executor_opts)
    return Session(executor, cost_model=cost_model, live=ll,
                   cache=_make_cache(cache))


def recover(path=None, *, wal=None, shards: int | None = None,
            cost_model: CostModel | None = None, cache=False,
            policy=None, **executor_opts) -> Session:
    """Open a live session from durable state: the latest good snapshot
    generation at ``path`` (if any; corrupt generations fall back — see
    store/snapshot.py) plus a replay of every WAL record past the snapshot's
    watermark (store/wal.py) — the crash-recovery path.  The recovered
    session answers queries with ids, scores and epoch bit-identical to the
    uninterrupted run, and keeps logging to ``wal``.

    ``shards=N`` only matters on a cold start with no snapshot (a recovered
    snapshot already knows its shard layout)."""
    from repro.store.live import LiveLake
    ll = LiveLake.recover(path, wal=wal, shards=shards, policy=policy)
    if hasattr(ll.store, "shards"):
        from repro.dist.shard import ShardedExecutor
        executor = ShardedExecutor(ll.store, **executor_opts)
    else:
        executor = Executor(ll.store, **executor_opts)
    return Session(executor, cost_model=cost_model, live=ll,
                   cache=_make_cache(cache))
