"""Rule-based rewriter: canonicalization passes over the logical IR.

Each rule is a named function ``rule(expr) -> expr`` (pure; returns the input
object unchanged when it does not apply), so rules are individually testable
and ``session.explain`` can list exactly which ones fired.  ``rewrite`` runs
the default pipeline to a fixpoint and records applied rule names — the
logical analogue of the paper's Section VII-B query-rewriting step, which
stays in the physical optimizer (core/optimizer.py) for ranking and mask
threading.

Dead-subtree pruning operates on the lowered physical plan and shares
``Plan.reachable()`` with ``Plan.validate()`` (one traversal, two clients).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.query import logical as L


def _map_children(e: L.Expr, fn) -> L.Expr:
    kids = e.children()
    if not kids:
        return e
    new = tuple(fn(c) for c in kids)
    if all(a is b for a, b in zip(new, kids)):
        return e
    return e.with_children(new)


def _bottom_up(e: L.Expr, visit) -> L.Expr:
    return visit(_map_children(e, lambda c: _bottom_up(c, visit)))


# ---------------------------------------------------------------------- rules
def flatten_and_or(e: L.Expr) -> L.Expr:
    """AND(AND(a,b),c) -> AND(a,b,c); same for OR.  A nested combiner with an
    explicit ``k`` is a cut point and is left in place (merging it would drop
    its intermediate top-k)."""

    def visit(n):
        if not isinstance(n, (L.And, L.Or)):
            return n
        kids = []
        changed = False
        for c in n.children():
            if type(c) is type(n) and c.k is None:
                kids.extend(c.children())
                changed = True
            else:
                kids.append(c)
        return n.with_children(kids) if changed else n

    return _bottom_up(e, visit)


def fold_idempotent(e: L.Expr) -> L.Expr:
    """X & X -> X and X | X -> X: drop structurally duplicate children of
    AND/OR (set semantics make them no-ops).  Counter is left alone — its
    score *is* the occurrence count."""

    def visit(n):
        if not isinstance(n, (L.And, L.Or)):
            return n
        seen, kids = set(), []
        for c in n.children():
            if c in seen:
                continue
            seen.add(c)
            kids.append(c)
        if len(kids) == len(n.children()):
            return n
        if len(kids) == 1:
            # a single-input combiner is just its input plus the cut: fold
            # the limit into the child (top-k of top-k = top-min(k))
            kid = kids[0]
            if n.k is None:
                return kid
            ck = getattr(kid, "k", None)
            return replace(kid, k=n.k if ck is None else min(ck, n.k))
        return n.with_children(kids)

    return _bottom_up(e, visit)


def push_limit(e: L.Expr, top: int | None = None) -> L.Expr:
    """Fold the query's ``SELECT TOP k`` into the root operator and keep
    interior combiners cut-free: only the root limits the result, interior
    nodes with ``k=None`` lower to an uncut pass-through, so no
    intermediate cut can hide a table the root would keep."""
    if top is None:
        return e
    if isinstance(e, L.Seek):
        return e if e.k <= top else replace(e, k=top)
    k = top if e.k is None else min(e.k, top)
    return e if k == e.k else e.top(k)


def hash_cons(e: L.Expr) -> L.Expr:
    """Intern structurally identical subtrees into single shared instances.
    Lowering memoizes per instance-equal node, so a seeker appearing in two
    branches becomes ONE physical plan node and executes exactly once."""
    interned: dict = {}

    def visit(n):
        canon = interned.get(n)
        if canon is not None:
            return canon
        interned[n] = n
        return n

    return _bottom_up(e, visit)


def annotate_masks(e: L.Expr) -> L.Expr:
    """Mark intersect nodes with >= 2 seeker children as execution-group
    candidates (``eg=True``): the physical optimizer will rank their seekers
    and thread the surviving-table mask through the group."""

    def visit(n):
        if isinstance(n, L.And) and not n.eg and \
                sum(isinstance(c, L.Seek) for c in n.children()) >= 2:
            return replace(n, eg=True)
        return n

    return _bottom_up(e, visit)


DEFAULT_RULES = (flatten_and_or, fold_idempotent, push_limit, hash_cons,
                 annotate_masks)


@dataclass
class RewriteResult:
    expr: L.Expr
    applied: list          # rule names, in application order

    def __iter__(self):    # (expr, applied) unpacking convenience
        return iter((self.expr, self.applied))


def rewrite(e: L.Expr, top: int | None = None,
            rules=DEFAULT_RULES, max_passes: int = 8) -> RewriteResult:
    """Run the rule pipeline to a fixpoint, recording which rules changed
    the tree.  ``top`` is the SELECT TOP k limit (push_limit's parameter)."""
    applied = []
    for _ in range(max_passes):
        changed = False
        for rule in rules:
            if rule is push_limit:
                new = rule(e, top)
                fired = new != e
            elif rule is hash_cons:
                # interning preserves structural equality; it "fires" when
                # some subtree occurs twice as distinct instances
                fired = _has_duplicate_instances(e)
                new = rule(e)
            elif rule is annotate_masks:
                new = rule(e)
                fired = _egs(new) != _egs(e)   # eg is compare=False
            else:
                new = rule(e)
                fired = new != e
            if fired:
                if rule.__name__ not in applied:
                    applied.append(rule.__name__)
                changed = True
            e = new
        if not changed:
            break
    return RewriteResult(e, applied)


def _has_duplicate_instances(e: L.Expr) -> bool:
    groups: dict = {}
    for n in L.walk(e):
        groups.setdefault(n, set()).add(id(n))
    return any(len(ids) > 1 for ids in groups.values())


def _egs(e: L.Expr) -> tuple:
    """eg annotations are compare=False; collect them for change detection."""
    return tuple(n.eg for n in L.walk(e) if isinstance(n, L.And))


def canonical_expr(e: L.Expr, top: int | None = None) -> L.Expr:
    """The normal form the query cache fingerprints (query/fingerprint.py):
    the full rule pipeline run to fixpoint, result only.  Rewriting before
    hashing means nesting and duplication differences the rules remove —
    ``(a & b) & c`` vs ``a & b & c``, ``x | x`` vs ``x`` — never split cache
    entries; the commutative child ordering itself is canonicalized inside
    the fingerprint, not here, so execution order is untouched."""
    return rewrite(e, top=top).expr


# ------------------------------------------------- physical-plan dead pruning
def prune_dead_nodes(plan) -> list:
    """Drop plan nodes unreachable from the output (shares the traversal
    with ``Plan.validate``).  Returns the removed node names."""
    return plan.prune_unreachable()
