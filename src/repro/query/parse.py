"""BlendQL parser: SQL-ish string form of the logical IR.

Grammar (case-insensitive keywords)::

    query   := SELECT [TOP INT] [TABLES] WHERE expr
    expr    := or_e
    or_e    := sub_e (OR sub_e)*                 -> union
    sub_e   := and_e (EXCEPT and_e)*             -> difference (left-assoc)
    and_e   := atom (AND atom)*                  -> intersect
    atom    := '(' expr ')' | call
    call    := sc(lit, ..., k=N) | kw(lit, ..., k=N)
             | mc((lit, ...), ..., k=N)
             | corr([lit, ...], [num, ...], k=N, h=N, sampling='conv')
             | counter(expr, ..., k=N)

String literals use single quotes with ``''`` escaping; bare numbers are
int/float literals.  ``Expr.to_sql()`` emits exactly this grammar, so every
expression round-trips: ``parse(e.to_sql())`` is structurally equal to ``e``
(modulo the TOP clause, which becomes the root limit).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.query import logical as L

_TOKEN = re.compile(r"""
      (?P<STRING>'(?:[^']|'')*')
    | (?P<NUMBER>-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)
    | (?P<NAME>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<PUNCT>[(),\[\]=])
    | (?P<WS>\s+)
""", re.VERBOSE)

_SEEKERS = {"sc", "kw", "mc", "corr"}


class BlendQLError(ValueError):
    """Raised on any lexical or syntactic error, with position context."""


@dataclass
class _Tok:
    kind: str
    text: str
    pos: int


def _lex(text: str) -> list:
    toks, i = [], 0
    while i < len(text):
        m = _TOKEN.match(text, i)
        if m is None:
            raise BlendQLError(f"unexpected character {text[i]!r} at {i}")
        i = m.end()
        kind = m.lastgroup
        if kind == "WS":
            continue
        toks.append(_Tok(kind, m.group(), m.start()))
    toks.append(_Tok("EOF", "", len(text)))
    return toks


class _Parser:
    def __init__(self, text: str):
        self.text = text
        self.toks = _lex(text)
        self.i = 0

    # ---------------------------------------------------------------- stream
    def peek(self) -> _Tok:
        return self.toks[self.i]

    def next(self) -> _Tok:
        t = self.toks[self.i]
        self.i += 1
        return t

    def _is_kw(self, word: str) -> bool:
        t = self.peek()
        return t.kind == "NAME" and t.text.lower() == word

    def expect_kw(self, word: str):
        if not self._is_kw(word):
            t = self.peek()
            raise BlendQLError(f"expected {word.upper()} at {t.pos}, "
                               f"got {t.text!r}")
        return self.next()

    def expect(self, text: str):
        t = self.peek()
        if t.text != text:
            raise BlendQLError(f"expected {text!r} at {t.pos}, got {t.text!r}")
        return self.next()

    # --------------------------------------------------------------- grammar
    def query(self) -> L.Expr:
        self.expect_kw("select")
        top = None
        if self._is_kw("top"):
            self.next()
            t = self.next()
            if t.kind != "NUMBER" or "." in t.text:
                raise BlendQLError(f"TOP expects an integer at {t.pos}")
            top = int(t.text)
        if self._is_kw("tables"):
            self.next()
        self.expect_kw("where")
        e = self.or_expr()
        if self.peek().kind != "EOF":
            t = self.peek()
            raise BlendQLError(f"trailing input at {t.pos}: {t.text!r}")
        if top is not None:
            e = e.top(min(top, e.k)) if isinstance(e, L.Seek) else e.top(top)
        return e

    def or_expr(self) -> L.Expr:
        kids = [self.sub_expr()]
        while self._is_kw("or"):
            self.next()
            kids.append(self.sub_expr())
        return kids[0] if len(kids) == 1 else L.Or(tuple(kids))

    def sub_expr(self) -> L.Expr:
        e = self.and_expr()
        while self._is_kw("except"):
            self.next()
            e = L.Sub(e, self.and_expr())
        return e

    def and_expr(self) -> L.Expr:
        kids = [self.atom()]
        while self._is_kw("and"):
            self.next()
            kids.append(self.atom())
        return kids[0] if len(kids) == 1 else L.And(tuple(kids))

    def atom(self) -> L.Expr:
        t = self.peek()
        if t.text == "(":
            self.next()
            e = self.or_expr()
            self.expect(")")
            return e
        if t.kind == "NAME":
            name = t.text.lower()
            if name in _SEEKERS:
                return self.seeker_call(name)
            if name == "counter":
                return self.counter_call()
        raise BlendQLError(f"expected seeker/counter call or '(' at {t.pos}, "
                           f"got {t.text!r}")

    # ----------------------------------------------------------------- calls
    def counter_call(self) -> L.Expr:
        self.next()                     # 'counter'
        self.expect("(")
        kids, kwargs = [], {}
        while True:
            if self._at_kwarg():
                kwargs.update([self.kwarg()])
            else:
                kids.append(self.or_expr())
            if self.peek().text == ",":
                self.next()
                continue
            break
        self.expect(")")
        bad = set(kwargs) - {"k"}
        if bad:
            raise BlendQLError(f"counter() got unknown options {sorted(bad)}")
        if len(kids) < 2:
            raise BlendQLError("counter() needs >= 2 input expressions")
        return L.Counter(tuple(kids), kwargs.get("k"))

    def seeker_call(self, name: str) -> L.Expr:
        tok = self.next()               # seeker name
        self.expect("(")
        args, kwargs = [], {}
        while self.peek().text != ")":
            if self._at_kwarg():
                kwargs.update([self.kwarg()])
            else:
                args.append(self.value())
            if self.peek().text == ",":
                self.next()
        self.expect(")")
        allowed = {"sc": {"k"}, "kw": {"k"}, "mc": {"k"},
                   "corr": {"k", "h", "sampling"}}[name]
        bad = set(kwargs) - allowed
        if bad:
            raise BlendQLError(f"{name}() got unknown options {sorted(bad)} "
                               f"at {tok.pos}")
        if not args:
            raise BlendQLError(f"{name}() needs at least one query value "
                               f"at {tok.pos}")
        k = kwargs.get("k", 100)
        if name == "sc":
            return L.sc(args, k=k)
        if name == "kw":
            return L.kw(args, k=k)
        if name == "mc":
            if not all(isinstance(a, tuple) for a in args):
                raise BlendQLError("mc() takes tuple arguments: mc(('a','b'))")
            return L.mc(args, k=k)
        # corr
        if len(args) != 2 or not all(isinstance(a, list) for a in args):
            raise BlendQLError("corr() takes two bracketed lists: "
                               "corr(['j1','j2'], [1.0, 2.0])")
        return L.corr(args[0], args[1], k=k, h=kwargs.get("h", 256),
                      sampling=kwargs.get("sampling", "conv"))

    def _at_kwarg(self) -> bool:
        return (self.peek().kind == "NAME"
                and self.toks[self.i + 1].text == "=")

    def kwarg(self):
        name = self.next().text.lower()
        self.expect("=")
        val = self.literal()
        return name, val

    def value(self):
        """literal | '(' literal, ... ')' | '[' literal, ... ']'"""
        t = self.peek()
        if t.text == "(":
            self.next()
            items = [self.literal()]
            while self.peek().text == ",":
                self.next()
                items.append(self.literal())
            self.expect(")")
            return tuple(items)
        if t.text == "[":
            self.next()
            items = [self.literal()]
            while self.peek().text == ",":
                self.next()
                items.append(self.literal())
            self.expect("]")
            return list(items)
        return self.literal()

    def literal(self):
        t = self.next()
        if t.kind == "STRING":
            return t.text[1:-1].replace("''", "'")
        if t.kind == "NUMBER":
            return float(t.text) if ("." in t.text or "e" in t.text.lower()) \
                else int(t.text)
        if t.kind == "NAME":            # bare word: treat as string value
            return t.text
        raise BlendQLError(f"expected a literal at {t.pos}, got {t.text!r}")


def parse(text: str) -> L.Expr:
    """Parse one BlendQL statement into a logical expression."""
    return _Parser(text).query()
