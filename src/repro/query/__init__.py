"""BlendQL: the declarative query frontend over the BLEND engine.

Layering (tentpole of the API redesign)::

    blendql string --parse.py--> logical IR --rules.py--> canonical IR
                                   (logical.py)              |
    fluent expressions -----------------^          lower.py  v
                                                   physical Plan
                                                   (core/plan.py ->
                                                    core/optimizer.py ->
                                                    core/executor.py)

IR node -> paper mapping (Blend: A Unified Data Discovery System):

==============  =======================================================
IR node         Paper construct
==============  =======================================================
``sc(...)``     Listing 1 / Section VI-B: single-column joinability
                seeker (JOSIE-style top-k overlap)
``kw(...)``     Section VI-A: keyword seeker over all cell values
``mc(...)``     Listing 2 / Section VI-C: multi-column join seeker
                (MATE superkeys)
``corr(...)``   Listing 3 / Section VI-D: correlation seeker (QCR
                sketches over join+target column pairs)
``&  (And)``    Section VII-A Intersection combiner (SQL ``INTERSECT``);
                execution groups + mask threading per Section VII-B
``|  (Or)``     Section VII-A Union combiner (SQL ``UNION``)
``-  (Sub)``    Section VII-A Difference combiner (SQL ``EXCEPT``) —
                the Fig. 1 negative-examples workload
``counter(..)`` Section VII-A Counter aggregator (union-table search,
                Listing 4's per-column vote)
``SELECT TOP``  the task-level result limit K of Listing 4
==============  =======================================================

Entry points: ``connect(lake, **executor_opts) -> Session``;
``Session.query`` (fluent), ``Session.sql`` (BlendQL text),
``Session.explain`` (rule + plan + timing transcript).  The legacy
imperative ``Plan.add`` frontend lowers through the same Session.
"""
from repro.query.logical import (And, Counter, Expr, Or, Seek, Sub, corr,
                                 counter, kw, mc, sc)
from repro.query.lower import lower
from repro.query.fingerprint import (fingerprint_expr, fingerprint_plan,
                                     fingerprint_query, index_epoch_key)
from repro.query.parse import BlendQLError, parse
from repro.query.rules import DEFAULT_RULES, rewrite
from repro.query.session import (Compiled, Explain, QueryResult, Session,
                                 connect, recover, restore)

__all__ = [
    "And", "BlendQLError", "Compiled", "Counter", "DEFAULT_RULES", "Expr",
    "Explain", "Or", "QueryResult", "Seek", "Session", "Sub", "connect",
    "corr", "counter", "fingerprint_expr", "fingerprint_plan",
    "fingerprint_query", "index_epoch_key", "kw", "lower", "mc", "parse",
    "recover", "restore", "rewrite", "sc",
]
