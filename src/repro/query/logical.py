"""BlendQL logical-plan IR: composable discovery expressions.

Leaves are the four seekers (paper Section VI); interior nodes are the four
combiners (Section VII-A) with SQL-set-op semantics.  Expressions are frozen
dataclasses, so structural equality / hashing come for free — the rewriter's
hash-consing and the lowering memo both key on the node itself.

Fluent form (operator overloading)::

    expr = sc(values, k=100) & kw(words) | corr(join, target)
    expr = mc(positives) - mc(outdated)          # difference
    expr = counter(sc(col_a), sc(col_b), k=10)   # union-search aggregator

``expr.to_sql()`` prints the equivalent BlendQL string (parse-able by
``repro.query.parse``), ``expr.render()`` pretty-prints the tree for
``session.explain``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields, replace

from repro.core.plan import SeekerSpec

#: combiners whose ``k`` is None are lowered with this cut-free limit —
#: ``topk_result`` clamps to n_tables, so "huge" means "keep every positive".
UNCUT = 1 << 20


def _literal(v) -> str:
    """Render one query value as a BlendQL literal."""
    if isinstance(v, bool):
        raise TypeError("bool query values are not supported")
    if isinstance(v, (int, float)):
        return repr(v)
    s = str(v).replace("'", "''")
    return f"'{s}'"


def _kwargs_sql(pairs) -> str:
    out = []
    for name, val, default in pairs:
        if val != default:
            out.append(f"{name}={_literal(val) if isinstance(val, str) else val}")
    return (", " + ", ".join(out)) if out else ""


class Expr:
    """Base class: every IR node supports ``& | -`` composition."""

    def __and__(self, other: "Expr") -> "And":
        return And((self, _expr(other)))

    def __or__(self, other: "Expr") -> "Or":
        return Or((self, _expr(other)))

    def __sub__(self, other: "Expr") -> "Sub":
        return Sub(self, _expr(other))

    def top(self, k: int) -> "Expr":
        """Return a copy with the result limit set to ``k``."""
        return replace(self, k=k)

    # -- traversal helpers -------------------------------------------------
    def children(self) -> tuple:
        return ()

    def with_children(self, kids) -> "Expr":
        raise NotImplementedError

    def label(self) -> str:
        raise NotImplementedError

    def render(self, indent: int = 0, _shared=None) -> str:
        """Pretty tree rendering (used by ``session.explain``)."""
        if _shared is None:
            counts: dict = {}
            _count_occurrences(self, counts)
            _shared = {e for e, n in counts.items() if n > 1}
        pad = "  " * indent
        tag = "  <shared>" if indent and self in _shared else ""
        lines = [f"{pad}{self.label()}{tag}"]
        for c in self.children():
            lines.append(c.render(indent + 1, _shared))
        return "\n".join(lines)

    def fingerprint(self, top: int | None = None) -> str:
        """Canonical content hash of this query (query/fingerprint.py):
        rewritten to normal form, commutative children order-blind — the
        identity the query cache serves repeats under."""
        from repro.query.fingerprint import fingerprint_query
        return fingerprint_query(self, top=top)

    def to_sql(self) -> str:
        """Full BlendQL statement for this expression (round-trips through
        ``repro.query.parse.parse``)."""
        k = getattr(self, "k", None)
        body = self._sql()
        if isinstance(self, Seek):          # the leaf carries its own k
            return f"SELECT TABLES WHERE {body}"
        if k is not None:
            return f"SELECT TOP {k} TABLES WHERE {self._sql(top_level=True)}"
        return f"SELECT TABLES WHERE {body}"

    def _sql(self, top_level: bool = False) -> str:
        raise NotImplementedError


def _count_occurrences(e: Expr, counts: dict):
    counts[e] = counts.get(e, 0) + 1
    for c in e.children():
        _count_occurrences(c, counts)


def _expr(x) -> Expr:
    if not isinstance(x, Expr):
        raise TypeError(f"expected a BlendQL expression, got {type(x)!r}")
    return x


# --------------------------------------------------------------------- leaves
@dataclass(frozen=True)
class Seek(Expr):
    """Seeker leaf; ``kind`` ∈ SC | KW | MC | C (paper Listings 1-3)."""
    kind: str
    values: tuple
    k: int = 100
    target: tuple = ()               # C: numeric target values
    h: int = 256                     # C: sketch sample size
    sampling: str = "conv"           # C: 'conv' | 'rand'

    def spec(self) -> SeekerSpec:
        return SeekerSpec(self.kind, self.k, self.values, self.target,
                          self.h, self.sampling)

    def label(self) -> str:
        n = len(self.values)
        extra = f", h={self.h}" if self.kind == "C" else ""
        return f"{self.kind.lower()}(|Q|={n}, k={self.k}{extra})"

    def _sql(self, top_level: bool = False) -> str:
        name = self.kind.lower() if self.kind != "C" else "corr"
        if self.kind == "MC":
            args = ", ".join("(" + ", ".join(_literal(v) for v in t) + ")"
                             for t in self.values)
            return f"mc({args}, k={self.k})"
        if self.kind == "C":
            joins = "[" + ", ".join(_literal(v) for v in self.values) + "]"
            tgt = "[" + ", ".join(_literal(v) for v in self.target) + "]"
            opts = f", k={self.k}" + _kwargs_sql([("h", self.h, 256),
                                                  ("sampling", self.sampling,
                                                   "conv")])
            return f"corr({joins}, {tgt}{opts})"
        args = ", ".join(_literal(v) for v in self.values)
        return f"{name}({args}, k={self.k})"


def sc(values, k: int = 100) -> Seek:
    """Joinable-table search (single column; JOSIE-style)."""
    return Seek("SC", tuple(values), k)


def kw(words, k: int = 100) -> Seek:
    """Keyword search over all columns."""
    return Seek("KW", tuple(words), k)


def mc(tuples, k: int = 100) -> Seek:
    """Multi-column join search (MATE-style superkeys)."""
    return Seek("MC", tuple(tuple(t) for t in tuples), k)


def corr(join_values, target_values, k: int = 100, h: int = 256,
         sampling: str = "conv") -> Seek:
    """Correlation discovery (QCR): joinable + correlating columns."""
    return Seek("C", tuple(join_values), k, tuple(target_values), h, sampling)


# ------------------------------------------------------------------ combiners
@dataclass(frozen=True)
class And(Expr):
    """Intersection (n-ary after the flatten rule)."""
    kids: tuple
    k: int | None = None
    eg: bool = field(default=False, compare=False)   # mask-threading annotation

    def children(self):
        return self.kids

    def with_children(self, kids):
        return replace(self, kids=tuple(kids))

    def label(self):
        eg = ", eg=mask-threaded" if self.eg else ""
        return f"intersect(k={self.k}{eg})"

    def _sql(self, top_level: bool = False):
        body = " AND ".join(c._sql() for c in self.kids)
        return body if top_level else f"({body})"


@dataclass(frozen=True)
class Or(Expr):
    """Union (max-score semantics, n-ary after the flatten rule)."""
    kids: tuple
    k: int | None = None

    def children(self):
        return self.kids

    def with_children(self, kids):
        return replace(self, kids=tuple(kids))

    def label(self):
        return f"union(k={self.k})"

    def _sql(self, top_level: bool = False):
        body = " OR ".join(c._sql() for c in self.kids)
        return body if top_level else f"({body})"


@dataclass(frozen=True)
class Sub(Expr):
    """Difference: tables matching ``left`` but not ``right``."""
    left: Expr
    right: Expr
    k: int | None = None

    def children(self):
        return (self.left, self.right)

    def with_children(self, kids):
        l, r = kids
        return replace(self, left=l, right=r)

    def label(self):
        return f"difference(k={self.k})"

    def _sql(self, top_level: bool = False):
        body = f"{self.left._sql()} EXCEPT {self.right._sql()}"
        return body if top_level else f"({body})"


@dataclass(frozen=True)
class Counter(Expr):
    """Count-based aggregator (the paper's union-search combiner)."""
    kids: tuple
    k: int | None = None

    def children(self):
        return self.kids

    def with_children(self, kids):
        return replace(self, kids=tuple(kids))

    def label(self):
        return f"counter(k={self.k})"

    def _sql(self, top_level: bool = False):
        args = ", ".join(c._sql() for c in self.kids)
        if self.k is not None:
            args += f", k={self.k}"
        return f"counter({args})"


def counter(*exprs, k: int | None = None) -> Counter:
    """``counter(e1, e2, ...)``: rank tables by how many inputs matched."""
    if len(exprs) == 1 and isinstance(exprs[0], (list, tuple)):
        exprs = tuple(exprs[0])
    if len(exprs) < 2:
        raise ValueError("counter() needs >= 2 input expressions")
    return Counter(tuple(_expr(e) for e in exprs), k)


def walk(e: Expr):
    """Post-order traversal."""
    for c in e.children():
        yield from walk(c)
    yield e
