"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

Implemented as mLSTM (matrix-memory) blocks in chunked gated-linear-attention
form; d_ff=0 (the block carries its own up/down projections).  See DESIGN.md
for the exp-gating stabilization note.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    ssm_state=0, ssm_expand=2, ssm_headdim=0,  # mLSTM uses n_heads over d_inner
)
