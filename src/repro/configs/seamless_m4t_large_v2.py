"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone.

[arXiv:2308.11596; hf] — the speech frontend is a STUB: input_specs()
provides precomputed frame embeddings [B, seq//enc_ratio, d_model].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    n_enc_layers=24, enc_ratio=4,
)
