"""internvl2-76b [vlm] — InternViT + InternLM2 backbone.

[arXiv:2404.16821; unverified] — the ViT frontend is a STUB: input_specs()
provides precomputed patch embeddings [B, n_patches, d_model].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    n_patches=256,
    fsdp=True,
    grad_accum=8,
)
