"""Architecture config schema + the assigned input-shape sets.

Every assigned architecture gets one module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (exact published hyper-parameters) — see the per-file
``[source]`` notes.  ``reduced()`` shrinks any config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0               # per-expert ffn hidden dim
    n_shared_experts: int = 0
    dense_residual: bool = False    # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0             # hybrid: shared attn block every N layers
    # --- enc-dec / multimodal ---
    n_enc_layers: int = 0
    enc_ratio: int = 4              # encoder len = seq_len // enc_ratio
    n_patches: int = 0              # vlm: stub patch embeddings prepended
    # --- common ---
    norm_type: str = "rmsnorm"      # rmsnorm | nonparam_ln
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # training-time knobs (hillclimb levers; defaults = paper-faithful baseline)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    ssm_chunk: int = 128
    remat: bool = True
    causal_block_skip: bool = True   # triangular schedule (OFF = paper-faithful baseline rect)
    opt_state_dtype: str = "float32"
    fsdp: bool = False               # ZeRO-3: shard params+opt state over data axis
    grad_accum: int = 1              # microbatched gradient accumulation
    opt_factored: bool = False       # Adafactor-style factored 2nd moment
    moe_group_size: int = 4096       # GShard dispatch group size
    expert_data_shard: bool = False  # resident EP over the data axis (no FSDP re-gather)
    moe_impl: str = "auto"          # sorted | einsum | shard_map | auto

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab + 127) // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


# The assigned LM-family shape set (applies to every assigned architecture).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for ssm/hybrid families.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


def reduced(cfg: ArchConfig, *, seq_hint: int = 64) -> ArchConfig:
    """Shrink a config to a CPU-smoke-testable size, preserving the family."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        q_chunk=max(16, seq_hint // 4),
        kv_chunk=max(16, seq_hint // 4),
        ssm_chunk=16,
        dtype="float32",
        grad_accum=1,
        fsdp=False,
        opt_factored=False,
    )
    if cfg.n_experts:
        kw.update(n_experts=8 if cfg.n_experts % 2 == 0 else 7, top_k=min(cfg.top_k, 2),
                  d_expert=32, n_shared_experts=min(cfg.n_shared_experts, 2))
        kw["n_experts"] = 8
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2)
    if cfg.n_patches:
        kw.update(n_patches=8)
    return cfg.replace(**kw)
