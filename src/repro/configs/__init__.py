"""Config registry: one module per assigned architecture (``--arch <id>``)."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    SUBQUADRATIC_FAMILIES,
    ArchConfig,
    ShapeConfig,
    reduced,
    shape_applicable,
)

ARCH_IDS = [
    "arctic-480b",
    "qwen2-moe-a2.7b",
    "smollm-360m",
    "minitron-8b",
    "yi-6b",
    "olmo-1b",
    "xlstm-1.3b",
    "zamba2-7b",
    "internvl2-76b",
    "seamless-m4t-large-v2",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
