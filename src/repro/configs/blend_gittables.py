"""The paper's own workload config: BLEND over a Gittables-scale lake.

Table II of the paper: Gittables = 1.5M tables / 16.8M columns / 345M rows;
we size the unified index at 1.4B postings (cells) with a 350M-posting
numeric view.  This is the config behind the ``blend-discovery`` dry-run
cells (``python -m repro.launch.dryrun --arch blend-discovery``) and the
distributed-seeker roofline rows.
"""
from repro.dist.shard import GITTABLES_SCALE

CONFIG = dict(
    name="blend-gittables",
    **GITTABLES_SCALE,
    # query-shape defaults for the dry-run cells
    nq=1024,              # values per SC/C probe batch
    n_tuples=256,         # MC tuples per batch
    n_cols=2,             # MC composite-key width
    m_cap=64,             # static matches per value
    row_cap=8,            # numeric cells per row (correlation join)
    h_sample=256,         # QCR sketch size (query-time, paper §V)
)
