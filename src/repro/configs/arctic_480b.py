"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.

[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, d_expert=4864, dense_residual=True,
    # 480B params: bf16 second moment to fit 256x16GB (see EXPERIMENTS §Dry-run)
    opt_state_dtype="bfloat16",
    fsdp=True,
    grad_accum=16,
    moe_group_size=2048,
    opt_factored=True,
)
