"""Mixture-of-Experts layer with sort-based dispatch (static shapes).

Dispatch is gather/scatter based (argsort tokens by expert id, capacity-
bounded slots) instead of the GShard one-hot-einsum formulation: the einsum
dispatch costs O(T*E*C*D) FLOPs which for 128-expert configs exceeds the
expert matmuls themselves; sort-based dispatch is O(T log T) + pure data
movement.  Expert weights are sharded over the ``model`` axis (expert
parallelism) when n_experts divides the axis, else TP on the ffn dim
(see repro.dist.sharding).

Tokens over capacity are dropped (standard capacity-factor semantics) and the
drop count is returned as a metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import ambient_mesh, maybe_constrain
from repro.models.layers import dense_init, swiglu

GROUP_SIZE = 4096    # tokens per dispatch group (GShard-style grouping)


def init_moe(key, d_model: int, n_experts: int, d_expert: int, dtype):
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d_model)
    e_init = lambda k, a, b: (jax.random.truncated_normal(
        k, -2.0, 2.0, (n_experts, a, b), jnp.float32) * scale).astype(dtype)
    return {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "experts_gate": e_init(ks[1], d_model, d_expert),
        "experts_up": e_init(ks[2], d_model, d_expert),
        "experts_down": (jax.random.truncated_normal(
            ks[3], -2.0, 2.0, (n_experts, d_expert, d_model), jnp.float32)
            / jnp.sqrt(d_expert)).astype(dtype),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_apply(params, x, *, n_experts: int, top_k: int, capacity_factor: float,
              impl: str = "auto", group_size: int = GROUP_SIZE,
              expert_axis: str = "model"):
    """x: [T, D] flattened tokens -> [T, D].

    impl: 'sorted' (exact, single-device friendly), 'einsum' (GShard-style
    grouped one-hot dispatch — partitions cleanly under GSPMD), or 'auto'
    (einsum when a mesh context is active, else sorted).
    Returns (y, aux) with aux = dict(load_balance_loss, dropped_fraction).
    """
    if impl == "auto":
        impl = "einsum" if ambient_mesh() is not None else "sorted"
    if impl == "shard_map":
        return moe_apply_shard_map(params, x, n_experts=n_experts,
                                   top_k=top_k,
                                   capacity_factor=capacity_factor)
    if impl == "einsum":
        return moe_apply_einsum(params, x, n_experts=n_experts, top_k=top_k,
                                capacity_factor=capacity_factor,
                                group_size=group_size, expert_axis=expert_axis)
    T, D = x.shape
    C = capacity(T, n_experts, top_k, capacity_factor)

    logits = (x.astype(jnp.float32) @ params["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)          # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- flatten the (token, choice) pairs and sort by expert ----
    flat_e = expert_ids.reshape(-1)                              # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]

    counts = jnp.bincount(flat_e, length=n_experts)              # [E]
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(T * top_k) - starts[e_sorted]              # pos within expert
    ok = slot < C
    slot = jnp.where(ok, slot, 0)

    # ---- dispatch: scatter token activations into [E, C, D] buffers ----
    gathered = jnp.where(ok[:, None], x[t_sorted], 0).astype(x.dtype)
    buf = jnp.zeros((n_experts, C, D), x.dtype).at[e_sorted, slot].add(
        gathered, mode="drop")
    # expert-parallel placement: the scatter above becomes the MoE all-to-all
    buf = maybe_constrain(buf, "model", None, None)

    # ---- expert computation (einsum over the expert axis => EP shardable) --
    h = swiglu(jnp.einsum("ecd,edf->ecf", buf, params["experts_gate"]),
               jnp.einsum("ecd,edf->ecf", buf, params["experts_up"]))
    out = jnp.einsum("ecf,efd->ecd", h, params["experts_down"])  # [E, C, D]

    # ---- combine: gather expert outputs back to token order ----
    expert_out = out[e_sorted, slot]                             # [T*k, D]
    expert_out = expert_out * (w_sorted * ok).astype(expert_out.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[t_sorted].add(
        expert_out.astype(x.dtype), mode="drop")

    # ---- aux metrics ----
    me = jnp.mean(probs, axis=0)                                 # mean router prob
    ce = jnp.mean(jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32),
                  axis=(0, 1)) * n_experts
    lb_loss = jnp.sum(me * ce)
    dropped = 1.0 - jnp.mean(ok.astype(jnp.float32))
    return y, {"load_balance_loss": lb_loss, "dropped_fraction": dropped}


def moe_apply_einsum(params, x, *, n_experts: int, top_k: int,
                     capacity_factor: float, group_size: int = GROUP_SIZE,
                     expert_axis: str = "model"):
    """GShard-style grouped one-hot dispatch [arXiv:2006.16668].

    Tokens are split into groups of GROUP_SIZE; each group dispatches into a
    per-group [E, C, D] buffer via a one-hot einsum.  Under GSPMD the groups
    shard over the data axis and the expert axis over the model axis, so the
    g->e resharding lowers to the MoE all-to-all.  The one-hot dispatch /
    combine einsums cost ~2*2.5*T*D extra FLOPs each — the 'GShard dispatch
    tax' that the shard_map EP path removes (see EXPERIMENTS §Perf).
    """
    T, D = x.shape
    E = n_experts
    G = max(T // group_size, 1)
    Tg = T // G
    assert G * Tg == T, "tokens must divide groups"
    C = capacity(Tg, E, top_k, capacity_factor)

    xg = maybe_constrain(x.reshape(G, Tg, D), "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)          # [G,Tg,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, choice) within its expert, priority = choice
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)    # [G,Tg,k,E]
    prio = onehot.transpose(0, 2, 1, 3).reshape(G, top_k * Tg, E)
    pos = jnp.cumsum(prio, axis=1) - prio                        # [G,k*Tg,E]
    pos = pos.reshape(G, top_k, Tg, E).transpose(0, 2, 1, 3)     # [G,Tg,k,E]
    in_cap = (pos < C) & (onehot > 0)
    dropped = 1.0 - jnp.mean(jnp.sum(in_cap, axis=-1))

    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype) * \
        in_cap[..., None].astype(x.dtype)                        # [G,Tg,k,E,C]
    dispatch = jnp.sum(slot_oh, axis=2)                          # [G,Tg,E,C]
    combine = jnp.sum(slot_oh * gate_vals[..., None, None].astype(x.dtype),
                      axis=2)                                    # [G,Tg,E,C]

    buf = jnp.einsum("gtec,gtd->gecd", dispatch, xg)             # [G,E,C,D]
    # expert placement: 'model' = classic EP over the TP axis; 'data' =
    # resident experts on the data axis (tokens a2a to them; weights never
    # re-gathered — see EXPERIMENTS §Perf, arctic hillclimb)
    if expert_axis == "data":
        buf = maybe_constrain(buf, None, "batch", None, None)
        h = swiglu(jnp.einsum("gecd,edf->gecf", buf, params["experts_gate"]),
                   jnp.einsum("gecd,edf->gecf", buf, params["experts_up"]))
        h = maybe_constrain(h, None, "batch", None, "model")
        out = jnp.einsum("gecf,efd->gecd", h, params["experts_down"])
        out = maybe_constrain(out, None, "batch", None, None)
    else:
        buf = maybe_constrain(buf, "batch", "model", None, None)
        h = swiglu(jnp.einsum("gecd,edf->gecf", buf, params["experts_gate"]),
                   jnp.einsum("gecd,edf->gecf", buf, params["experts_up"]))
        out = jnp.einsum("gecf,efd->gecd", h, params["experts_down"])
        out = maybe_constrain(out, "batch", "model", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, out).reshape(T, D)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(onehot, axis=(0, 1, 2)) * E
    lb_loss = jnp.sum(me * ce)
    return y, {"load_balance_loss": lb_loss, "dropped_fraction": dropped}

def moe_apply_shard_map(params, x, *, n_experts: int, top_k: int,
                        capacity_factor: float):
    """Explicit expert parallelism via shard_map (the §Perf arctic hillclimb).

    Per-device sort-based dispatch (no one-hot einsums), one all_to_all of
    the routed token slots to the resident experts (E over the data axis,
    ffn dim column-parallel over the model axis), one psum of the expert
    outputs, inverse all_to_all, local weighted combine.  Collective volume
    per layer = routed slots x D (+ the model-axis output reduction) —
    orders of magnitude less than the GShard einsum path's F-contraction
    gather at arctic scale.

    Requires: E % data_axis == 0; x enters sharded (batch x seq over all
    devices, D full).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import ambient_mesh, dp_axes

    mesh = ambient_mesh()
    assert mesh is not None, "shard_map MoE needs a mesh context"
    dp = dp_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)
    E = n_experts
    assert E % dsize == 0, "experts must divide the data axis"
    T, D = x.shape
    T_loc = T // (dsize * msize)
    C_loc = capacity(T_loc, E, top_k, capacity_factor)

    def local(x_loc, router, w_gate, w_up, w_down):
        # x_loc [T_loc, D]; router [D, E]; w_* local expert slices
        logits = x_loc.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        flat_e = expert_ids.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_loc), top_k)
        flat_w = gate_vals.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_s, t_s, w_s = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        slot = jnp.arange(T_loc * top_k) - starts[e_s]
        ok = slot < C_loc
        slot = jnp.where(ok, slot, 0)
        gathered = jnp.where(ok[:, None], x_loc[t_s], 0).astype(x_loc.dtype)
        buf = jnp.zeros((E, C_loc, D), x_loc.dtype).at[e_s, slot].add(
            gathered, mode="drop")
        # route slots to the experts' home data-shards
        buf = jax.lax.all_to_all(buf, dp, split_axis=0, concat_axis=1,
                                 tiled=True)              # [e_loc, S*C_loc, D]
        h = swiglu(jnp.einsum("ecd,edf->ecf", buf, w_gate),
                   jnp.einsum("ecd,edf->ecf", buf, w_up))
        out = jnp.einsum("ecf,efd->ecd", h, w_down)       # partial over F
        out = jax.lax.psum(out, "model")
        out = jax.lax.all_to_all(out, dp, split_axis=1, concat_axis=0,
                                 tiled=True)              # [E, C_loc, D]
        expert_out = out[e_s, slot] * (w_s * ok).astype(out.dtype)[:, None]
        y = jnp.zeros((T_loc, D), x_loc.dtype).at[t_s].add(
            expert_out.astype(x_loc.dtype), mode="drop")
        me = jax.lax.pmean(jnp.mean(probs, axis=0), dp + ("model",))
        ce = jax.lax.pmean(jnp.mean(jax.nn.one_hot(
            expert_ids, E, dtype=jnp.float32), axis=(0, 1)), dp + ("model",))
        lb = jnp.sum(me * ce * E)
        dropped = 1.0 - jax.lax.pmean(jnp.mean(ok.astype(jnp.float32)),
                                      dp + ("model",))
        return y, lb, dropped

    tok_spec = P((*dp, "model"), None)       # tokens sharded over all devices
    fn = shard_map(local, mesh=mesh,
                   in_specs=(tok_spec, P(None, None), P(dp, None, "model"),
                             P(dp, None, "model"), P(dp, "model", None)),
                   out_specs=(tok_spec, P(), P()), check_rep=False)
    y, lb, dropped = fn(x, params["router"], params["experts_gate"],
                        params["experts_up"], params["experts_down"])
    return y, {"load_balance_loss": lb, "dropped_fraction": dropped}
