"""Encoder-decoder backbone (seamless-m4t style).

The speech frontend is a stub: the encoder consumes precomputed frame
embeddings [B, S_enc, d_model] (S_enc = seq_len // enc_ratio).  The decoder is
a causal transformer with cross-attention over the encoder output; decode
shapes lower the *decoder* step (cross K/V precomputed into the cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import maybe_constrain
from repro.models import attention as attn
from repro.models.layers import apply_norm, dense_init, embed_init, norm_param
from repro.models.lm import chunked_ce_loss, init_mlp, mlp_apply


def _init_enc_layer(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    return {
        "norm1": norm_param(cfg.d_model, cfg.norm_type, dtype),
        "norm2": norm_param(cfg.d_model, cfg.norm_type, dtype),
        "attn": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "norm1": norm_param(cfg.d_model, cfg.norm_type, dtype),
        "norm2": norm_param(cfg.d_model, cfg.norm_type, dtype),
        "norm3": norm_param(cfg.d_model, cfg.norm_type, dtype),
        "attn": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, dtype),
        "xattn": attn.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "tok_embed": embed_init(ks[2], cfg.vocab_padded, cfg.d_model, dtype),
        "enc": {"layers": jax.vmap(functools.partial(_init_enc_layer, cfg))(enc_keys),
                "final_norm": norm_param(cfg.d_model, cfg.norm_type, dtype)},
        "layers": jax.vmap(functools.partial(_init_dec_layer, cfg))(dec_keys),
        "final_norm": norm_param(cfg.d_model, cfg.norm_type, dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab_padded, dtype),
    }


def _attn_kw(cfg, causal):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                kv_chunk=cfg.kv_chunk, causal=causal)


def encode(params, cfg, frames):
    """frames: [B, S_enc, D] stub embeddings -> encoder hidden states."""
    def body(x, lp):
        x = maybe_constrain(x, "batch", "seq", None)
        h = apply_norm(x, lp["norm1"], cfg.norm_type)
        x = x + attn.attention_train(lp["attn"], h, **_attn_kw(cfg, causal=False))
        h = apply_norm(x, lp["norm2"], cfg.norm_type)
        return x + mlp_apply(lp["mlp"], h), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, frames, params["enc"]["layers"])
    return apply_norm(x, params["enc"]["final_norm"], cfg.norm_type)


def _cross_attention(lp, h, enc_kv):
    """h: [B,S,D] queries; enc_kv = (k, v) [B,Se,K,hd] precomputed."""
    B, S, _ = h.shape
    n_heads = lp["wq"].shape[1] // enc_kv[0].shape[-1]
    hd = enc_kv[0].shape[-1]
    q = (h @ lp["wq"]).reshape(B, S, n_heads, hd)
    out = attn.chunked_attention(q, enc_kv[0], enc_kv[1], q_chunk=min(1024, S),
                                 kv_chunk=min(1024, enc_kv[0].shape[1]),
                                 causal=False)
    return out.reshape(B, S, n_heads * hd) @ lp["wo"]


def _enc_kv(lp, enc_out, n_kv, head_dim):
    B, Se, _ = enc_out.shape
    k = (enc_out @ lp["wk"]).reshape(B, Se, n_kv, head_dim)
    v = (enc_out @ lp["wv"]).reshape(B, Se, n_kv, head_dim)
    return k, v


def decode_train(params, cfg, tokens, enc_out, collect_caches=False):
    x = params["tok_embed"][tokens]

    def body(x, lp):
        x = maybe_constrain(x, "batch", "seq", None)
        h = apply_norm(x, lp["norm1"], cfg.norm_type)
        kw = _attn_kw(cfg, causal=True)
        if collect_caches:
            kw.pop("causal")
            a, kv = attn.attention_prefill(lp["attn"], h, **kw)
        else:
            a, kv = attn.attention_train(lp["attn"], h, **kw), None
        x = x + a
        h = apply_norm(x, lp["norm2"], cfg.norm_type)
        ek, ev = _enc_kv(lp["xattn"], enc_out, cfg.n_kv_heads, cfg.head_dim)
        x = x + _cross_attention(lp["xattn"], h, (ek, ev))
        h = apply_norm(x, lp["norm3"], cfg.norm_type)
        x = x + mlp_apply(lp["mlp"], h)
        caches = (kv, (ek, ev)) if collect_caches else None
        return x, caches

    if not collect_caches and cfg.remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["layers"])
    return x, caches


def encdec_loss(params, cfg, batch):
    tokens = batch["tokens"]
    enc_out = encode(params, cfg, batch["frames"])
    hidden, _ = decode_train(params, cfg, tokens, enc_out)
    hidden = apply_norm(hidden, params["final_norm"], cfg.norm_type)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    # chunked_ce_loss applies final_norm again via logits_fn; pass a params
    # view with an identity final_norm to avoid double-normalizing.
    loss = chunked_ce_loss(_head_view(params, cfg), cfg, hidden, labels, mask)
    return loss, {}


def _head_view(params, cfg):
    return {"final_norm": jnp.zeros_like(params["final_norm"]),
            "lm_head": params["lm_head"], "tok_embed": params["tok_embed"]}


def init_cache(cfg, batch: int, max_len: int, enc_len: int):
    dtype = jnp.dtype(cfg.dtype)
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    kv = jnp.zeros((L, batch, max_len, K, hd), dtype)
    ekv = jnp.zeros((L, batch, enc_len, K, hd), dtype)
    return {"k": kv, "v": kv, "ek": ekv, "ev": ekv,
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg, tokens, frames, max_len: int):
    """Encoder pass + decoder prefill; returns (cache, last logits)."""
    enc_out = encode(params, cfg, frames)
    hidden, caches = decode_train(params, cfg, tokens, enc_out,
                                  collect_caches=True)
    (ks, vs), (eks, evs) = caches
    S = tokens.shape[1]
    pad = max_len - S
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    hidden = apply_norm(hidden, params["final_norm"], cfg.norm_type)
    w = params["lm_head"]
    last = (hidden[:, -1, :] @ w)
    cache = {"k": ks, "v": vs, "ek": eks, "ev": evs,
             "pos": jnp.asarray(S, jnp.int32)}
    return cache, last


def decode_step(params, cfg, cache, token):
    """One decoder step with cross-attention over the cached encoder K/V."""
    pos = cache["pos"]
    x = params["tok_embed"][token]
    B = x.shape[0]
    posv = jnp.full((B,), pos, jnp.int32)
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
               rope_theta=cfg.rope_theta)

    def body(carry, inp):
        x, k_all, v_all = carry
        lp, ek, ev, idx = inp
        h = apply_norm(x, lp["norm1"], cfg.norm_type)
        q, k, v = attn.decode_qkv(lp["attn"], h, posv, **akw)
        k_all = jax.lax.dynamic_update_slice(
            k_all, k[None].astype(k_all.dtype), (idx, 0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            v_all, v[None].astype(v_all.dtype), (idx, 0, pos, 0, 0))
        ck = jax.lax.dynamic_index_in_dim(k_all, idx, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(v_all, idx, 0, keepdims=False)
        a = attn.decode_scores(lp["attn"], q, ck, cv, posv, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                               dtype=h.dtype)
        x = x + a
        h = apply_norm(x, lp["norm2"], cfg.norm_type)
        x = x + _cross_attention(lp["xattn"], h[:, None, :], (ek, ev))[:, 0]
        h = apply_norm(x, lp["norm3"], cfg.norm_type)
        x = x + mlp_apply(lp["mlp"], h)
        return (x, k_all, v_all), None

    (x, k_new, v_new), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], cache["ek"], cache["ev"],
         jnp.arange(cfg.n_layers)))
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = x @ params["lm_head"]
    new_cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
    return new_cache, logits
