"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layer stacks are parameter-stacked and executed with ``lax.scan`` (small HLO,
fast 512-device SPMD compiles); blocks are rematerialized in the backward
pass.  The vocabulary is padded to a multiple of 128 for clean TP sharding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import maybe_constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, dense_init, embed_init, norm_param, swiglu


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp_apply(params, x):
    return swiglu(x @ params["w_gate"], x @ params["w_up"]) @ params["w_down"]


def _init_layer(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_param(cfg.d_model, cfg.norm_type, dtype),
         "norm2": norm_param(cfg.d_model, cfg.norm_type, dtype)}
    if cfg.family in ("dense", "vlm", "moe"):
        p["attn"] = attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, dtype)
        if cfg.family == "moe":
            p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.n_experts,
                                        cfg.d_expert, dtype)
            if cfg.dense_residual:
                p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
            if cfg.n_shared_experts:
                p["shared_mlp"] = init_mlp(
                    ks[3], cfg.d_model, cfg.n_shared_experts * cfg.d_expert, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif cfg.family == "ssm":
        p["mlstm"] = ssm_mod.init_mlstm(ks[0], cfg)
    elif cfg.family == "hybrid":
        p["mamba"] = ssm_mod.init_mamba2(ks[0], cfg)
    else:
        raise ValueError(cfg.family)
    return p


def init_lm(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    params = {
        "tok_embed": embed_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype),
        "layers": jax.vmap(functools.partial(_init_layer, cfg))(layer_keys),
        "final_norm": norm_param(cfg.d_model, cfg.norm_type, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded, dtype)
    if cfg.family == "hybrid":  # zamba-style shared attention + mlp block
        params["shared_attn"] = attn.init_attention(
            ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype)
        params["shared_attn_norm"] = norm_param(cfg.d_model, cfg.norm_type, dtype)
        params["shared_mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff, dtype)
        params["shared_mlp_norm"] = norm_param(cfg.d_model, cfg.norm_type, dtype)
    return params


# --------------------------------------------------------------------------
# blocks (train / full-sequence path)
# --------------------------------------------------------------------------

def _attn_block_train(lp, x, cfg, collect_kv=False):
    h = apply_norm(x, lp["norm1"], cfg.norm_type)
    kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
              rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
              block_skip=cfg.causal_block_skip)
    if collect_kv:
        a, kv = attn.attention_prefill(lp["attn"], h, **kw)
    else:
        a, kv = attn.attention_train(lp["attn"], h, **kw), None
    x = x + a
    h = apply_norm(x, lp["norm2"], cfg.norm_type)
    aux = {}
    if cfg.family == "moe":
        B, S, D = h.shape
        y, aux = moe_mod.moe_apply(lp["moe"], h.reshape(B * S, D),
                                   n_experts=cfg.n_experts, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   group_size=cfg.moe_group_size,
                                   impl=cfg.moe_impl,
                                   expert_axis="data" if cfg.expert_data_shard
                                   else "model")
        y = y.reshape(B, S, D)
        if cfg.dense_residual:
            y = y + mlp_apply(lp["mlp"], h)
        if cfg.n_shared_experts:
            y = y + mlp_apply(lp["shared_mlp"], h)
    else:
        y = mlp_apply(lp["mlp"], h)
    return x + y, aux, kv


def _shared_block_train(params, x, cfg, collect_kv=False):
    h = apply_norm(x, params["shared_attn_norm"], cfg.norm_type)
    kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
              rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
              block_skip=cfg.causal_block_skip)
    if collect_kv:
        a, kv = attn.attention_prefill(params["shared_attn"], h, **kw)
    else:
        a, kv = attn.attention_train(params["shared_attn"], h, **kw), None
    x = x + a
    h = apply_norm(x, params["shared_mlp_norm"], cfg.norm_type)
    return x + mlp_apply(params["shared_mlp"], h), kv


def _zeros_like_aux(cfg):
    if cfg.family == "moe":
        return {"load_balance_loss": jnp.zeros((), jnp.float32),
                "dropped_fraction": jnp.zeros((), jnp.float32)}
    return {}


def forward_hidden(params, cfg, x, collect_caches=False):
    """Run the layer stack on embedded input x [B,S,D].

    Returns (hidden, aux_mean, caches) where caches is a pytree of per-layer
    prefill caches (stacked along the leading layer axis) when requested.
    """
    B, S, D = x.shape
    is_hybrid = cfg.family == "hybrid"

    def body(x, inp):
        lp, idx = inp
        # sequence-parallel residual stream: the saved per-layer carries are
        # sharded over the model axis, bounding activation memory at long seq
        x = maybe_constrain(x, "batch", "seq", None)
        if cfg.family == "ssm":
            h = apply_norm(x, lp["norm1"], cfg.norm_type)
            x = x + ssm_mod.mlstm_train(lp["mlstm"], h, cfg)
            return x, ({}, None)
        if is_hybrid:
            x = x + ssm_mod.mamba2_train(lp["mamba"], apply_norm(
                x, lp["norm1"], cfg.norm_type), cfg)
            is_attn = (idx % cfg.attn_every) == 0

            def with_attn(x):
                y, _ = _shared_block_train(params, x, cfg)
                return y

            x = jax.lax.cond(is_attn, with_attn, lambda x: x, x)
            return x, ({}, None)
        x, aux, kv = _attn_block_train(lp, x, cfg, collect_kv=collect_caches)
        return x, (aux, kv)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = (params["layers"], jnp.arange(cfg.n_layers))
    x, (aux, caches) = jax.lax.scan(body_fn, x, xs)
    aux = {k: jnp.mean(v) for k, v in aux.items()} if aux else _zeros_like_aux(cfg)
    return x, aux, caches


def embed_tokens(params, cfg, tokens, patch_embeds=None):
    x = params["tok_embed"][tokens]
    if cfg.family == "vlm" and patch_embeds is not None:
        P = cfg.n_patches
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, P:, :]], axis=1)
    return x


def logits_fn(params, cfg, hidden):
    h = apply_norm(hidden, params["final_norm"], cfg.norm_type)
    w = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w


def chunked_ce_loss(params, cfg, hidden, labels, mask, chunk: int = 512):
    """Cross-entropy over the (padded) vocab, scanned over sequence chunks so
    the [B, S, V] logits tensor never fully materializes."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def one(h_blk, y_blk, m_blk):
        logits = logits_fn(params, cfg, h_blk).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_blk[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m_blk), jnp.sum(m_blk)

    one = jax.checkpoint(one)

    def body(carry, inp):
        tot, cnt = carry
        h_blk, y_blk, m_blk = inp
        s, c = one(h_blk, y_blk, m_blk)
        return (tot + s, cnt + c), None

    hs = hidden[:, :n * chunk].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ys = labels[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ys, ms))
    if rem:
        s, c = one(hidden[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:])
        tot, cnt = tot + s, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg, batch):
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, batch.get("patch_embeds"))
    hidden, aux, _ = forward_hidden(params, cfg, x)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    if cfg.family == "vlm":  # don't predict inside the patch prefix
        mask = mask.at[:, :cfg.n_patches - 1].set(0.0)
    loss = chunked_ce_loss(params, cfg, hidden, labels, mask)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["load_balance_loss"]
    return loss, aux


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    """Decode cache pytree (stacked along the leading layer axis)."""
    dtype = jnp.dtype(cfg.dtype)
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        kv = jnp.zeros((L, batch, max_len, K, hd), dtype)
        return {"k": kv, "v": kv, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        c = jax.vmap(lambda _: ssm_mod.mlstm_init_cache(cfg, batch, dtype))(
            jnp.arange(L))
        return {**c, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        c = jax.vmap(lambda _: ssm_mod.mamba2_init_cache(cfg, batch, dtype))(
            jnp.arange(L))
        n_attn = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        kv = jnp.zeros((n_attn, batch, max_len, K, hd), dtype)
        return {**c, "ak": kv, "av": kv, "pos": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.family)


def decode_step(params, cfg, cache, token):
    """One greedy decode step.  token: [B] int32 -> (new_cache, logits [B, V]).

    Mutated cache buffers ride in the scan *carry* (single buffer, in-place
    single-token DUS writes) instead of xs/ys — scanning them as ys keeps the
    old and new cache stacks alive simultaneously (2x peak) and rewrites the
    full cache every step (the dry-run's memory-term pathology)."""
    pos = cache["pos"]
    x = params["tok_embed"][token]                                # [B, D]
    B = x.shape[0]
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
               rope_theta=cfg.rope_theta)
    posv = jnp.full((B,), pos, jnp.int32)

    def attend(lp_attn, h, k_all, v_all, idx):
        """q/k/v for the token, in-place cache write, attention read."""
        q, k, v = attn.decode_qkv(lp_attn, h, posv, **akw)
        k_all = jax.lax.dynamic_update_slice(
            k_all, k[None].astype(k_all.dtype), (idx, 0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            v_all, v[None].astype(v_all.dtype), (idx, 0, pos, 0, 0))
        ck = jax.lax.dynamic_index_in_dim(k_all, idx, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(v_all, idx, 0, keepdims=False)
        a = attn.decode_scores(lp_attn, q, ck, cv, posv, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                               dtype=h.dtype)
        return a, k_all, v_all

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, inp):
            x, k_all, v_all = carry
            lp, idx = inp
            h = apply_norm(x, lp["norm1"], cfg.norm_type)
            a, k_all, v_all = attend(lp["attn"], h, k_all, v_all, idx)
            x = x + a
            h = apply_norm(x, lp["norm2"], cfg.norm_type)
            if cfg.family == "moe":
                y, _ = moe_mod.moe_apply(lp["moe"], h, n_experts=cfg.n_experts,
                                         top_k=cfg.top_k,
                                         capacity_factor=cfg.capacity_factor,
                                         group_size=cfg.moe_group_size,
                                         impl=cfg.moe_impl,
                                         expert_axis="data"
                                         if cfg.expert_data_shard else "model")
                if cfg.dense_residual:
                    y = y + mlp_apply(lp["mlp"], h)
                if cfg.n_shared_experts:
                    y = y + mlp_apply(lp["shared_mlp"], h)
            else:
                y = mlp_apply(lp["mlp"], h)
            return (x + y, k_all, v_all), None

        (x, k_new, v_new), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}

    elif cfg.family == "ssm":
        def body(carry, inp):
            x, states, convs = carry
            lp, idx = inp
            st = jax.lax.dynamic_index_in_dim(states, idx, 0, keepdims=False)
            cw = jax.lax.dynamic_index_in_dim(convs, idx, 0, keepdims=False)
            h = apply_norm(x, lp["norm1"], cfg.norm_type)
            y, c2 = ssm_mod.mlstm_decode(lp["mlstm"], h,
                                         {"state": st, "conv": cw}, cfg)
            states = jax.lax.dynamic_update_index_in_dim(
                states, c2["state"], idx, 0)
            convs = jax.lax.dynamic_update_index_in_dim(
                convs, c2["conv"].astype(convs.dtype), idx, 0)
            return (x + y, states, convs), None

        (x, st, cw), _ = jax.lax.scan(
            body, (x, cache["state"], cache["conv"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"state": st, "conv": cw, "pos": pos + 1}

    elif cfg.family == "hybrid":
        def body(carry, inp):
            x, states, convs, ak, av = carry
            lp, idx = inp
            st = jax.lax.dynamic_index_in_dim(states, idx, 0, keepdims=False)
            cw = jax.lax.dynamic_index_in_dim(convs, idx, 0, keepdims=False)
            h = apply_norm(x, lp["norm1"], cfg.norm_type)
            y, c2 = ssm_mod.mamba2_decode(lp["mamba"], h,
                                          {"state": st, "conv": cw}, cfg)
            states = jax.lax.dynamic_update_index_in_dim(
                states, c2["state"], idx, 0)
            convs = jax.lax.dynamic_update_index_in_dim(
                convs, c2["conv"].astype(convs.dtype), idx, 0)
            x = x + y
            slot = idx // cfg.attn_every

            def with_attn(arg):
                x, ak, av = arg
                h = apply_norm(x, params["shared_attn_norm"], cfg.norm_type)
                a, ak, av = attend(params["shared_attn"], h, ak, av, slot)
                x = x + a
                h = apply_norm(x, params["shared_mlp_norm"], cfg.norm_type)
                return x + mlp_apply(params["shared_mlp"], h), ak, av

            x, ak, av = jax.lax.cond((idx % cfg.attn_every) == 0, with_attn,
                                     lambda a: a, (x, ak, av))
            return (x, states, convs, ak, av), None

        (x, st, cw, ak, av), _ = jax.lax.scan(
            body, (x, cache["state"], cache["conv"], cache["ak"], cache["av"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"state": st, "conv": cw, "ak": ak, "av": av, "pos": pos + 1}
    else:
        raise ValueError(cfg.family)

    logits = logits_fn(params, cfg, x[:, None, :])[:, 0]
    return new_cache, logits


# --------------------------------------------------------------------------
# prefill path (inference-prefill shape): build the cache for a full prompt
# --------------------------------------------------------------------------

def prefill(params, cfg, tokens, max_len: int, patch_embeds=None):
    """Returns (cache at position S, last-token logits [B, V])."""
    B, S = tokens.shape
    x = embed_tokens(params, cfg, tokens, patch_embeds)
    if cfg.family in ("dense", "moe", "vlm"):
        hidden, _, (ks, vs) = forward_hidden(params, cfg, x, collect_caches=True)
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    elif cfg.family == "ssm":
        # run the train path but collect the final GLA state per layer
        def body(x, lp):
            h = apply_norm(x, lp["norm1"], cfg.norm_type)
            u, z = jnp.split(h @ lp["mlstm"]["w_in_ssm"], 2, axis=-1)
            conv_win = u[:, -(cfg.conv_kernel - 1):, :]
            u = jax.nn.silu(ssm_mod.causal_conv(u, lp["mlstm"]["conv_w"],
                                                lp["mlstm"]["conv_b"]))
            q, k, v_aug, a = ssm_mod._mlstm_qkva(lp["mlstm"], u, cfg)
            y_aug, state = ssm_mod.chunked_gla(q, k, v_aug, a, chunk=cfg.ssm_chunk)
            y = ssm_mod._mlstm_finish(y_aug, z, lp["mlstm"], cfg, h.shape[:-1])
            return x + y, (state, conv_win)

        x, (states, convs) = jax.lax.scan(body, x, params["layers"])
        hidden = x
        cache = {"state": states, "conv": convs, "pos": jnp.asarray(S, jnp.int32)}
    else:  # hybrid — prefill via repeated decode is wasteful; use train path +
        # final states.  Implemented as scan over layers mirroring train.
        n_attn = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        K, hd = cfg.n_kv_heads, cfg.head_dim
        ak0 = jnp.zeros((n_attn, B, max_len, K, hd), x.dtype)

        def body(carry, inp):
            x, ak, av = carry
            lp, idx = inp
            h = apply_norm(x, lp["norm1"], cfg.norm_type)
            zxbcdt = h @ lp["mamba"]["w_in_ssm"]
            conv = lambda u: ssm_mod.causal_conv(u, lp["mamba"]["conv_w"],
                                                 lp["mamba"]["conv_b"])
            q, k, v, a, z, xh = ssm_mod._mamba2_qkva(lp["mamba"], zxbcdt, cfg, conv)
            y, state = ssm_mod.chunked_gla(q, k, v, a, chunk=cfg.ssm_chunk)
            y = y + xh * lp["mamba"]["D_skip"][None, None, :, None].astype(xh.dtype)
            y = y.reshape(*h.shape[:-1], cfg.d_inner)
            y = ssm_mod.rmsnorm(y * jax.nn.silu(z), lp["mamba"]["out_norm"])
            x = x + y @ lp["mamba"]["w_out_ssm"]
            xr = jnp.split(zxbcdt, [cfg.d_inner, 2 * cfg.d_inner], axis=-1)[1]
            conv_win = xr[:, -(cfg.conv_kernel - 1):, :]
            slot = idx // cfg.attn_every

            def with_attn(arg):
                x, ak, av = arg
                y, (kc, vc) = _shared_block_train(params, x, cfg, collect_kv=True)
                pad = max_len - S
                kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
                ak = jax.lax.dynamic_update_index_in_dim(ak, kc, slot, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, vc, slot, 0)
                return y, ak, av

            x, ak, av = jax.lax.cond((idx % cfg.attn_every) == 0, with_attn,
                                     lambda a: a, (x, ak, av))
            return (x, ak, av), (state, conv_win)

        (x, ak, av), (states, convs) = jax.lax.scan(
            body, (x, ak0, ak0), (params["layers"], jnp.arange(cfg.n_layers)))
        hidden = x
        cache = {"state": states, "conv": convs, "ak": ak, "av": av,
                 "pos": jnp.asarray(S, jnp.int32)}
    last = logits_fn(params, cfg, hidden[:, -1:, :])[:, 0]
    return cache, last
