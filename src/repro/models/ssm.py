"""Sub-quadratic sequence mixers: a shared chunked gated-linear-attention
core, instantiated as Mamba2 (SSD) and mLSTM (xLSTM) blocks.

Recurrence (per head):  S_t = exp(a_t) * S_{t-1} + k_t v_t^T,   y_t = q_t^T S_t
with a_t <= 0 (log-decay).  The chunked form computes an intra-chunk
decay-masked attention plus a cross-chunk term from the carried state; all
exponents are differences of a *decreasing* cumulative sum, hence <= 0 and
numerically safe in fp32.

Mamba2 mapping:  q=C, k=B, v=dt*x (per-head), a=A*dt          [arXiv:2405.21060]
mLSTM mapping:   q,k,v projections, a=log_sigmoid(f_pre); the xLSTM
normalizer n_t is tracked as an appended all-ones value column; the exp input
gate is realized as a bounded sigmoid(i_pre) scaling of k (stabilization
deviation from the paper, noted in DESIGN.md).     [arXiv:2405.04517]

Decode: single-step recurrence carrying (state, conv window) — O(1) per token,
which is why these families run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import maybe_constrain
from repro.models.layers import dense_init, rmsnorm


# --------------------------------------------------------------------------
# chunked gated linear attention core
# --------------------------------------------------------------------------

def chunked_gla(q, k, v, log_decay, *, chunk: int, initial_state=None):
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; log_decay: [B,S,H] (<=0).

    Returns (y [B,S,H,dv], final_state [B,H,dk,dv]).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    n = S // chunk
    assert n * chunk == S, "seq must divide ssm chunk"

    # head-parallel: keep the recurrence local to a device along H
    q = maybe_constrain(q, "batch", None, "model", None)
    k = maybe_constrain(k, "batch", None, "model", None)
    v = maybe_constrain(v, "batch", None, "model", None)
    log_decay = maybe_constrain(log_decay, "batch", None, "model")

    qc = q.reshape(B, n, chunk, H, dk).transpose(1, 0, 3, 2, 4)   # [n,B,H,L,dk]
    kc = k.reshape(B, n, chunk, H, dk).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n, chunk, H, dv).transpose(1, 0, 3, 2, 4)
    ac = log_decay.reshape(B, n, chunk, H).transpose(1, 0, 3, 2)  # [n,B,H,L]

    S0 = (jnp.zeros((B, H, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, inp):
        qb, kb, vb, ab = inp                   # [B,H,L,*]
        cum = jnp.cumsum(ab.astype(jnp.float32), axis=-1)         # [B,H,L]
        # intra-chunk: scores[t,j] = (q_t.k_j) exp(cum_t - cum_j), t >= j
        diff = cum[..., :, None] - cum[..., None, :]              # [B,H,L,L]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        scores = jnp.einsum("bhtd,bhjd->bhtj", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * decay
        y_intra = jnp.einsum("bhtj,bhjv->bhtv", scores, vb.astype(jnp.float32))
        # cross-chunk: y_t += (q_t exp(cum_t)) @ S_prev
        q_scaled = qb.astype(jnp.float32) * jnp.exp(cum)[..., None]
        y_cross = jnp.einsum("bhtd,bhdv->bhtv", q_scaled, state)
        # state update: S_new = exp(cum_L) S + sum_j exp(cum_L - cum_j) k_j v_j^T
        last = cum[..., -1:]                                      # [B,H,1]
        k_scaled = kb.astype(jnp.float32) * jnp.exp(last - cum)[..., None]
        outer = jnp.einsum("bhjd,bhjv->bhdv", k_scaled, vb.astype(jnp.float32))
        state = jnp.exp(last)[..., None] * state + outer
        return state, (y_intra + y_cross).astype(v.dtype)

    # remat: recompute the [B,H,L,L] intra-chunk decay/score matrices in bwd
    final, yc = jax.lax.scan(jax.checkpoint(step), S0, (qc, kc, vc, ac))
    y = yc.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return y, final


def gla_decode_step(state, q, k, v, log_decay):
    """One-token recurrence.  state: [B,H,dk,dv]; q,k: [B,H,dk]; v: [B,H,dv]."""
    decay = jnp.exp(log_decay.astype(jnp.float32))[..., None, None]
    state = decay * state + jnp.einsum(
        "bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return state, y.astype(v.dtype)


# --------------------------------------------------------------------------
# causal depthwise conv (Mamba-style, kernel k)
# --------------------------------------------------------------------------

def causal_conv(x, w, b):
    """x: [B,S,C]; w: [C,k]; causal depthwise conv along S.

    tap i of the kernel multiplies the input delayed by (k-1-i) steps, i.e.
    out_t = sum_i w[:, i] * x_{t - (k-1-i)}  (unrolled: k is 4).
    """
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[:, i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def conv_decode_step(window, x_t, w, b):
    """window: [B, k-1, C] past inputs; x_t: [B, C]."""
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)     # [B,k,C]
    out = jnp.einsum("bkc,ck->bc", full, w) + b[None, :]
    return full[:, 1:, :], out


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def init_mamba2(key, cfg):
    D, d_inner, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    proj_out = 2 * d_inner + 2 * N + H                             # z, x, B, C, dt
    return {
        "w_in_ssm": dense_init(ks[0], D, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_inner, cfg.conv_kernel), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "out_norm": jnp.zeros((d_inner,), dtype),
        "w_out_ssm": dense_init(ks[2], d_inner, D, dtype),
    }


def _mamba2_qkva(params, zxbcdt, cfg, conv_apply):
    """Split the input projection and build (q, k, v, a, z) for the GLA core."""
    d_inner, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_headdim
    z, xr, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    xr = conv_apply(xr)
    xr = jax.nn.silu(xr)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # [...,H]
    A = -jnp.exp(params["A_log"])                                      # [H] < 0
    a = dt * A                                                         # log-decay
    shape = xr.shape[:-1]
    v = xr.reshape(*shape, H, P) * dt[..., None].astype(xr.dtype)
    q = jnp.broadcast_to(Cc[..., None, :], (*shape, H, N))
    k = jnp.broadcast_to(Bc[..., None, :], (*shape, H, N))
    return q, k, v, a, z, xr.reshape(*shape, H, P)


def mamba2_train(params, x, cfg):
    """x: [B,S,D] -> [B,S,D]."""
    zxbcdt = x @ params["w_in_ssm"]
    conv = lambda u: causal_conv(u, params["conv_w"], params["conv_b"])
    q, k, v, a, z, xh = _mamba2_qkva(params, zxbcdt, cfg, conv)
    y, _ = chunked_gla(q, k, v, a, chunk=cfg.ssm_chunk)
    y = y + xh * params["D_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(*x.shape[:-1], cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"])
    return y @ params["w_out_ssm"]


def mamba2_init_cache(cfg, batch: int, dtype):
    return {
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    }


def mamba2_decode(params, x_t, cache, cfg):
    """x_t: [B, D]; one-step."""
    zxbcdt = x_t @ params["w_in_ssm"]

    def conv(u):
        nonlocal cache
        win, out = conv_decode_step(cache["conv"], u, params["conv_w"],
                                    params["conv_b"])
        cache = dict(cache, conv=win)
        return out

    q, k, v, a, z, xh = _mamba2_qkva(params, zxbcdt, cfg, conv)
    state, y = gla_decode_step(cache["state"], k=k, q=q, v=v, log_decay=a)
    cache = dict(cache, state=state)
    y = y + xh * params["D_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(x_t.shape[0], cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"])
    return y @ params["w_out_ssm"], cache


# --------------------------------------------------------------------------
# mLSTM block (xLSTM)
# --------------------------------------------------------------------------

def _mlstm_dims(cfg):
    H = cfg.n_heads
    d_inner = cfg.d_inner
    dk = cfg.d_model // H
    dv = d_inner // H
    return H, d_inner, dk, dv


def init_mlstm(key, cfg):
    D = cfg.d_model
    H, d_inner, dk, dv = _mlstm_dims(cfg)
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "w_in_ssm": dense_init(ks[0], D, 2 * d_inner, dtype),      # u, z
        "conv_w": (jax.random.normal(ks[1], (d_inner, cfg.conv_kernel), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_qk": dense_init(ks[2], d_inner, 2 * H * dk, dtype),
        "w_if": dense_init(ks[3], d_inner, 2 * H, dtype),
        "out_norm": jnp.zeros((d_inner,), dtype),
        "w_out_ssm": dense_init(ks[4], d_inner, D, dtype),
    }


def _mlstm_qkva(params, u, cfg):
    H, d_inner, dk, dv = _mlstm_dims(cfg)
    shape = u.shape[:-1]
    qk = u @ params["w_qk"]
    q, k = jnp.split(qk.reshape(*shape, H, 2 * dk), 2, axis=-1)
    v = u.reshape(*shape, H, dv)
    gates = (u @ params["w_if"]).astype(jnp.float32).reshape(*shape, H, 2)
    i_pre, f_pre = gates[..., 0], gates[..., 1]
    a = jax.nn.log_sigmoid(f_pre)                                  # log-decay
    k = k * jax.nn.sigmoid(i_pre)[..., None].astype(k.dtype)       # bounded input gate
    k = k / jnp.sqrt(dk).astype(k.dtype)
    # normalizer column: v_aug[..., -1] accumulates the gate mass
    v_aug = jnp.concatenate([v, jnp.ones((*v.shape[:-1], 1), v.dtype)], axis=-1)
    return q, k, v_aug, a


def _mlstm_finish(y_aug, z, params, cfg, lead_shape):
    dv = _mlstm_dims(cfg)[3]
    y, nrm = y_aug[..., :dv], y_aug[..., dv:]
    y = y / jnp.maximum(jnp.abs(nrm.astype(jnp.float32)), 1.0).astype(y.dtype)
    y = y.reshape(*lead_shape, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"])
    return y @ params["w_out_ssm"]


def mlstm_train(params, x, cfg):
    u, z = jnp.split(x @ params["w_in_ssm"], 2, axis=-1)
    u = jax.nn.silu(causal_conv(u, params["conv_w"], params["conv_b"]))
    q, k, v_aug, a = _mlstm_qkva(params, u, cfg)
    y_aug, _ = chunked_gla(q, k, v_aug, a, chunk=cfg.ssm_chunk)
    return _mlstm_finish(y_aug, z, params, cfg, x.shape[:-1])


def mlstm_init_cache(cfg, batch: int, dtype):
    H, d_inner, dk, dv = _mlstm_dims(cfg)
    return {
        "state": jnp.zeros((batch, H, dk, dv + 1), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner), dtype),
    }


def mlstm_decode(params, x_t, cache, cfg):
    u, z = jnp.split(x_t @ params["w_in_ssm"], 2, axis=-1)
    win, u = conv_decode_step(cache["conv"], u, params["conv_w"], params["conv_b"])
    u = jax.nn.silu(u)
    q, k, v_aug, a = _mlstm_qkva(params, u, cfg)
    state, y_aug = gla_decode_step(cache["state"], q=q, k=k, v=v_aug, log_decay=a)
    cache = {"state": state, "conv": win}
    return _mlstm_finish(y_aug, z, params, cfg, x_t.shape[:-1]), cache
