"""GQA attention: chunked (flash-style) training/prefill path + decode path.

The training path is a pure-JAX blockwise online-softmax (lax.scan over query
and key/value chunks) so it compiles on any backend and never materializes the
[S, S] score matrix.  On TPU the Pallas kernel in
``repro.kernels.flash_attention`` is a drop-in for the inner computation; the
dry-run lowers the pure-JAX path (Pallas does not lower on the CPU backend).

Baseline causality is mask-based (fully-masked kv blocks are still computed:
exact static FLOPs, ~2x causal waste — visible in the roofline useful-compute
ratio).  ``causal_block_skip=True`` switches to a triangular pair schedule
that only visits j <= i blocks (hillclimb lever, see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import ambient_mesh, maybe_constrain
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def _heads_factorizable(K: int, G: int) -> bool:
    """Can GSPMD split the model axis across the (kv-head, group) dims?"""
    mesh = ambient_mesh()
    if mesh is None:
        return True
    ms = mesh.shape.get("model", 1)
    for a in range(1, ms + 1):
        if ms % a == 0 and K % a == 0 and G % (ms // a) == 0:
            return True
    return False


def _constrain_blocks(qb, mesh_axis_ok: bool):
    """For non-factorizable head counts (e.g. 56 or 15 heads on a 16-way
    axis), shard the query-chunk dim instead — context-parallel attention:
    online softmax is row-local, so no cross-shard reductions appear."""
    if mesh_axis_ok:
        return qb
    # qb: [nq, B, K, G, Tq, D] — shard Tq
    return maybe_constrain(qb, None, "batch", None, None, "model", None)


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def _qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta):
    """Megatron-style column-parallel projections: the flattened head dim is
    constrained to the model axis so attention runs head-local (no in-loop
    resharding); the seq-sharded residual is all-gathered once per layer."""
    B, S, _ = x.shape
    q = maybe_constrain(x @ params["wq"], "batch", None, "model")
    k = maybe_constrain(x @ params["wk"], "batch", None, "model")
    v = maybe_constrain(x @ params["wv"], "batch", None, "model")
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, q_chunk: int, kv_chunk: int, causal: bool,
                      q_offset=0, kv_lens=None, block_skip: bool = False):
    """Online-softmax blockwise attention.

    q: [B, Sq, H, D]; k/v: [B, Skv, K, D] with H = K*G (GQA).
    q_offset: global position of q[0] (prefill continuation / decode).
    kv_lens: optional [B] valid kv lengths (padding mask).
    Returns [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    assert nq * q_chunk == Sq and nk * kv_chunk == Skv, "seq must divide chunks"
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    qb = q.reshape(B, nq, q_chunk, K, G, D).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,K,G,Tq,D]
    kb = k.reshape(B, nk, kv_chunk, K, D).transpose(1, 0, 3, 2, 4)       # [nk,B,K,Tk,D]
    vb = v.reshape(B, nk, kv_chunk, K, D).transpose(1, 0, 3, 2, 4)
    qb = _constrain_blocks(qb, _heads_factorizable(K, G))

    cp = not _heads_factorizable(K, G)
    if block_skip and causal:
        out = _triangular_attention(qb, kb, vb, scale, q_chunk, kv_chunk,
                                    q_offset, kv_lens, cp)
    else:
        out = _rect_attention(qb, kb, vb, scale, q_chunk, kv_chunk, causal,
                              q_offset, kv_lens, cp)
    # out: [nq, B, K, G, Tq, D] -> [B, Sq, H, D]
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, D)


def _block(q_blk, k_blk, v_blk, m, l, acc, qi, kj, scale, q_chunk, kv_chunk,
           causal, q_offset, kv_lens, cp=False):
    """One online-softmax update.  q_blk [B,K,G,Tq,D]; k/v [B,K,Tk,D].

    cp=True pins the query-chunk dim to the model axis (context-parallel) —
    applied inside the block so the checkpointed backward recompute carries
    the same sharding (constraints transpose to themselves)."""
    def pin(x):
        if not cp:
            return x
        spec = [("batch" if i == 0 else "model" if i == 3 else None)
                for i in range(x.ndim)]
        return maybe_constrain(x, *spec)

    q_blk, m, l, acc = pin(q_blk), pin(m), pin(l), pin(acc)
    # NOTE §Perf: bf16-operand dots with preferred_element_type=f32 were
    # tried and measured NEUTRAL-to-worse (+0.7% memory term) in this
    # lowering — the f32 tile converts below fuse into the dot's operand
    # reads, so removing them buys nothing here (they would on the MXU; the
    # Pallas kernel takes bf16 operands directly).
    s = jnp.einsum("bkgqd,bktd->bkgqt", q_blk.astype(jnp.float32),
                   k_blk.astype(jnp.float32)) * scale
    s = pin(s)
    qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
    kpos = kj * kv_chunk + jnp.arange(kv_chunk)
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask = qpos[:, None] >= kpos[None, :]
    if kv_lens is not None:
        mask = mask[None] & (kpos[None, None, :] < kv_lens[:, None, None])
        mask = mask[:, None, None]          # [B,1,1,Tq,Tk]
    else:
        mask = mask[None, None, None]       # [1,1,1,Tq,Tk]
    s = jnp.where(mask, s, NEG_INF)
    m_new = pin(jnp.maximum(m, jnp.max(s, axis=-1)))
    p = pin(jnp.exp(s - m_new[..., None]))
    corr = jnp.exp(m - m_new)
    l_new = pin(l * corr + jnp.sum(p, axis=-1))
    # NOTE §Perf: casting p to bf16 for the pv matmul was tried and REFUTED —
    # the cast materializes an extra copy of p in the measured lowering
    # (memory term +3.5%).
    acc_new = pin(acc * corr[..., None] + jnp.einsum(
        "bkgqt,bktd->bkgqd", p, v_blk.astype(jnp.float32)))
    return m_new, l_new, acc_new


def _finish(m, l, acc, dtype):
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(dtype)


def _rect_attention(qb, kb, vb, scale, q_chunk, kv_chunk, causal, q_offset,
                    kv_lens, cp=False):
    nq, B, K, G, Tq, D = qb.shape
    nk = kb.shape[0]

    def per_q(qi, q_blk):
        m = jnp.full((B, K, G, Tq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, K, G, Tq), jnp.float32)
        acc = jnp.zeros((B, K, G, Tq, D), jnp.float32)

        def kv_step(carry, inp):
            kj, k_blk, v_blk = inp
            m, l, acc = carry
            m, l, acc = _block(q_blk, k_blk, v_blk, m, l, acc, qi, kj, scale,
                               q_chunk, kv_chunk, causal, q_offset, kv_lens, cp)
            return (m, l, acc), None

        # remat: recompute scores/probs/mask in bwd instead of saving the
        # [B,K,G,Tq,Tk] residuals per block (flash-attention-style backward)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m, l, acc),
                                      (jnp.arange(nk), kb, vb))
        return _finish(m, l, acc, qb.dtype)

    def q_step(_, inp):
        qi, q_blk = inp
        return None, per_q(qi, q_blk)

    _, out = jax.lax.scan(jax.checkpoint(q_step), None, (jnp.arange(nq), qb))
    return out


def _triangular_attention(qb, kb, vb, scale, q_chunk, kv_chunk, q_offset,
                          kv_lens, cp=False):
    """Causal-only schedule visiting exactly the j <= i block pairs.

    Static pair list of length nq*(nq+1)/2 (requires q_chunk == kv_chunk),
    grouped by q block so the online-softmax updates stay ordered; state for
    every q block is carried in dense buffers updated via dynamic_update_slice.
    ~Halves attention FLOPs vs the rectangular schedule.
    """
    nq, B, K, G, Tq, D = qb.shape
    nk = kb.shape[0]
    assert nq == nk and q_chunk == kv_chunk, "block_skip needs equal chunks"
    pairs = [(i, j) for i in range(nq) for j in range(i + 1)]
    pi = jnp.array([p[0] for p in pairs], jnp.int32)
    pj = jnp.array([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((nq, B, K, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, K, G, Tq), jnp.float32)
    a0 = jnp.zeros((nq, B, K, G, Tq, D), jnp.float32)

    def step(carry, ij):
        m_all, l_all, a_all = carry
        i, j = ij
        q_blk = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        m = jax.lax.dynamic_index_in_dim(m_all, i, 0, keepdims=False)
        l = jax.lax.dynamic_index_in_dim(l_all, i, 0, keepdims=False)
        acc = jax.lax.dynamic_index_in_dim(a_all, i, 0, keepdims=False)
        m, l, acc = _block(q_blk, k_blk, v_blk, m, l, acc, i, j, scale,
                           q_chunk, kv_chunk, True, q_offset, kv_lens, cp)
        m_all = jax.lax.dynamic_update_index_in_dim(m_all, m, i, 0)
        l_all = jax.lax.dynamic_update_index_in_dim(l_all, l, i, 0)
        a_all = jax.lax.dynamic_update_index_in_dim(a_all, acc, i, 0)
        return (m_all, l_all, a_all), None

    (m_all, l_all, a_all), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                            (pi, pj))
    return _finish(m_all, l_all, a_all, qb.dtype)


def attention_train(params, x, *, n_heads, n_kv, head_dim, rope_theta,
                    q_chunk, kv_chunk, causal=True, block_skip=False):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta)
    out = chunked_attention(q, k, v, q_chunk=q_chunk, kv_chunk=kv_chunk,
                            causal=causal, block_skip=block_skip)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


def attention_prefill(params, x, *, n_heads, n_kv, head_dim, rope_theta,
                      q_chunk, kv_chunk, block_skip=False):
    """Like train but also returns the (k, v) cache contents."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta)
    out = chunked_attention(q, k, v, q_chunk=q_chunk, kv_chunk=kv_chunk,
                            causal=True, block_skip=block_skip)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"], (k, v)


def decode_qkv(params, x_t, pos, *, n_heads, n_kv, head_dim, rope_theta):
    """Single-token q/k/v for decode.  x_t: [B, D]; pos: [B]."""
    B = x_t.shape[0]
    q = (x_t @ params["wq"]).reshape(B, 1, n_heads, head_dim)
    k = (x_t @ params["wk"]).reshape(B, 1, n_kv, head_dim)
    v = (x_t @ params["wv"]).reshape(B, 1, n_kv, head_dim)
    q = apply_rope(q, pos[:, None], rope_theta)
    k = apply_rope(k, pos[:, None], rope_theta)
    return q, k, v


def decode_scores(params, q, cache_k, cache_v, pos, *, n_heads, n_kv,
                  head_dim, dtype):
    """Attention read over a (layer-sliced) cache.  q: [B,1,H,D];
    cache_k/v: [B,T,K,D] with the CURRENT token already written."""
    B, T = cache_k.shape[0], cache_k.shape[1]
    K = n_kv
    G = n_heads // K
    qg = q.reshape(B, K, G, head_dim)
    # accumulate in f32 WITHOUT materializing an f32 copy of the cache
    s = jnp.einsum("bkgd,btkd->bkgt", qg, cache_k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(head_dim)
    valid = jnp.arange(T)[None, :] <= pos[:, None]            # [B, T]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, n_heads * head_dim).astype(dtype)
    return o @ params["wo"]


def attention_decode(params, x_t, cache_k, cache_v, pos, *, n_heads, n_kv,
                     head_dim, rope_theta):
    """One decode step over a per-layer cache (compat path; the lm decode
    loop uses decode_qkv/decode_scores with full-stack in-place updates)."""
    q, k, v = decode_qkv(params, x_t, pos, n_heads=n_heads, n_kv=n_kv,
                         head_dim=head_dim, rope_theta=rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos[0], 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos[0], 0, 0))
    out = decode_scores(params, q, cache_k, cache_v, pos, n_heads=n_heads,
                        n_kv=n_kv, head_dim=head_dim, dtype=x_t.dtype)
    return out, cache_k, cache_v
