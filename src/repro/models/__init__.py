from repro.models.registry import (  # noqa: F401
    cache_specs,
    decode_fn,
    init_cache,
    init_params,
    input_specs,
    is_encdec,
    loss_fn,
    make_batch,
    param_specs_tree,
    prefill_fn,
)
