"""Model registry: uniform (init / loss / prefill / decode / input_specs) API
for every assigned architecture."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, lm


def is_encdec(cfg) -> bool:
    return cfg.family == "audio"


def init_params(cfg, key):
    return encdec.init_encdec(cfg, key) if is_encdec(cfg) else lm.init_lm(cfg, key)


def loss_fn(cfg):
    if is_encdec(cfg):
        return lambda params, batch: encdec.encdec_loss(params, cfg, batch)
    return lambda params, batch: lm.lm_loss(params, cfg, batch)


def init_cache(cfg, batch: int, max_len: int):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, max_len, enc_len=max_len // cfg.enc_ratio)
    return lm.init_cache(cfg, batch, max_len)


def decode_fn(cfg):
    if is_encdec(cfg):
        return lambda params, cache, token: encdec.decode_step(params, cfg, cache, token)
    return lambda params, cache, token: lm.decode_step(params, cfg, cache, token)


def prefill_fn(cfg, max_len: int):
    if is_encdec(cfg):
        return lambda params, batch: encdec.prefill(params, cfg, batch["tokens"],
                                                    batch["frames"], max_len)
    return lambda params, batch: lm.prefill(params, cfg, batch["tokens"], max_len,
                                            batch.get("patch_embeds"))


def input_specs(cfg, shape, *, dtype=None):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    For ``train``/``prefill``: the full batch.  For ``decode``: the per-step
    token batch (the cache is built separately via ``cache_specs``).
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"token": sds((B,), jnp.int32)}
    specs = {"tokens": sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model), dtype)
    if is_encdec(cfg):
        specs["frames"] = sds((B, S // cfg.enc_ratio, cfg.d_model), dtype)
    return specs


def cache_specs(cfg, shape):
    """ShapeDtypeStructs of the decode cache for a shape cell (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, shape.global_batch, shape.seq_len))


def param_specs_tree(cfg, key=None):
    """Shape/dtype pytree of the parameters (no allocation)."""
    k = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: init_params(cfg, k))


def make_batch(cfg, shape, key, *, vocab_cap=None):
    """Materialize a concrete random batch (for smoke tests / benchmarks)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = vocab_cap or cfg.vocab
            out[name] = jax.random.randint(sub, s.shape, 0, hi, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    return out
