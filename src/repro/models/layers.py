"""Shared layer primitives: init helpers, norms, rotary embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim), jnp.float32)
            * 0.02).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * (1.0 + scale.astype(jnp.float32))
    return x.astype(dtype)


def nonparam_ln(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dtype)


def apply_norm(x: jax.Array, scale, norm_type: str) -> jax.Array:
    if norm_type == "nonparam_ln":
        return nonparam_ln(x)
    return rmsnorm(x, scale)


def norm_param(d_model: int, norm_type: str, dtype):
    if norm_type == "nonparam_ln":
        return jnp.zeros((1,), dtype)  # placeholder so pytrees stay uniform
    return jnp.zeros((d_model,), dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up
