"""Deterministic training-data pipeline, fed by BLEND discovery.

The discovery layer selects lake tables (e.g. a KW-seeker domain filter, an
SC-seeker dedup pass); selected tables are tokenized (value-hash % vocab) into
a flat stream, and batches are *step-indexed*: batch(i) is a pure function of
(seed, i), so a restarted job replays the exact same data order from the
checkpoint step — the fault-tolerance contract.
"""
from __future__ import annotations

import numpy as np

from repro.core.combiners import ResultSet
from repro.core.executor import Executor
from repro.core.hashing import hash_value
from repro.core.lake import DataLake
from repro.core.plan import Plan


def select_tables(lake: DataLake, plan: Plan, executor: Executor) -> list:
    """Run a discovery plan and return the selected table objects."""
    rs, _ = executor.run(plan, optimize=True)
    return [lake.tables[int(t)] for t in rs.ids()]


def tokenize_tables(tables, vocab: int, bos: int = 1) -> np.ndarray:
    """Row-major value-hash tokenization of the selected tables."""
    toks = []
    for tab in tables:
        for r in range(tab.n_rows):
            toks.append(bos)
            for v in tab.row(r):
                toks.append(2 + hash_value(v) % (vocab - 2))
    return np.array(toks, np.int32)


class TokenStream:
    """Step-indexed deterministic batcher over a token array."""

    def __init__(self, tokens: np.ndarray, batch: int, seq_len: int,
                 seed: int = 0):
        self.tokens = tokens
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.n_windows = max(len(tokens) - seq_len - 1, 1)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        starts = rng.integers(0, self.n_windows, self.batch)
        rows = np.stack([self.tokens[s:s + self.seq_len] for s in starts])
        return {"tokens": rows.astype(np.int32)}
