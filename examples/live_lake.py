"""Live lakes: mutate the index under a running session — no rebuilds.

Walks the full LiveLake lifecycle::

    connect(live=True) -> add_table -> query -> drop_table -> compact
                       -> snapshot -> restore

Run with ``PYTHONPATH=src python examples/live_lake.py``.
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import blend
from repro.core.lake import Table, synthetic_lake


def main():
    lake = synthetic_lake(n_tables=120, rows=40, vocab=1200, seed=1)
    session = blend.connect(lake, live=True)
    print("connected live:", session.live)

    # a query workload that keeps running across every mutation below
    probe = lake.tables[7]
    workload = (blend.sc(list(probe.columns[0][:10]), k=40)
                | blend.kw(list(probe.columns[1][:5]), k=40)).top(10)
    print("baseline top tables:", session.query(workload).ids)

    # -- add: one small table becomes an L0 delta segment (no rebuild) ------
    new = Table("fresh_metrics",
                [list(probe.columns[0][:12]),
                 [float(x) for x in np.linspace(0, 5, 12)]])
    t0 = time.perf_counter()
    tid = session.add_table(new)
    print(f"add_table -> id {tid} in {(time.perf_counter() - t0) * 1e3:.2f} "
          f"ms; shape: {session.index_shape()}")
    assert tid in session.query(workload).ids

    # -- drop: tombstone (base table) and whole-run delete (the delta) ------
    session.drop_table(3)            # tombstoned inside the base segment
    session.drop_table(tid)          # sole table of its delta: run removed
    print("after drops:", session.index_shape())

    # -- compact: merge deltas + garbage-collect tombstones -----------------
    for i in range(6):
        session.add_table(Table(
            f"burst{i}", [[f"tok_{j + i}" for j in range(20)],
                          [float(j) for j in range(20)]]))
    print("after burst of adds:", session.index_shape())
    session.compact()
    print("after compact:      ", session.index_shape())

    # -- explain shows the live index shape ---------------------------------
    print()
    print(session.explain(workload))

    # -- snapshot / restore: a server restart skips indexing ----------------
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "lake.snap"
        session.snapshot(path)
        t0 = time.perf_counter()
        restored = blend.restore(path)
        load_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        blend.connect(lake)
        rebuild_s = time.perf_counter() - t0
        a = session.query(workload).ids
        b = restored.query(workload).ids
        assert a == b, (a, b)
        print(f"\nsnapshot restore: {load_s * 1e3:.1f} ms vs rebuild "
              f"{rebuild_s * 1e3:.1f} ms "
              f"({rebuild_s / max(load_s, 1e-9):.1f}x faster); "
              f"results identical")


if __name__ == "__main__":
    main()
