"""Approximate discovery: sketch-tier answers with error bounds.

Walks ``Session.query(approx=...)`` (core/sketch.py) end to end::

    approx=True -> top-k from KMV/MinHash sketches, per-hit estimates and
    confidence intervals -> only the contended ranking boundary escalates
    to the exact path -> epsilon=0 returns ids bit-identical to exact ->
    DiscoveryEngine.serve(approx=...) surfaces the same accounting

Run with ``PYTHONPATH=src python examples/approx_discovery.py``.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import blend
from repro.core.lake import DataLake, Table
from repro.serve.engine import DiscoveryEngine

VOCAB = 1500


def window_lake(n_tables: int, rows: int = 80, seed: int = 1) -> DataLake:
    """Window-skewed lake: each table's tokens come from a random vocab
    window, so containment rankings have realistic spread."""
    rng = np.random.default_rng(seed)
    tables = []
    for i in range(n_tables):
        lo = int(rng.integers(0, VOCAB))
        width = int(rng.integers(60, 300))
        cols = [[f"tok_{(lo + int(x)) % VOCAB}"
                 for x in rng.integers(0, width, rows)] for _ in range(3)]
        cols.append([float(x) for x in np.round(rng.normal(0, 5, rows), 3)])
        tables.append(Table(f"t{i}", cols))
    return DataLake(tables)


def timed(label, fn):
    t0 = time.perf_counter()
    out = fn()
    print(f"  {label:<44s} {(time.perf_counter() - t0) * 1e3:8.2f} ms")
    return out


def main():
    lake = window_lake(400)
    session = blend.connect(lake)
    rng = np.random.default_rng(7)
    lo = int(rng.integers(0, VOCAB))
    vals = list(dict.fromkeys(
        f"tok_{(lo + int(x)) % VOCAB}" for x in rng.integers(0, 240, 240)))
    query = blend.sc(vals, k=10)

    # -- exact vs approximate ----------------------------------------------
    print("set-containment top-10, exact vs sketch tier:")
    exact = timed("exact (full COUNT DISTINCT group-by)",
                  lambda: session.query(query))
    approx = timed("approx=True (KMV sketch probe)",
                   lambda: session.query(query, approx=True))
    overlap = len(set(exact.ids) & set(approx.ids))
    print(f"  top-10 overlap: {overlap}/10")

    # -- every hit carries an estimate and a confidence interval ------------
    info = approx.approx
    print(f"\nestimator={info.estimator}  kind={info.kind}  "
          f"escalated {info.escalated}/{info.candidates} contenders "
          f"(threshold {info.threshold:.1f}):")
    for t in approx.ids[:5]:
        est, lo_, hi_ = info.interval(t)
        print(f"  table {t:>4d}  est={est:6.1f}  "
              f"ci=[{lo_:6.1f}, {hi_:6.1f}]")

    # -- the epsilon/confidence contract ------------------------------------
    # epsilon: ranking tolerance — a top-k contender whose interval is wider
    # than epsilon escalates to the exact path.  confidence: nominal coverage
    # of the reported intervals.  epsilon=0 tolerates nothing: the contended
    # boundary is resolved exactly and the ids are bit-identical to exact.
    strict = session.query(query, approx={"epsilon": 0.0})
    assert strict.ids == exact.ids
    print(f"\nepsilon=0: ids identical to exact "
          f"(escalated {strict.approx.escalated} boundary tables)")

    loose = session.query(query, approx={"epsilon": 0.2, "confidence": 0.9})
    print(f"epsilon=0.2: escalated {loose.approx.escalated}/"
          f"{loose.approx.candidates} — wider tolerance, fewer exact visits")

    # -- served responses carry the same accounting -------------------------
    engine = DiscoveryEngine(None, session=session)
    resp = engine.serve(query, approx=True)
    d = resp.approx
    print(f"\nDiscoveryResponse.approx: epsilon={d['epsilon']} "
          f"confidence={d['confidence']} escalated={d['escalated']}")
    first = resp.table_ids[0]
    print(f"  hit {first}: {d['estimates'][first]}")


if __name__ == "__main__":
    main()
