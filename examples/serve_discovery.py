"""End-to-end driver: serve batched discovery requests over a resident lake.

This is the paper's deployment mode — the unified index lives in memory and
heterogeneous BlendQL requests stream in.  Reports per-request latency with
and without the plan optimizer (the Table III/IV effect, live).

    PYTHONPATH=src python examples/serve_discovery.py
"""
import numpy as np

import blend
from repro.core.cost_model import train_cost_model
from repro.serve.engine import DiscoveryEngine
from repro.core.lake import synthetic_lake


def build_request(lake, rng, kind):
    """Discovery workloads as BlendQL expressions (imputation / union /
    enrichment; every fourth enrichment request arrives as SQL text)."""
    t = lake.tables[int(rng.integers(0, lake.n_tables))]
    rows = rng.choice(t.n_rows, 8, replace=False)
    if kind == "imputation":
        return (blend.mc([(t.columns[0][r], t.columns[1][r]) for r in rows],
                         k=40)
                & blend.sc([t.columns[0][r] for r in rows], k=40)).top(10)
    if kind == "union":
        cols = [blend.sc(list(t.columns[c]), k=60)
                for c in range(min(3, t.n_cols))]
        return blend.counter(*cols, k=10)
    # enrichment
    expr = (blend.kw([t.columns[0][0], t.columns[1][1]], k=10)
            | blend.corr([t.columns[0][r] for r in rows],
                         list(map(float, range(8))), k=10)).top(20)
    return expr.to_sql() if int(rng.integers(0, 4)) == 0 else expr


def main():
    rng = np.random.default_rng(0)
    lake = synthetic_lake(n_tables=200, rows=40, vocab=1500, seed=1)
    engine = DiscoveryEngine(lake)
    print("index ready:", engine.index.n_postings, "postings")
    engine.cost_model = train_cost_model(engine.executor, lake, n_samples=15)
    print("cost model trained")

    kinds = ["imputation", "union", "enrichment"]
    requests = [build_request(lake, rng, kinds[i % 3]) for i in range(12)]

    # warmup: compile all capacity buckets once (a production engine keeps
    # these jit variants resident; see DESIGN.md on static-shape serving)
    engine.serve_many(requests, optimize=True)
    engine.serve_many(requests, optimize=False)

    opt = engine.serve_many(requests, optimize=True)
    naive = engine.serve_many(requests, optimize=False)
    t_opt = sum(r.seconds for r in opt)
    t_naive = sum(r.seconds for r in naive)
    print(f"served {len(requests)} requests | optimized {t_opt*1000:.0f} ms "
          f"| naive {t_naive*1000:.0f} ms "
          f"| speedup {t_naive/max(t_opt,1e-9):.2f}x")
    for i, r in enumerate(opt[:4]):
        print(f"  req{i} ({kinds[i%3]:11s}) {r.seconds*1000:6.1f} ms "
              f"-> tables {r.table_ids[:5]} "
              f"(order {'->'.join(r.order)}, overflow {r.overflow})")


if __name__ == "__main__":
    main()
