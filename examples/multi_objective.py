"""The paper's Listing 4: multi-objective discovery (keyword + union search +
data imputation + correlation), with the optimizer's plan shown.

    PYTHONPATH=src python examples/multi_objective.py
"""
import numpy as np

from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import synthetic_lake
from repro.core.optimizer import optimize
from repro.core.plan import Combiners, Plan, Seekers


def build_search_plan(keywords, example_cols, example_tuples, queries,
                      joinkey, target):
    plan = Plan()
    # Keyword Search
    plan.add("kw", Seekers.KW(keywords, k=10))
    # Union Search
    for name, col in example_cols.items():
        plan.add(name, Seekers.SC(col, k=100))
    plan.add("counter", Combiners.Counter(k=10), list(example_cols))
    # Data Imputation
    plan.add("examples", Seekers.MC(example_tuples, k=10))
    plan.add("query", Seekers.SC(queries, k=10))
    plan.add("intersection", Combiners.Intersect(k=10), ["examples", "query"])
    # Correlation Search
    plan.add("correlation", Seekers.Correlation(joinkey, target, k=10))
    # Results Aggregation
    plan.add("union", Combiners.Union(k=40),
             ["kw", "counter", "intersection", "correlation"])
    return plan


def main():
    lake = synthetic_lake(n_tables=150, rows=30, vocab=900, seed=3)
    ex = Executor(build_index(lake))
    t = lake.tables[4]

    plan = build_search_plan(
        keywords=[t.columns[0][0], t.columns[1][3]],
        example_cols={"col_a": list(t.columns[0][:12]),
                      "col_b": list(t.columns[1][:12])},
        example_tuples=[(t.columns[0][r], t.columns[1][r]) for r in range(6)],
        queries=[t.columns[0][r] for r in range(6, 16)],
        joinkey=list(t.columns[0][:20]),
        target=list(np.linspace(-1, 1, 20)),
    )
    ep = optimize(plan, ex.seeker_stats)
    print("execution groups:", {g: eg.seekers for g, eg in ep.groups.items()})

    ex.run(plan, optimize=True)      # warm up jit caches
    ex.run(plan, optimize=False)
    rs, info = ex.run(plan, optimize=True)
    print("order:", info.order)
    print("result tables:", [lake.tables[i].name for i in rs.ids()][:10])
    rs2, info2 = ex.run(plan, optimize=False)
    print(f"optimized {info.total_seconds*1000:.1f} ms vs "
          f"naive {info2.total_seconds*1000:.1f} ms (post-warmup)")


if __name__ == "__main__":
    main()
