"""Sharded lakes: one lake partitioned across a device mesh.

Forces 8 host CPU devices (the same trick the tests and CI use), then walks
the sharded serving lifecycle::

    connect(shards=8, live=True) -> query (fused per-shard probes + one
    cross-shard merge) -> add_table (routed to the least-loaded shard)
    -> drop_table (tombstoned on the owner) -> explain (mesh shape +
    per-shard segment/postings/tombstone counts)

Every answer is bit-identical to a 1-shard session on the same data; a
plan still costs ~n_kinds + 1 logical launches no matter how many shards
fan out underneath it.

Run with ``PYTHONPATH=src python examples/sharded_lake.py``.
"""
import os
import sys
from pathlib import Path

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

import blend
from repro.core.lake import Table, synthetic_lake


def main():
    print(f"visible devices: {len(jax.devices())}")

    lake = synthetic_lake(n_tables=96, rows=32, vocab=1500, seed=4)
    session = blend.connect(lake, shards=8, live=True)
    single = blend.connect(lake, shards=1, live=True)

    probe = lake.tables[7]
    workload = (blend.sc(list(probe.columns[0][:10]), k=40)
                | blend.kw(list(probe.columns[1][:5]), k=40)).top(10)

    # -- fused per-shard probes + one cross-shard merge ---------------------
    r8, r1 = session.query(workload), single.query(workload)
    assert (np.asarray(r8.scores) == np.asarray(r1.scores)).all()
    assert r8.ids == r1.ids
    print("top tables (8 shards == 1 shard):", r8.ids)
    print(f"launches: {r8.info.launches} (n_kinds + 1 — the per-shard "
          f"fan-out is one logical dispatch per seeker kind)")

    # -- mutations stay shard-local ----------------------------------------
    new = Table("fresh_metrics",
                [list(probe.columns[0][:12]),
                 [float(x) for x in np.linspace(0, 5, 12)]])
    tid = session.add_table(new)          # routed to the least-loaded shard
    single.add_table(new)
    print(f"add_table -> global id {tid}, epoch now "
          f"{session.executor.index.epoch} (one shard moved)")
    session.drop_table(3)                 # tombstoned in place on its owner
    single.drop_table(3)

    r8, r1 = session.query(workload), single.query(workload)
    assert (np.asarray(r8.scores) == np.asarray(r1.scores)).all()
    print("post-mutation top tables (still bit-identical):", r8.ids)

    # -- explain shows the mesh and the per-shard layout --------------------
    print()
    print(session.explain(workload))
    print("SHARDED_LAKE_OK")


if __name__ == "__main__":
    main()
