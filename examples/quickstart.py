"""Quickstart: build a lake, connect a session, run BlendQL queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import blend
from repro.core.lake import synthetic_lake


def main():
    lake = synthetic_lake(n_tables=100, rows=30, vocab=800, seed=0)
    print("lake:", lake.stats())

    session = blend.connect(lake)
    print(f"unified index: {session.index.n_postings} postings, "
          f"{session.index.storage_bytes()/1e6:.1f} MB")

    # Fig 1's task: tables containing ("HR", "Firenze")-style positive
    # examples and a set of joinable department values, minus tables with the
    # outdated pair.
    t = lake.tables[7]
    positives = [(t.columns[0][r], t.columns[1][r]) for r in range(4)]
    outdated = [(t.columns[0][5], t.columns[1][6])]   # misaligned pair
    departments = list(t.columns[0][:12])

    # fluent form: & = intersect, - = difference
    expr = (blend.mc(positives, k=50) & blend.sc(departments, k=50)) \
        - blend.mc(outdated, k=50)
    res = session.query(expr, top=10)
    print("optimized execution order:", res.info.order)
    print("top tables:", [lake.tables[i].name for i in res.ids])
    print(f"total {res.info.total_seconds*1000:.1f} ms "
          f"({ {k: round(v*1000, 1) for k, v in res.info.node_seconds.items()} })")

    # the same task as a BlendQL string (expr.to_sql() prints this form)
    sql_res = session.sql(expr.top(10).to_sql())
    assert sql_res.ids == res.ids
    print("\nBlendQL:", expr.top(10).to_sql()[:88], "...")

    # the explain transcript: logical tree, rewrite rules, ranked order,
    # per-node timings
    print("\n" + str(session.explain(expr, top=10)))

    # legacy imperative frontend (still supported, same engine underneath)
    from repro.core.plan import Combiners, Plan, Seekers
    plan = Plan()
    plan.add("examples", Seekers.MC(positives, k=50))
    plan.add("departments", Seekers.SC(departments, k=50))
    plan.add("relevant", Combiners.Intersect(k=50), ["examples", "departments"])
    plan.add("outdated", Seekers.MC(outdated, k=50))
    plan.add("answer", Combiners.Difference(k=10), ["relevant", "outdated"])
    legacy = session.query(plan)
    print("\nlegacy Plan.add ids:", legacy.ids)


if __name__ == "__main__":
    main()
