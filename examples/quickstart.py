"""Quickstart: build a lake, build the unified index, run a discovery plan.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers


def main():
    lake = synthetic_lake(n_tables=100, rows=30, vocab=800, seed=0)
    print("lake:", lake.stats())

    index = build_index(lake)
    print(f"unified index: {index.n_postings} postings, "
          f"{index.storage_bytes()/1e6:.1f} MB")

    ex = Executor(index)

    # Fig 1's task: tables containing ("HR", "Firenze")-style positive
    # examples and a set of joinable department values, minus tables with the
    # outdated pair.
    t = lake.tables[7]
    positives = [(t.columns[0][r], t.columns[1][r]) for r in range(4)]
    outdated = [(t.columns[0][5], t.columns[1][6])]   # misaligned pair
    departments = list(t.columns[0][:12])

    plan = Plan()
    plan.add("examples", Seekers.MC(positives, k=50))
    plan.add("departments", Seekers.SC(departments, k=50))
    plan.add("relevant", Combiners.Intersect(k=20), ["examples", "departments"])
    plan.add("outdated", Seekers.MC(outdated, k=50))
    plan.add("answer", Combiners.Difference(k=10), ["relevant", "outdated"])

    rs, info = ex.run(plan, optimize=True)
    print("optimized execution order:", info.order)
    print("top tables:", [lake.tables[i].name for i in rs.ids()])
    print(f"total {info.total_seconds*1000:.1f} ms "
          f"({ {k: round(v*1000, 1) for k, v in info.node_seconds.items()} })")


if __name__ == "__main__":
    main()
