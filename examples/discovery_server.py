"""Serving under load: the DiscoveryServer front tier end-to-end.

Starts a continuous-batching server over a live lake, replays a seeded
mixed-tenant trace (Zipf query mix, bursty arrivals, add/drop mutations),
then demonstrates overload behavior — bounded queues shedding with typed
``Overloaded`` responses instead of queueing unboundedly — and the asyncio
façade.

    PYTHONPATH=src python examples/discovery_server.py
"""
import asyncio

import numpy as np

import blend  # noqa: F401  (registers the fluent API used by loadgen)
from repro.core.lake import synthetic_lake
from repro.serve.engine import DiscoveryEngine
from repro.serve.loadgen import make_trace, query_pool, replay
from repro.serve.server import AsyncDiscoveryServer, DiscoveryServer


def warm(engine, trace, max_batch=16):
    """Compile the batched jit variants the trace will actually hit (a
    production server keeps these resident): replay the whole trace —
    mutations included, since probe programs are keyed on the segment
    layout each add/drop produces — through a throwaway unlimited server,
    once unpaced (compile flood) and once paced (the batch compositions a
    paced run forms), resetting the mutations after each round so the demo
    replays the same segment-layout path the warmup compiled."""
    def reset():
        if not any(e.kind != "query" for e in trace.events):
            return
        for tid, tab in list(engine.live.tables.items()):
            if getattr(tab, "name", "").startswith("loadgen_"):
                engine.drop_table(tid)
        engine.compact(full=True)

    for kw in ({"sleep": lambda s: None}, {}, {}):
        srv = DiscoveryServer(engine, max_batch=max_batch)
        replay(srv, trace, **kw)
        srv.stop()
        reset()


def main():
    lake = synthetic_lake(n_tables=150, rows=30, vocab=1200, seed=1)
    engine = DiscoveryEngine(lake, live=True)
    print(f"index ready: {engine.index.n_postings} postings, "
          f"{lake.n_tables} tables")

    # ---- mixed-tenant traffic through the batching window ----------------
    trace = make_trace(lake, seed=11, duration_s=2.0, rate_rps=120.0,
                       n_distinct=12, k=24, p_mutation=0.03,
                       tenants=("alice", "bob", "carol"))
    warm(engine, trace)
    server = DiscoveryServer(engine, max_batch=16,
                             interactive_window_s=0.004,
                             batch_window_s=0.02)
    report = replay(server, trace)
    d = report.as_dict()
    print(f"\n== mixed-tenant trace (seed {trace.seed}) ==")
    print(f"offered {d['offered']} queries + {d['mutations']} mutations "
          f"at ~{trace.offered_rps:.0f} rps")
    print(f"goodput {d['goodput_rps']:.0f} rps | "
          f"p50 {d['latency_ms']['p50']:.1f} ms | "
          f"p99 {d['latency_ms']['p99']:.1f} ms | "
          f"mean batch {d['batch_size_mean']:.1f}")
    stats = server.stats()
    print(f"batches formed: {stats['batches']['formed']} "
          f"(launches/batch {stats['launches']['per_batch_mean']:.1f}) | "
          f"mutations: {stats['mutations']['executed']}")
    ex = server.explain(query_pool(lake, np.random.default_rng(11),
                                   n_distinct=1, k=24)[0])
    print("\n".join(line for line in str(ex).splitlines()
                    if line.startswith(("== server", "  queue", "  lane",
                                        "  served", "  batches"))))
    server.stop()

    # ---- overload: bounded queues shed, p99 stays bounded ----------------
    overload = make_trace(lake, seed=12, duration_s=1.5, rate_rps=2000.0,
                          n_distinct=8, k=24, burst_factor=6.0)
    warm(engine, overload)
    server = DiscoveryServer(engine, max_batch=16, max_queue=32,
                             batch_max_queue=16,
                             rate=400.0, burst=60.0)   # per-tenant buckets
    report = replay(server, overload)
    d = report.as_dict()
    print(f"\n== overload demo (offered ~{overload.offered_rps:.0f} rps) ==")
    print(f"shed rate {d['shed_rate']:.1%} ({d['shed_reasons']}) | "
          f"served {d['completed']} at {d['goodput_rps']:.0f} rps | "
          f"p99 {d['latency_ms']['p99']:.1f} ms (bounded: queue depth "
          f"capped at 32)")
    server.stop()

    # ---- asyncio façade --------------------------------------------------
    async def async_demo():
        async with AsyncDiscoveryServer(engine, max_batch=8) as srv:
            pool = query_pool(lake, np.random.default_rng(13),
                              n_distinct=4, k=24)
            out = await asyncio.gather(
                *[srv.serve(q, tenant=f"t{i}") for i, q in enumerate(pool)])
            return out

    out = asyncio.run(async_demo())
    print(f"\n== async façade == served {len(out)} concurrent awaits, "
          f"batch sizes {[r.batch_size for r in out]}, "
          f"top tables {out[0].table_ids[:5]}")


if __name__ == "__main__":
    main()
