"""Train a tiny LM on BLEND-selected data, with checkpoint/restart.

The discovery layer picks topically-related tables from the lake (keyword
seeker + union counter), their cells are tokenized, and a smollm-family
reduced model trains for a few hundred steps with periodic checkpoints.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers
from repro.data.pipeline import TokenStream, select_tables, tokenize_tables
from repro.launch.train import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    lake = synthetic_lake(n_tables=120, rows=40, vocab=2000, seed=5)
    ex = Executor(build_index(lake))

    # discovery-driven data selection: tables overlapping a seed domain
    seed_table = lake.tables[11]
    plan = Plan()
    for c in range(2):
        plan.add(f"c{c}", Seekers.SC(list(seed_table.columns[c]), k=60))
    plan.add("out", Combiners.Counter(k=30), ["c0", "c1"])
    tables = select_tables(lake, plan, ex)
    print(f"discovery selected {len(tables)} tables for training")

    cfg = reduced(get_config("smollm-360m")).replace(
        n_layers=4, d_model=128, d_ff=512, vocab=2048)
    tokens = tokenize_tables(tables, vocab=cfg.vocab)
    print(f"tokenized {len(tokens)} tokens")
    stream = TokenStream(tokens, batch=8, seq_len=64, seed=0)

    report = train_loop(cfg, stream,
                        TrainLoopConfig(steps=args.steps, ckpt_every=50,
                                        ckpt_dir=args.ckpt))
    if report.resumed_from:
        print(f"resumed from step {report.resumed_from}")
    losses = report.losses
    print(f"step   0: loss {losses[0]:.3f}")
    print(f"step {report.final_step:3d}: loss {np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < losses[0], "loss should decrease"
    print("ok: loss decreased; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
