"""Crash recovery: WAL-backed durability for live lakes.

Walks the durability lifecycle::

    connect(live=True, wal=...) -> snapshot -> mutate (each ack durably
    logged) -> CRASH -> blend.recover(snapshot, wal=...) -> bit-identical

The "crash" is injected with the deterministic fault harness
(``repro.faults``): the process "dies" at a named fault point, and recovery
replays snapshot + WAL back to exactly the acknowledged prefix — ids,
scores AND epoch identical to the uninterrupted run.  A torn tail (a
half-written record from a crash mid-append) is truncated, never partially
replayed.

Run with ``PYTHONPATH=src python examples/crash_recovery.py``.
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import blend
from repro import faults
from repro.core.lake import Table, synthetic_lake
from repro.faults import FaultInjector, InjectedCrash
from repro.store import wal as walmod


def fresh_table(i):
    rng = np.random.default_rng(500 + i)
    return Table(f"ingest{i}",
                 [[f"tok_{int(x)}" for x in rng.integers(0, 400, 30)],
                  [float(x) for x in np.round(rng.normal(0, 3, 30), 3)]])


def main():
    tmp = Path(tempfile.mkdtemp(prefix="blend-crash-"))
    snap_path, wal_path = str(tmp / "lake.snap"), str(tmp / "lake.wal")

    lake = synthetic_lake(n_tables=40, rows=24, vocab=500, seed=3)
    session = blend.connect(lake, live=True, wal=wal_path)
    session.snapshot(snap_path)     # baseline: WAL only covers mutations
    print("connected live with WAL:", session.live.wal)

    probe = lake.tables[5]
    workload = (blend.sc(list(probe.columns[0][:10]), k=30)
                | blend.kw(list(probe.columns[1][:5]), k=30)).top(10)

    # -- acknowledged mutations, each durably logged before the ack ---------
    session.add_table(fresh_table(0))
    session.add_tables([fresh_table(1), fresh_table(2)])   # one group commit
    session.drop_table(3)
    want = session.query(workload)
    epoch = session.live.store.epoch
    print(f"acknowledged 4 mutations; epoch={epoch}, "
          f"top ids={list(want.ids)}")

    # -- CRASH: the process dies before the next append becomes durable -----
    try:
        with faults.inject(FaultInjector(crash={"wal.append.pre": 1})):
            session.add_table(fresh_table(9))       # never acknowledged
    except InjectedCrash:
        print("crashed mid-mutation (unacknowledged add lost, by design)")

    # -- recover: latest snapshot generation + WAL replay -------------------
    t0 = time.perf_counter()
    recovered = blend.recover(snap_path, wal=wal_path)
    dt = (time.perf_counter() - t0) * 1e3
    got = recovered.query(workload)
    assert list(got.ids) == list(want.ids)
    assert np.array_equal(np.asarray(got.scores), np.asarray(want.scores))
    assert recovered.live.store.epoch == epoch
    print(f"recovered in {dt:.1f} ms — ids, scores and epoch bit-identical")

    # -- torn tail: a half-written record is truncated, never replayed ------
    try:
        with faults.inject(FaultInjector(torn={"wal.append.torn": 1})):
            recovered.add_table(fresh_table(10))    # record torn mid-write
    except InjectedCrash:
        pass
    records, _ = walmod.recover_records(wal_path)   # truncates the tail
    survivors = blend.recover(snap_path, wal=wal_path)
    assert survivors.live.store.epoch == epoch      # torn suffix dropped
    print(f"torn tail truncated; {len(records)} intact records replayed, "
          f"state unchanged")
    print("done.")


if __name__ == "__main__":
    main()
