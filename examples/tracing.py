"""End-to-end observability: metrics registry + per-query flight recorder.

Enables ``repro.obs``, serves a seeded trace through a tracing
DiscoveryServer over a live lake, then dumps the flight recorder as
Perfetto-loadable Chrome trace JSON (TRACE_8.json), renders one request's
span tree, and prints the process metrics snapshot.

    PYTHONPATH=src python examples/tracing.py [out.json]
"""
import sys

import blend  # noqa: F401  (registers the fluent API used by loadgen)
from repro import obs
from repro.core.lake import synthetic_lake
from repro.serve.engine import DiscoveryEngine
from repro.serve.loadgen import make_trace, replay
from repro.serve.server import DiscoveryServer


def main(out_path="TRACE_8.json"):
    lake = synthetic_lake(n_tables=120, rows=30, vocab=1000, seed=1)
    engine = DiscoveryEngine(lake, live=True, cache=True)
    print(f"index ready: {engine.index.n_postings} postings, "
          f"{lake.n_tables} tables")

    trace = make_trace(lake, seed=21, duration_s=1.5, rate_rps=80.0,
                       n_distinct=10, k=24, p_mutation=0.02)

    # warm the jit caches so the recorded trace shows steady-state serving,
    # not compilation (compile-heavy spans carry a compiled=True attribute)
    with DiscoveryServer(engine) as srv:
        replay(srv, trace, sleep=lambda s: None)

    reg = obs.enable()
    server = DiscoveryServer(engine, trace=True,
                             interactive_window_s=0.004, batch_window_s=0.02)
    report = replay(server, trace)
    d = report.as_dict()
    print(f"\n== replay == goodput {d['goodput_rps']:.0f} rps | "
          f"e2e p50 {d['latency_ms']['p50']:.1f} ms "
          f"p99 {d['latency_ms']['p99']:.1f} ms | "
          f"queue p50 {d['queue_ms_p50']:.2f} ms "
          f"p99 {d['queue_ms_p99']:.2f} ms")

    # one served request's flight-recorder tree: queue -> batch ->
    # pin_epoch -> per-kind probes (per-shard children) -> merge -> drain
    # -> transfer.  The same trees go into the Chrome/Perfetto export.
    # A fresh value draw (same shapes, new values) misses the result cache,
    # so the tree shows the full probe path, not a cache-hit short-circuit.
    import numpy as np
    from repro.serve.loadgen import query_pool
    fresh = query_pool(lake, np.random.default_rng(99), n_distinct=1, k=24)
    resp = server.serve(fresh[0])
    print("\n== one request's span tree ==")
    print(resp.trace.render())

    path = server.dump_trace(out_path)
    print(f"\nwrote {path} — open in https://ui.perfetto.dev or "
          f"chrome://tracing")

    print("\n== metrics snapshot ==")
    print(reg.render())
    server.stop()
    obs.disable()


if __name__ == "__main__":
    main(*sys.argv[1:2])
