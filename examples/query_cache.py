"""Serving with the query cache: warm hits, partial hits, invalidation.

Walks the semantic QueryCache (serve/cache.py) through the serving stack::

    connect(cache=True) -> cold miss -> warm hit (same ids, ~100x faster)
    -> commuted/SQL forms hit the same entry -> partial hit on a shared
    subtree -> LiveLake mutation invalidates -> serve_many pays no drain
    share for cached requests

Run with ``PYTHONPATH=src python examples/query_cache.py``.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import blend
from repro.core.lake import Table, synthetic_lake
from repro.serve.engine import DiscoveryEngine


def timed(label, fn):
    t0 = time.perf_counter()
    out = fn()
    print(f"  {label:<38s} {(time.perf_counter() - t0) * 1e3:8.2f} ms")
    return out


def main():
    lake = synthetic_lake(n_tables=120, rows=40, vocab=1200, seed=1)
    session = blend.connect(lake, live=True, cache=True)
    t = lake.tables[7]
    sc = blend.sc(list(t.columns[0][:10]), k=40)
    kw = blend.kw(list(t.columns[1][:4]), k=40)
    query = (sc & kw).top(10)

    # -- cold vs warm: the second serve never touches the executor ----------
    print("cold miss, then warm hit:")
    cold = timed("miss (compile + execute)", lambda: session.query(query))
    warm = timed("hit  (fingerprint lookup)", lambda: session.query(query))
    assert warm.ids == cold.ids and warm.cache.status == "hit"
    print(f"  same ids: {warm.ids}")

    # -- one semantic entry, many spellings ---------------------------------
    commuted = session.query((kw & sc).top(10))
    via_sql = session.sql(query.to_sql())
    assert commuted.cache.status == via_sql.cache.status == "hit"
    print("commuted `kw & sc` and the SQL text both hit the same entry")

    # -- partial hit: a new query sharing the sc subtree --------------------
    session.query(sc)        # e.g. the user searched the join column alone
    variant = (sc | blend.mc([(t.columns[0][0], t.columns[1][0])],
                             k=40)).top(10)
    res = session.query(variant)
    print(f"new query sharing `sc`: status={res.cache.status} "
          f"({res.cache.seekers_cached} seeker cached, "
          f"{res.cache.seekers_run} run)")
    assert res.cache.status == "partial"

    # -- explain surfaces the telemetry -------------------------------------
    print()
    print(session.explain(query))

    # -- mutation: the epoch moves, the cache invalidates, ids stay fresh ---
    fresh = Table("fresh_metrics",
                  [list(t.columns[0][:12]), list(t.columns[1][:12]),
                   [float(i) for i in range(12)]])
    tid = session.add_table(fresh)
    res = session.query(query)
    print(f"\nafter add_table: status={res.cache.status} "
          f"(invalidations={session.cache.invalidations}); "
          f"new table ranked: {tid in res.ids}")
    assert res.cache.status != "hit" and tid in res.ids
    session.drop_table(tid)
    assert tid not in session.query(query).ids     # never a stale id

    # -- batched serving: cached requests pay no drain share ----------------
    engine = DiscoveryEngine(None, session=session)
    batch = [query, (kw & sc).top(10), variant, query.to_sql()]
    engine.serve_many(batch)                       # warm every entry
    responses = engine.serve_many(batch)
    print("\nwarm serve_many batch:")
    for r in responses:
        print(f"  {r.cache['status']:<8s} {r.seconds * 1e6:8.1f} us  "
              f"ids={r.table_ids[:5]}")
    assert all(r.cache["status"] == "hit" for r in responses)

    print(f"\ncache stats: {session.cache.stats()}")


if __name__ == "__main__":
    main()
