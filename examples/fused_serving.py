"""Fused execution: whole plans in ~n_kinds + 1 device launches.

Walks the fused path (core/fused.py) through the serving stack::

    session.query(q, fused=True)   -> batched same-kind seeker dispatch +
                                      one whole-DAG device program
    session.explain(q, fused=True) -> the collapsed `launches` count
    serve_many(reqs, fused=True)   -> seekers batched ACROSS the requests

Results are bit-identical to the unfused executor — fusion only removes
per-node dispatch overhead and host round-trips, which dominate warm-path
latency on deep discovery DAGs.

Run with ``PYTHONPATH=src python examples/fused_serving.py``.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import blend
from repro.core.lake import synthetic_lake
from repro.serve.engine import DiscoveryEngine


def timed(label, fn, iters=20):
    fn()                                     # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    print(f"  {label:<40s} {(time.perf_counter() - t0) / iters * 1e3:8.2f} "
          f"ms/query")
    return out


def deep_query(lake, tab=7):
    """A deep multi-operator DAG (Ver/MATE-style pipeline): 7 seekers
    feeding intersect/union/counter/difference layers."""
    t = lake.tables[tab]
    sc1 = blend.sc(list(t.columns[0][:8]), k=40)
    sc2 = blend.sc(list(t.columns[1][:8]), k=40)
    sc3 = blend.sc(list(t.columns[2][:8]), k=40)
    kw = blend.kw(list(t.columns[0][:3]), k=40)
    mc = blend.mc([(t.columns[0][r], t.columns[1][r]) for r in range(6)],
                  k=40)
    corr = blend.corr(list(t.columns[0][:8]),
                      [float(i) for i in range(8)], k=40)
    neg = blend.kw([t.columns[2][0]], k=40)
    return ((blend.counter(sc1, sc2, sc3, k=30)
             & (kw | mc) & corr) - neg).top(10)


def main():
    lake = synthetic_lake(n_tables=200, rows=40, vocab=1500, seed=1)
    session = blend.connect(lake)
    q = deep_query(lake)

    # -- one deep plan: per-node dispatch vs n_kinds + 1 launches -----------
    print("deep DAG (7 seekers, 4 combiner layers):")
    unfused = timed("unfused (one program per node)",
                    lambda: session.query(q).ids)
    fused = timed("fused   (batched kinds + one DAG)",
                  lambda: session.query(q, fused=True).ids)
    assert fused == unfused                       # bit-identical ranking

    ex_u = session.explain(q)
    ex_f = session.explain(q, fused=True)
    print(f"  launches: {ex_u.launches} unfused -> {ex_f.launches} fused "
          f"(<= n_kinds + 1)")

    # -- the explain transcript shows the collapse --------------------------
    print("\nexplain(fused=True) execution section:")
    for line in str(ex_f).splitlines():
        if line.startswith("== execution") or line.startswith("  launches") \
                or line.startswith("  order"):
            print(" ", line)

    # -- serve_many: seekers batched across the whole request batch ---------
    engine = DiscoveryEngine(lake, session=session)
    reqs = [deep_query(lake, tab) for tab in range(12)]
    engine.serve_many(reqs)                       # warm
    engine.serve_many(reqs, fused=True)

    t0 = time.perf_counter()
    base = engine.serve_many(reqs)
    t_unfused = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched = engine.serve_many(reqs, fused=True)
    t_fused = time.perf_counter() - t0
    assert [r.table_ids for r in base] == [r.table_ids for r in batched]
    print(f"\nserve_many, 12 deep requests:")
    print(f"  unfused {t_unfused * 1e3:8.2f} ms   "
          f"fused {t_fused * 1e3:8.2f} ms   "
          f"({t_unfused / t_fused:.1f}x)")
    print(f"  per-request launches (fused): {batched[0].launches} "
          f"(shared kind batches + one DAG each)")


if __name__ == "__main__":
    main()
