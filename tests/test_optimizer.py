"""Optimizer: EG identification, ranking rules, Theorem 1 (output
preservation) as a hypothesis property over random plans."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import RULE_RANK
from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import synthetic_lake
from repro.core.optimizer import identify_groups, optimize, rank_seekers
from repro.core.plan import Combiners, Plan, Seekers


def _mk_plan(lake, rng, n_seekers, combiner_kind):
    plan = Plan()
    names = []
    for i in range(n_seekers):
        t = lake.tables[int(rng.integers(0, lake.n_tables))]
        n = int(rng.integers(2, 8))
        rows = rng.choice(t.n_rows, n, replace=False)
        kind = rng.choice(["SC", "KW", "MC"])
        if kind == "SC":
            spec = Seekers.SC([t.columns[0][r] for r in rows], k=20)
        elif kind == "KW":
            spec = Seekers.KW([t.columns[1][r] for r in rows], k=20)
        else:
            spec = Seekers.MC([(t.columns[0][r], t.columns[1][r])
                               for r in rows], k=20)
        plan.add(f"s{i}", spec)
        names.append(f"s{i}")
    comb = {"intersect": Combiners.Intersect, "union": Combiners.Union,
            "counter": Combiners.Counter}[combiner_kind]
    plan.add("out", comb(k=10), names)
    return plan


def test_eg_identification():
    plan = Plan()
    plan.add("a", Seekers.SC(["x"], k=5))
    plan.add("b", Seekers.KW(["y"], k=5))
    plan.add("c", Seekers.MC([("x", "y")], k=5))
    plan.add("i", Combiners.Intersect(k=5), ["a", "b", "c"])
    plan.add("u", Combiners.Union(k=5), ["i", "a"])
    groups = identify_groups(plan)
    assert set(groups) == {"i"}
    assert set(groups["i"].seekers) == {"a", "b", "c"}


def test_rules_order():
    plan = Plan()
    plan.add("mc", Seekers.MC([("x", "y")], k=5))
    plan.add("c", Seekers.Correlation(["x"], [1.0], k=5))
    plan.add("sc", Seekers.SC(["x"], k=5))
    plan.add("kw", Seekers.KW(["x"], k=5))
    plan.add("i", Combiners.Intersect(k=5), ["mc", "c", "sc", "kw"])
    stats = lambda spec: (1.0, spec.n_cols, 1.0)
    order = rank_seekers(plan, ["mc", "c", "sc", "kw"], stats, None)
    kinds = [plan.nodes[n].spec.kind for n in order]
    assert kinds == ["KW", "SC", "C", "MC"]      # Rules 1-3
    assert [RULE_RANK[k] for k in kinds] == sorted(RULE_RANK[k] for k in kinds)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 4),
       st.sampled_from(["union", "counter"]))
def test_theorem1_exact_for_union_counter(seed, n_seekers, comb):
    """Union/Counter get no rewriting: optimized == naive exactly."""
    rng = np.random.default_rng(seed)
    lake = synthetic_lake(n_tables=40, rows=16, vocab=300, seed=seed % 97)
    ex = Executor(build_index(lake))
    plan = _mk_plan(lake, rng, n_seekers, comb)
    rs_opt, _ = ex.run(plan, optimize=True)
    rs_no, _ = ex.run(plan, optimize=False)
    assert set(rs_opt.ids().tolist()) == set(rs_no.ids().tolist())
    np.testing.assert_allclose(np.asarray(rs_opt.scores),
                               np.asarray(rs_no.scores), rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 4))
def test_theorem1_soundness_for_intersection(seed, n_seekers):
    """Theorem 1 under filtered-top-k semantics (see DESIGN.md): the
    rewritten intersection (a) never loses a table the naive plan returns
    before the final cut, and (b) never admits a table that fails any
    seeker's criterion.  (Exact set equality does not hold in general because
    per-seeker LIMIT K does not commute with the threaded predicate — the
    paper's SQL rewriting has the same property.)"""
    rng = np.random.default_rng(seed)
    lake = synthetic_lake(n_tables=40, rows=16, vocab=300, seed=seed % 97)
    ex = Executor(build_index(lake))
    plan = _mk_plan(lake, rng, n_seekers, "intersect")
    # pre-cut comparison: lift the final combiner k so the cut doesn't hide
    # the containment property
    plan.nodes["out"].spec = type(plan.nodes["out"].spec)("intersect",
                                                          lake.n_tables)
    rs_opt, _ = ex.run(plan, optimize=True)
    rs_no, _ = ex.run(plan, optimize=False)
    opt_ids = set(rs_opt.ids().tolist())
    no_ids = set(rs_no.ids().tolist())
    assert no_ids <= opt_ids                       # (a) nothing lost
    # (b) every extra table genuinely satisfies all seeker criteria
    for name, node in plan.nodes.items():
        if not node.is_seeker:
            continue
        full = ex.run_seeker(node.spec._replace_k(lake.n_tables)
                             if hasattr(node.spec, "_replace_k")
                             else _with_k(node.spec, lake.n_tables))
        scores = np.asarray(full.scores)
        for t in opt_ids:
            assert scores[t] > 0, (name, t)


def _with_k(spec, k):
    import dataclasses
    return dataclasses.replace(spec, k=k)


def test_theorem1_difference_rewriting(small_lake, small_executor):
    t0, t1 = small_lake.tables[0], small_lake.tables[1]
    plan = Plan()
    plan.add("pos", Seekers.MC([(t0.columns[0][r], t0.columns[1][r])
                                for r in range(6)], k=30))
    plan.add("neg", Seekers.MC([(t1.columns[0][r], t1.columns[1][r])
                                for r in range(6)], k=30))
    plan.add("out", Combiners.Difference(k=10), ["pos", "neg"])
    rs_opt, info_opt = small_executor.run(plan, optimize=True)
    rs_no, _ = small_executor.run(plan, optimize=False)
    assert set(rs_opt.ids().tolist()) == set(rs_no.ids().tolist())


def test_grammar_validation():
    import pytest
    plan = Plan()
    plan.add("a", Seekers.SC(["x"], k=5))
    with pytest.raises(ValueError):
        plan.add("bad", Combiners.Intersect(k=5), ["a"])       # < 2 inputs
    plan.add("b", Seekers.SC(["y"], k=5))
    plan.add("c", Seekers.SC(["z"], k=5))
    with pytest.raises(ValueError):
        plan.add("bad2", Combiners.Difference(k=5), ["a", "b", "c"])
    with pytest.raises(ValueError):
        plan.add("bad3", Combiners.Union(k=5), ["a", "missing"])
