"""BlendQL frontend: IR / parser round-trip, rewrite rules, lowering,
Session parity (fluent == SQL == legacy Plan.add), hash-consed sharing,
explain transcripts, and the served ExecInfo satellite.

Parity methodology mirrors tests/test_optimizer.py: with per-seeker k lifted
to n_tables the optimizer's mask threading is exactly output-preserving
(Theorem 1 pre-cut), so all three frontends must return identical ids; with
binding k we compare under ``optimize=False`` (no rewriting), where results
are again exact.  Fluent vs SQL is asserted in both regimes — they compile
to the same plan by construction.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import blend
from repro.core.plan import CombinerSpec, Combiners, Plan, Seekers
from repro.query import logical as L
from repro.query.parse import BlendQLError, parse
from repro.query.rules import (annotate_masks, flatten_and_or,
                               fold_idempotent, hash_cons, push_limit,
                               rewrite)
from repro.query.lower import lower
from repro.query.session import Session


@pytest.fixture(scope="session")
def session(small_executor, small_lake):
    return Session(small_executor, lake=small_lake)


def _leaves(lake, k=60):
    """One leaf of each seeker kind, drawn from a real table."""
    t = lake.tables[2]
    return {
        "sc": blend.sc(list(t.columns[0][:8]), k=k),
        "kw": blend.kw([t.columns[1][0], t.columns[1][1]], k=k),
        "mc": blend.mc([(t.columns[0][r], t.columns[1][r])
                        for r in range(4)], k=k),
        "corr": blend.corr(list(t.columns[0][:10]),
                           list(map(float, range(10))), k=k),
    }


def legacy_build(e, plan=None, _n=None):
    """The old imperative frontend: naive Plan.add walk of the raw IR (no
    rewriting, no hash-consing — shared subtrees become duplicate nodes)."""
    top = plan is None
    if top:
        plan, _n = Plan(), [0]

    def name(tag):
        _n[0] += 1
        return f"{tag}_{_n[0]}"

    if isinstance(e, L.Seek):
        n = name(e.kind.lower())
        plan.add(n, e.spec())
        return plan if top else n
    deps = [legacy_build(c, plan, _n) for c in e.children()]
    kind = {L.And: "intersect", L.Or: "union", L.Sub: "difference",
            L.Counter: "counter"}[type(e)]
    k = e.k if e.k is not None else L.UNCUT
    n = name(kind)
    plan.add(n, CombinerSpec(kind, k), deps)
    return plan if top else n


# ------------------------------------------------------------------------- IR
def test_operator_overloading_builds_ir():
    a, b, c = blend.sc(["x"]), blend.kw(["y"]), blend.mc([("x", "y")])
    assert isinstance(a & b, L.And) and (a & b).kids == (a, b)
    assert isinstance(a | b, L.Or)
    assert isinstance(a - b, L.Sub)
    cnt = blend.counter(a, b, c, k=5)
    assert isinstance(cnt, L.Counter) and cnt.k == 5
    assert (a & b).top(7).k == 7
    with pytest.raises(ValueError):
        blend.counter(a)
    with pytest.raises(TypeError):
        a & "not an expression"


def test_structural_equality_and_hashing():
    e1 = blend.sc(["x", "y"], k=10) & blend.kw(["z"], k=10)
    e2 = blend.sc(["x", "y"], k=10) & blend.kw(["z"], k=10)
    assert e1 == e2 and hash(e1) == hash(e2)
    assert e1 != (blend.sc(["x"], k=10) & blend.kw(["z"], k=10))


# --------------------------------------------------------------------- parser
def test_sql_round_trip_all_node_kinds():
    a = blend.sc(["ab'c", "d"], k=30)
    b = blend.kw(["w1", "w2"], k=20)
    m = blend.mc([("u", "v"), ("p", "q")], k=15)
    c = blend.corr(["j1", "j2"], [1.0, -2.5], k=9, h=128, sampling="rand")
    for e in (a, a & b, a | b, a - b, blend.counter(a, b, k=4),
              ((a & b) | (m - c)).top(12),
              (a & b & m).top(40)):
        assert parse(e.to_sql()) == e, e.to_sql()


def test_parse_sql_text_forms():
    e = parse("SELECT TOP 40 TABLES WHERE sc('a', 'b', k=100) "
              "AND kw('x') EXCEPT mc(('a', 'b'), k=50)")
    assert isinstance(e, L.Sub) and e.k == 40
    assert isinstance(e.left, L.And)
    kinds = [c.kind for c in e.left.children()]
    assert kinds == ["SC", "KW"]
    assert e.right.kind == "MC" and e.right.values == (("a", "b"),)
    # keywords are case-insensitive, TABLES optional, numbers are literals
    e2 = parse("select top 5 where sc(1, 2.5, 'x')")
    assert e2.values == (1, 2.5, "x") and e2.k == 5
    # corr with options
    e3 = parse("SELECT TABLES WHERE corr(['j'], [1.0, 2.0], k=7, h=64, "
               "sampling='rand')")
    assert e3.kind == "C" and (e3.k, e3.h, e3.sampling) == (7, 64, "rand")


@pytest.mark.parametrize("bad", [
    "sc('a')",                               # no SELECT
    "SELECT TOP x WHERE sc('a')",            # non-integer TOP
    "SELECT WHERE sc('a'",                   # unbalanced paren
    "SELECT WHERE sc()",                     # empty query set
    "SELECT WHERE counter(sc('a'))",         # counter arity
    "SELECT WHERE corr('a', 'b')",           # corr needs two lists
    "SELECT WHERE sc('a', h=3)",             # unknown option for sc
    "SELECT WHERE sc('a') AND",              # dangling operator
    "SELECT WHERE mc('a')",                  # mc takes tuples
    "SELECT WHERE sc('a') extra",            # trailing input
])
def test_parse_errors(bad):
    with pytest.raises(BlendQLError):
        parse(bad)


# ---------------------------------------------------------------------- rules
def test_rule_flatten_and_or():
    a, b, c = blend.sc(["x"]), blend.kw(["y"]), blend.mc([("x", "y")])
    e = flatten_and_or((a & b) & c)
    assert isinstance(e, L.And) and e.kids == (a, b, c)
    e = flatten_and_or((a | b) | (c | a))
    assert isinstance(e, L.Or) and e.kids == (a, b, c, a)
    # an inner combiner with explicit k is a cut point: not flattened
    inner = (a & b).top(5)
    assert flatten_and_or(inner & c).kids == (inner, c)


def test_rule_fold_idempotent():
    a, b = blend.sc(["x"]), blend.kw(["y"])
    assert fold_idempotent(L.And((a, b, a))) == L.And((a, b))
    assert fold_idempotent(L.Or((a, a))) == a
    # singleton-with-limit folds the cut into the child
    folded = fold_idempotent(L.And((a, a), k=5))
    assert folded == a.top(5)


def test_rule_push_limit():
    a, b = blend.sc(["x"], k=50), blend.kw(["y"])
    assert push_limit(a & b, 12).k == 12
    assert push_limit((a & b).top(5), 12).k == 5      # keeps the tighter cut
    assert push_limit(a, 12).k == 12                  # seeker root clamps
    assert push_limit(a, 80).k == 50
    assert push_limit(a & b, None) == (a & b)


def test_rule_hash_cons_and_annotate():
    x1 = blend.sc(["x", "y"], k=30)
    x2 = blend.sc(["x", "y"], k=30)           # equal, distinct instance
    kw1, mcl = blend.kw(["w"], k=30), blend.mc([("x", "y")], k=30)
    e = (x1 & kw1) | (x2 & mcl)
    assert x1 is not x2
    interned = hash_cons(e)
    sc_leaves = [n for n in L.walk(interned)
                 if isinstance(n, L.Seek) and n.kind == "SC"]
    assert len({id(n) for n in sc_leaves}) == 1       # one shared instance
    annotated = annotate_masks(e)
    assert all(n.eg for n in L.walk(annotated) if isinstance(n, L.And))


def test_rewrite_reports_applied_rules():
    x1 = blend.sc(["x", "y"], k=30)
    x2 = blend.sc(["x", "y"], k=30)
    left = (x1 & blend.kw(["w"], k=30)) & x1          # nested + duplicate
    e = left | (x2 & blend.mc([("x", "y")], k=30))
    out = rewrite(e, top=10)
    assert out.applied == ["flatten_and_or", "fold_idempotent", "push_limit",
                           "hash_cons", "annotate_masks"]
    assert out.expr.k == 10
    # fixpoint: rewriting the result again applies nothing
    assert rewrite(out.expr, top=10).applied == []


# ------------------------------------------------------------------- lowering
def test_lowering_shares_hash_consed_subtrees():
    x = blend.sc(["x", "y"], k=30)
    e = (x & blend.kw(["w"], k=30)) | (x & blend.mc([("x", "y")], k=30))
    plan, node_of = lower(rewrite(e, top=10).expr)
    sc_nodes = [n for n in plan.nodes.values()
                if n.is_seeker and n.spec.kind == "SC"]
    assert len(sc_nodes) == 1                         # one physical node
    assert plan.output and plan.validate()
    # UNCUT interior: the inner intersects lower cut-free, root keeps k=10
    assert plan.nodes[plan.output].spec.k == 10
    inner = [n for n in plan.nodes.values()
             if not n.is_seeker and n.name != plan.output
             and n.spec.kind == "intersect"]
    assert all(n.spec.k == L.UNCUT for n in inner)


# ------------------------------------------------- Plan.validate reachability
def test_validate_reports_unreachable_nodes():
    plan = Plan()
    plan.add("a", Seekers.SC(["x"], k=5))
    plan.add("b", Seekers.SC(["y"], k=5))
    plan.add("dead", Seekers.KW(["z"], k=5))
    plan.add("out", Combiners.Intersect(k=5), ["a", "b"])
    with pytest.raises(ValueError, match="dead"):
        plan.validate()
    assert plan.prune_unreachable() == ["dead"]
    assert plan.validate() and set(plan.nodes) == {"a", "b", "out"}
    assert plan.prune_unreachable() == []             # idempotent


def test_session_prunes_legacy_dead_nodes(session, small_lake):
    t = small_lake.tables[1]
    plan = Plan()
    plan.add("a", Seekers.SC(list(t.columns[0][:6]), k=20))
    plan.add("b", Seekers.KW([t.columns[1][0]], k=20))
    plan.add("dead", Seekers.MC([(t.columns[0][0], t.columns[1][0])], k=20))
    plan.add("out", Combiners.Union(k=10), ["a", "b"])
    res = session.query(plan)
    assert res.applied_rules == ["prune_dead_nodes"]
    assert "dead" not in res.info.order
    # the caller-owned plan is never mutated: pruning happens on a copy
    assert "dead" in plan.nodes
    plan.add("out2", Combiners.Intersect(k=5), ["a", "dead"])


# --------------------------------------------------------------- parity suite
def test_parity_all_seekers_all_combiners_exact(session, small_lake):
    """Acceptance: fluent, SQL, and legacy Plan.add agree on a task using
    all four seeker kinds and all four combiners (k lifted to n_tables, so
    optimizer rewriting is exactly output-preserving)."""
    lv = _leaves(small_lake, k=small_lake.n_tables)
    e = ((lv["sc"] & lv["kw"])
         | blend.counter(lv["sc"], lv["mc"], k=small_lake.n_tables)
         | lv["corr"]) - lv["mc"]
    fluent = session.query(e)
    via_sql = session.sql(e.to_sql())
    legacy = session.query(legacy_build(e))
    assert fluent.ids == via_sql.ids == legacy.ids
    assert len(fluent.ids) > 0
    # the four seeker kinds and four combiner kinds all actually lowered
    plan = fluent.compiled.plan
    seeker_kinds = {n.spec.kind for n in plan.nodes.values() if n.is_seeker}
    comb_kinds = {n.spec.kind for n in plan.nodes.values()
                  if not n.is_seeker}
    assert seeker_kinds == {"SC", "KW", "MC", "C"}
    assert comb_kinds == {"intersect", "union", "difference", "counter"}


def test_parity_binding_k_unoptimized(session, small_lake):
    """With binding per-seeker k, optimize=False (no rewriting) is exact:
    the three frontends must still agree."""
    lv = _leaves(small_lake, k=12)
    e = ((lv["sc"] & lv["kw"]) - lv["mc"]).top(8)
    fluent = session.query(e, optimize=False)
    via_sql = session.sql(e.to_sql(), optimize=False)
    legacy = session.query(legacy_build(e), optimize=False)
    assert fluent.ids == via_sql.ids == legacy.ids


def test_fluent_equals_sql_with_binding_k_optimized(session, small_lake):
    lv = _leaves(small_lake, k=10)
    e = ((lv["sc"] & lv["mc"]) | (lv["kw"] & lv["corr"])).top(6)
    assert session.query(e).ids == session.sql(e.to_sql()).ids


def test_hash_consed_shared_subtree_executes_once(session, small_lake):
    """Acceptance: a seeker shared by two intersection groups runs exactly
    once (asserted via ExecInfo.order)."""
    t = small_lake.tables[4]
    shared = blend.sc(list(t.columns[0][:8]), k=small_lake.n_tables)
    e = ((shared & blend.kw([t.columns[1][0]], k=small_lake.n_tables))
         | (shared & blend.mc([(t.columns[0][0], t.columns[1][0])],
                              k=small_lake.n_tables)))
    res = session.query(e, top=10)
    sc_names = [n for n, node in res.compiled.plan.nodes.items()
                if node.is_seeker and node.spec.kind == "SC"]
    assert len(sc_names) == 1
    assert res.info.order.count(sc_names[0]) == 1
    # every node executes at most once
    assert len(res.info.order) == len(set(res.info.order))
    # and the shared run matches the legacy duplicate-node walk
    legacy = session.query(legacy_build(e.top(10)))
    assert res.ids == legacy.ids


# -------------------------------------------------------------------- explain
def test_explain_lists_rules_order_and_timings(session, small_lake):
    x = blend.sc(list(small_lake.tables[2].columns[0][:6]), k=30)
    dup = blend.sc(list(small_lake.tables[2].columns[0][:6]), k=30)
    e = ((x & blend.kw([small_lake.tables[2].columns[1][0]], k=30)) & x) \
        | (dup & blend.mc([(small_lake.tables[2].columns[0][0],
                            small_lake.tables[2].columns[1][0])], k=30))
    ex = session.explain(e, top=10)
    assert ex.applied_rules == ["flatten_and_or", "fold_idempotent",
                                "push_limit", "hash_cons", "annotate_masks"]
    assert ex.exec_order and ex.node_seconds
    assert ex.physical_order                          # ranked EGs present
    text = str(ex)
    for section in ("logical plan", "rewrite rules applied",
                    "physical order", "execution"):
        assert section in text
    for rule in ex.applied_rules:
        assert rule in text
    # explain without execution still renders the static sections
    static = session.explain(e, top=10, execute=False)
    assert static.exec_order == [] and "== execution ==" not in str(static)


# ------------------------------------------------------------ serving surface
def test_discovery_response_carries_exec_info(small_lake):
    from repro.serve.engine import DiscoveryEngine
    engine = DiscoveryEngine(small_lake)
    t = small_lake.tables[3]
    expr = (blend.mc([(t.columns[0][r], t.columns[1][r]) for r in range(4)],
                     k=30)
            & blend.sc(list(t.columns[0][:8]), k=30)).top(10)
    r = engine.serve(expr)
    assert r.table_ids and r.order and r.node_seconds
    assert set(r.node_seconds) == set(r.order)   # every run node is timed
    assert r.overflow >= 0 and r.total_node_seconds > 0
    assert r.applied_rules                            # push_limit at least
    # serve_many: same info on every response, for plain SQL text too
    batch = engine.serve_many([expr, expr.to_sql()])
    assert all(b.order and b.node_seconds for b in batch)
    assert batch[0].table_ids == batch[1].table_ids == r.table_ids


# ------------------------------------------------------ property-style parity
@st.composite
def expr_trees(draw, n_tables):
    """Random expression trees over a fixed leaf pool (k = n_tables so the
    optimizer's rewriting stays exactly output-preserving — Theorem 1)."""
    kinds = draw(st.lists(st.sampled_from(["sc", "kw", "mc", "corr"]),
                          min_size=2, max_size=4))
    tab = draw(st.integers(0, 7))
    depth = draw(st.integers(1, 3))

    def build(d):
        which = draw(st.sampled_from(kinds))
        if d == 0:
            return ("leaf", which)
        op = draw(st.sampled_from(["and", "or", "sub", "counter", "leaf"]))
        if op == "leaf":
            return ("leaf", which)
        if op == "sub":
            return ("sub", build(d - 1), build(d - 1))
        n = draw(st.integers(2, 3))
        return (op, *[build(d - 1) for _ in range(n)])

    return tab, build(depth)


def _materialize(tree, lake, tab, n_tables):
    kind = tree[0]
    if kind == "leaf":
        t = lake.tables[tab]
        cols = t.columns
        k = n_tables
        return {"sc": blend.sc(list(cols[0][:6]), k=k),
                "kw": blend.kw([cols[1][0], cols[1][2]], k=k),
                "mc": blend.mc([(cols[0][r], cols[1][r]) for r in range(3)],
                               k=k),
                "corr": blend.corr(list(cols[0][:8]),
                                   list(map(float, range(8))), k=k)}[tree[1]]
    kids = [_materialize(c, lake, tab, n_tables) for c in tree[1:]]
    if kind in ("and", "or"):
        # drop duplicate siblings: the fold_idempotent rule removes them on
        # the BlendQL side but the naive legacy walk would sum their scores
        # twice, which is set-preserving yet can reorder equal-set rankings
        uniq = list(dict.fromkeys(kids))
        if len(uniq) == 1:
            return uniq[0]
        return (L.And if kind == "and" else L.Or)(tuple(uniq))
    if kind == "sub":
        return L.Sub(kids[0], kids[1])
    return L.Counter(tuple(kids))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_property_random_tree_frontend_equivalence(session, small_lake, data):
    """Theorem 1 extended through the frontend: a random expression tree
    yields identical top-k ids via session.query, session.sql on its printed
    form, and the legacy Plan.add path."""
    n = small_lake.n_tables
    tab, tree = data.draw(expr_trees(n))
    e = _materialize(tree, small_lake, tab, n)
    if isinstance(e, L.Seek):
        e = e & e                 # ensure at least one combiner in the plan
    fluent = session.query(e)
    via_sql = session.sql(e.to_sql())
    legacy = session.query(legacy_build(e))
    assert fluent.ids == via_sql.ids == legacy.ids
    naive = session.query(e, optimize=False)
    assert fluent.ids == naive.ids
