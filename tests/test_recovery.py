"""Durability and fault tolerance (tentpole acceptance for the WAL PR).

The centerpiece is the crash-at-every-fault-point property: a scripted
mutation sequence runs under a deterministic :class:`FaultInjector` that
kills the "process" (``InjectedCrash``) at every named durability fault
point the clean run crosses; after each crash, ``blend.recover`` must
rebuild a state **bit-identical** (ids AND scores, same epoch) to the
uninterrupted run's acknowledged prefix — and the crash-point semantics
make the expected prefix exact, not a range:

* ``*.pre`` crashes (before the record is durable) recover the prefix
  *without* the interrupted mutation;
* ``store.*.post`` / ``wal.append.post`` crashes (after the record is
  durable) recover the prefix *with* it;
* snapshot-commit crashes never change logical state (the previous
  generation plus WAL replay still covers every acknowledged mutation);
* torn WAL tails (a seeded strict prefix of the final record on disk) are
  truncated on recovery, never partially replayed.

Around the property: WAL scan/truncation unit tests, snapshot corruption
and version-skew handling, deadline scheduling in the batch former and
server, shard-failure degraded serving, client retry backoff, and the
consolidated typed-error contract."""
import json
import os
import time
from concurrent.futures import Future

import numpy as np
import pytest

import blend
from repro import faults
from repro.core.lake import Table, synthetic_lake
from repro.errors import (BlendFault, CorruptSnapshot, DeadlineExceeded,
                          Overloaded, WalReplayError)
from repro.faults import FaultInjector, InjectedCrash, InjectedFault
from repro.serve.batching import Batch, BatchFormer, LaneConfig
from repro.serve.client import RetryingClient
from repro.serve.engine import DiscoveryEngine
from repro.serve.loadgen import make_trace, replay
from repro.serve.server import DiscoveryServer
from repro.store import LiveLake
from repro.store import snapshot as snap
from repro.store import wal as walmod


def mk_lake(seed=2, n_tables=10):
    return synthetic_lake(n_tables=n_tables, rows=12, cols=3, vocab=160,
                          seed=seed)


def extra_table(i, rows=10, vocab=160):
    rng = np.random.default_rng(7000 + i)
    return Table(f"rec_extra{i}",
                 [[f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [float(x) for x in np.round(rng.normal(0, 5, rows), 3)]])


def probe_query(lake, k=20):
    t = lake.tables[1]
    sc = blend.sc(list(t.columns[0][:8]), k=k)
    kw = blend.kw([t.columns[1][0], t.columns[1][2]], k=k)
    return (sc & kw).top(10)


def capture(session, q):
    """(ids, scores, epoch) — the bit-identity surface."""
    res = session.query(q, fused=True)
    ep = session.live.store.epoch
    ep = tuple(int(e) for e in ep) if isinstance(ep, tuple) else int(ep)
    return (tuple(res.ids), np.asarray(res.scores).copy(), ep)


def assert_state_equal(got, want, msg):
    assert got[0] == want[0], f"{msg}: ids {got[0]} != {want[0]}"
    np.testing.assert_array_equal(got[1], want[1], err_msg=msg)
    assert got[2] == want[2], f"{msg}: epoch {got[2]} != {want[2]}"


# The crash script: 4 acknowledged mutations with a snapshot commit in the
# middle (so crash points hit both WAL-only and snapshot+WAL recovery).
MUTATIONS = (("add", 0), ("drop", 3), ("add", 1), ("compact",))
STEPS = (MUTATIONS[0], MUTATIONS[1], "snap", MUTATIONS[2], MUTATIONS[3])


def apply_step(session, st):
    if st[0] == "add":
        session.add_table(extra_table(st[1]))
    elif st[0] == "drop":
        session.drop_table(st[1])
    else:
        session.compact(full=True)


_REFS: dict = {}


@pytest.fixture(scope="module", autouse=True)
def _release_module_footprint():
    """The ~40 crash-recover cycles in this module compile a lot of one-off
    programs (every recovered lake has its own segment layout).  Left
    cached, that accumulation pushes the XLA CPU compiler over a threshold
    where a *later* suite module (test_shardlake) segfaults inside
    backend_compile — deterministic, reproducible, absent when this module
    is skipped.  Dropping our session refs and clearing jax's caches on
    module teardown keeps the rest of the suite on the same footing as a
    run without this file."""
    yield
    import gc
    import jax
    _REFS.clear()
    gc.collect()
    jax.clear_caches()


def reference_states(backend, shards):
    """State after each acknowledged-mutation prefix of an uninterrupted
    run: refs[k] = state once the first k mutations are applied."""
    key = (backend, shards)
    if key not in _REFS:
        lake = mk_lake()
        session = blend.connect(lake, live=True, backend=backend,
                                shards=shards,
                                interpret=backend == "bucket")
        q = probe_query(lake)
        states = [capture(session, q)]
        for mut in MUTATIONS:
            apply_step(session, mut)
            states.append(capture(session, q))
        _REFS[key] = states
    return _REFS[key]


def run_script(tmp_path, backend, shards, injector):
    """Connect with a WAL, take a baseline snapshot, then run STEPS under
    ``injector``.  Returns (acked, crashed_point, crashed_hit, session)."""
    tmp_path.mkdir(parents=True, exist_ok=True)
    lake = mk_lake()
    sp, wp = str(tmp_path / "lake.snap"), str(tmp_path / "lake.wal")
    session = blend.connect(lake, live=True, backend=backend, shards=shards,
                            wal=wp, interpret=backend == "bucket")
    session.snapshot(sp)          # baseline: initial lake is durable
    acked = 0
    try:
        with faults.inject(injector):
            for st in STEPS:
                if st == "snap":
                    session.snapshot(sp)
                else:
                    apply_step(session, st)
                    acked += 1
        return acked, None, 0, session
    except InjectedCrash as e:
        return acked, e.point, e.hit, session


def recovered_state(tmp_path, backend):
    sess = blend.recover(str(tmp_path / "lake.snap"),
                         wal=str(tmp_path / "lake.wal"), backend=backend,
                         interpret=backend == "bucket")
    return capture(sess, probe_query(mk_lake()))


def crash_occurrences(tmp_path, backend, shards):
    """Record-mode clean run: every fault point crossed under injection,
    with first and last hit numbers (the crash matrix)."""
    rec = FaultInjector(record=True)
    acked, point, _, _ = run_script(tmp_path, backend, shards, rec)
    assert point is None and acked == len(MUTATIONS)
    return [(p, n) for p in rec.points
            for n in sorted({1, rec.hits[p]})]


def expected_prefix(point, hit, acked):
    """The exact acknowledged prefix recovery must reproduce (module
    docstring): durable-post crashes include the interrupted mutation."""
    durable_post = point.endswith(".post") and \
        (point.startswith("store.") or point == "wal.append.post")
    return acked + 1 if durable_post else acked


CONFIGS = [("sorted", None), ("sorted", 4)]


@pytest.mark.parametrize("backend,shards", CONFIGS,
                         ids=["sorted-static", "sorted-shards4"])
def test_crash_at_every_fault_point_recovers_bit_identical(
        tmp_path, backend, shards):
    refs = reference_states(backend, shards)
    matrix = crash_occurrences(tmp_path / "record", backend, shards)
    assert {p for p, _ in matrix} >= {
        "store.add.pre", "store.add.post", "store.drop.pre",
        "store.drop.post", "store.compact.pre", "store.compact.post",
        "wal.append.pre", "wal.append.post", "snapshot.write.pre",
        "snapshot.rename.pre", "snapshot.post"}
    for i, (point, hit) in enumerate(matrix):
        d = tmp_path / f"run{i}"
        d.mkdir()
        inj = FaultInjector(crash={point: hit})
        acked, cpoint, chit, _ = run_script(d, backend, shards, inj)
        assert (cpoint, chit) == (point, hit)
        want = refs[expected_prefix(point, hit, acked)]
        assert_state_equal(recovered_state(d, backend), want,
                           f"crash at {point} hit {hit} (acked={acked})")


@pytest.mark.parametrize("backend,shards", CONFIGS,
                         ids=["sorted-static", "sorted-shards4"])
def test_torn_wal_tail_truncated_never_partially_replayed(
        tmp_path, backend, shards):
    refs = reference_states(backend, shards)
    for n in range(1, len(MUTATIONS) + 1):
        d = tmp_path / f"torn{n}"
        d.mkdir()
        inj = FaultInjector(seed=n, torn={"wal.append.torn": n})
        acked, point, _, _ = run_script(d, backend, shards, inj)
        assert point == "wal.append.torn" and acked == n - 1
        # the torn record must vanish: exactly the pre-crash prefix
        assert_state_equal(recovered_state(d, backend), refs[acked],
                           f"torn append {n}")
        # and recovery physically truncated the tail: a clean rescan
        _, _, torn = walmod.scan(d / "lake.wal")
        assert not torn


@pytest.mark.parametrize("shards", [None, 4],
                         ids=["static", "shards4"])
def test_crash_recovery_bucket_backend(tmp_path, shards):
    """Backend spot check: the recovery machinery is backend-agnostic,
    but recovered scores must be bit-identical under the bucket probe
    too (one mid-script crash + one torn tail)."""
    refs = reference_states("bucket", shards)
    d = tmp_path / "crash"
    d.mkdir()
    inj = FaultInjector(crash={"wal.append.pre": 3})
    acked, point, _, _ = run_script(d, "bucket", shards, inj)
    assert point == "wal.append.pre" and acked == 2
    assert_state_equal(recovered_state(d, "bucket"), refs[2],
                       "bucket crash")
    d = tmp_path / "torn"
    d.mkdir()
    inj = FaultInjector(torn={"wal.append.torn": 4})
    acked, point, _, _ = run_script(d, "bucket", shards, inj)
    assert point == "wal.append.torn" and acked == 3
    assert_state_equal(recovered_state(d, "bucket"), refs[3], "bucket torn")


def test_wal_only_cold_start_recovery(tmp_path):
    """No snapshot ever taken: recovery replays the whole WAL from an
    empty store (mutations before the first snapshot are WAL-covered
    only when the lake itself started empty)."""
    wp = str(tmp_path / "cold.wal")
    ll = LiveLake(None, wal=wp)
    for i in range(4):
        ll.add_table(extra_table(i))
    ll.drop_table(1)
    want_ids, want_epoch = ll.live_ids(), ll.store.epoch
    rec = LiveLake.recover(str(tmp_path / "nope.snap"), wal=wp)
    assert rec.live_ids() == want_ids
    assert rec.store.epoch == want_epoch


# --------------------------------------------------------------------------
# WAL format unit tests
# --------------------------------------------------------------------------

def _write_wal(path, n=3):
    w = walmod.WriteAheadLog(path, fsync=False)
    sizes = []
    for i in range(n):
        before = os.path.getsize(path) if os.path.exists(path) else 0
        w.append({"op": "add_table", "i": i, "blob": "x" * (20 + 7 * i)})
        sizes.append(os.path.getsize(path) - before)
    w.close()
    return sizes


def test_wal_roundtrip_and_seq_floor(tmp_path):
    p = tmp_path / "a.wal"
    _write_wal(p, 3)
    records, good, torn = walmod.scan(p)
    assert [r["seq"] for r in records] == [1, 2, 3]
    assert good == os.path.getsize(p) and not torn
    # reopening scans the file for the seq floor
    w = walmod.WriteAheadLog(p, fsync=False)
    assert w.seq == 3
    assert w.append({"op": "noop"}) == 4
    # clear drops records but the seq counter keeps counting
    w.clear()
    assert w.append({"op": "noop"}) == 5
    w.close()
    records, _, _ = walmod.scan(p)
    assert [r["seq"] for r in records] == [5]


def test_wal_group_commit_bulk_add(tmp_path):
    from repro import obs
    reg = obs.enable()          # metrics count the barriers (cached at init)
    try:
        w = walmod.WriteAheadLog(tmp_path / "g.wal", fsync=True)
        ll = LiveLake(None, wal=w)
        tids = ll.add_tables([extra_table(i) for i in range(4)])
        assert len(tids) == 4
        # one durability barrier covers the whole batch (group commit) ...
        assert reg.counter("wal.fsyncs").value == 1
        assert reg.counter("wal.appends").value == 4
        assert w.fsync is True                  # per-record barrier restored
        w.close()
    finally:
        obs.disable()
    # ... and the redo records are identical to four single adds
    records, last = walmod.recover_records(tmp_path / "g.wal")
    assert [r["op"] for r in records] == ["add_table"] * 4 and last == 4
    rec = LiveLake.recover(wal=tmp_path / "g.wal")
    assert rec.live_ids() == ll.live_ids()
    assert rec.store.epoch == ll.store.epoch


@pytest.mark.parametrize("cut", ["one_byte", "header", "mid_payload"])
def test_wal_torn_tail_truncation(tmp_path, cut):
    p = tmp_path / "t.wal"
    sizes = _write_wal(p, 3)
    total = os.path.getsize(p)
    drop = {"one_byte": 1, "header": sizes[2] - 4,
            "mid_payload": sizes[2] // 2}[cut]
    with open(p, "r+b") as f:
        f.truncate(total - drop)
    records, last = walmod.recover_records(p)
    assert [r["seq"] for r in records] == [1, 2] and last == 2
    assert os.path.getsize(p) == sizes[0] + sizes[1]  # physically truncated
    # post-recovery appends extend a clean file
    w = walmod.WriteAheadLog(p, fsync=False, start_seq=last)
    w.append({"op": "noop"})
    w.close()
    records, _, torn = walmod.scan(p)
    assert [r["seq"] for r in records] == [1, 2, 3] and not torn


def test_wal_preallocated_zero_tail_recovers(tmp_path):
    """``preallocate=`` extends the file with zeros past the logical tail;
    a crash (no close) leaves them — replay must treat the zero tail like
    any torn tail and the recovered log must keep appending cleanly."""
    p = tmp_path / "p.wal"
    w = walmod.WriteAheadLog(p, fsync=False, preallocate=1 << 16)
    for i in range(3):
        w.append({"op": "add_table", "i": i})
    assert os.path.getsize(p) >= 1 << 16     # zero tail on disk
    # simulated crash: no close(), so the preallocated tail stays
    records, last = walmod.recover_records(p)
    assert [r["seq"] for r in records] == [1, 2, 3] and last == 3
    w2 = walmod.WriteAheadLog(p, fsync=False, preallocate=1 << 16)
    assert w2.seq == 3
    w2.append({"op": "noop"})
    w2.close()                               # truncates the zero tail
    records, good, torn = walmod.scan(p)
    assert [r["seq"] for r in records] == [1, 2, 3, 4]
    assert good == os.path.getsize(p) and not torn


def test_wal_midlog_corruption_raises(tmp_path):
    p = tmp_path / "m.wal"
    sizes = _write_wal(p, 3)
    with open(p, "r+b") as f:
        f.seek(sizes[0] + sizes[1] - 3)   # payload byte of record 2
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(WalReplayError):
        walmod.scan(p)
    with pytest.raises(WalReplayError):  # recovery must not truncate it away
        walmod.recover_records(p)


# --------------------------------------------------------------------------
# snapshot hardening: checksums, generations, version skew
# --------------------------------------------------------------------------

def _saved_store(tmp_path, mutate=0):
    lake = mk_lake(n_tables=6)
    ll = LiveLake(lake)
    for i in range(mutate):
        ll.add_table(extra_table(10 + i))
    p = str(tmp_path / "lake.snap")
    snap.save(ll.store, p)
    return ll.store, p


def test_snapshot_version1_still_loads(tmp_path):
    store, p = _saved_store(tmp_path)
    _, man_path = snap._paths(p)
    man = json.loads(man_path.read_text())
    for k in ("checksums", "table_cap", "wal_seq", "sketch"):
        man.pop(k, None)
    man["version"] = 1
    man_path.write_text(json.dumps(man))
    st = snap.load(p)
    assert st.table_names[:st.n_slots] == store.table_names[:store.n_slots]
    assert st.epoch == store.epoch


def test_snapshot_unsupported_version_raises(tmp_path):
    _, p = _saved_store(tmp_path)
    _, man_path = snap._paths(p)
    man = json.loads(man_path.read_text())
    man["version"] = 99
    man_path.write_text(json.dumps(man))
    with pytest.raises(CorruptSnapshot, match="version"):
        snap.load(p)
    with pytest.raises(ValueError):      # old contract preserved
        snap.load(p)


@pytest.mark.parametrize("damage", ["bitflip", "truncate"])
def test_snapshot_checksum_detects_corruption(tmp_path, damage):
    _, p = _saved_store(tmp_path)
    npz_path, _ = snap._paths(p)
    raw = bytearray(npz_path.read_bytes())
    if damage == "bitflip":
        raw[len(raw) // 2] ^= 0xFF
        npz_path.write_bytes(bytes(raw))
    else:
        npz_path.write_bytes(bytes(raw[:len(raw) // 2]))
    with pytest.raises(CorruptSnapshot):
        snap.load(p)


def test_snapshot_generation_fallback(tmp_path):
    lake = mk_lake(n_tables=6)
    ll = LiveLake(lake)
    p = str(tmp_path / "lake.snap")
    ll.snapshot(p)
    old_epoch = ll.store.epoch
    ll.add_table(extra_table(30))
    ll.snapshot(p)                        # rotates the first save to .g1
    npz_path, _ = snap._paths(p)
    raw = bytearray(npz_path.read_bytes())
    raw[len(raw) // 3] ^= 0xFF
    npz_path.write_bytes(bytes(raw))
    st = snap.load(p)                     # current corrupt -> .g1 serves
    assert st.epoch == old_epoch
    with pytest.raises(CorruptSnapshot):
        snap.load(p, fallback=False)


# --------------------------------------------------------------------------
# deadline scheduling
# --------------------------------------------------------------------------

def _former():
    return BatchFormer(max_batch=4,
                       lanes={"interactive": LaneConfig(window_s=0.01,
                                                        max_queue=8)})


def test_former_culls_expired_head():
    f = _former()
    p, _ = f.submit("q", lane="interactive", now=0.0, deadline_s=0.005)
    out = f.poll(0.006)
    assert isinstance(out, Batch) and out.requests == []
    assert out.expired == [p] and f.stats.expired == 1
    assert f.poll(0.02) is None          # queue is empty now


def test_former_culls_expired_mid_prefix_at_dispatch():
    f = _former()
    p1, _ = f.submit("a", lane="interactive", now=0.0)
    p2, _ = f.submit("b", lane="interactive", now=0.0, deadline_s=0.004)
    out = f.poll(0.02)                   # window closed: both taken
    assert out.requests == [p1] and out.expired == [p2]
    assert f.stats.batches == 1


def test_former_expires_queries_behind_mutation_barrier():
    f = _former()
    m, _ = f.submit("mut", kind="mutation", now=0.0)
    p, _ = f.submit("b", lane="interactive", now=0.0, deadline_s=0.005)
    out = f.poll(10.0)         # head cull reaches even behind the barrier
    assert out.requests == [] and out.expired == [p]
    assert f.poll(10.0).request is m     # barrier still runs


def test_former_next_deadline_tracks_head_deadline():
    f = _former()
    f.submit("a", lane="interactive", now=0.0, deadline_s=0.003)
    assert f.next_deadline(0.0) == pytest.approx(0.003)


def test_server_deadline_exceeded_typed_response():
    lake = mk_lake()
    server = DiscoveryServer(DiscoveryEngine(lake), max_batch=4,
                             start=False)
    q = probe_query(lake)
    fut = server.submit(q, deadline_s=0.01)   # server not started yet
    time.sleep(0.05)
    with server:
        resp = fut.result(timeout=10.0)
        assert isinstance(resp, DeadlineExceeded) and not resp.ok
        assert resp.deadline_s == pytest.approx(0.01)
        assert resp.waited_s >= 0.04
        ok = server.serve(q)                  # server still healthy
        assert not isinstance(ok, BlendFault)
        assert server.stats()["deadline_exceeded"] == 1


# --------------------------------------------------------------------------
# shard failure: retry, then degraded response
# --------------------------------------------------------------------------

def test_shard_failure_transparent_after_retry():
    lake = mk_lake()
    session = blend.connect(lake, live=True, shards=4)
    q = probe_query(lake)
    want = capture(session, q)
    inj = FaultInjector(fail={"shard.probe.2": 1})   # one failure: retried
    with faults.inject(inj):
        res = session.query(q, fused=True)
    assert res.info.failed_shards == []
    assert tuple(res.ids) == want[0]
    np.testing.assert_array_equal(np.asarray(res.scores), want[1])


def test_shard_failure_degrades_with_zero_wrong_results():
    lake = mk_lake()
    session = blend.connect(lake, live=True, shards=4)
    q = probe_query(lake)
    ref = session.query(q, fused=True)
    inj = FaultInjector(fail={"shard.probe.1": 2})   # retry fails too
    with faults.inject(inj):
        res = session.query(q, fused=True)
    assert res.info.failed_shards == [1]
    store = session.live.store
    ref_sc, deg_sc = np.asarray(ref.scores), np.asarray(res.scores)
    for tid in res.ids:
        # never a result from the dead shard, and surviving tables keep
        # their exact scores (zero wrong results, just fewer)
        assert store.owner_of(tid) != 1
        if tid in ref.ids:
            assert deg_sc[tid] == ref_sc[tid]


def test_degraded_response_flagged_by_server():
    lake = mk_lake()
    engine = DiscoveryEngine(lake, shards=4, live=True)
    q = probe_query(lake)
    clean = engine.serve(q)
    assert clean.degraded is False and clean.failed_shards == []
    inj = FaultInjector(fail={"shard.probe.0": 2})
    with faults.inject(inj):
        resp = engine.serve(q)
    assert resp.degraded is True and resp.failed_shards == [0]


# --------------------------------------------------------------------------
# client retries
# --------------------------------------------------------------------------

class _StubServer:
    def __init__(self, responses):
        self.responses = list(responses)
        self.calls = 0

    def submit(self, query, **kw):
        fut = Future()
        fut.set_result(self.responses[min(self.calls,
                                          len(self.responses) - 1)])
        self.calls += 1
        return fut


def test_retrying_client_honors_retry_after_floor():
    srv = _StubServer([Overloaded("rate_limit", "interactive", "t",
                                  retry_after_s=0.3),
                       Overloaded("queue_full", "interactive", "t"),
                       "ok"])
    slept = []
    c = RetryingClient(srv, max_retries=4, base_backoff_s=0.01,
                       sleep=slept.append)
    assert c.serve("q") == "ok"
    assert srv.calls == 3 and c.retries == 2 and c.gave_up == 0
    assert slept[0] >= 0.3               # server hint floors the backoff
    assert slept[1] < 0.3                # no hint: base * 2**1, jittered


def test_retrying_client_gives_up_and_never_retries_deadlines():
    over = Overloaded("queue_full", "interactive", "t")
    srv = _StubServer([over])
    c = RetryingClient(srv, max_retries=2, sleep=lambda s: None)
    assert c.serve("q") is over
    assert srv.calls == 3 and c.gave_up == 1
    dead = DeadlineExceeded("interactive", "t", deadline_s=0.1)
    srv2 = _StubServer([dead, "ok"])
    c2 = RetryingClient(srv2, max_retries=2, sleep=lambda s: None)
    assert c2.serve("q") is dead         # final: no retry
    assert srv2.calls == 1


def test_loadgen_replay_retries_overload(tmp_path):
    lake = mk_lake(seed=9, n_tables=12)
    engine = DiscoveryEngine(lake, live=True)
    trace = make_trace(lake, seed=3, duration_s=0.4, rate_rps=80.0,
                       n_distinct=4, k=12)
    server = DiscoveryServer(engine, max_batch=8, rate=30.0, burst=4.0)
    with server:
        rep = replay(server, trace, sleep=lambda s: None,
                     max_retries=3, base_backoff_s=0.0, max_backoff_s=0.0)
    assert rep.offered == rep.completed + rep.shed + rep.expired
    assert rep.retried > 0               # rate limiting forced resubmits
    d = rep.as_dict()
    assert d["retries"]["resubmitted"] == rep.retried
    assert d["retries"]["gave_up"] == rep.gave_up


# --------------------------------------------------------------------------
# typed-error consolidation (satellite a)
# --------------------------------------------------------------------------

def test_error_types_consolidated_and_backcompat():
    from repro.serve.server import Overloaded as ServerOverloaded
    assert ServerOverloaded is Overloaded
    for exc in (Overloaded, DeadlineExceeded, InjectedFault):
        assert issubclass(exc, BlendFault)
    for exc in (CorruptSnapshot, WalReplayError):
        assert issubclass(exc, BlendFault) and issubclass(exc, ValueError)
    o = Overloaded("rate_limit", "interactive", "t", retry_after_s=0.5)
    d = DeadlineExceeded("interactive", "t", deadline_s=0.1, waited_s=0.2)
    assert o.ok is False and d.ok is False
    assert not issubclass(InjectedCrash, Exception)   # kill -9 semantics
