"""QueryCache: canonical fingerprints, hit/partial/miss serving, epoch
invalidation under LiveLake mutations, LRU byte budgets, serve_many drain
accounting, and the cache-vs-cold bit-identical parity property.

Ground truth: a cold session over the same store must see identical ids at
every step (tests/test_oracle.py anchors that engine to the brute-force
oracle), and the mutation-invalidation workload is additionally checked
against a from-scratch rebuild of the live tables.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import blend
from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import DataLake, Table, synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers
from repro.query.fingerprint import (fingerprint_expr, fingerprint_plan,
                                     fingerprint_query, index_epoch_key)
from repro.query.lower import lower
from repro.query.rules import rewrite
from repro.serve.cache import QueryCache
from repro.serve.engine import DiscoveryEngine
from repro.store import LiveLake


def cache_lake(seed=5, n_tables=16):
    return synthetic_lake(n_tables=n_tables, rows=14, cols=4, vocab=200,
                          seed=seed)


def extra_table(i, rows=10, vocab=200):
    rng = np.random.default_rng(2000 + i)
    return Table(f"qc_extra{i}",
                 [[f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [float(x) for x in np.round(rng.normal(0, 5, rows), 3)]])


def query_pool(lake, k=20):
    """Queries with shared subtrees (the repetitive-workload shape)."""
    t = lake.tables[3]
    sc = blend.sc(list(t.columns[0][:8]), k=k)
    kw = blend.kw([t.columns[1][0], t.columns[1][2]], k=k)
    mc = blend.mc([(t.columns[0][r], t.columns[1][r]) for r in range(4)], k=k)
    corr = blend.corr(list(t.columns[0][:8]),
                      [float(i) for i in range(8)], k=k, h=64)
    return [(sc & mc).top(10),
            (sc | corr).top(10),                    # shares sc
            (blend.counter(sc, kw, mc, k=10)),      # shares sc, mc
            (mc - kw).top(10)]


# --------------------------------------------------------------------------
# canonical fingerprints
# --------------------------------------------------------------------------

def test_fingerprint_commutative_and_normalized():
    a = blend.sc(["x", "y"], k=30)
    b = blend.kw(["w"], k=30)
    c = blend.mc([("u", "v")], k=30)
    assert fingerprint_query(a & b) == fingerprint_query(b & a)
    assert fingerprint_query((a & b) & c) == fingerprint_query(a & (b & c))
    assert fingerprint_query(a | a) == fingerprint_query(a)   # fold
    assert fingerprint_query(a - b) != fingerprint_query(b - a)
    assert fingerprint_query(a & b) != fingerprint_query(a | b)
    assert fingerprint_query(a & b, top=5) != fingerprint_query(a & b)
    assert (a & b).fingerprint() == (b & a).fingerprint()


def test_fingerprint_order_blindness_limited_to_exact_merges():
    """Union/counter are bit-commutative at any arity; a >= 3-ary intersect
    re-associates an f32 score sum, so permuted spellings keep separate
    entries (a hit must equal that spelling's own cold run)."""
    a = blend.sc(["x"], k=30)
    b = blend.kw(["y"], k=30)
    c = blend.mc([("u", "v")], k=30)
    assert fingerprint_query(a | b | c) == fingerprint_query(c | b | a)
    assert blend.counter(a, b, c).fingerprint() == \
        blend.counter(c, a, b).fingerprint()
    assert fingerprint_query(a & b & c) != fingerprint_query(c & b & a)
    # both associations flatten to the same written order and still share
    assert fingerprint_query((a & b) & c) == fingerprint_query(a & (b & c))


def test_fingerprint_numpy_scalars_match_python_values():
    assert blend.sc([np.int32(2)]).fingerprint() == \
        blend.sc([2]).fingerprint() == blend.sc([np.float64(2.0)]).fingerprint()
    assert blend.sc([np.float32(2.5)]).fingerprint() == \
        blend.sc([2.5]).fingerprint()
    assert blend.kw([np.str_("tok")]).fingerprint() == \
        blend.kw(["tok"]).fingerprint()


def test_fingerprint_value_set_semantics():
    assert blend.sc(["x", "y"]).fingerprint() == \
        blend.sc(["y", "x", "x"]).fingerprint()
    assert blend.sc([2]).fingerprint() == blend.sc([2.0]).fingerprint()
    assert blend.sc([2]).fingerprint() != blend.sc(["2"]).fingerprint()
    assert blend.sc(["x"]).fingerprint() != blend.kw(["x"]).fingerprint()
    # MC tuples: position-independent within a tuple, multiset across tuples
    assert blend.mc([("u", "v")]).fingerprint() == \
        blend.mc([("v", "u")]).fingerprint()
    assert blend.mc([("u", "v"), ("v", "u")]).fingerprint() != \
        blend.mc([("u", "v")]).fingerprint()
    # C pairs dedupe; h / sampling are part of the identity
    j, tg = ["a", "b", "a"], [1.0, 2.0, 1.0]
    assert blend.corr(j, tg).fingerprint() == \
        blend.corr(["a", "b"], [1.0, 2.0]).fingerprint()
    assert blend.corr(j, tg, h=64).fingerprint() != \
        blend.corr(j, tg, h=128).fingerprint()
    # permuted C pairs are NOT shared: the executor's k0/k1 split thresholds
    # on tgt.mean(), which can move by an ulp under pair reordering
    assert blend.corr(["j1", "j2", "j3"], [0.1, 0.2, 0.3]).fingerprint() != \
        blend.corr(["j3", "j2", "j1"], [0.3, 0.2, 0.1]).fingerprint()


def test_fingerprint_plan_agrees_with_expr():
    a = blend.sc(["x", "y"], k=30)
    b = blend.kw(["w"], k=30)
    e = rewrite((a & b) | b, top=10).expr
    plan, _ = lower(e)
    assert fingerprint_plan(plan) == fingerprint_expr(e)
    # a hand-built legacy plan of the same query shares the fingerprint
    legacy = Plan()
    legacy.add("s1", Seekers.KW(["w"], k=30))
    legacy.add("s2", Seekers.SC(["x", "y"], k=30))
    legacy.add("and", Combiners.Intersect(k=1 << 20), ["s2", "s1"])
    legacy.add("or", Combiners.Union(k=10), ["and", "s1"])
    assert fingerprint_plan(legacy) == fingerprint_expr(e)


def test_index_epoch_key_moves_on_every_mutation():
    lake = cache_lake(n_tables=8)
    ll = LiveLake(lake, auto_compact=False)
    keys = [ll.cache_key()]
    tid = ll.add_table(extra_table(0))
    keys.append(index_epoch_key(ll.store))
    ll.drop_table(tid)
    keys.append(ll.cache_key())
    ll.compact()
    keys.append(ll.cache_key())
    assert len(set(keys)) == len(keys)            # every mutation moved it
    # two different stores never share a key, even at equal epochs
    other = LiveLake(cache_lake(n_tables=8), auto_compact=False)
    assert other.cache_key() != keys[0]


def test_shared_cache_never_crosses_index_objects():
    """A caller-owned QueryCache reused across connects must never serve one
    lake's ids for another — even when the dead index's memory address is
    reused by a same-shaped successor (id() reuse; guarded by the nonce)."""
    import gc
    qc = QueryCache()
    lake_a = cache_lake(seed=81, n_tables=6)
    lake_b = cache_lake(seed=82, n_tables=6)
    q = blend.kw([lake_a.tables[0].columns[0][0]], k=6)
    s1 = blend.connect(lake_a, cache=qc)
    ids_a = s1.query(q).ids
    key_a = s1.cache._epoch_key
    del s1
    gc.collect()
    s2 = blend.connect(lake_b, cache=qc)
    r = s2.query(q)
    assert s2.cache._epoch_key != key_a           # fresh index, fresh key
    assert r.cache.status != "hit"                # never lake_a's entry
    cold = blend.connect(lake_b)
    assert r.ids == cold.query(q).ids


# --------------------------------------------------------------------------
# hit / partial / miss serving
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cached_session():
    return blend.connect(cache_lake(), cache=True)


def test_exact_hit_serves_identical_ids(cached_session):
    s = cached_session
    q = query_pool(s.lake)[0]
    r1 = s.query(q)
    r2 = s.query(q)
    assert r1.cache.status in ("miss", "partial", "hit")
    assert r2.cache.status == "hit" and r2.ids == r1.ids
    assert r2.cache.seekers_run == 0
    np.testing.assert_array_equal(np.asarray(r1.result.scores),
                                  np.asarray(r2.result.scores))
    # commuted and SQL-text forms resolve to the same entry
    t = s.lake.tables[3]
    sc = blend.sc(list(t.columns[0][:8]), k=20)
    mc = blend.mc([(t.columns[0][r], t.columns[1][r]) for r in range(4)],
                  k=20)
    assert s.query((mc & sc).top(10)).cache.status == "hit"
    assert s.sql(q.to_sql()).cache.status == "hit"


def test_partial_hit_reuses_shared_seeker_bit_identically(cached_session):
    s = cached_session
    pool = query_pool(s.lake)
    s.query(pool[0])                        # warms sc (and mc) unrestricted?
    r = s.query(pool[1])                    # shares the sc leaf
    assert r.cache.status in ("partial", "miss", "hit")
    cold = blend.connect(s.lake)
    for q in pool:
        assert s.query(q).ids == cold.query(q).ids


def test_optimize_flag_is_part_of_the_result_key():
    lake = cache_lake(seed=9)
    s = blend.connect(lake, cache=True)
    q = query_pool(lake)[0]
    r_opt = s.query(q)
    r_no = s.query(q, optimize=False)
    assert r_no.cache.status != "hit"       # B-NO gets its own entry
    assert s.query(q, optimize=False).cache.status == "hit"
    assert r_no.ids == r_opt.ids            # (and both are correct)


def test_plan_cache_memoizes_compilation(cached_session):
    s = cached_session
    q = query_pool(s.lake)[2]
    c1 = s.compile(q, top=10)
    assert s.compile(q, top=10) is c1             # memoized by content
    assert s.compile(q, top=7) is not c1          # top is part of the key
    sql = q.to_sql()
    assert s.compile(sql) is s.compile(sql)


def test_legacy_plan_queries_share_cache_entries(cached_session):
    s = cached_session
    t = s.lake.tables[6]
    plan = Plan()
    plan.add("a", Seekers.SC(list(t.columns[0][:6]), k=20))
    plan.add("b", Seekers.KW([t.columns[1][0]], k=20))
    plan.add("out", Combiners.Union(k=10), ["a", "b"])
    r1 = s.query(plan)
    flipped = Plan()
    flipped.add("b", Seekers.KW([t.columns[1][0]], k=20))
    flipped.add("a", Seekers.SC(list(t.columns[0][:6]), k=20))
    flipped.add("out", Combiners.Union(k=10), ["b", "a"])
    r2 = s.query(flipped)
    assert r2.cache.status == "hit" and r2.ids == r1.ids


# --------------------------------------------------------------------------
# epoch invalidation (mutations never serve stale ids)
# --------------------------------------------------------------------------

def rebuild_ids(session, tables_by_tid, q):
    """Expected ids from a cold from-scratch rebuild of the live tables,
    mapped back to the session's stable table ids."""
    live = session.live.live_ids()
    ref = blend.Session(Executor(build_index(
        DataLake([tables_by_tid[t] for t in live]))))
    return [live[i] for i in ref.query(q).ids]


def test_mutation_invalidation_bit_identical_to_cold_rebuild():
    """Acceptance: the mutation-invalidation workload returns bit-identical
    table ids to a cold rebuild after every add/drop/compact."""
    lake = cache_lake(seed=21)
    s = blend.connect(lake, live=True, cache=True)
    tbl = dict(enumerate(lake.tables))
    pool = query_pool(lake)
    for q in pool:
        s.query(q)
    assert all(s.query(q).cache.status == "hit" for q in pool)

    t0 = extra_table(0)
    tbl[s.add_table(t0)] = t0
    r = s.query(pool[0])
    assert r.cache.status != "hit"                 # epoch moved: invalidated
    for q in pool:
        assert s.query(q).ids == rebuild_ids(s, tbl, q)

    victim = s.query(pool[0]).ids[0]
    s.drop_table(victim)
    del tbl[victim]
    for q in pool:
        ids = s.query(q).ids
        assert victim not in ids                   # never a stale id
        assert ids == rebuild_ids(s, tbl, q)

    s.compact()
    for q in pool:
        assert s.query(q).ids == rebuild_ids(s, tbl, q)
    assert s.cache.invalidations >= 3


def test_interleaved_queries_and_mutations_match_cold_session():
    """Deterministic interleaving (the hypothesis property below, runnable
    without hypothesis): cached and cold sessions over the same store agree
    at every epoch."""
    lake = cache_lake(seed=31, n_tables=10)
    ll = LiveLake(lake)
    cached = blend.connect(ll, live=True, cache=True)
    cold = blend.connect(ll, live=True)
    pool = query_pool(lake, k=12)
    script = ["q0", "q1", "add", "q0", "q2", "drop", "q0", "q3", "compact",
              "q1", "q0", "add", "q3", "q3"]
    n_added = 0
    for step in script:
        if step == "add":
            cached.add_table(extra_table(10 + n_added))
            n_added += 1
        elif step == "drop":
            cached.drop_table(sorted(ll.live_ids())[0])
        elif step == "compact":
            cached.compact()
        else:
            q = pool[int(step[1:])]
            assert cached.query(q).ids == cold.query(q).ids, step
    st_ = cached.cache.stats()
    assert st_["hits"] > 0 and st_["invalidations"] > 0


@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(st.lists(st.tuples(st.sampled_from(["query", "add", "drop",
                                           "compact"]),
                          st.integers(0, 10 ** 6)),
                min_size=2, max_size=8))
def test_property_cache_parity_under_random_interleaving(ops):
    """Property: ANY interleaving of queries and LiveLake mutations yields
    identical results with the cache enabled vs a cold engine at every
    epoch."""
    lake = cache_lake(seed=41, n_tables=10)
    ll = LiveLake(lake)
    cached = blend.connect(ll, live=True, cache=True)
    cold = blend.connect(ll, live=True)
    pool = query_pool(lake, k=12)
    for i, (op, arg) in enumerate(ops):
        if op == "add":
            cached.add_table(extra_table(50 + arg % 40, rows=6 + arg % 9))
        elif op == "drop" and len(ll.live_ids()) > 4:
            live = sorted(ll.live_ids())
            cached.drop_table(live[arg % len(live)])
        elif op == "compact":
            cached.compact(full=arg % 2 == 0)
        else:
            q = pool[arg % len(pool)]
            assert cached.query(q).ids == cold.query(q).ids, (i, op)
    for q in pool:                                  # final epoch, full pool
        assert cached.query(q).ids == cold.query(q).ids


# --------------------------------------------------------------------------
# LRU byte budget
# --------------------------------------------------------------------------

def test_shared_cache_keys_on_executor_config_and_cost_model():
    """Entries produced under one executor configuration or cost model are
    never served to a session running another (different capacity ladders /
    seeker rankings are different computations)."""
    from repro.core.cost_model import train_cost_model
    lake = cache_lake(seed=101, n_tables=8)
    ll = LiveLake(lake)
    qc = QueryCache()
    q = query_pool(lake, k=8)[0]
    s1 = blend.connect(ll, live=True, cache=qc)
    s1.query(q)
    assert s1.query(q).cache.status == "hit"
    s2 = blend.connect(ll, live=True, cache=qc, m_cap_max=64)
    r = s2.query(q)                       # same store+epoch, other ladder
    assert r.cache.status != "hit"
    assert r.ids == blend.connect(ll, live=True, m_cap_max=64).query(q).ids
    # swapping the cost model reorders execution groups: entries invalidate
    s2.query(q)
    assert s2.query(q).cache.status == "hit"
    s2.cost_model = train_cost_model(s2.executor, lake, n_samples=4)
    assert s2.query(q).cache.status != "hit"


def test_lru_eviction_under_byte_budget():
    lake = cache_lake(seed=51)
    # budget fits only a couple of entries per level
    s = blend.connect(lake, cache=QueryCache(max_bytes=2000))
    pool = query_pool(lake)
    for q in pool:
        s.query(q)
    assert s.cache.resident_bytes <= 2000
    assert s.cache.evictions > 0
    for q in pool:                  # correctness survives any eviction state
        cold = blend.connect(lake)
        assert s.query(q).ids == cold.query(q).ids


def test_oversized_entries_are_refused_not_evicting_everything():
    cache = QueryCache(max_bytes=1000)            # 500 bytes per level
    cache.put_seeker("big", object(), 0, n_tables=10 ** 6)
    assert len(cache.seekers) == 0 and cache.seekers.bytes == 0
    cache.put_seeker("ok", object(), 0, n_tables=1)
    assert len(cache.seekers) == 1


def test_connect_cache_argument_forms():
    lake = cache_lake(seed=61, n_tables=6)
    assert blend.connect(lake).cache is None
    assert isinstance(blend.connect(lake, cache=True).cache, QueryCache)
    s = blend.connect(lake, cache=1 << 16)
    assert s.cache.results.max_bytes + s.cache.seekers.max_bytes == 1 << 16
    qc = QueryCache()
    assert blend.connect(lake, cache=qc).cache is qc


# --------------------------------------------------------------------------
# serving integration: telemetry + drain accounting
# --------------------------------------------------------------------------

def test_discovery_engine_cache_telemetry_and_drain_exclusion():
    lake = cache_lake(seed=71)
    eng = DiscoveryEngine(lake, cache=True)
    pool = query_pool(lake)
    cold = eng.serve(pool[0])
    assert cold.cache is not None and cold.cache["status"] != "hit"
    hit = eng.serve(pool[0])
    assert hit.cache["status"] == "hit" and hit.table_ids == cold.table_ids

    # batch: warmed requests are zero-dispatch; the one cold request pays
    # the drain, the hits do not
    batch = eng.serve_many([pool[0], pool[0], pool[1]])
    assert batch[0].cache["status"] == "hit"
    assert batch[1].cache["status"] == "hit"
    assert batch[2].cache["status"] != "hit"
    assert batch[0].table_ids == batch[1].table_ids == cold.table_ids
    assert max(batch[0].seconds, batch[1].seconds) < batch[2].seconds
    # fully-warmed batch: nothing dispatches, everything still answers
    first = eng.serve_many(pool)
    again = eng.serve_many(pool)
    assert all(b.cache["status"] == "hit" for b in again)
    assert [b.table_ids for b in again] == [b.table_ids for b in first]

    with pytest.raises(ValueError, match="cache"):
        DiscoveryEngine(lake, session=eng.session, cache=True)


def test_sync_false_hit_does_not_block_and_batch_dup_is_served():
    """serve_many([q, q]) on a cold cache: the duplicate hits the entry the
    first request stored moments earlier (still undrained) — the hit must
    not sync inside the dispatch loop, and both answers must agree."""
    lake = cache_lake(seed=91)
    eng = DiscoveryEngine(lake, cache=True)
    q = query_pool(lake)[0]
    r1, r2 = eng.serve_many([q, q])
    assert r2.cache["status"] == "hit"
    assert r1.table_ids == r2.table_ids
    assert r2.seconds < r1.seconds          # no drain share, no hidden sync
    # the lazily-materialized ids were written back into the entry
    s = eng.session
    entry = s.cache.get_result(s.cache.result_key(s.compile(q).plan, True))
    assert entry.ids == r1.table_ids


def test_drain_exclusion_predicate():
    class R:
        def __init__(self, cache):
            self.cache = cache

    class C:
        def __init__(self, status, runs):
            self.status, self.seekers_run = status, runs

    assert DiscoveryEngine._dispatched(R(None))                 # cache off
    assert DiscoveryEngine._dispatched(R(C("miss", 2)))
    assert DiscoveryEngine._dispatched(R(C("partial", 1)))
    assert not DiscoveryEngine._dispatched(R(C("hit", 0)))
    # all seekers cached but the combiners still enqueued device work:
    # the request keeps its drain share
    assert DiscoveryEngine._dispatched(R(C("partial", 0)))


def test_explain_renders_cache_section(cached_session):
    s = cached_session
    q = query_pool(s.lake)[3]
    s.query(q)
    ex = s.explain(q)
    assert ex.cache and ex.cache["status"] == "hit"
    text = str(ex)
    assert "== cache ==" in text and "status: hit" in text
    # cache off: no section
    off = blend.connect(s.lake)
    t2 = str(off.explain(blend.kw(["tok_1"], k=5)))
    assert "== cache ==" not in t2


def test_repeat_query_latency_much_faster_than_cold(cached_session):
    """Supports the BENCH_4 acceptance: repeat-query p50 is far below cold
    p50 (asserted loosely here; the full 10x criterion is measured on the
    benchmark lake by benchmarks/run_all.py)."""
    import time
    s = cached_session
    q = query_pool(s.lake)[2]          # 3-seeker counter query
    s.query(q)                         # warm jit + cache

    def p50(fn, n=15):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return float(np.percentile(ts, 50))

    hit = p50(lambda: s.query(q).ids)
    assert s.query(q).cache.status == "hit"

    def cold():
        s.cache.clear()
        return s.query(q).ids

    miss = p50(cold)
    s.cache.clear()
    assert miss / hit >= 3, (miss, hit)
