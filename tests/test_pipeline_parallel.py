"""Pipeline-parallel wrapper == sequential stage application (subprocess:
needs multiple host devices)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import compat_make_mesh
    from repro.dist.pipeline import pipeline_apply, bubble_fraction

    mesh = compat_make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)), jnp.float32)
    bs = jnp.asarray(rng.normal(0, 0.1, (n_stages, d)), jnp.float32)
    xs = jnp.asarray(rng.normal(0, 1, (n_micro, mb, d)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    got = pipeline_apply(stage_fn, {"w": ws, "b": bs}, xs, mesh=mesh,
                         axis="stage")
    # sequential reference
    want = xs
    for s in range(n_stages):
        want = jnp.tanh(want @ ws[s] + bs[s])
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, err
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
    print("PIPELINE_OK", err)
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout
