"""Unit tests for the trip-count-aware HLO analyzer (the §Roofline source)."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H

HLO = textwrap.dedent("""
    HloModule test, num_partitions=4

    %body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
      %p = (s32[], f32[64,64]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      %w = f32[64,64]{1,0} constant({...})
      %dot.1 = f32[64,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
      ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%i2, %ar)
    }

    %cond (p2: (s32[], f32[64,64])) -> pred[] {
      %p2 = (s32[], f32[64,64]{1,0}) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(7)
      ROOT %lt = pred[] compare(%i3, %n), direction=LT
    }

    ENTRY %main (a: f32[64,64]) -> f32[64,64] {
      %a = f32[64,64]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[64,64]{1,0}) tuple(%zero, %a)
      %while.1 = (s32[], f32[64,64]{1,0}) while(%init), condition=%cond, body=%body
      %dot.2 = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %out = f32[64,64]{1,0} get-tuple-element(%while.1), index=1
    }
""")


def test_trip_count_multiplication():
    a = H.analyze(HLO)
    per_dot = 2 * 64 * 64 * 64
    # dot.1 runs 7x (while trip count), dot.2 once
    assert a["flops"] == 8 * per_dot
    # all-reduce inside the loop: 7 x result bytes
    assert a["collective_bytes"]["all-reduce"] == 7 * 64 * 64 * 4


def test_known_trip_count_backend_config():
    txt = HLO.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}')
    a = H.analyze(txt)
    per_dot = 2 * 64 * 64 * 64
    assert a["flops"] == 13 * per_dot       # backend_config wins over the cond


def test_roofline_term_conventions():
    analysis = {"flops": 197e12, "hbm_bytes": 819e9,
                "collective_bytes": {"all-reduce": 25e9, "all-gather": 50e9,
                                     "reduce-scatter": 1e9, "all-to-all": 0,
                                     "collective-permute": 0},
                "collective_bytes_total": 76e9}
    t = H.roofline_terms(analysis, chips=4, link_bw=50e9)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    # 2x25 + 50 + 4x1 = 104 GB over 50 GB/s
    assert abs(t["collective_s"] - 104e9 / 50e9) < 1e-9


def test_against_real_compiled_module():
    """Cross-check the parser against a real XLA-compiled scan: flops must
    scale linearly with the scan length (which cost_analysis gets wrong)."""
    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    x = jnp.ones((32, 32))
    flops = {}
    for L in (2, 8):
        ws = jnp.ones((L, 32, 32))
        txt = jax.jit(f).lower(x, ws).compile().as_text()
        flops[L] = H.analyze(txt)["flops"]
    per = 2 * 32 * 32 * 32
    assert flops[2] == 2 * per
    assert flops[8] == 8 * per
