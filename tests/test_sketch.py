"""Statistical-coverage suite for the sketch tier (core/sketch.py).

The approximate discovery contract is *calibration*: every reported interval
``[ci_lo, ci_hi]`` must contain the exact score at least as often as the
nominal confidence says, with the brute-force oracle (tests/oracle.py) as
the referee.  Each estimator is exercised over >= 200 seeded trials — one
trial is one (seed, table) or one (seed, pair-of-sets) — and the empirical
coverage is asserted against the nominal level.  Everything is seeded, so
the measured coverage is a deterministic property of the estimator, not a
flaky sample.

Alongside calibration, the suite pins the two hard guarantees:

* the SC/KW bottom-k bounds ``bound_lo <= exact <= bound_hi`` hold
  *deterministically* (every trial, not just at confidence);
* ``approx={"epsilon": 0}`` returns ids identical to the exact path —
  every contended candidate escalates, so the ranking cannot move.
"""
import numpy as np
import pytest

import blend
from oracle import oracle_c, oracle_kw, oracle_sc
from repro.core import sketch as sk
from repro.core.executor import Executor
from repro.core.hashing import hash_array
from repro.core.index import build_index
from repro.core.lake import synthetic_lake
from repro.core.plan import Plan, Seekers

#: small sketches force the estimation regime (distinct counts >> k), so
#: coverage is measured on real extrapolation, not on degenerate intervals
SMALL = sk.SketchConfig(k=32, minhash_m=16, samples=48)

CONFIDENCE = 0.9
#: f32 kernels vs float64 oracle
TOL = 1e-4


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    """This module's many small lakes compile a lot of one-off program
    signatures; freeing them at teardown keeps the suite-wide XLA:CPU
    executable footprint at its pre-module level (the LLVM JIT segfaults
    late in the full run if compiled programs only ever accumulate)."""
    import jax
    yield
    jax.clear_caches()


def _probe(lake, spec, config=SMALL, confidence=CONFIDENCE):
    ex = Executor(build_index(lake, sketch_config=config))
    return ex.sketch_probe(spec, confidence=confidence)


# ---------------------------------------------------------------- containment
def _containment_trials(kind, oracle):
    covered, total, widths = 0, 0, []
    for seed in (0, 1, 2):
        lake = synthetic_lake(n_tables=50, rows=200, cols=3, vocab=4000,
                              seed=seed)
        rng = np.random.default_rng(seed + 100)
        for q in range(2):
            vals = [f"tok_{i}" for i in
                    rng.choice(4000, size=400, replace=False)]
            spec = (Seekers.SC(vals, k=10) if kind == "SC"
                    else Seekers.KW(vals, k=10))
            probe = _probe(lake, spec)
            truth = oracle(lake, vals)
            # the deterministic sandwich must hold in EVERY trial
            assert np.all(probe.bound_lo <= truth + TOL), (kind, seed, q)
            assert np.all(truth <= probe.bound_hi + TOL), (kind, seed, q)
            covered += int(np.sum((probe.ci_lo <= truth + TOL)
                                  & (truth <= probe.ci_hi + TOL)))
            total += lake.n_tables
            widths.append(float(np.mean(probe.ci_hi - probe.ci_lo)))
    return covered, total, float(np.mean(widths))


@pytest.mark.parametrize("kind,oracle", [("SC", oracle_sc),
                                         ("KW", oracle_kw)])
def test_containment_coverage(kind, oracle):
    covered, total, mean_width = _containment_trials(kind, oracle)
    assert total >= 200
    assert covered / total >= CONFIDENCE, \
        f"{kind}: {covered}/{total} = {covered / total:.3f} < {CONFIDENCE}"
    # the intervals must actually estimate (k=32 << ~195 distinct per col):
    # a degenerate all-exact run would vacuously pass the coverage bar
    assert mean_width > 1.0, f"{kind}: intervals degenerate ({mean_width})"


# ---------------------------------------------------------------- correlation
def test_correlation_coverage():
    covered = total = informative = 0
    for seed in range(5):
        lake = synthetic_lake(n_tables=40, rows=160, cols=4, vocab=50,
                              seed=seed, numeric_cols=2)
        rng = np.random.default_rng(seed + 200)
        jv = [f"tok_{i}" for i in rng.choice(50, size=15, replace=False)]
        tv = [float(x) for x in rng.normal(0, 1, len(jv)).round(3)]
        spec = Seekers.Correlation(jv, tv, k=10)
        probe = _probe(lake, spec)
        # rows <= h_sample: the oracle scores the full population, which is
        # exactly what the row-sample estimator targets
        truth = oracle_c(lake, jv, tv, h_sample=spec.h,
                         sampling=spec.sampling)
        covered += int(np.sum((probe.ci_lo <= truth + TOL)
                              & (truth <= probe.ci_hi + TOL)))
        total += lake.n_tables
        informative += int(np.sum(probe.ci_hi - probe.ci_lo < 0.999))
    assert total >= 200
    assert covered / total >= CONFIDENCE, \
        f"C: {covered}/{total} = {covered / total:.3f} < {CONFIDENCE}"
    # most tables must carry a real estimate (samples=48 < rows=160), not
    # the uninformative [0, 1] fallback
    assert informative / total > 0.5, f"C: only {informative}/{total} " \
        "informative intervals — the sample tier never engaged"


# ------------------------------------------------------- library estimators
def _kmv_of(values, k):
    h = np.unique(hash_array(values))
    return h[:k], int(min(len(h), k)), len(h)


def _tokens(rng, n):
    # random tokens, not sequential "v{i}" strings: FNV-1a's low order
    # statistics are visibly non-uniform on tiny sequential keys, which
    # would test the fixture universe rather than the estimator
    return [f"{x:012x}" for x in rng.integers(0, 1 << 48, size=n)]


def test_kmv_union_coverage():
    k, covered, total = 64, 0, 0
    rng = np.random.default_rng(7)
    for trial in range(250):
        na, nb, shared = (int(x) for x in rng.integers(50, 1200, 3))
        common = _tokens(rng, shared)
        a = common + _tokens(rng, na)
        b = common + _tokens(rng, nb)
        ka, ma, _ = _kmv_of(a, k)
        kb, mb, _ = _kmv_of(b, k)
        truth = len(np.union1d(hash_array(a), hash_array(b)))
        est, lo, hi = sk.kmv_union_size(ka, ma, kb, mb, k, confidence=0.95)
        covered += int(lo - TOL <= truth <= hi + TOL)
        total += 1
    assert total >= 200
    assert covered / total >= 0.95, f"{covered}/{total}"


def test_minhash_jaccard_coverage():
    m, covered, total = 128, 0, 0
    a_mh, b_mh = sk._minhash_params(seed=0, m=m)
    rng = np.random.default_rng(11)
    for trial in range(250):
        na, nb, shared = (int(x) for x in rng.integers(100, 800, 3))
        common = _tokens(rng, shared)
        ha = np.unique(hash_array(common + _tokens(rng, na)))
        hb = np.unique(hash_array(common + _tokens(rng, nb)))
        truth = len(np.intersect1d(ha, hb)) / len(np.union1d(ha, hb))

        def sig(h):
            u = h.astype(np.uint64)
            return ((a_mh[:, None] * u[None, :] + b_mh[:, None])
                    >> np.uint64(32)).min(axis=1)

        est, lo, hi = sk.minhash_jaccard(sig(ha), sig(hb), confidence=0.95)
        covered += int(lo - TOL <= truth <= hi + TOL)
        total += 1
    assert total >= 200
    assert covered / total >= 0.95, f"{covered}/{total}"


# ----------------------------------------------------- epsilon=0 exactness
def _id_lake(seed):
    return synthetic_lake(n_tables=30, rows=80, cols=4, vocab=300,
                          seed=seed, numeric_cols=2)


@pytest.mark.parametrize("kind", ["SC", "KW", "C"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_epsilon_zero_identical_ids(kind, seed):
    lake = _id_lake(seed)
    rng = np.random.default_rng(seed + 300)
    vals = [f"tok_{i}" for i in rng.choice(300, size=60, replace=False)]
    if kind == "C":
        spec = Seekers.Correlation(
            vals[:20], [float(x) for x in rng.normal(0, 1, 20)], k=8)
    else:
        spec = (Seekers.SC if kind == "SC" else Seekers.KW)(vals, k=8)
    ses = blend.connect(lake)
    p = Plan()
    p.add("out", spec)
    exact = ses.query(p)
    approx = ses.query(p, approx={"epsilon": 0.0})
    assert approx.ids == exact.ids
    assert approx.approx is not None
    np.testing.assert_array_equal(np.asarray(approx.result.scores),
                                  np.asarray(exact.result.scores))


def test_default_epsilon_reports_estimates():
    lake = _id_lake(5)
    vals = [f"tok_{i}" for i in range(0, 200, 2)]
    ses = blend.connect(lake)
    p = Plan()
    p.add("out", Seekers.SC(vals, k=8))
    res = ses.query(p, approx=True)
    info = res.approx
    assert info.estimator == "kmv-bottomk"
    assert info.candidates >= len(res.ids)
    for t in res.ids:
        est, lo, hi = info.interval(t)
        assert lo - TOL <= est <= hi + TOL
    d = info.as_dict(ids=res.ids)
    assert set(d["estimates"]) == set(res.ids)
    assert d["epsilon"] == 0.05 and d["confidence"] == 0.95


# ------------------------------------------------------------- escalation
def _fake_probe(lo, hi, sound=True):
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    est = (lo + hi) / 2
    return sk.SketchProbeResult(kind="SC", estimator="kmv-bottomk", est=est,
                                bound_lo=lo, bound_hi=hi, ci_lo=lo,
                                ci_hi=hi, sound=sound)


def test_escalation_set_semantics():
    # threshold = 2nd largest lower bound = 5; table 2 straddles it (hi 6,
    # wide), table 3 is provably below (hi 4), table 0/1 are degenerate
    probe = _fake_probe([8, 5, 3, 2], [8, 5, 6, 4])
    esc, cand, thresh = sk.escalation_set(probe, k=2,
                                          params=sk.ApproxParams(epsilon=0.0))
    assert thresh == 5.0
    assert list(esc) == [2]
    assert cand == 3          # tables 0, 1, 2 reach the bar
    # wide-but-hopeless tables never escalate
    probe = _fake_probe([8, 7, 0], [8, 7, 3])
    esc, _, _ = sk.escalation_set(probe, k=2, params=sk.ApproxParams(0.0))
    assert len(esc) == 0
    # epsilon tolerance: a straddler narrower than eps (relative) stays
    probe = _fake_probe([10, 9.8, 9.7], [10, 10.1, 9.9])
    esc, _, _ = sk.escalation_set(probe, k=2,
                                  params=sk.ApproxParams(epsilon=0.1))
    assert len(esc) == 0


def test_approx_params_normalization():
    assert sk.ApproxParams.of(False) is None
    assert sk.ApproxParams.of(None) is None
    assert sk.ApproxParams.of(True) == sk.ApproxParams()
    p = sk.ApproxParams.of({"epsilon": 0.1, "confidence": 0.99})
    assert (p.epsilon, p.confidence) == (0.1, 0.99)
    assert sk.ApproxParams.of(p) is p
    with pytest.raises(ValueError):
        sk.ApproxParams.of({"epsilon": 0.1, "bogus": 1})
    with pytest.raises(TypeError):
        sk.ApproxParams.of(0.5)


# ------------------------------------------------------------- fallbacks
def test_mc_and_multinode_fall_back_exact():
    lake = _id_lake(6)
    ses = blend.connect(lake)
    tuples = [(lake.tables[0].columns[0][r], lake.tables[0].columns[1][r])
              for r in range(6)]
    p = Plan()
    p.add("out", Seekers.MC(tuples, k=5))
    exact = ses.query(p)
    res = ses.query(p, approx=True)
    assert res.approx.fallback == "mc-no-estimator"
    assert res.ids == exact.ids
    vals = [f"tok_{i}" for i in range(50)]
    q = blend.sc(vals, k=8) & blend.kw(vals, k=8)
    res = ses.query(q, approx=True)
    assert res.approx.fallback == "multi-node-plan"
    assert res.ids == ses.query(q).ids


# ----------------------------------------------------------- determinism
def test_sketches_deterministic_and_seeded():
    lake = _id_lake(7)
    a = build_index(lake, sketch_config=SMALL)
    b = build_index(lake, sketch_config=SMALL)
    assert set(a.sketches) == set(b.sketches)
    for t in a.sketches:
        sa, sb = a.sketches[t], b.sketches[t]
        for name in ("kmv", "kmv_m", "tbl_kmv", "minhash", "samp_rows",
                     "samp_hash", "samp_quad"):
            np.testing.assert_array_equal(getattr(sa, name),
                                          getattr(sb, name), err_msg=name)
    c = build_index(lake, seed=9, sketch_config=SMALL)
    assert any(not np.array_equal(a.sketches[t].minhash,
                                  c.sketches[t].minhash)
               for t in a.sketches)
