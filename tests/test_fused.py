"""Fused execution: bit-identical parity with the unfused executor across
all four seekers and combiners on both probe backends and both store kinds,
oracle conformance, retrace-freedom within capacity buckets, query-cache
composition (cached seekers drop out of the fused batch), launch-count
observability, and a hypothesis property over random DAGs.

Ground truth is the unfused walk (itself anchored to tests/oracle.py): the
fused path's contract is *bit-identity*, so every assertion here is exact
array equality, never approximate.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import blend
from repro.core import seekers as seek
from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import Table, synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers
from repro.query import logical as L
from repro.serve.engine import DiscoveryEngine
from repro.store import LiveLake

from oracle import oracle_ids, oracle_run

N_TABLES = 24


@pytest.fixture(scope="module")
def lake():
    return synthetic_lake(n_tables=N_TABLES, rows=16, cols=4, vocab=300,
                          seed=11)


@pytest.fixture(scope="module")
def mutated_live(lake):
    """A live store with a delta segment and a tombstone (the fused path
    must fan out over segments and respect tombstones exactly)."""
    ll = LiveLake(lake, auto_compact=False)
    t = lake.tables[2]
    ll.add_table(Table("fx_extra", [[f"fx{i}" for i in range(10)],
                                    [t.columns[0][0]] * 10,
                                    [float(i) for i in range(10)]]))
    ll.drop_table(3)
    return ll


def seekers_for(lake, tab=2, k=12):
    t = lake.tables[tab]
    return {
        "sc": Seekers.SC(t.columns[0][:6], k=k),
        "kw": Seekers.KW([t.columns[1][0], t.columns[1][1]], k=k),
        "mc": Seekers.MC([(t.columns[0][r], t.columns[1][r])
                          for r in range(4)], k=k),
        "c": Seekers.Correlation(t.columns[0][:6],
                                 [float(i) for i in range(6)], k=k, h=64),
    }


def flat_plan(lake, comb, tab=2):
    """All four seekers feeding one combiner (difference nests two)."""
    p = Plan()
    for name, spec in seekers_for(lake, tab).items():
        p.add(name, spec)
    if comb == "difference":
        p.add("ab", Combiners.Intersect(k=16), ["sc", "kw"])
        p.add("cd", Combiners.Union(k=16), ["mc", "c"])
        p.add("root", Combiners.Difference(k=8), ["ab", "cd"])
    else:
        p.add("root", getattr(Combiners, comb.capitalize())(k=8),
              ["sc", "kw", "mc", "c"])
    return p


def deep_plan(lake, tab=2):
    """Every combiner kind + a shared seeker + a seeker-subtrahend rewrite
    in one DAG — the worst case for the instruction compiler."""
    p = Plan()
    for name, spec in seekers_for(lake, tab).items():
        p.add(name, spec)
    p.add("kw2", Seekers.KW([lake.tables[tab].columns[2][0]], k=12))
    p.add("and1", Combiners.Intersect(k=16), ["sc", "kw", "mc"])
    p.add("or1", Combiners.Union(k=16), ["sc", "c"])       # shares sc
    p.add("cnt", Combiners.Counter(k=16), ["and1", "or1"])
    p.add("root", Combiners.Difference(k=8), ["cnt", "kw2"])
    return p


def assert_bit_identical(ex, plan, optimize=True):
    a, ia = ex.run(plan, optimize=optimize)
    b, ib = ex.run(plan, optimize=optimize, fused=True)
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    assert ia.overflow == ib.overflow
    assert ia.order == ib.order
    return ia, ib


# --------------------------------------------------------------------------
# parity: 4 seekers x 4 combiners, optimized + naive, both store kinds
# --------------------------------------------------------------------------

@pytest.mark.parametrize("optimize", [True, False])
@pytest.mark.parametrize("comb", ["intersect", "union", "counter",
                                  "difference"])
def test_fused_parity_sorted_static(lake, comb, optimize):
    ex = Executor(build_index(lake))
    ia, ib = assert_bit_identical(ex, flat_plan(lake, comb), optimize)
    assert ib.launches <= 4 + 1                 # n_kinds + 1
    assert ib.launches < ia.launches or ia.launches <= ib.launches == 5


@pytest.mark.parametrize("comb", ["intersect", "union", "counter",
                                  "difference"])
def test_fused_parity_sorted_live(lake, mutated_live, comb):
    ex = Executor(mutated_live.store)
    assert_bit_identical(ex, flat_plan(lake, comb))


@pytest.mark.parametrize("live", [False, True])
def test_fused_parity_bucket_backend(lake, mutated_live, live):
    idx = mutated_live.store if live else build_index(lake)
    ex = Executor(idx, backend="bucket", interpret=True)
    for optimize in (True, False):
        assert_bit_identical(ex, deep_plan(lake), optimize)


def test_fused_deep_dag_parity_and_launches(lake):
    ex = Executor(build_index(lake))
    ia, ib = assert_bit_identical(ex, deep_plan(lake))
    # 4 seeker kinds (sc+kw+kw2 share two groups: SC, KW, MC, C) + 1 DAG
    assert ib.launches <= 4 + 1
    assert ia.launches > ib.launches


def test_fused_same_kind_multiple_groups(lake):
    """Same-kind seekers with different static shape args (MC n_cols) are
    separate device programs: launches = n_groups + 1 and each group keeps
    its own node_seconds entry."""
    t = lake.tables[2]
    p = Plan()
    p.add("mc2", Seekers.MC([(t.columns[0][r], t.columns[1][r])
                             for r in range(4)], k=12))
    p.add("mc3", Seekers.MC([(t.columns[0][r], t.columns[1][r],
                              t.columns[2][r]) for r in range(4)], k=12))
    p.add("root", Combiners.Union(k=8), ["mc2", "mc3"])
    ex = Executor(build_index(lake))
    _, ib = assert_bit_identical(ex, p)
    assert ib.launches == 2 + 1                 # two MC groups + the DAG
    assert {"fused:MC/2", "fused:MC/3"} <= set(ib.node_seconds)


def test_fused_single_seeker_plan(lake):
    plan = Plan()
    plan.add("solo", seekers_for(lake)["sc"])
    ex = Executor(build_index(lake))
    _, ib = assert_bit_identical(ex, plan)
    assert ib.launches == 2                     # one group + the DAG top-k


# --------------------------------------------------------------------------
# oracle conformance
# --------------------------------------------------------------------------

def test_fused_matches_oracle(lake):
    ex = Executor(build_index(lake))
    for comb in ("intersect", "union", "counter", "difference"):
        plan = flat_plan(lake, comb)
        rs, _ = ex.run(plan, optimize=False, fused=True)
        scores, mask = oracle_run(lake, plan)
        assert [int(t) for t in rs.ids()] == oracle_ids(scores, mask)
        np.testing.assert_allclose(np.asarray(rs.scores), scores,
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# retrace-freedom within capacity / seeker-count buckets
# --------------------------------------------------------------------------

def test_fused_zero_retrace_within_buckets(lake):
    ex = Executor(build_index(lake))
    ex.run(deep_plan(lake, tab=2), fused=True)          # warm every program
    before = dict(seek.TRACE_COUNTS)
    for tab in (5, 9, 14):                              # new values, same shape
        ex.run(deep_plan(lake, tab=tab), fused=True)
    assert dict(seek.TRACE_COUNTS) == before
    assert before.get("DAG", 0) >= 1
    for kind in ("SC_seg", "KW_seg", "MC_seg", "C_seg"):
        assert before.get(kind, 0) >= 1


def test_fused_cut_free_combiner_k_none(lake):
    """Legacy cut-free plans (CombinerSpec k=None) run fused too."""
    from repro.core.plan import CombinerSpec
    ex = Executor(build_index(lake))
    t = lake.tables[2]
    for kind in ("union", "intersect", "counter"):
        p = Plan()
        p.add("sc", Seekers.SC(t.columns[0][:6], k=12))
        p.add("kw", Seekers.KW([t.columns[1][0]], k=12))
        p.add("root", CombinerSpec(kind, None), ["sc", "kw"])
        assert_bit_identical(ex, p)


def test_fused_batch_reorder_reuses_dag_programs(lake):
    """Batch rows are traced inputs: reshuffling a batch of known plan
    shapes must not recompile any DAG program."""
    session = blend.connect(lake)
    def qa(tab):
        t = lake.tables[tab]
        return (blend.sc(list(t.columns[0][:6]), k=12)
                & blend.kw([t.columns[1][0]], k=12)).top(8)
    def qb(tab):
        t = lake.tables[tab]
        return (blend.mc([(t.columns[0][r], t.columns[1][r])
                          for r in range(3)], k=12)
                | blend.kw([t.columns[1][0]], k=12)).top(8)
    session.query_many([qa(2), qb(4)], fused=True)
    before = dict(seek.TRACE_COUNTS)
    session.query_many([qb(6), qa(8)], fused=True)     # swapped order
    assert dict(seek.TRACE_COUNTS) == before


def test_fused_batch_dedupes_identical_seekers(lake):
    """Identical seekers across a batch collapse onto one batch row (the
    seeker-count bucket stays at the deduped width — observable as zero
    retrace vs the single-request run) and stay bit-identical."""
    session = blend.connect(lake)
    t = lake.tables[3]
    q = (blend.sc(list(t.columns[0][:6]), k=12)
         & blend.kw([t.columns[1][0]], k=12)).top(8)
    session.query_many([q], fused=True)
    before = dict(seek.TRACE_COUNTS)
    rs = session.query_many([q, q, q], fused=True)
    assert dict(seek.TRACE_COUNTS) == before           # nsp stayed 1
    cold = session.query(q)
    assert rs[0].ids == rs[1].ids == rs[2].ids == cold.ids


def test_fused_serve_many_zero_retrace(lake):
    engine = DiscoveryEngine(lake)
    def batch(tabs):
        return [(blend.sc(list(lake.tables[t].columns[0][:6]), k=12)
                 & blend.kw([lake.tables[t].columns[1][0]], k=12)).top(8)
                for t in tabs]
    engine.serve_many(batch((2, 4, 6)), fused=True)
    before = dict(seek.TRACE_COUNTS)
    engine.serve_many(batch((8, 10, 12)), fused=True)
    assert dict(seek.TRACE_COUNTS) == before


# --------------------------------------------------------------------------
# query-cache composition
# --------------------------------------------------------------------------

def test_fused_cached_seekers_drop_out_of_batch(lake):
    session = blend.connect(lake, cache=True)
    cold = blend.connect(lake)
    t = lake.tables[2]
    sc = blend.sc(list(t.columns[0][:8]), k=20)
    q1 = (sc | blend.kw([t.columns[1][0]], k=20)).top(10)
    q2 = (sc | blend.mc([(t.columns[0][r], t.columns[1][r])
                         for r in range(4)], k=20)).top(10)
    r1 = session.query(q1, fused=True)
    assert r1.cache.status == "miss" and r1.info.seeker_runs == 2
    r2 = session.query(q2, fused=True)                 # shares sc -> partial
    assert r2.cache.status == "partial"
    assert r2.info.cached_nodes and r2.info.seeker_runs == 1
    assert r2.ids == cold.query(q2).ids                # bit-identical to cold
    r3 = session.query(q2, fused=True)                 # exact-result hit
    assert r3.cache.status == "hit" and r3.ids == r2.ids


def test_fused_cache_epoch_invalidation(lake):
    session = blend.connect(lake, live=True, cache=True)
    t = lake.tables[2]
    q = (blend.sc(list(t.columns[0][:6]), k=20)
         & blend.kw([t.columns[1][0]], k=20)).top(10)
    session.query(q, fused=True)
    tid = session.add_table(Table("fx_inv", [[t.columns[0][0], "zq1"],
                                             ["zq2", "zq3"]]))
    r = session.query(q, fused=True)                   # epoch moved: cold
    assert r.cache.status == "miss"
    cold = blend.connect(session.live, live=True)
    assert r.ids == cold.query(q).ids
    session.drop_table(tid)


# --------------------------------------------------------------------------
# serve_many fused batching
# --------------------------------------------------------------------------

def test_fused_serve_many_parity_and_launches(lake):
    engine = DiscoveryEngine(lake)
    rng = np.random.default_rng(0)
    from examples.serve_discovery import build_request
    kinds = ["imputation", "union", "enrichment"]
    reqs = [build_request(lake, rng, kinds[i % 3]) for i in range(6)]
    unfused = engine.serve_many(reqs)
    fused = engine.serve_many(reqs, fused=True)
    for a, b in zip(unfused, fused):
        assert a.table_ids == b.table_ids
        assert a.overflow == b.overflow
        assert 0 < b.launches <= 4 + 1
        assert b.launches <= a.launches


# --------------------------------------------------------------------------
# launches observability
# --------------------------------------------------------------------------

def test_launches_surfaced_in_response_and_explain(lake):
    session = blend.connect(lake)
    t = lake.tables[2]
    q = (blend.sc(list(t.columns[0][:6]), k=12)
         & blend.kw([t.columns[1][0]], k=12)).top(8)
    engine = DiscoveryEngine(lake, session=session)
    r_u = engine.serve(q)
    r_f = engine.serve(q, fused=True)
    assert r_u.launches >= 3                    # 2 seekers + combiner
    assert r_f.launches == 3                    # SC group + KW group + DAG
    assert r_f.table_ids == r_u.table_ids
    text = str(session.explain(q, fused=True))
    assert "launches: 3" in text


# --------------------------------------------------------------------------
# satellite: hash-memo eviction keeps the newest half
# --------------------------------------------------------------------------

def test_hash_cache_evicts_oldest_half(lake):
    ex = Executor(build_index(lake))
    ex._hash_cache.clear()
    ex._hash_cache_max = 8
    ex._hash_many([f"old{i}" for i in range(6)])
    ex._hash_many([f"new{i}" for i in range(3)])       # 9 entries > 8
    h = ex._hash_many(["probe"])                       # triggers eviction
    assert len(ex._hash_cache) == 9 // 2 + 1 + 1       # kept half + probe
    assert "new2" in ex._hash_cache                    # newest survive
    assert "old0" not in ex._hash_cache                # oldest evicted
    # evicted values re-hash to the same value (pure function)
    from repro.core.hashing import hash_value
    assert ex._hash_many(["old0"])[0] == hash_value("old0")
    assert h[0] == hash_value("probe")


# --------------------------------------------------------------------------
# property: random DAGs stay bit-identical on the fused path
# --------------------------------------------------------------------------

@st.composite
def plan_trees(draw):
    kinds = ["sc", "kw", "mc", "c"]
    tab = draw(st.integers(0, 7))
    depth = draw(st.integers(1, 3))

    def build(d):
        if d == 0:
            return ("leaf", draw(st.sampled_from(kinds)))
        op = draw(st.sampled_from(["and", "or", "sub", "counter", "leaf"]))
        if op == "leaf":
            return ("leaf", draw(st.sampled_from(kinds)))
        if op == "sub":
            return ("sub", build(d - 1), build(d - 1))
        n = draw(st.integers(2, 3))
        return (op, *[build(d - 1) for _ in range(n)])

    return tab, build(depth)


def _materialize(tree, lake, tab):
    kind = tree[0]
    if kind == "leaf":
        cols = lake.tables[tab].columns
        return {"sc": blend.sc(list(cols[0][:6]), k=12),
                "kw": blend.kw([cols[1][0], cols[1][2]], k=12),
                "mc": blend.mc([(cols[0][r], cols[1][r]) for r in range(3)],
                               k=12),
                "c": blend.corr(list(cols[0][:8]),
                                list(map(float, range(8))), k=12)}[tree[1]]
    kids = [_materialize(c, lake, tab) for c in tree[1:]]
    if kind in ("and", "or"):
        uniq = list(dict.fromkeys(kids))
        if len(uniq) == 1:
            return uniq[0]
        return (L.And if kind == "and" else L.Or)(tuple(uniq))
    if kind == "sub":
        return L.Sub(kids[0], kids[1])
    return L.Counter(tuple(kids))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_property_random_dag_fused_parity(lake, data):
    session = blend.connect(lake)
    tab, tree = data.draw(plan_trees())
    e = _materialize(tree, lake, tab)
    if isinstance(e, L.Seek):
        e = e & (e | e)
    for optimize in (True, False):
        a = session.query(e, optimize=optimize)
        b = session.query(e, optimize=optimize, fused=True)
        np.testing.assert_array_equal(np.asarray(a.scores),
                                      np.asarray(b.scores))
        np.testing.assert_array_equal(np.asarray(a.result.mask),
                                      np.asarray(b.result.mask))
        assert a.ids == b.ids
