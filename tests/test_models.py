"""Per-arch reduced-config smoke tests + decode/parallel equivalence.

Every assigned architecture instantiates a REDUCED config of the same family
and runs one forward/train step on CPU asserting output shapes and finiteness;
the FULL configs are exercised via the dry-run only (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.models import lm, registry
from repro.train.step import make_prefill_step, make_serve_step, \
    make_train_state, make_train_step

SMALL = ShapeConfig("small", 64, 2, "train")
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    state = make_train_state(cfg, KEY)
    batch = registry.make_batch(cfg, SMALL, KEY)
    step = jax.jit(make_train_step(cfg))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    # params changed and stayed finite
    l0 = jax.tree.leaves(state2["params"])[0]
    assert bool(jnp.all(jnp.isfinite(l0)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    params = registry.init_params(cfg, KEY)
    batch = registry.make_batch(cfg, SMALL, KEY)
    pre = jax.jit(make_prefill_step(cfg, max_len=SMALL.seq_len + 8))
    cache, tok = pre(params, batch)
    dec = jax.jit(make_serve_step(cfg))
    for _ in range(2):
        cache, tok, logits = dec(params, cache, tok)
    assert tok.shape == (SMALL.global_batch,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == SMALL.seq_len + 2


@pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-1.3b", "zamba2-7b"])
def test_decode_equals_parallel(arch):
    """Greedy decode logits == full-sequence forward logits (cache
    correctness for attention, mLSTM recurrence and the mamba2 hybrid)."""
    S, S0 = 32, 16
    cfg = reduced(get_config(arch), seq_hint=S).replace(remat=False)
    params = registry.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, S), 0, cfg.vocab, jnp.int32)
    x = lm.embed_tokens(params, cfg, tokens)
    hidden, _, _ = lm.forward_hidden(params, cfg, x)
    full_logits = lm.logits_fn(params, cfg, hidden)
    cache, last = lm.prefill(params, cfg, tokens[:, :S0], max_len=S)
    logits_seq = [last]
    dec = registry.decode_fn(cfg)
    for t in range(S0, S - 1):
        cache, lg = dec(params, cache, tokens[:, t])
        logits_seq.append(lg)
    got = jnp.stack(logits_seq, axis=1)
    want = full_logits[:, S0 - 1:S - 1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_moe_decode_equals_parallel_with_capacity():
    """MoE matches when capacity is high enough to avoid drops; the delta at
    low capacity is the documented capacity-dropping semantics."""
    S, S0 = 32, 16
    cfg = reduced(get_config("arctic-480b"), seq_hint=S).replace(
        remat=False, capacity_factor=16.0)
    params = registry.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, S), 0, cfg.vocab, jnp.int32)
    x = lm.embed_tokens(params, cfg, tokens)
    hidden, _, _ = lm.forward_hidden(params, cfg, x)
    full_logits = lm.logits_fn(params, cfg, hidden)
    cache, last = lm.prefill(params, cfg, tokens[:, :S0], max_len=S)
    got = [last]
    dec = registry.decode_fn(cfg)
    for t in range(S0, S - 1):
        cache, lg = dec(params, cache, tokens[:, t])
        got.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(got, 1)),
                               np.asarray(full_logits[:, S0 - 1:S - 1]),
                               atol=2e-4)


def test_attention_block_skip_equivalence():
    """Triangular (block-skip) attention == rectangular masked attention."""
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 32)), jnp.float32)
    a = chunked_attention(q, k, v, q_chunk=64, kv_chunk=64, causal=True,
                          block_skip=False)
    b = chunked_attention(q, k, v, q_chunk=64, kv_chunk=64, causal=True,
                          block_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_gla_chunk_invariance():
    """chunked_gla result is independent of chunk size (exact recurrence)."""
    from repro.models.ssm import chunked_gla
    rng = np.random.default_rng(1)
    B, S, H, dk, dv = 2, 64, 2, 8, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dv)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(0, 0.1, (B, S, H))), jnp.float32)
    y1, s1 = chunked_gla(q, k, v, a, chunk=8)
    y2, s2 = chunked_gla(q, k, v, a, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_grad_accum_equivalence():
    """accum=2 gives (numerically) the same update as accum=1."""
    cfg = reduced(get_config("smollm-360m")).replace(remat=False)
    batch = registry.make_batch(cfg, ShapeConfig("s", 32, 4, "train"), KEY)
    s1 = make_train_state(cfg, KEY)
    s2 = jax.tree.map(jnp.copy, s1)
    st1, m1 = jax.jit(make_train_step(cfg))(s1, batch)
    cfg2 = cfg.replace(grad_accum=2)
    st2, m2 = jax.jit(make_train_step(cfg2))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    a = jax.tree.leaves(st1["params"])[-1]
    b = jax.tree.leaves(st2["params"])[-1]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)
