"""Serving front tier correctness: server-batched responses bit-identical
to sequential ``serve`` (ids AND scores) across all four seekers + combiner
DAGs on static / live / sharded stores and both probe backends; mutation
barriers under concurrent traffic; admission control (rate limits, bounded
queues, typed Overloaded); telemetry (queue_seconds, batch_size, stats,
explain); the asyncio façade; and a hypothesis property interleaving
queries with mutations against the brute-force oracle."""
import threading

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import blend
from oracle import oracle_ids, oracle_run
from repro.core.lake import DataLake, Table, synthetic_lake
from repro.serve.batching import BATCH, INTERACTIVE
from repro.serve.engine import DiscoveryEngine
from repro.serve.loadgen import (Trace, TraceEvent, make_trace,
                                 mutation_table, query_pool, replay,
                                 zipf_qids)
from repro.serve.server import (AsyncDiscoveryServer, DiscoveryServer,
                                Overloaded)


def serving_lake(seed=9, n_tables=16):
    return synthetic_lake(n_tables=n_tables, rows=14, cols=4, vocab=200,
                          seed=seed)


def pool4(lake, k=20):
    """All four seekers and every combiner shape (the parity surface)."""
    t = lake.tables[3]
    sc = blend.sc(list(t.columns[0][:8]), k=k)
    kw = blend.kw([t.columns[1][0], t.columns[1][2]], k=k)
    mc = blend.mc([(t.columns[0][r], t.columns[1][r]) for r in range(4)], k=k)
    corr = blend.corr(list(t.columns[0][:8]),
                      [float(i) for i in range(8)], k=k, h=64)
    return [(sc & mc).top(10),
            (sc | corr).top(10),
            blend.counter(sc, kw, mc, k=10),
            (mc - kw).top(10),
            ((sc & kw) | corr).top(10)]


def extra_table(i, rows=10, vocab=200):
    rng = np.random.default_rng(3000 + i)
    return Table(f"srv_extra{i}",
                 [[f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [float(x) for x in np.round(rng.normal(0, 5, rows), 3)]])


def assert_responses_identical(got, want, ctx=""):
    assert got.table_ids == want.table_ids, ctx
    np.testing.assert_array_equal(np.asarray(got.scores),
                                  np.asarray(want.scores), err_msg=str(ctx))


# --------------------------------------------------------------------------
# bit-identical parity: server-batched vs sequential serve
# --------------------------------------------------------------------------

MODES = ["static", "live", "sharded"]
BACKENDS = [("sorted", False), ("bucket", True)]


def mode_engine(mode, lake, backend="sorted", interpret=False):
    if mode == "static":
        return DiscoveryEngine(lake, backend=backend, interpret=interpret)
    if mode == "live":
        return DiscoveryEngine(lake, live=True, backend=backend,
                               interpret=interpret)
    return DiscoveryEngine(lake, shards=2, live=True, backend=backend,
                           interpret=interpret)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_server_batched_matches_sequential(mode, backend, interpret):
    """The acceptance property: concurrent submissions coalesced into fused
    batches return ids and scores bit-identical to one-at-a-time serve, on
    every store mode and both probe backends."""
    lake = serving_lake()
    engine = mode_engine(mode, lake, backend=backend, interpret=interpret)
    pool = pool4(lake)
    want = [engine.serve(q, fused=True) for q in pool]
    server = DiscoveryServer(engine, max_batch=8,
                             interactive_window_s=0.02)
    try:
        futs = [server.submit(q) for q in pool]
        got = [f.result(timeout=120) for f in futs]
    finally:
        server.stop()
    assert max(r.batch_size for r in got) >= 2     # actually coalesced
    for q, g, w in zip(pool, got, want):
        assert_responses_identical(g, w, ctx=(mode, backend, q.to_sql()))


def test_concurrent_submitters_parity():
    """Many client threads hammering submit() concurrently: every response
    still matches its own sequential serve."""
    lake = serving_lake(seed=11)
    engine = DiscoveryEngine(lake, live=True)
    pool = pool4(lake)
    want = {i: engine.serve(q, fused=True) for i, q in enumerate(pool)}
    server = DiscoveryServer(engine, max_batch=16)
    results: dict = {}

    def client(tid):
        futs = [(i, server.submit(pool[i],
                                  lane=INTERACTIVE if i % 2 else BATCH,
                                  tenant=f"t{tid}"))
                for i in range(len(pool))]
        results[tid] = [(i, f.result(timeout=120)) for i, f in futs]

    try:
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
    finally:
        server.stop()
    assert len(results) == 4
    for tid, rs in results.items():
        for i, resp in rs:
            assert not isinstance(resp, Overloaded)
            assert_responses_identical(resp, want[i], ctx=(tid, i))


def test_mutation_barrier_epoch_consistency():
    """Queries before a mutation observe the old epoch, queries after it the
    new one — matching a sequential reference engine executing the same
    arrival order on its own identical store."""
    lake = serving_lake(seed=13)
    engine = DiscoveryEngine(lake, live=True)
    ref = DiscoveryEngine(lake, live=True)
    pool = pool4(lake)
    server = DiscoveryServer(engine, max_batch=8)
    try:
        pre = [server.submit(q) for q in pool]
        mut = server.add_table(extra_table(0))
        post = [server.submit(q) for q in pool]
        drop = server.drop_table(mut.result(timeout=120))
        final = [server.submit(q) for q in pool]

        want_pre = [ref.serve(q, fused=True) for q in pool]
        ref_tid = ref.add_table(extra_table(0))
        want_post = [ref.serve(q, fused=True) for q in pool]
        ref.drop_table(ref_tid)
        want_final = [ref.serve(q, fused=True) for q in pool]

        assert drop.result(timeout=120) == ref_tid
        for futs, wants in ((pre, want_pre), (post, want_post),
                            (final, want_final)):
            for f, w in zip(futs, wants):
                assert_responses_identical(f.result(timeout=120), w)
    finally:
        server.stop()
    assert server.stats()["mutations"]["executed"] == 2


def test_sharded_mutation_barrier_parity():
    lake = serving_lake(seed=17)
    engine = DiscoveryEngine(lake, shards=2, live=True)
    ref = DiscoveryEngine(lake, shards=2, live=True)
    q = pool4(lake)[0]
    server = DiscoveryServer(engine)
    try:
        server.add_table(extra_table(5)).result(timeout=120)
        ref.add_table(extra_table(5))
        assert_responses_identical(server.serve(q),
                                   ref.serve(q, fused=True))
    finally:
        server.stop()


# --------------------------------------------------------------------------
# hypothesis: interleaved queries + mutations vs the brute-force oracle
# --------------------------------------------------------------------------

def oracle_want(session, tables, q):
    """Expected ids for ``q`` over the current live tables, straight from
    the pure-NumPy oracle (add-only traffic keeps table ids positional)."""
    plan = session.compile(q).plan
    scores, mask = oracle_run(DataLake(tables=list(tables)), plan)
    return oracle_ids(scores, mask)


@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(st.lists(st.tuples(st.sampled_from(["query", "add", "compact"]),
                          st.integers(0, 10 ** 6)),
                min_size=2, max_size=7))
def test_property_server_matches_oracle_under_interleaving(ops):
    """Property: ANY interleaving of concurrent queries and mutations
    through the server yields oracle-exact ids at every epoch (queries are
    submitted unoptimized so the oracle's evaluation order applies)."""
    lake = serving_lake(seed=23, n_tables=10)
    engine = DiscoveryEngine(lake, live=True)
    pool = pool4(lake, k=12)
    tables = list(lake.tables)
    server = DiscoveryServer(engine, max_batch=8, optimize=False)
    try:
        checks = []                        # (future, expected ids, step)
        n_added = 0
        for i, (op, arg) in enumerate(ops):
            if op == "add":
                tab = extra_table(100 + n_added, rows=6 + arg % 7)
                n_added += 1
                server.add_table(tab)
                tables.append(tab)
            elif op == "compact":
                server.compact(full=arg % 2 == 0)
            else:
                q = pool[arg % len(pool)]
                want = oracle_want(engine.session, tables, q)
                checks.append((server.submit(q), want, (i, op)))
        for fut, want, step in checks:
            assert fut.result(timeout=120).table_ids == want, step
    finally:
        server.stop()


# --------------------------------------------------------------------------
# admission control: rate limits, backpressure, typed shedding
# --------------------------------------------------------------------------

def test_rate_limit_sheds_with_retry_after():
    lake = serving_lake(seed=29)
    clock = [0.0]
    server = DiscoveryServer(DiscoveryEngine(lake), rate=10.0, burst=2.0,
                             start=False, now=lambda: clock[0])
    q = pool4(lake)[0]
    a = server.submit(q, tenant="alice").done()
    b = server.submit(q, tenant="alice").done()
    shed = server.submit(q, tenant="alice").result()   # bucket empty
    assert not a and not b                 # admitted: still queued
    assert isinstance(shed, Overloaded)
    assert shed.reason == "rate_limit" and shed.tenant == "alice"
    assert shed.retry_after_s == pytest.approx(0.1)
    ok = server.submit(q, tenant="bob")    # other tenants unaffected
    assert not ok.done()
    clock[0] += 0.1                        # one token refilled
    assert not server.submit(q, tenant="alice").done()
    stats = server.stats()
    assert stats["shed"]["rate_limit"] == 1
    assert stats["shed"]["by_tenant"] == {"alice": 1}
    server.start()                         # drain the admitted requests
    server.stop()


def test_queue_full_sheds_and_bounds_depth():
    lake = serving_lake(seed=31)
    server = DiscoveryServer(DiscoveryEngine(lake), max_queue=2,
                             batch_max_queue=1, start=False)
    q = pool4(lake)[0]
    admitted = [server.submit(q, lane=INTERACTIVE) for _ in range(2)]
    shed = server.submit(q, lane=INTERACTIVE).result()
    assert isinstance(shed, Overloaded) and shed.reason == "queue_full"
    assert shed.lane == INTERACTIVE
    server.submit(q, lane=BATCH)
    shed_b = server.submit(q, lane=BATCH).result()
    assert isinstance(shed_b, Overloaded) and shed_b.lane == BATCH
    stats = server.stats()
    assert stats["queue_depth"][INTERACTIVE] == 2      # bounded, not grown
    assert stats["lane_occupancy"][INTERACTIVE]["utilization"] == 1.0
    assert stats["shed"]["queue_full"] == 2
    server.start()                         # backlog drains after start
    for f in admitted:
        assert f.result(timeout=120).table_ids
    server.stop()


# --------------------------------------------------------------------------
# telemetry
# --------------------------------------------------------------------------

def test_response_telemetry_and_stats():
    lake = serving_lake(seed=37)
    engine = DiscoveryEngine(lake)
    pool = pool4(lake)
    for q in pool:                         # warm jit before timing-ish bits
        engine.serve(q, fused=True)
    server = DiscoveryServer(engine, max_batch=8,
                             interactive_window_s=0.02)
    try:
        futs = [server.submit(q) for q in pool]
        got = [f.result(timeout=120) for f in futs]
    finally:
        server.stop()
    for r in got:
        assert r.queue_seconds > 0.0       # sat in the window
        assert r.batch_size == len(pool)
        assert r.launches > 0
    stats = server.stats()
    assert stats["served"] == len(pool)
    assert stats["batches"]["formed"] >= 1
    assert stats["batches"]["size_hist"][str(len(pool))] >= 1
    assert stats["launches"]["per_batch_mean"] > 0
    assert stats["launches"]["total"] >= stats["batches"]["formed"]


def test_explain_renders_server_section():
    lake = serving_lake(seed=41)
    server = DiscoveryServer(DiscoveryEngine(lake, live=True))
    try:
        q = pool4(lake)[0]
        server.serve(q)
        ex = server.explain(q)
    finally:
        server.stop()
    assert ex.server["served"] == 1
    text = str(ex)
    assert "== server ==" in text
    assert "queue depth" in text and "lane occupancy" in text
    assert "shed:" in text and "launches/batch" in text
    # plain session.explain stays server-free
    assert "== server ==" not in str(server.session.explain(q))


# --------------------------------------------------------------------------
# async façade
# --------------------------------------------------------------------------

def test_async_facade_parity():
    import asyncio
    lake = serving_lake(seed=43)
    engine = DiscoveryEngine(lake, live=True)
    pool = pool4(lake)
    want = [engine.serve(q, fused=True) for q in pool]

    async def run():
        async with AsyncDiscoveryServer(engine, max_batch=8) as server:
            tid = await server.add_table(extra_table(9))
            await server.drop_table(tid)
            out = await asyncio.gather(
                *[server.serve(q, tenant=f"t{i % 2}")
                  for i, q in enumerate(pool)])
            return out, server.stats()

    got, stats = asyncio.run(run())
    for g, w in zip(got, want):
        assert_responses_identical(g, w)
    assert stats["mutations"]["executed"] == 2


# --------------------------------------------------------------------------
# load generator determinism
# --------------------------------------------------------------------------

def test_trace_generation_deterministic():
    lake = serving_lake(seed=47)
    kw = dict(seed=5, duration_s=1.0, rate_rps=100.0, p_mutation=0.1)
    a = make_trace(lake, **kw)
    b = make_trace(lake, **kw)
    assert len(a.events) == len(b.events) > 10
    for ea, eb in zip(a.events, b.events):
        assert (ea.t, ea.kind, ea.tenant, ea.lane, ea.qid) == \
            (eb.t, eb.kind, eb.tenant, eb.lane, eb.qid)
        if ea.kind == "query":
            assert ea.payload.fingerprint() == eb.payload.fingerprint()
    assert make_trace(lake, seed=6, duration_s=1.0,
                      rate_rps=100.0).events[0].t != a.events[0].t
    # drops only ever name previously added tables
    alive = set()
    for ev in a.events:
        if ev.kind == "add":
            alive.add(ev.payload.name)
        elif ev.kind == "drop":
            assert ev.payload in alive
            alive.discard(ev.payload)


def test_zipf_mix_is_cache_friendly():
    rng = np.random.default_rng(0)
    qids = zipf_qids(rng, 24, 2000, a=1.1)
    counts = np.bincount(qids, minlength=24)
    assert counts[0] > counts[-1]          # head >> tail
    assert counts[0] > 2000 / 24 * 3


def test_replay_without_real_pacing():
    """Replay with injected no-op sleep: the whole trace submits instantly,
    metrics still line up with the server's own accounting."""
    lake = serving_lake(seed=53)
    engine = DiscoveryEngine(lake, live=True)
    trace = make_trace(lake, seed=3, duration_s=0.5, rate_rps=60.0,
                       n_distinct=6, k=12, p_mutation=0.1)
    server = DiscoveryServer(engine, max_batch=8)
    try:
        report = replay(server, trace, sleep=lambda s: None)
    finally:
        server.stop()
    n_queries = sum(1 for e in trace.events if e.kind == "query")
    n_muts = len(trace.events) - n_queries
    assert report.offered == n_queries
    assert report.completed + report.shed == n_queries
    assert report.mutations == n_muts
    assert report.completed == len(report.latencies_s)
    assert report.goodput_rps > 0
    d = report.as_dict()
    assert set(d["latency_ms"]) == {"p50", "p95", "p99"}
    assert d["batch_occupancy_hist"]


def test_replay_overload_sheds_but_serves_admitted():
    """A tiny-queue server under a no-pacing burst: some traffic shed with
    typed reasons, everything admitted still answered (bounded queues,
    no unbounded buildup)."""
    lake = serving_lake(seed=59)
    engine = DiscoveryEngine(lake)
    trace = make_trace(lake, seed=4, duration_s=0.5, rate_rps=400.0,
                       n_distinct=6, k=12)
    server = DiscoveryServer(engine, max_batch=4, max_queue=4,
                             batch_max_queue=2, start=False)
    try:
        submitted = [(ev, server.submit(ev.payload, lane=ev.lane,
                                        tenant=ev.tenant))
                     for ev in trace.events]
        sheds = [f.result() for _, f in submitted if f.done()]
        assert sheds and all(isinstance(s, Overloaded) for s in sheds)
        assert server.stats()["queue_depth"][INTERACTIVE] <= 4
        server.start()
        for _, f in submitted:
            out = f.result(timeout=120)
            assert isinstance(out, Overloaded) or out.table_ids is not None
    finally:
        server.stop()
