import sys
import types

import numpy as np
import pytest


def _install_hypothesis_stub():
    """Let the suite collect on images without hypothesis installed.

    Property tests import ``given/settings/strategies`` at module scope; with
    this stub they collect normally and individually skip (importorskip-style
    guard, but per-test instead of per-module so the non-property tests in the
    same files still run).
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        def map(self, f):
            return self

        def filter(self, f):
            return self

        def flatmap(self, f):
            return self

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: (lambda *a, **k: _Strategy())

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(see requirements-dev.txt)")

    def settings(*a, **k):
        return lambda fn: fn

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


@pytest.fixture(scope="session")
def small_lake():
    from repro.core.lake import synthetic_lake
    return synthetic_lake(n_tables=60, rows=24, cols=4, vocab=800, seed=7)


@pytest.fixture(scope="session")
def small_index(small_lake):
    from repro.core.index import build_index
    return build_index(small_lake)


@pytest.fixture(scope="session")
def small_executor(small_index):
    from repro.core.executor import Executor
    return Executor(small_index)


def brute_force_sc(lake, query_values):
    """Best single-column distinct overlap per table."""
    qs = set(query_values)
    out = np.zeros(lake.n_tables)
    for t, tab in enumerate(lake.tables):
        out[t] = max(len(qs & set(c)) for c in tab.columns)
    return out


def brute_force_kw(lake, query_values):
    qs = set(query_values)
    out = np.zeros(lake.n_tables)
    for t, tab in enumerate(lake.tables):
        allv = set()
        for c in tab.columns:
            allv |= set(c)
        out[t] = len(qs & allv)
    return out


def brute_force_mc(lake, tuples):
    """Tuples exactly joinable (all values in one row, any column order)."""
    out = np.zeros(lake.n_tables)
    for t, tab in enumerate(lake.tables):
        rows = [set(tab.row(r)) for r in range(tab.n_rows)]
        n = 0
        for tup in set(tuples):
            if any(all(v in row for v in tup) for row in rows):
                n += 1
        out[t] = n
    return out
