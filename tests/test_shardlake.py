"""Sharded-lake execution parity, in-process (device-modulo fallback: with
one visible CPU device the shards wrap round-robin, so the MPMD layout, the
per-shard capacity windows and the merge epilogue are all exercised without
a forced multi-device subprocess — tests/test_distributed.py covers the
real 8-device mesh).

Contract under test: an n-shard lake is **bit-identical** to a 1-shard lake
on the same data — across all four seekers, all four combiners, both probe
backends and both store kinds (static and mutated-live) — and conforms to
the brute-force oracle.  A hypothesis property interleaves shard-local
mutations with cached queries and checks every answer against a cold
n-shard AND a cold 1-shard session.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import blend
from repro.core.lake import Table, synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers
from repro.dist.shard import ShardedExecutor, ShardedStore
from repro.store import LiveLake

from oracle import oracle_ids, oracle_run

N_TABLES = 24


@pytest.fixture(scope="module")
def lake():
    return synthetic_lake(n_tables=N_TABLES, rows=16, cols=4, vocab=300,
                          seed=11)


def seekers_for(lake, tab=2, k=12):
    t = lake.tables[tab]
    return {
        "sc": Seekers.SC(t.columns[0][:6], k=k),
        "kw": Seekers.KW([t.columns[1][0], t.columns[1][1]], k=k),
        "mc": Seekers.MC([(t.columns[0][r], t.columns[1][r])
                          for r in range(4)], k=k),
        "c": Seekers.Correlation(t.columns[0][:6],
                                 [float(i) for i in range(6)], k=k, h=64),
    }


def flat_plan(lake, comb, tab=2):
    p = Plan()
    for name, spec in seekers_for(lake, tab).items():
        p.add(name, spec)
    if comb == "difference":
        p.add("ab", Combiners.Intersect(k=16), ["sc", "kw"])
        p.add("cd", Combiners.Union(k=16), ["mc", "c"])
        p.add("root", Combiners.Difference(k=8), ["ab", "cd"])
    else:
        p.add("root", getattr(Combiners, comb.capitalize())(k=8),
              ["sc", "kw", "mc", "c"])
    return p


def mutate(ll, lake):
    """One delta segment + one tombstone (same mutation on every store
    under comparison, so parity includes segment fan-out and tombstones)."""
    t = lake.tables[2]
    ll.add_table(Table("fx_extra", [[f"fx{i}" for i in range(10)],
                                    [t.columns[0][0]] * 10,
                                    [float(i) for i in range(10)]]))
    ll.drop_table(3)
    return ll


def executors(lake, n_shards, backend, live):
    out = []
    for n in (1, n_shards):
        store = ShardedStore(lake, n_shards=n)
        if live:
            mutate(LiveLake(lake, store=store, auto_compact=False), lake)
        out.append(ShardedExecutor(store, backend=backend,
                                   interpret=backend == "bucket"))
    return out


def assert_parity(ex1, exn, plan):
    a, ia = ex1.run(plan)
    b, ib = exn.run(plan)
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    assert [int(t) for t in a.ids()] == [int(t) for t in b.ids()]
    assert ia.overflow == 0 and ib.overflow == 0
    assert ib.launches <= 4 + 1                   # n_kinds + 1, sharded too
    return ia, ib


# --------------------------------------------------------------------------
# parity: 4 seekers x 4 combiners x both backends x static/live
# --------------------------------------------------------------------------

@pytest.mark.parametrize("comb", ["intersect", "union", "counter",
                                  "difference"])
@pytest.mark.parametrize("live", [False, True], ids=["static", "live"])
def test_shard_parity_sorted(lake, comb, live):
    ex1, ex3 = executors(lake, 3, "sorted", live)
    assert_parity(ex1, ex3, flat_plan(lake, comb))


@pytest.mark.parametrize("live", [False, True], ids=["static", "live"])
def test_shard_parity_bucket_backend(lake, live):
    ex1, ex2 = executors(lake, 2, "bucket", live)
    for comb in ("intersect", "union", "counter", "difference"):
        assert_parity(ex1, ex2, flat_plan(lake, comb))


def test_shard_single_seeker_launches(lake):
    ex1, ex4 = executors(lake, 4, "sorted", live=False)
    p = Plan()
    p.add("solo", seekers_for(lake)["sc"])
    _, ib = assert_parity(ex1, ex4, p)
    assert ib.launches == 2                       # one group + the DAG top-k


# --------------------------------------------------------------------------
# oracle conformance on a sharded lake
# --------------------------------------------------------------------------

def test_sharded_matches_oracle(lake):
    ex = ShardedExecutor(ShardedStore(lake, n_shards=4))
    for comb in ("intersect", "union", "counter", "difference"):
        plan = flat_plan(lake, comb)
        rs, _ = ex.run(plan, optimize=False)
        scores, mask = oracle_run(lake, plan)
        assert [int(t) for t in rs.ids()] == oracle_ids(scores, mask)
        np.testing.assert_allclose(np.asarray(rs.scores)[:N_TABLES], scores,
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# interleaved mutations + cached queries == cold n-shard == cold 1-shard
# --------------------------------------------------------------------------

def _extra(i, t):
    return Table(f"delta{i}", [[f"d{i}_{j}" for j in range(8)],
                               [t.columns[0][0]] * 8,
                               [float(j) for j in range(8)]])


def _run_trace(lake, ops):
    """Apply an (op, arg) trace to a cached 3-shard live session, checking
    every query against cold 3-shard and cold 1-shard replicas of the
    mutation history so far."""
    t = lake.tables[2]
    hot = blend.connect(lake, shards=3, live=True, cache=True)
    qs = {
        0: (blend.sc(list(t.columns[0][:6]), k=12)
            & blend.kw([t.columns[1][0]], k=12)).top(8),
        1: (blend.sc(list(t.columns[0][:6]), k=12)
            | blend.kw([t.columns[1][1]], k=12)).top(8),
        2: blend.mc([(t.columns[0][r], t.columns[1][r])
                     for r in range(4)], k=12).top(8),
    }
    history = []
    for step, (op, arg) in enumerate(ops):
        if op == "add":
            hot.add_table(_extra(step, t))
            history.append(("add", step))
        elif op == "drop":
            live = [i for i in hot.live.live_ids() if i != 2]
            tid = live[arg % len(live)]
            hot.drop_table(tid)
            history.append(("drop", tid))
        else:
            q = qs[arg % len(qs)]
            res = hot.query(q)
            cold3 = blend.connect(lake, shards=3, live=True)
            cold1 = blend.connect(lake, shards=1, live=True)
            for cold in (cold3, cold1):
                for h_op, h_arg in history:
                    if h_op == "add":
                        cold.add_table(_extra(h_arg, t))
                    else:
                        cold.drop_table(h_arg)
            r3, r1 = cold3.query(q), cold1.query(q)
            for ref in (r3, r1):
                np.testing.assert_array_equal(np.asarray(res.scores),
                                              np.asarray(ref.scores))
                assert res.ids == ref.ids
            assert res.info.overflow == 0


ops_strategy = st.lists(
    st.tuples(st.sampled_from(["add", "drop", "query"]), st.integers(0, 5)),
    min_size=2, max_size=6)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(ops_strategy)
def test_shard_mutation_query_property(ops):
    lake = synthetic_lake(n_tables=N_TABLES, rows=16, cols=4, vocab=300,
                          seed=11)
    _run_trace(lake, [op for op in ops] + [("query", 0)])


def test_shard_mutation_query_interleaving(lake):
    """Deterministic instance of the property (runs even where hypothesis
    is stubbed out): add/drop/query interleavings, cache on."""
    _run_trace(lake, [("query", 0), ("add", 0), ("query", 0), ("add", 1),
                      ("drop", 0), ("query", 1), ("query", 0), ("drop", 1),
                      ("query", 2), ("query", 0)])


def test_shard_cache_hits_after_mutation_settles(lake):
    session = blend.connect(lake, shards=3, live=True, cache=True)
    t = lake.tables[2]
    q = (blend.sc(list(t.columns[0][:6]), k=12)
         & blend.kw([t.columns[1][0]], k=12)).top(8)
    assert session.query(q).cache.status == "miss"
    assert session.query(q).cache.status == "hit"
    session.add_table(_extra(0, t))
    assert session.query(q).cache.status == "miss"   # epoch tuple moved
    assert session.query(q).cache.status == "hit"


# --------------------------------------------------------------------------
# sketch tier: 1-vs-N-shard approx parity
# --------------------------------------------------------------------------

def test_shard_sketch_probe_bit_identical(lake):
    """Per-shard sketch probes merged by elementwise sum == the 1-shard
    probe, bit-for-bit (every table's slots are nonzero on exactly one
    shard), on static and mutated-live stores."""
    specs = {k: v for k, v in seekers_for(lake).items() if k != "mc"}
    for live in (False, True):
        ex1, ex3 = executors(lake, 3, "sorted", live)
        for name, spec in specs.items():
            p1 = ex1.sketch_probe(spec)
            p3 = ex3.sketch_probe(spec)
            for f in ("est", "bound_lo", "bound_hi", "ci_lo", "ci_hi"):
                np.testing.assert_array_equal(
                    getattr(p1, f), getattr(p3, f),
                    err_msg=f"{name} live={live} field {f}")


def test_shard_approx_query_parity(lake):
    """Session-level approx answers are shard-count-invariant, and
    epsilon=0 stays id-identical to the exact path on a sharded lake."""
    t = lake.tables[2]
    ses1 = blend.connect(lake, shards=1)
    ses3 = blend.connect(lake, shards=3)
    for q in (blend.sc(list(t.columns[0][:6]), k=8),
              blend.kw([t.columns[1][0], t.columns[1][1]], k=8)):
        exact = ses3.query(q)
        for params in ({"epsilon": 0.0}, True):
            a1 = ses1.query(q, approx=params)
            a3 = ses3.query(q, approx=params)
            assert a1.ids == a3.ids
            np.testing.assert_array_equal(np.asarray(a1.scores),
                                          np.asarray(a3.scores))
        assert ses3.query(q, approx={"epsilon": 0.0}).ids == exact.ids
