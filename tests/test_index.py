"""Unified index invariants + hashing properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hashing
from repro.core.index import build_index
from repro.core.lake import synthetic_lake


def test_index_sorted_and_complete(small_lake, small_index):
    idx = small_index
    assert (np.diff(idx.cell_hash.astype(np.int64)) >= 0).all()
    total_cells = sum(t.n_rows * t.n_cols for t in small_lake.tables)
    assert idx.n_postings == total_cells
    # bucket offsets consistent with the hash prefix
    shift = 32 - idx.bucket_bits
    for b in (0, 5, (1 << idx.bucket_bits) - 1):
        s, e = idx.bucket_offsets[b], idx.bucket_offsets[b + 1]
        if e > s:
            assert ((idx.cell_hash[s:e] >> shift) == b).all()


def test_quadrant_semantics(small_lake, small_index):
    idx = small_index
    t = 0
    tab = small_lake.tables[t]
    for c, col in enumerate(tab.columns):
        sel = (idx.table_id == t) & (idx.col_id == c)
        quads = idx.quadrant[sel]
        try:
            vals = np.array([float(v) for v in col])
            numeric = True
        except (TypeError, ValueError):
            numeric = False
        if numeric:
            rows = idx.row_id[sel]
            want = (vals[rows] >= vals.mean()).astype(np.int8)
            np.testing.assert_array_equal(quads, want)
        else:
            assert (quads == -1).all()


def test_superkey_contains_row_values(small_lake, small_index):
    """Every row superkey contains the digest of any subset of its values."""
    idx = small_index
    tab = small_lake.tables[2]
    pos = np.nonzero((idx.table_id == 2) & (idx.row_id == 3))[0]
    sk = (np.uint64(idx.superkey_hi[pos[0]]) << np.uint64(32)) | \
        np.uint64(idx.superkey_lo[pos[0]])
    row_vals = tab.row(3)
    hs = hashing.hash_array(row_vals[:2])
    q = hashing.row_superkey(hs, np.zeros(2, np.int64))
    assert (sk & q) == q


def test_padded_buckets_roundtrip(small_index):
    bh, bp, overflow = small_index.padded_buckets(width=64)
    nb = 1 << small_index.bucket_bits
    assert bh.shape == (nb, 64) and bp.shape == (nb, 64)
    # every non-overflowed posting appears exactly once in the payload
    got = np.sort(bp[bp >= 0])
    assert len(got) == small_index.n_postings - overflow
    assert len(np.unique(got)) == len(got)


def test_sample_ranks_are_permutations(small_index):
    idx = small_index
    sel = (idx.table_id == 1) & (idx.col_id == 0)
    for ranks in (idx.rank_conv[sel], idx.rank_rand[sel]):
        assert sorted(ranks) == list(range(sel.sum()))


def test_storage_smaller_than_baselines(small_lake, small_index):
    """Pr.3: the unified index is leaner than the sum of standalone indexes
    (Table VIII claim, checked structurally at test scale)."""
    from repro.core.baselines import JosieLike, MateLike, QcrLike, UnionBaseline
    combined = (JosieLike(small_lake).storage_bytes()
                + MateLike(small_lake).storage_bytes()
                + QcrLike(small_lake).storage_bytes()
                + UnionBaseline(small_lake).storage_bytes())
    assert small_index.storage_bytes() < combined


@settings(max_examples=50, deadline=None)
@given(st.text(max_size=30))
def test_hash_stability_and_range(s):
    h1, h2 = hashing.hash_value(s), hashing.hash_value(s)
    assert h1 == h2
    assert 0 <= h1 < 0xFFFFFFFF      # MISSING sentinel reserved


@settings(max_examples=30, deadline=None)
@given(st.integers(-10 ** 9, 10 ** 9))
def test_int_float_hash_equivalence(n):
    """Integral floats join with ints (numeric join keys, Table VII)."""
    assert hashing.hash_value(n) == hashing.hash_value(float(n))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2 ** 32 - 2), min_size=1, max_size=10),
       st.integers(1, 5))
def test_superkey_monotone_containment(hs, extra):
    """Adding values to a row never removes superkey bits (bloom property)."""
    hs = np.array(hs, np.uint64)
    base = hashing.row_superkey(hs, np.zeros(len(hs), np.int64))
    more = np.concatenate([hs, hs[:extra % len(hs) + 1]])
    bigger = hashing.row_superkey(more, np.zeros(len(more), np.int64))
    assert (bigger & base) == base
