"""Deterministic-clock tests for the serving front tier's policy core:
TokenBucket / RateLimiter refill and shed decisions, BatchFormer window
close, lane priority, bounded-queue backpressure, and mutation barriers —
all driven with explicit ``now`` values, no threads, no sleeps."""
import pytest

from repro.serve.batching import (BATCH, INTERACTIVE, SHED_QUEUE_FULL,
                                  BatchFormer, Barrier, Batch, LaneConfig,
                                  RateLimiter, TokenBucket)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


# --------------------------------------------------------------------------
# token buckets
# --------------------------------------------------------------------------

def test_token_bucket_burst_then_refill():
    clk = FakeClock()
    b = TokenBucket(rate=10.0, burst=3.0, now=clk)
    assert [b.try_acquire()[0] for _ in range(3)] == [True] * 3
    ok, retry = b.try_acquire()
    assert not ok and retry == pytest.approx(0.1)
    clk.advance(0.05)                      # half a token refilled
    assert not b.try_acquire()[0]
    clk.advance(0.05)                      # full token now
    assert b.try_acquire()[0]


def test_token_bucket_rate_sustained():
    clk = FakeClock()
    b = TokenBucket(rate=100.0, burst=1.0, now=clk)
    admitted = 0
    for _ in range(1000):                  # 1kHz offered for 1 second
        clk.advance(0.001)
        admitted += b.try_acquire()[0]
    assert 95 <= admitted <= 101           # ~rate, never more than rate+burst


def test_token_bucket_caps_at_burst():
    clk = FakeClock()
    b = TokenBucket(rate=10.0, burst=2.0, now=clk)
    clk.advance(100.0)                     # long idle: no unbounded credit
    assert b.available() == pytest.approx(2.0)


def test_token_bucket_unlimited_and_zero_rate():
    clk = FakeClock()
    assert TokenBucket(rate=None, now=clk).try_acquire() == (True, 0.0)
    b = TokenBucket(rate=0.0, burst=1.0, now=clk)
    assert b.try_acquire()[0]              # the burst token
    ok, retry = b.try_acquire()
    assert not ok and retry == float("inf")


def test_rate_limiter_per_tenant_isolation():
    clk = FakeClock()
    lim = RateLimiter(rate=10.0, burst=1.0,
                      per_tenant={"vip": (1000.0, 100.0)}, now=clk)
    assert lim.admit("a")[0]
    assert not lim.admit("a")[0]           # a's bucket empty
    assert lim.admit("b")[0]               # b unaffected
    assert all(lim.admit("vip")[0] for _ in range(50))
    assert lim.sheds == {"a": 1}


# --------------------------------------------------------------------------
# batch former: windows, fullness, lanes
# --------------------------------------------------------------------------

def former(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("lanes", {INTERACTIVE: LaneConfig(0.002, 4),
                            BATCH: LaneConfig(0.010, 4)})
    return BatchFormer(**kw)


def test_window_close_timing():
    f = former()
    f.submit("q0", lane=INTERACTIVE, now=0.0)
    assert f.poll(0.0) is None             # window open
    assert f.poll(0.0019) is None
    assert f.next_deadline(0.001) == pytest.approx(0.002)
    out = f.poll(0.002)                    # window closed exactly at deadline
    assert isinstance(out, Batch)
    assert [p.payload for p in out.requests] == ["q0"]
    assert f.poll(1.0) is None             # drained


def test_full_batch_closes_before_window():
    f = former(max_batch=2)
    f.submit("q0", lane=INTERACTIVE, now=0.0)
    f.submit("q1", lane=INTERACTIVE, now=0.0)
    f.submit("q2", lane=INTERACTIVE, now=0.0)
    out = f.poll(0.0)                      # full: no waiting
    assert [p.payload for p in out.requests] == ["q0", "q1"]
    assert f.poll(0.0) is None             # q2 alone: window still open
    assert [p.payload for p in f.poll(0.002).requests] == ["q2"]


def test_lane_priority_interactive_first():
    f = former()
    f.submit("b0", lane=BATCH, now=0.0)    # arrives first
    f.submit("i0", lane=INTERACTIVE, now=0.001)
    out = f.poll(0.01)                     # both windows closed
    assert [p.payload for p in out.requests] == ["i0", "b0"]


def test_lane_priority_under_max_batch_pressure():
    f = former(max_batch=2)
    f.submit("b0", lane=BATCH, now=0.0)
    f.submit("b1", lane=BATCH, now=0.0)
    f.submit("i0", lane=INTERACTIVE, now=0.0)
    out = f.poll(0.02)
    assert [p.payload for p in out.requests] == ["i0", "b0"]
    assert [p.payload for p in f.poll(0.02).requests] == ["b1"]


def test_earliest_window_flushes_both_lanes():
    """One closed window dispatches everything runnable — the batch lane
    request rides along with the interactive flush."""
    f = former()
    f.submit("b0", lane=BATCH, now=0.0)
    f.submit("i0", lane=INTERACTIVE, now=0.0)
    out = f.poll(0.0021)                   # interactive window closed only
    assert [p.payload for p in out.requests] == ["i0", "b0"]


def test_bounded_queue_sheds_not_buffers():
    f = former(lanes={INTERACTIVE: LaneConfig(0.002, 2),
                      BATCH: LaneConfig(0.010, 4)})
    assert f.submit("q0", lane=INTERACTIVE, now=0.0)[0] is not None
    assert f.submit("q1", lane=INTERACTIVE, now=0.0)[0] is not None
    pending, reason = f.submit("q2", lane=INTERACTIVE, now=0.0)
    assert pending is None and reason == SHED_QUEUE_FULL
    assert f.depth()[INTERACTIVE] == 2     # never grew past the bound
    assert f.stats.shed == {SHED_QUEUE_FULL: 1}
    assert f.stats.shed_by_lane[INTERACTIVE][SHED_QUEUE_FULL] == 1


def test_unknown_lane_rejected():
    with pytest.raises(ValueError, match="unknown lane"):
        former().submit("q", lane="bulk", now=0.0)


def test_next_deadline_none_when_idle():
    f = former()
    assert f.next_deadline(5.0) is None
    assert f.poll(5.0) is None


def test_batch_size_histogram_and_stats():
    f = former(max_batch=8)
    for i in range(3):
        f.submit(f"q{i}", lane=INTERACTIVE, now=0.0)
    f.poll(1.0)
    f.submit("q3", lane=INTERACTIVE, now=2.0)
    f.poll(3.0)
    assert f.stats.batches == 2
    assert f.stats.batched_requests == 4
    assert f.stats.batch_size_hist == {3: 1, 1: 1}
    assert f.stats.admitted[INTERACTIVE] == 4


# --------------------------------------------------------------------------
# mutation barriers
# --------------------------------------------------------------------------

def test_barrier_orders_queries_around_mutation():
    """q0 (pre-barrier) flushes immediately; the mutation waits for it; q1
    (post-barrier) waits for the mutation."""
    f = former()
    f.submit("q0", lane=INTERACTIVE, now=0.0)
    f.submit("m0", kind="mutation", now=0.0)
    f.submit("q1", lane=INTERACTIVE, now=0.0)
    out = f.poll(0.0)                      # barrier flush: window cut short
    assert isinstance(out, Batch)
    assert [p.payload for p in out.requests] == ["q0"]
    out = f.poll(0.0)                      # now the mutation is runnable
    assert isinstance(out, Barrier) and out.request.payload == "m0"
    assert f.poll(0.0) is None             # q1's window restarts post-barrier
    assert [p.payload for p in f.poll(0.002).requests] == ["q1"]


def test_mutation_alone_runs_immediately():
    f = former()
    f.submit("m0", kind="mutation", now=0.0)
    out = f.poll(0.0)
    assert isinstance(out, Barrier)
    assert f.stats.barriers == 1


def test_consecutive_barriers_preserve_fifo():
    f = former()
    f.submit("m0", kind="mutation", now=0.0)
    f.submit("q0", lane=BATCH, now=0.0)
    f.submit("m1", kind="mutation", now=0.0)
    f.submit("q1", lane=BATCH, now=0.0)
    assert f.poll(0.0).request.payload == "m0"
    assert [p.payload for p in f.poll(0.0).requests] == ["q0"]
    assert f.poll(0.0).request.payload == "m1"
    assert [p.payload for p in f.poll(1.0).requests] == ["q1"]


def test_barrier_flush_deadline_is_now():
    f = former()
    f.submit("q0", lane=BATCH, now=0.0)    # 10ms window...
    f.submit("m0", kind="mutation", now=0.001)
    assert f.next_deadline(0.001) == 0.001  # ...cut short by the barrier


def test_mutation_queue_bounded():
    f = former(mutation_max_queue=1)
    assert f.submit("m0", kind="mutation", now=0.0)[0] is not None
    pending, reason = f.submit("m1", kind="mutation", now=0.0)
    assert pending is None and reason == SHED_QUEUE_FULL


def test_post_barrier_queries_not_counted_runnable():
    f = former(max_batch=2)
    f.submit("m0", kind="mutation", now=0.0)
    f.submit("q0", lane=INTERACTIVE, now=0.0)
    f.submit("q1", lane=INTERACTIVE, now=0.0)
    out = f.poll(10.0)                     # barrier first despite closed
    assert isinstance(out, Barrier)        # windows behind it
    assert [p.payload for p in f.poll(10.0).requests] == ["q0", "q1"]
