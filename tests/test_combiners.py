"""Combiner set-algebra properties (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import combiners as C

N = 32


def _rs(rng):
    scores = rng.uniform(0, 10, N).astype(np.float32)
    mask = rng.random(N) < 0.5
    scores = np.where(mask, scores, 0.0)
    return C.ResultSet(jnp.asarray(scores), jnp.asarray(mask))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_set_algebra(seed):
    rng = np.random.default_rng(seed)
    a, b = _rs(rng), _rs(rng)
    ma, mb = np.asarray(a.mask), np.asarray(b.mask)
    inter = np.asarray(C.intersect([a, b]).mask)
    uni = np.asarray(C.union([a, b]).mask)
    diff = np.asarray(C.difference(a, b).mask)
    np.testing.assert_array_equal(inter, ma & mb)
    np.testing.assert_array_equal(uni, ma | mb)
    np.testing.assert_array_equal(diff, ma & ~mb)
    # algebraic identities
    np.testing.assert_array_equal(inter | np.asarray(C.difference(a, b).mask)
                                  | np.asarray(C.difference(b, a).mask), uni)
    # intersection subset of operands
    assert not (inter & ~ma).any() and not (inter & ~mb).any()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 5))
def test_counter_counts(seed, n_sets):
    rng = np.random.default_rng(seed)
    sets = [_rs(rng) for _ in range(n_sets)]
    counts = np.asarray(C.counter(sets).scores)
    manual = sum(np.asarray(s.mask).astype(np.float32) for s in sets)
    np.testing.assert_array_equal(counts, manual)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, N))
def test_topk_selects_best(seed, k):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0, 10, N).astype(np.float32)
    rs = C.topk_result(jnp.asarray(scores), k)
    picked = np.nonzero(np.asarray(rs.mask))[0]
    assert len(picked) <= k
    if len(picked) and len(picked) < N:
        unpicked_max = scores[~np.asarray(rs.mask)].max()
        assert scores[picked].min() >= unpicked_max - 1e-6


def test_commutativity_of_intersection():
    rng = np.random.default_rng(0)
    a, b, c = _rs(rng), _rs(rng), _rs(rng)
    m1 = np.asarray(C.intersect([a, b, c]).mask)
    m2 = np.asarray(C.intersect([c, a, b]).mask)
    np.testing.assert_array_equal(m1, m2)
