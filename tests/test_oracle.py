"""Conformance sweep: both probe backends vs the brute-force oracle.

Every seeker and every combiner, on randomized lakes, must reproduce the
pure-NumPy ground truth of tests/oracle.py bit-for-bit — scores, masks, and
tie-broken id order.  This is the ground-truth anchor the query-cache parity
suite (tests/test_query_cache.py) leans on: if the engine matches the oracle
and the cache matches the engine, the cache matches the truth.
"""
import numpy as np
import pytest

from repro.core import combiners as comb
from repro.core.executor import Executor
from repro.core.index import build_index
from repro.core.lake import synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers

from oracle import (oracle_ids, oracle_run, oracle_seeker, oracle_topk)

BACKENDS = [("sorted", False), ("bucket", True)]


def conformance_lake(seed):
    return synthetic_lake(n_tables=12, rows=12, cols=4, vocab=60, seed=seed)


def random_specs(lake, rng, k):
    """One spec of each seeker kind with randomized hit/miss/dup queries."""
    t = lake.tables[int(rng.integers(0, lake.n_tables))]
    rows = [int(r) for r in rng.integers(0, t.n_rows, 6)]
    vals = [t.columns[0][r] for r in rows] + ["never_in_lake"]
    words = ([t.columns[1][rows[0]], t.columns[2][rows[1]],
              t.columns[3][rows[2]]] + vals[:2])
    tuples = ([(t.columns[0][r], t.columns[1][r]) for r in rows[:4]]
              + [(t.columns[0][rows[0]], t.columns[1][rows[1]])]   # misaligned
              + [(t.columns[0][rows[0]], t.columns[1][rows[0]])])  # duplicate
    joins = [t.columns[0][r] for r in rows] + [t.columns[0][r] for r in rows]
    targets = [float(x) for x in rng.normal(0, 1, len(joins)).round(3)]
    return [
        Seekers.SC(vals, k=k),
        Seekers.KW(words, k=k),
        Seekers.MC(tuples, k=k),
        Seekers.Correlation(joins, targets, k=k, h=256),
        Seekers.Correlation(joins, targets, k=k, h=8),        # rank filter on
        Seekers.Correlation(joins, targets, k=k, h=8, sampling="rand"),
    ]


def conformance_plan(lake, rng, k):
    specs = random_specs(lake, rng, k)
    plan = Plan()
    plan.add("sc", specs[0])
    plan.add("kw", specs[1])
    plan.add("mc", specs[2])
    plan.add("c", specs[3])
    plan.add("and", Combiners.Intersect(k=k), ["sc", "mc"])
    plan.add("or", Combiners.Union(k=k), ["and", "c"])
    plan.add("cnt", Combiners.Counter(k=k), ["sc", "kw"])
    plan.add("out", Combiners.Difference(k=k), ["or", "cnt"])
    return plan


def assert_resultset_matches(rs, oscores, omask, msg=""):
    np.testing.assert_array_equal(np.asarray(rs.scores), oscores, err_msg=msg)
    np.testing.assert_array_equal(np.asarray(rs.mask), omask, err_msg=msg)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_all_seekers_match_oracle(backend, interpret, seed):
    lake = conformance_lake(seed)
    ex = Executor(build_index(lake), backend=backend, interpret=interpret)
    rng = np.random.default_rng(100 + seed)
    for spec in random_specs(lake, rng, k=lake.n_tables):
        rs = ex.run_seeker(spec)
        oscores, omask = oracle_topk(oracle_seeker(lake, spec), spec.k)
        assert_resultset_matches(rs, oscores, omask,
                                 f"{spec.kind} h={spec.h} {spec.sampling}")


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_seekers_match_oracle_binding_k(backend, interpret):
    """With a binding top-k the cut itself (ties included) must match."""
    lake = conformance_lake(3)
    ex = Executor(build_index(lake), backend=backend, interpret=interpret)
    rng = np.random.default_rng(7)
    for spec in random_specs(lake, rng, k=4):
        rs = ex.run_seeker(spec)
        oscores, omask = oracle_topk(oracle_seeker(lake, spec), spec.k)
        assert_resultset_matches(rs, oscores, omask, spec.kind)
        assert [int(t) for t in rs.ids()] == oracle_ids(oscores, omask)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1])
def test_combiner_plan_matches_oracle(backend, interpret, seed):
    """A 4-seeker / 4-combiner DAG end-to-end (unoptimized execution is
    exactly the oracle's evaluation order)."""
    lake = conformance_lake(seed)
    ex = Executor(build_index(lake), backend=backend, interpret=interpret)
    plan = conformance_plan(lake, np.random.default_rng(200 + seed), k=8)
    rs, _ = ex.run(plan, optimize=False)
    oscores, omask = oracle_run(lake, plan)
    assert_resultset_matches(rs, oscores, omask)
    assert [int(t) for t in rs.ids()] == oracle_ids(oscores, omask)


def test_optimized_run_preserves_oracle_ids():
    """With per-node k lifted to n_tables the optimizer's mask threading is
    output-preserving — optimized ids must equal the oracle's."""
    lake = conformance_lake(4)
    ex = Executor(build_index(lake))
    plan = conformance_plan(lake, np.random.default_rng(42), k=lake.n_tables)
    rs, _ = ex.run(plan, optimize=True)
    oscores, omask = oracle_run(lake, plan)
    assert [int(t) for t in rs.ids()] == oracle_ids(oscores, omask)


def test_oracle_topk_matches_device_topk():
    """The oracle's top-k (stable index-order tie-break, positive-only)
    is bit-compatible with combiners.topk_result."""
    rng = np.random.default_rng(11)
    for trial in range(5):
        scores = rng.integers(0, 4, 40).astype(np.float32)   # heavy ties
        for k in (1, 5, 40, 1 << 20):
            dev = comb.topk_result(np.asarray(scores), k)
            oscores, omask = oracle_topk(scores, k)
            np.testing.assert_array_equal(np.asarray(dev.scores), oscores)
            np.testing.assert_array_equal(np.asarray(dev.mask), omask)
