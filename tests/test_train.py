"""Training substrate: checkpoint/restart, fault tolerance, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.data.pipeline import TokenStream
from repro.launch.train import TrainLoopConfig, train_loop
from repro.train import checkpoint as ckpt
from repro.train.optim import (AdamWConfig, adamw_init, adamw_update,
                               compressed_grads)
from repro.train.step import make_train_state

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return reduced(get_config("smollm-360m")).replace(n_layers=1, d_model=32,
                                                      n_heads=2, n_kv_heads=2,
                                                      d_ff=64, vocab=128)


def test_checkpoint_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    state = make_train_state(cfg, KEY)
    ckpt.save(state, tmp_path, step=7)
    restored, step = ckpt.restore(state, tmp_path)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    cfg = _tiny_cfg()
    state = make_train_state(cfg, KEY)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(state, tmp_path, step=s, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert dirs == ["step_00000004", "step_00000005"]


def test_restart_replays_same_data(tmp_path):
    """A crashed-and-restarted run produces the same loss sequence as an
    uninterrupted run (deterministic step-indexed pipeline + checkpoints)."""
    cfg = _tiny_cfg()
    tokens = np.random.default_rng(0).integers(0, cfg.vocab, 4096,
                                               dtype=np.int32)
    mk = lambda: TokenStream(tokens, batch=4, seq_len=32, seed=3)
    full = train_loop(cfg, mk(), TrainLoopConfig(steps=8, ckpt_every=4,
                                                 ckpt_dir=str(tmp_path / "a")))
    # interrupted run: first 4 steps...
    part1 = train_loop(cfg, mk(), TrainLoopConfig(steps=4, ckpt_every=4,
                                                  ckpt_dir=str(tmp_path / "b")))
    # ...then resume to 8
    part2 = train_loop(cfg, mk(), TrainLoopConfig(steps=8, ckpt_every=4,
                                                  ckpt_dir=str(tmp_path / "b")))
    assert part2.resumed_from == 4
    np.testing.assert_allclose(full.losses[4:], part2.losses, rtol=1e-5)


def test_elastic_reshard_restore(tmp_path):
    """Restore onto explicit shardings (single-device mesh here; the same
    path re-places onto any mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import param_specs
    from repro.launch.mesh import make_host_mesh
    cfg = _tiny_cfg()
    state = make_train_state(cfg, KEY)
    ckpt.save(state, tmp_path, step=1)
    mesh = make_host_mesh()
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      param_specs(state["params"], mesh))
    shardings = {"params": sh, "opt": {
        "m": sh, "v": jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                   state["opt"]["v"]),
        "step": NamedSharding(mesh, P())}}
    restored, step = ckpt.restore(state, tmp_path, shardings=shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_factored_optimizer_matches_adam_direction():
    """Factored second moment approximates dense Adam on rank-1 g^2."""
    p = {"w": jnp.ones((256, 256)) * 0.5}
    g = {"w": jnp.full((256, 256), 0.1)}
    dense_cfg = AdamWConfig(factored=False, weight_decay=0.0)
    fact_cfg = AdamWConfig(factored=True, weight_decay=0.0)
    sd = adamw_init(p, dense_cfg)
    sf = adamw_init(p, fact_cfg)
    pd, _ = adamw_update(p, g, sd, dense_cfg)
    pf, _ = adamw_update(p, g, sf, fact_cfg)
    np.testing.assert_allclose(np.asarray(pd["w"]), np.asarray(pf["w"]),
                               rtol=1e-4)


def test_int8_compression_error_feedback():
    """Error feedback makes the *accumulated* compressed gradient converge to
    the true gradient sum."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    res = {"w": jnp.zeros((64, 64))}
    total = jnp.zeros((64, 64))
    for _ in range(20):
        deq, res = compressed_grads(g, res)
        total = total + deq["w"]
    err = float(jnp.max(jnp.abs(total + res["w"] - 20 * g["w"])))
    assert err < 1e-3


def test_straggler_watchdog(tmp_path):
    import time
    cfg = _tiny_cfg()
    tokens = np.zeros(4096, np.int32)
    stream = TokenStream(tokens, batch=2, seq_len=16, seed=0)
    events = []
    slow = {"step": 10}

    class SlowStream:
        def batch_at(self, step):
            if step == slow["step"]:
                time.sleep(4.0)     # far above any plausible median, even
                                    # under CI CPU contention
            return stream.batch_at(step)

    rep = train_loop(cfg, SlowStream(),
                     TrainLoopConfig(steps=12, ckpt_every=100,
                                     ckpt_dir=str(tmp_path / "ckpt"),
                                     straggler_factor=3.0),
                     straggler_cb=lambda s, dt, med: events.append(s))
    assert slow["step"] in rep.straggler_steps
    assert events == rep.straggler_steps
