"""Seeker correctness against brute-force oracles (unit + hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import brute_force_kw, brute_force_mc, brute_force_sc
from repro.core import seekers as seek
from repro.core.executor import Executor
from repro.core.hashing import hash_array
from repro.core.index import build_index
from repro.core.lake import (DataLake, Table, correlation_lake, joinable_lake,
                             mc_joinable_lake, synthetic_lake)
from repro.core.plan import Seekers


def raw_sc_scores(ex, values):
    h = hash_array(values)
    scores, ovf = seek.sc_seeker(
        ex.engine, jnp.asarray(h), jnp.ones(len(h), bool),
        m_cap=ex._mcap_for(h), n_tables=ex.n_tables, max_cols=ex.max_cols)
    return np.asarray(scores), int(ovf)


def test_sc_exact_vs_bruteforce(small_lake, small_executor):
    vals = [small_lake.tables[0].columns[0][i] for i in range(10)]
    got, ovf = raw_sc_scores(small_executor, vals)
    assert ovf == 0
    np.testing.assert_array_equal(got, brute_force_sc(small_lake, vals))


def test_sc_controlled_overlap():
    lake, query, truth = joinable_lake(n_tables=80, seed=11)
    ex = Executor(build_index(lake))
    got, _ = raw_sc_scores(ex, query)
    # truth counts only the planted column; other columns may coincidentally
    # overlap, so got >= truth and got matches full brute force
    np.testing.assert_array_equal(got, brute_force_sc(lake, query))
    assert (got >= truth).all()


def test_kw_exact(small_lake, small_executor):
    vals = [small_lake.tables[1].columns[0][i] for i in range(8)]
    h = hash_array(vals)
    scores, _ = seek.kw_seeker(
        small_executor.engine, jnp.asarray(h), jnp.ones(len(h), bool),
        m_cap=small_executor._mcap_for(h), n_tables=small_lake.n_tables)
    np.testing.assert_array_equal(np.asarray(scores),
                                  brute_force_kw(small_lake, vals))


def test_mc_exact_and_alignment():
    lake, tuples, truth = mc_joinable_lake(seed=4)
    ex = Executor(build_index(lake))
    rs = ex.run_seeker(Seekers.MC(tuples, k=lake.n_tables))
    got = np.asarray(rs.scores).astype(int)
    np.testing.assert_array_equal(got, brute_force_mc(lake, tuples))
    # misaligned tables (mode 2) must score zero
    np.testing.assert_array_equal(got, truth)


def test_mc_superkey_is_pure_filter(small_lake, small_executor):
    """The bloom prune never changes the final (validated) result."""
    t0 = small_lake.tables[0]
    tuples = [(t0.columns[0][r], t0.columns[1][r]) for r in range(6)]
    from repro.core.hashing import row_superkey, split_u64
    th = np.stack([hash_array([t[c] for t in tuples]) for c in range(2)], 1)
    counts = np.stack([small_executor.index.host_counts(th[:, c])
                       for c in range(2)], 1)
    init = np.argmin(counts, 1).astype(np.int32)
    qks = np.array([row_superkey(th[i], np.zeros(2, np.int64))
                    for i in range(len(tuples))], np.uint64)
    lo, hi = split_u64(qks)
    kw = dict(m_cap=64, n_tables=small_lake.n_tables, n_cols=2,
              row_stride=small_executor.index.row_stride)
    with_sk, _, _ = seek.mc_seeker(small_executor.engine, jnp.asarray(th),
                                   jnp.asarray(init), jnp.asarray(lo),
                                   jnp.asarray(hi), use_superkey=True, **kw)
    without, _, _ = seek.mc_seeker(small_executor.engine, jnp.asarray(th),
                                   jnp.asarray(init), jnp.asarray(lo),
                                   jnp.asarray(hi), use_superkey=False, **kw)
    np.testing.assert_array_equal(np.asarray(with_sk), np.asarray(without))


def test_correlation_ranks_high_corr_tables():
    lake, keys, target, truth = correlation_lake(n_tables=40, seed=9)
    ex = Executor(build_index(lake))
    ids = ex.run_seeker(Seekers.Correlation(keys, target, k=10, h=512)).ids()
    top_truth = truth[ids[:5]]
    assert top_truth.mean() > 0.75, top_truth


def test_correlation_numeric_join_keys():
    """BLEND supports numeric join keys (the baseline does not)."""
    lake, keys, target, truth = correlation_lake(n_tables=30, seed=10,
                                                 numeric_join_keys=True)
    ex = Executor(build_index(lake))
    ids = ex.run_seeker(Seekers.Correlation(keys, target, k=5, h=512)).ids()
    assert len(ids) > 0
    assert truth[ids[:3]].mean() > 0.6


def test_allowed_mask_is_exact_restriction(small_lake, small_executor):
    """Mask threading == post-hoc filtering (the rewriting soundness core)."""
    vals = [small_lake.tables[2].columns[1][i] for i in range(12)]
    full, _ = raw_sc_scores(small_executor, vals)
    allowed = np.zeros(small_lake.n_tables, bool)
    allowed[::3] = True
    h = hash_array(vals)
    got, _ = seek.sc_seeker(
        small_executor.engine, jnp.asarray(h), jnp.ones(len(h), bool),
        m_cap=small_executor._mcap_for(h), n_tables=small_lake.n_tables,
        max_cols=small_executor.max_cols, allowed=jnp.asarray(allowed))
    np.testing.assert_array_equal(np.asarray(got), np.where(allowed, full, 0))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 12))
def test_sc_property_random_lakes(seed, nq):
    """Property: SC seeker == brute force on arbitrary random lakes."""
    rng = np.random.default_rng(seed)
    tables = []
    for t in range(10):
        nr = int(rng.integers(3, 12))
        cols = [[f"v{int(x)}" for x in rng.integers(0, 40, nr)]
                for _ in range(int(rng.integers(1, 4)))]
        tables.append(Table(f"t{t}", cols))
    lake = DataLake(tables)
    ex = Executor(build_index(lake))
    vals = sorted({f"v{int(x)}" for x in rng.integers(0, 40, nq)})
    got, ovf = raw_sc_scores(ex, vals)
    assert ovf == 0
    np.testing.assert_array_equal(got, brute_force_sc(lake, vals))


def test_overflow_is_reported():
    lake = synthetic_lake(n_tables=30, rows=30, vocab=3, seed=1)  # tiny vocab
    ex = Executor(build_index(lake), m_cap_max=8)
    vals = [f"tok_{i}" for i in range(3)]
    got, ovf = raw_sc_scores(ex, vals)
    assert ovf > 0          # capacity clipped, surfaced not silent
