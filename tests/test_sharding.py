"""Property tests for the sharding rules (pure: no device state needed)."""
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import param_specs
from repro.models import registry


class FakeMesh:
    """Duck-typed mesh (axis sizes only) so the rules run without devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = math.prod(shape.values())


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16}),
          FakeMesh({"data": 4, "model": 2})]


def _axis_size(mesh, entry):
    n = 1
    for a in (entry if isinstance(entry, tuple) else (entry,)):
        if a is not None:
            n *= mesh.shape[a]
    return n


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod", "tiny"])
def test_param_specs_always_divisible(arch, mesh):
    """Every assigned axis evenly divides its dim (jit input requirement),
    for every arch x mesh, with and without fsdp/expert_data_shard."""
    cfg = get_config(arch)
    tree = registry.param_specs_tree(cfg)
    for fsdp in (False, True):
        for eds in (False, True):
            specs = param_specs(tree, mesh, fsdp=fsdp, expert_data_shard=eds)

            def check(leaf, spec):
                for dim, entry in zip(leaf.shape, tuple(spec)):
                    n = _axis_size(mesh, entry)
                    assert dim % n == 0, (arch, leaf.shape, tuple(spec))
                return 0

            jax.tree.map(check, tree, specs,
                         is_leaf=lambda x: hasattr(x, "shape"))


def test_fsdp_shards_large_params():
    cfg = get_config("arctic-480b")
    tree = registry.param_specs_tree(cfg)
    mesh = MESHES[0]
    specs = param_specs(tree, mesh, fsdp=True)

    def bytes_per_device(leaf, spec):
        n = 1
        for dim, entry in zip(leaf.shape, tuple(spec)):
            n *= _axis_size(mesh, entry)
        return math.prod(leaf.shape) * leaf.dtype.itemsize / n

    total = sum(jax.tree.leaves(jax.tree.map(
        bytes_per_device, tree, specs, is_leaf=lambda x: hasattr(x, "shape"))))
    # 480B bf16 params over 256 devices must land well under 16 GB/device
    assert total < 6e9, total / 1e9


def test_expert_data_shard_places_experts_on_data():
    cfg = get_config("arctic-480b")
    tree = registry.param_specs_tree(cfg)
    specs = param_specs(tree, MESHES[0], expert_data_shard=True)
    eg = specs["layers"]["moe"]["experts_gate"]
    assert tuple(eg) == (None, "data", None, "model")
    ed = specs["layers"]["moe"]["experts_down"]
    assert tuple(ed) == (None, "data", "model", None)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6))
def test_batch_spec_shards_when_divisible(logd, logm):
    from repro.dist.sharding import batch_spec
    mesh = FakeMesh({"data": 2 ** logd, "model": 2 ** logm})
    spec = batch_spec(mesh, ndim=2)
    assert tuple(spec)[0] in ("data", ("data",))


# ---------------------------------------------------------------------------
# host-side ShardedStore layout (dist/shard.py): table-axis partitioning,
# global geometry, placement and routing — no device execution needed
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shard_lake():
    from repro.core.lake import synthetic_lake
    return synthetic_lake(n_tables=20, rows=12, cols=3, vocab=200, seed=3)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_sharded_store_partitions_whole_tables(shard_lake, n_shards):
    from repro.dist.shard import ShardedStore
    store = ShardedStore(shard_lake, n_shards=n_shards)
    # every table owned by exactly one shard, ids global, round-robin
    owners = {}
    for i, s in enumerate(store.shards):
        for tid in s.live_ids():
            assert tid not in owners, "table on two shards"
            owners[tid] = i
    assert sorted(owners) == list(range(20))
    assert all(owners[g] == g % n_shards for g in owners)
    assert store.live_ids() == list(range(20))
    # global geometry imposed identically on every shard
    assert len({(s.n_tables, s.row_stride, s.max_cols)
                for s in store.shards}) == 1


def test_sharded_store_geometry_matches_single_store(shard_lake):
    from repro.dist.shard import ShardedStore
    from repro.store.segments import SegmentStore
    single = SegmentStore(shard_lake)
    store = ShardedStore(shard_lake, n_shards=4)
    assert store.n_tables == single.n_tables
    assert store.row_stride == single.row_stride
    assert store.max_cols == single.max_cols
    assert store.n_postings == single.n_postings
    assert (store.alive == single.alive).all()
    assert store.table_names[:20] == single.table_names[:20]


def test_sharded_host_counts_sum_to_single_store(shard_lake):
    from repro.core.hashing import hash_array
    from repro.dist.shard import ShardedStore
    from repro.store.segments import SegmentStore
    h = np.unique(hash_array(list(shard_lake.tables[0].columns[0][:8])))
    single = SegmentStore(shard_lake)
    store = ShardedStore(shard_lake, n_shards=4)
    per = store.host_counts(h, per_shard=True)
    assert per.shape == (4, len(h))
    assert (per.sum(axis=0) == single.host_counts(h)).all()
    assert (store.host_counts(h) == single.host_counts(h)).all()


def test_sharded_store_routes_and_reuses_global_ids(shard_lake):
    from repro.core.lake import Table
    from repro.dist.shard import ShardedStore
    store = ShardedStore(shard_lake, n_shards=3)
    tab = Table("routed", [["a", "b", "c"], [1.0, 2.0, 3.0]])
    target = store.least_loaded()
    tid = store.add_table(tab)
    assert tid == 20                              # fresh global id
    assert store.owner_of("routed") == target     # least-loaded routing
    # epoch is a per-shard tuple; only the owner moved
    assert sum(e != 0 for e in store.epoch) == 1
    store.drop_table(tid)
    tid2 = store.add_table(Table("again", [["x", "y"], [0.5, 1.5]]))
    assert tid2 == tid                            # freed id reused globally
    with pytest.raises(KeyError):
        store.owner_of("routed")


def test_sharded_store_shape_reports_mesh_layout(shard_lake):
    from repro.dist.shard import ShardedStore
    store = ShardedStore(shard_lake, n_shards=2)
    s = store.shape()
    assert s["mode"] == "sharded" and s["shards"] == 2
    assert s["mesh_axes"] == ("shard",)
    assert len(s["per_shard"]) == 2
    assert sum(p["postings"] for p in s["per_shard"]) == s["postings"]
    assert sum(p["live_tables"] for p in s["per_shard"]) == 20
