"""Property tests for the sharding rules (pure: no device state needed)."""
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import param_specs
from repro.models import registry


class FakeMesh:
    """Duck-typed mesh (axis sizes only) so the rules run without devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = math.prod(shape.values())


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16}),
          FakeMesh({"data": 4, "model": 2})]


def _axis_size(mesh, entry):
    n = 1
    for a in (entry if isinstance(entry, tuple) else (entry,)):
        if a is not None:
            n *= mesh.shape[a]
    return n


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod", "tiny"])
def test_param_specs_always_divisible(arch, mesh):
    """Every assigned axis evenly divides its dim (jit input requirement),
    for every arch x mesh, with and without fsdp/expert_data_shard."""
    cfg = get_config(arch)
    tree = registry.param_specs_tree(cfg)
    for fsdp in (False, True):
        for eds in (False, True):
            specs = param_specs(tree, mesh, fsdp=fsdp, expert_data_shard=eds)

            def check(leaf, spec):
                for dim, entry in zip(leaf.shape, tuple(spec)):
                    n = _axis_size(mesh, entry)
                    assert dim % n == 0, (arch, leaf.shape, tuple(spec))
                return 0

            jax.tree.map(check, tree, specs,
                         is_leaf=lambda x: hasattr(x, "shape"))


def test_fsdp_shards_large_params():
    cfg = get_config("arctic-480b")
    tree = registry.param_specs_tree(cfg)
    mesh = MESHES[0]
    specs = param_specs(tree, mesh, fsdp=True)

    def bytes_per_device(leaf, spec):
        n = 1
        for dim, entry in zip(leaf.shape, tuple(spec)):
            n *= _axis_size(mesh, entry)
        return math.prod(leaf.shape) * leaf.dtype.itemsize / n

    total = sum(jax.tree.leaves(jax.tree.map(
        bytes_per_device, tree, specs, is_leaf=lambda x: hasattr(x, "shape"))))
    # 480B bf16 params over 256 devices must land well under 16 GB/device
    assert total < 6e9, total / 1e9


def test_expert_data_shard_places_experts_on_data():
    cfg = get_config("arctic-480b")
    tree = registry.param_specs_tree(cfg)
    specs = param_specs(tree, MESHES[0], expert_data_shard=True)
    eg = specs["layers"]["moe"]["experts_gate"]
    assert tuple(eg) == (None, "data", None, "model")
    ed = specs["layers"]["moe"]["experts_down"]
    assert tuple(ed) == (None, "data", "model", None)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6))
def test_batch_spec_shards_when_divisible(logd, logm):
    from repro.dist.sharding import batch_spec
    mesh = FakeMesh({"data": 2 ** logd, "model": 2 ** logm})
    spec = batch_spec(mesh, ndim=2)
    assert tuple(spec)[0] in ("data", ("data",))
