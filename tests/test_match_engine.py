"""MatchEngine: backend parity (searchsorted vs Pallas bucket probe in
interpret mode), kernel wiring, retrace-free serving, batched serve_many."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import seekers as seek
from repro.core.executor import Executor
from repro.core.hashing import MISSING, hash_array
from repro.core.index import build_index
from repro.core.lake import (DataLake, Table, correlation_lake,
                             mc_joinable_lake, synthetic_lake)
from repro.core.match import MatchEngine, probe_sorted
from repro.core.plan import Combiners, Plan, Seekers


def random_lake(seed, n_tables=12, numeric=False):
    rng = np.random.default_rng(seed)
    tables = []
    for t in range(n_tables):
        nr = int(rng.integers(4, 14))
        cols = [[f"v{int(x)}" for x in rng.integers(0, 50, nr)]
                for _ in range(int(rng.integers(1, 4)))]
        if numeric:
            cols.append([float(x) for x in rng.normal(0, 1, nr)])
        tables.append(Table(f"t{t}", cols))
    return DataLake(tables)


def executors(lake, **kw):
    idx = build_index(lake)
    return (Executor(idx, backend="sorted", **kw),
            Executor(idx, backend="bucket", interpret=True, **kw))


# --------------------------------------------------------------------------
# probe-level parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_probe_backend_parity(seed):
    lake = random_lake(seed)
    ref_ex, ker_ex = executors(lake)
    rng = np.random.default_rng(seed + 100)
    # mix of hits, misses, duplicates + masked padding
    vals = [f"v{int(x)}" for x in rng.integers(0, 60, 24)]
    h = np.concatenate([hash_array(vals),
                        np.full(8, MISSING, np.uint32)])
    qm = np.arange(len(h)) < 24
    for m_cap in (4, 64):
        args = (jnp.asarray(h), jnp.asarray(qm), m_cap)
        p_ref, v_ref, o_ref = ref_ex.engine.probe(*args)
        p_ker, v_ker, o_ker = ker_ex.engine.probe(*args)
        np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_ker))
        np.testing.assert_array_equal(
            np.where(np.asarray(v_ref), np.asarray(p_ref), -1),
            np.where(np.asarray(v_ker), np.asarray(p_ker), -1))
        assert int(o_ref) == int(o_ker)


# --------------------------------------------------------------------------
# seeker-level parity: kernel backend must be bit-identical on every seeker
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [3, 4])
def test_sc_kw_backend_parity(seed):
    lake = random_lake(seed)
    ref_ex, ker_ex = executors(lake)
    rng = np.random.default_rng(seed)
    vals = [f"v{int(x)}" for x in rng.integers(0, 60, 15)]
    for kind in ("SC", "KW"):
        spec = getattr(Seekers, kind)(vals, k=lake.n_tables)
        a = ref_ex.run_seeker(spec)
        b = ker_ex.run_seeker(spec)
        np.testing.assert_array_equal(np.asarray(a.scores),
                                      np.asarray(b.scores))
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


def test_mc_backend_parity():
    from conftest import brute_force_mc
    lake, tuples, _ = mc_joinable_lake(seed=6)
    ref_ex, ker_ex = executors(lake)
    spec = Seekers.MC(tuples, k=lake.n_tables)
    a = ref_ex.run_seeker(spec)
    b = ker_ex.run_seeker(spec)
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.scores).astype(int),
                                  brute_force_mc(lake, tuples))


def test_c_backend_parity():
    lake, keys, target, _ = correlation_lake(n_tables=20, seed=7)
    ref_ex, ker_ex = executors(lake)
    spec = Seekers.Correlation(keys, target, k=10, h=256)
    a = ref_ex.run_seeker(spec)
    b = ker_ex.run_seeker(spec)
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_plan_backend_parity():
    """A full optimized plan (mask threading + compaction stages) agrees
    across backends."""
    lake = synthetic_lake(n_tables=40, rows=20, vocab=300, seed=8)
    ref_ex, ker_ex = executors(lake)
    t0 = lake.tables[2]
    plan = Plan()
    plan.add("a", Seekers.SC(list(t0.columns[0][:8]), k=40))
    plan.add("b", Seekers.MC([(t0.columns[0][r], t0.columns[1][r])
                              for r in range(5)], k=40))
    plan.add("out", Combiners.Intersect(k=10), ["a", "b"])
    ra, _ = ref_ex.run(plan, optimize=True)
    rb, _ = ker_ex.run(plan, optimize=True)
    np.testing.assert_array_equal(np.asarray(ra.scores),
                                  np.asarray(rb.scores))
    np.testing.assert_array_equal(np.asarray(ra.mask), np.asarray(rb.mask))


# --------------------------------------------------------------------------
# layout + free-function invariants
# --------------------------------------------------------------------------

def test_padded_buckets_matches_loop_reference(small_index):
    """The vectorized layout equals the per-bucket loop construction."""
    import repro.core.hashing as hashing
    width = 16
    bh, bp, ovf = small_index.padded_buckets(width)
    nb = 1 << small_index.bucket_bits
    bh2 = np.full((nb, width), hashing.MISSING, np.uint32)
    bp2 = np.full((nb, width), -1, np.int32)
    ovf2 = 0
    starts = small_index.bucket_offsets
    for b in range(nb):
        s, e = int(starts[b]), int(starts[b + 1])
        n = min(e - s, width)
        ovf2 += max(e - s - width, 0)
        bh2[b, :n] = small_index.cell_hash[s:s + n]
        bp2[b, :n] = np.arange(s, s + n)
    np.testing.assert_array_equal(bh, bh2)
    np.testing.assert_array_equal(bp, bp2)
    assert ovf == ovf2


def test_probe_sorted_masks_padding_overflow(small_index):
    """Padded (masked) queries contribute no matches and no overflow."""
    h = np.full(8, MISSING, np.uint32)
    qm = np.zeros(8, bool)
    pidx, valid, ovf = probe_sorted(jnp.asarray(small_index.cell_hash),
                                    jnp.asarray(h), jnp.asarray(qm), 4)
    assert not bool(valid.any())
    assert int(ovf) == 0


def test_lossy_bucket_width_rejected(small_index):
    """A layout narrower than the fullest bucket would silently drop
    matches — construction must refuse it."""
    need = small_index.max_bucket_count()
    with pytest.raises(ValueError, match="fullest bucket"):
        MatchEngine.from_index(small_index, backend="bucket",
                               bucket_width=need - 1)
    with pytest.raises(ValueError, match="backend"):
        MatchEngine.from_index(small_index, backend="btree")


def test_num_perm_dtype_is_i32(small_index):
    assert small_index.num_perm.dtype == np.int32
    assert small_index.num_rowkey.dtype == np.int32


# --------------------------------------------------------------------------
# retrace-free serving
# --------------------------------------------------------------------------

def _mixed_plan(lake, rng, n_vals, n_tuples):
    t = lake.tables[int(rng.integers(0, lake.n_tables))]
    vals = [t.columns[0][int(rng.integers(0, t.n_rows))] for _ in range(n_vals)]
    tuples = [(t.columns[0][r], t.columns[1][r])
              for r in rng.choice(t.n_rows, n_tuples, replace=False)]
    plan = Plan()
    plan.add("sc", Seekers.SC(vals, k=20))
    plan.add("kw", Seekers.KW(vals[: n_vals // 2], k=20))
    plan.add("mc", Seekers.MC(tuples, k=20))
    plan.add("out", Combiners.Intersect(k=10), ["sc", "kw", "mc"])
    return plan


def test_repeat_query_zero_retrace():
    """A new query set in the same capacity bucket compiles nothing new."""
    lake = synthetic_lake(n_tables=50, rows=24, vocab=600, seed=9)
    ex = Executor(build_index(lake))
    rng = np.random.default_rng(0)
    ex.run(_mixed_plan(lake, rng, 10, 5), optimize=True)     # warm the cache
    before = dict(seek.TRACE_COUNTS)
    for _ in range(3):      # same bucket: n_vals<=16 pad, n_tuples<=8 pad
        ex.run(_mixed_plan(lake, rng, int(rng.integers(6, 14)),
                           int(rng.integers(3, 8))), optimize=True)
    assert dict(seek.TRACE_COUNTS) == before


def test_capacity_ladder_quantizes():
    lake = synthetic_lake(n_tables=30, rows=20, vocab=400, seed=10)
    ex = Executor(build_index(lake))
    assert ex._quantize_cap(1) == ex.cap_ladder[0]
    assert ex._quantize_cap(65) == 128
    assert ex._quantize_cap(10 ** 9) == ex.cap_ladder[-1]
    ex8 = Executor(build_index(lake), m_cap_max=8)
    assert ex8.cap_ladder == (8,)
    ex4k = Executor(build_index(lake), m_cap_max=4096)
    assert ex4k.cap_ladder[-1] == 4096       # caps above the ladder honored
    assert ex4k._quantize_cap(2000) == 4096


def test_serve_many_matches_serial():
    from repro.serve.engine import DiscoveryEngine
    lake = synthetic_lake(n_tables=40, rows=20, vocab=300, seed=11)
    eng = DiscoveryEngine(lake)
    rng = np.random.default_rng(1)
    plans = [_mixed_plan(lake, rng, 8, 4) for _ in range(4)]
    serial = [eng.serve(p) for p in plans]
    batched = eng.serve_many(plans)
    for a, b in zip(serial, batched):
        assert a.table_ids == b.table_ids
