"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs pure-jnp
oracle (assert_allclose), plus hypothesis properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.bucket_probe import ops as bp
from repro.kernels.bucket_probe.ref import bucket_probe_ref
from repro.kernels.flash_attention import ops as fa
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.qcr_score import ops as qc
from repro.kernels.qcr_score.ref import qcr_score_ref
from repro.kernels.superkey_filter import ops as sk
from repro.kernels.superkey_filter.ref import superkey_filter_ref


def _bucket_table(rng, bits, width):
    nb = 1 << bits
    bh = rng.integers(0, 2 ** 32, (nb, width), dtype=np.uint32)
    for b in range(nb):   # top bits must equal the bucket id
        bh[b] = (np.uint32(b) << np.uint32(32 - bits)) | \
            (bh[b] & np.uint32((1 << (32 - bits)) - 1))
    bp_ = rng.integers(0, 10 ** 6, (nb, width), dtype=np.int32)
    return bh, bp_


@pytest.mark.parametrize("bits,width,m", [(4, 8, 32), (6, 16, 64),
                                          (8, 128, 128)])
def test_bucket_probe_sweep(bits, width, m):
    rng = np.random.default_rng(bits * 100 + width)
    bh, payload = _bucket_table(rng, bits, width)
    q = bh[rng.integers(0, 1 << bits, m), rng.integers(0, width, m)]
    want = bucket_probe_ref(jnp.asarray(bh), jnp.asarray(payload),
                            jnp.asarray(q), bits)
    got = bp.probe(jnp.asarray(bh), jnp.asarray(payload), jnp.asarray(q),
                   bits, use_kernel=True, interpret=True, q_block=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bucket_probe_misses():
    rng = np.random.default_rng(0)
    bh, payload = _bucket_table(rng, 5, 8)
    q = np.zeros(16, np.uint32)      # most likely all misses
    got = bp.probe(jnp.asarray(bh), jnp.asarray(payload), jnp.asarray(q), 5,
                   use_kernel=True, interpret=True, q_block=16)
    want = bucket_probe_ref(jnp.asarray(bh), jnp.asarray(payload),
                            jnp.asarray(q), 5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,t", [(1024, 4), (2048, 8), (3000, 5)])
def test_superkey_sweep(n, t):
    rng = np.random.default_rng(n + t)
    sk_lo = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    sk_hi = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    # half of the queries are guaranteed-contained digests
    q_lo = np.concatenate([sk_lo[:t // 2] & rng.integers(0, 2 ** 32, t // 2,
                                                         dtype=np.uint32),
                           rng.integers(0, 2 ** 32, t - t // 2,
                                        dtype=np.uint32)])
    q_hi = rng.integers(0, 2 ** 32, t, dtype=np.uint32)
    want = superkey_filter_ref(*map(jnp.asarray, (sk_lo, sk_hi, q_lo, q_hi)))
    got = sk.filter_rows(*map(jnp.asarray, (sk_lo, sk_hi, q_lo, q_hi)),
                         use_kernel=True, interpret=True, t_block=4,
                         n_block=512)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_superkey_containment_property(seed):
    """(a | b) always contains a — kernel must agree."""
    rng = np.random.default_rng(seed)
    a_lo = rng.integers(0, 2 ** 32, 256, dtype=np.uint32)
    a_hi = rng.integers(0, 2 ** 32, 256, dtype=np.uint32)
    b_lo = rng.integers(0, 2 ** 32, 256, dtype=np.uint32)
    row_lo, row_hi = a_lo | b_lo, a_hi
    got = sk.filter_rows(jnp.asarray(row_lo), jnp.asarray(row_hi),
                         jnp.asarray(a_lo[:4]), jnp.asarray(a_hi[:4]),
                         use_kernel=True, interpret=True, t_block=4,
                         n_block=256)
    # query digest i is contained in row i by construction
    for i in range(4):
        assert bool(got[i, i])


@pytest.mark.parametrize("t,m", [(8, 64), (24, 128), (5, 32)])
def test_superkey_rows_sweep(t, m):
    """Rowwise candidate-containment variant (the MC bloom stage)."""
    from repro.kernels.superkey_filter.ref import superkey_filter_rows_ref
    rng = np.random.default_rng(t * 10 + m)
    sk_lo = rng.integers(0, 2 ** 32, (t, m), dtype=np.uint32)
    sk_hi = rng.integers(0, 2 ** 32, (t, m), dtype=np.uint32)
    q_lo = sk_lo[:, 0] & rng.integers(0, 2 ** 32, t, dtype=np.uint32)
    q_hi = rng.integers(0, 2 ** 32, t, dtype=np.uint32)
    want = superkey_filter_rows_ref(*map(jnp.asarray,
                                         (sk_lo, sk_hi, q_lo, q_hi)))
    got = sk.filter_candidates(*map(jnp.asarray, (sk_lo, sk_hi, q_lo, q_hi)),
                               use_kernel=True, interpret=True, t_block=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d", [128, 2048, 5000])
def test_qcr_segments_sweep(d):
    """Fused segment epilogue (the C seeker scoring stage)."""
    from repro.kernels.qcr_score.ref import qcr_segments_ref
    rng = np.random.default_rng(d)
    n_all = rng.integers(0, 12, d).astype(np.float32)
    n_agree = np.minimum(rng.integers(0, 12, d), n_all).astype(np.float32)
    want = qcr_segments_ref(jnp.asarray(n_agree), jnp.asarray(n_all))
    got = qc.score_segments(jnp.asarray(n_agree), jnp.asarray(n_all),
                            use_kernel=True, interpret=True, d_block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("g,h", [(64, 32), (128, 64), (200, 128)])
def test_qcr_sweep(g, h):
    rng = np.random.default_rng(g + h)
    quad = rng.integers(0, 2, (g, h)).astype(np.int8)
    qb = rng.integers(0, 2, (g, h)).astype(np.int8)
    val = rng.random((g, h)) < 0.6
    want = qcr_score_ref(jnp.asarray(quad), jnp.asarray(qb), jnp.asarray(val))
    got = qc.score(jnp.asarray(quad), jnp.asarray(qb), jnp.asarray(val),
                   use_kernel=True, interpret=True, g_block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_qcr_perfect_correlation():
    quad = np.ones((8, 64), np.int8)
    qb = np.ones((8, 64), np.int8)
    val = np.ones((8, 64), bool)
    got = qc.score(jnp.asarray(quad), jnp.asarray(qb), jnp.asarray(val),
                   use_kernel=True, interpret=True, g_block=8)
    np.testing.assert_allclose(np.asarray(got), 1.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,k,d,causal", [
    (128, 2, 1, 64, True), (256, 4, 2, 64, True), (256, 2, 2, 128, False)])
def test_flash_attention_sweep(s, h, k, d, causal, dtype):
    rng = np.random.default_rng(s + h + d)
    B = 2
    q = jnp.asarray(rng.normal(0, 1, (B, s, h, d)), dtype)
    kk = jnp.asarray(rng.normal(0, 1, (B, s, k, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, s, k, d)), dtype)
    want = attention_ref(q, kk, v, causal=causal)
    got = fa.attention(q, kk, v, causal=causal, use_kernel=True,
                       interpret=True, q_block=128, kv_block=128)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_matches_model_chunked_path():
    """The Pallas kernel and the model-side pure-JAX chunked attention agree."""
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(0, 1, (1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
    a = chunked_attention(q, k, v, q_chunk=64, kv_chunk=64, causal=True)
    b = fa.attention(q, k, v, causal=True, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
