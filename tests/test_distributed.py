"""Sharded lakes on a real 8-device mesh (subprocess: jax locks the host
device count at first init, so the forced-8-CPU run needs its own process).

Covers the acceptance contract of the shard layer end to end:
  - 8-shard results bit-identical to 1-shard across all four seekers,
    with zero probe-window overflow;
  - a plan still costs ~n_kinds + 1 logical launches (the per-shard
    fan-out counts as ONE dispatch per seeker kind);
  - shards land on 8 distinct devices and per-shard probe windows are
    sized from per-shard counts (the scale-out win: per-device footprint
    and window are ~1/8 of the single-device run, so a fixed per-device
    budget holds >= 8x the tables);
  - live mutations stay shard-local, bump only the owner's epoch, and
    the query cache (keyed on the epoch tuple) never serves stale ids.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    assert len(jax.devices()) == 8, jax.devices()

    import blend
    from repro.core.lake import Table, synthetic_lake
    from repro.dist.shard import ShardedStore, make_shard_mesh

    lake = synthetic_lake(n_tables=48, rows=16, cols=4, vocab=500, seed=7)
    t = lake.tables[5]
    s1 = blend.connect(lake, shards=1)
    s8 = blend.connect(lake, shards=8)

    # one engine per shard, on 8 distinct devices
    assert len(s8.executor.engines) == 8
    assert len({str(d) for d in s8.executor.devices}) == 8
    mesh = make_shard_mesh(8)
    assert mesh is not None and mesh.shape == {"shard": 8}

    queries = {
        "sc":   blend.sc(list(t.columns[0][:6]), k=16).top(8),
        "kw":   blend.kw([t.columns[1][0], t.columns[1][1]], k=16).top(8),
        "mc":   blend.mc([(t.columns[0][r], t.columns[1][r])
                          for r in range(4)], k=16).top(8),
        "corr": blend.corr(list(t.columns[0][:6]),
                           [float(i) for i in range(6)], k=16, h=64).top(8),
        "and":  (blend.sc(list(t.columns[0][:6]), k=16)
                 & blend.kw([t.columns[1][0]], k=16)).top(8),
        "or":   (blend.sc(list(t.columns[0][:6]), k=16)
                 | blend.kw([t.columns[1][0]], k=16)).top(8),
    }
    for name, q in queries.items():
        r1, r8 = s1.query(q), s8.query(q)
        a, b = np.asarray(r1.scores), np.asarray(r8.scores)
        assert a.shape == b.shape and (a == b).all(), f"{name}: not bit-identical"
        assert r1.ids == r8.ids, name
        assert r8.info.overflow == 0, name
        n_kinds = len({n.spec.kind for n in r8.compiled.plan.nodes.values()
                       if n.is_seeker})
        assert r8.info.launches <= n_kinds + 1, (name, r8.info.launches)

    # per-shard probe windows sized from per-shard counts: each shard holds
    # ~1/8 of the postings, so per-device bytes stay ~1/8 of the total —
    # a fixed per-device budget holds >= 8x the single-device table count
    store = s8.executor.index
    per = [s.n_postings for s in store.shards]
    assert sum(per) == store.n_postings
    assert max(per) * 8 <= store.n_postings * 2         # balanced round-robin
    single_bytes = s1.executor.index.storage_bytes()
    assert max(s.storage_bytes() for s in store.shards) * 8 \
        <= single_bytes * 2.5                           # per-shard padding slack
    from repro.core.hashing import hash_array
    h = np.unique(hash_array(list(t.columns[0][:6])))
    pershard = store.host_counts(h, per_shard=True)
    assert pershard.shape[0] == 8
    assert (pershard.sum(axis=0) ==
            s1.executor.index.host_counts(h)).all()

    # live + cache: shard-local mutations under the global epoch tuple
    live8 = blend.connect(lake, shards=8, live=True, cache=True)
    live1 = blend.connect(lake, shards=1, live=True)
    q = queries["and"]
    cold = live8.query(q)
    assert cold.cache.status == "miss"
    assert live8.query(q).cache.status == "hit"
    extra = Table("delta", [[f"d{i}" for i in range(8)],
                            [t.columns[0][0]] * 8,
                            [float(i) for i in range(8)]])
    before = live8.executor.index.epoch
    tid8, tid1 = live8.add_table(extra), live1.add_table(extra)
    assert tid8 == tid1
    after = live8.executor.index.epoch
    assert sum(a != b for a, b in zip(before, after)) == 1   # one shard moved
    live8.drop_table(5); live1.drop_table(5)
    r8, r1 = live8.query(q), live1.query(q)
    assert r8.cache.status == "miss"                         # epoch invalidated
    assert (np.asarray(r8.scores) == np.asarray(r1.scores)).all()
    assert r8.ids == r1.ids
    assert live8.query(q).cache.status == "hit"
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_sharded_serving_8_devices():
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DISTRIBUTED_OK" in r.stdout
