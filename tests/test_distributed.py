"""Distributed seekers == local seekers (subprocess: needs 8 host devices,
and jax locks the device count at first init in the main pytest process)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.launch.mesh import compat_make_mesh
    from repro.core.lake import joinable_lake, correlation_lake, mc_joinable_lake
    from repro.core.index import build_index
    from repro.core.executor import Executor
    from repro.core import distributed as D
    from repro.core.hashing import hash_array, row_superkey, split_u64
    from repro.core import seekers as seek

    mesh = compat_make_mesh((2,2,2), ("pod","data","model"))

    lake, query, _ = joinable_lake(n_tables=60, seed=1)
    idx = build_index(lake); ex = Executor(idx)
    h = hash_array(query); m_cap = ex._mcap_for(h)
    ref, _ = seek.sc_seeker(ex.engine, jnp.asarray(h), jnp.ones(len(h), bool),
                            m_cap=m_cap, n_tables=idx.n_tables,
                            max_cols=idx.max_cols)
    sharded = D.shard_device_index(idx, mesh)
    fn = D.make_distributed_sc(mesh, m_cap=m_cap, n_tables=idx.n_tables,
                               max_cols=idx.max_cols)
    got, _ = fn(sharded, jnp.asarray(h), jnp.ones(len(h), bool))
    assert bool(jnp.all(got == ref)), "SC mismatch"

    fnk = D.make_distributed_kw(mesh, m_cap=m_cap, n_tables=idx.n_tables)
    gotk, _ = fnk(sharded, jnp.asarray(h), jnp.ones(len(h), bool))
    refk, _ = seek.kw_seeker(ex.engine, jnp.asarray(h), jnp.ones(len(h), bool),
                             m_cap=m_cap, n_tables=idx.n_tables)
    assert bool(jnp.all(gotk == refk)), "KW mismatch"

    lake3, keys, target, _ = correlation_lake(n_tables=30, seed=3)
    idx3 = build_index(lake3); ex3 = Executor(idx3)
    h3 = hash_array(keys); m3 = ex3._mcap_for(h3)
    tgt = np.array([float(v) for v in target])
    qb = (tgt >= tgt.mean()).astype(np.int8)
    ref3, _ = seek.c_seeker(ex3.engine, jnp.asarray(h3), jnp.ones(len(h3), bool),
                            jnp.asarray(qb), m_cap=m3, row_cap=8,
                            n_tables=idx3.n_tables, max_cols=idx3.max_cols,
                            h_sample=256, row_stride=idx3.row_stride)
    sh3 = D.shard_device_index(idx3, mesh)
    fn3 = D.make_distributed_c(mesh, m_cap=m3, row_cap=8,
                               n_tables=idx3.n_tables, max_cols=idx3.max_cols,
                               h_sample=256, row_stride=idx3.row_stride)
    got3, _ = fn3(sh3, jnp.asarray(h3), jnp.ones(len(h3), bool), jnp.asarray(qb))
    assert float(jnp.max(jnp.abs(got3 - ref3))) < 1e-6, "C mismatch"

    lake2, tuples, truth2 = mc_joinable_lake(n_tables=40, seed=2)
    idx2 = build_index(lake2)
    th = np.stack([hash_array([t[c] for t in tuples]) for c in range(2)], 1)
    counts = np.stack([idx2.host_counts(th[:, c]) for c in range(2)], 1)
    init_col = np.argmin(counts, 1).astype(np.int32)
    qks = np.array([row_superkey(th[i], np.zeros(2, np.int64))
                    for i in range(len(tuples))], np.uint64)
    lo, hi = split_u64(qks)
    sh2 = D.shard_device_index(idx2, mesh)
    fn2 = D.make_distributed_mc(mesh, m_cap=64, n_tables=idx2.n_tables,
                                n_cols=2, row_stride=idx2.row_stride)
    got2, _ = fn2(sh2, jnp.asarray(th), jnp.asarray(init_col),
                  jnp.asarray(lo), jnp.asarray(hi))
    assert np.array_equal(np.asarray(got2).astype(int), truth2), "MC mismatch"
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_seekers_match_local():
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DISTRIBUTED_OK" in r.stdout
