"""LiveLake: mutation parity with from-scratch rebuilds, LSM segment
behavior, compaction, snapshot persistence, rowkey-stride guards, and the
retrace-free mutation contract."""
import time

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import blend
from repro.core import seekers as seek
from repro.core.executor import Executor
from repro.core.index import build_index, validate_row_stride
from repro.core.lake import DataLake, Table, synthetic_lake
from repro.core.plan import Combiners, Plan, Seekers
from repro.store import CompactionPolicy, LiveLake
from repro.store import snapshot as snap


def small_live_lake(seed=5, n_tables=16):
    return synthetic_lake(n_tables=n_tables, rows=14, cols=4, vocab=200,
                          seed=seed)


def extra_table(i, rows=10, vocab=200):
    rng = np.random.default_rng(1000 + i)
    return Table(f"extra{i}",
                 [[f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [f"tok_{int(x)}" for x in rng.integers(0, vocab, rows)],
                  [float(x) for x in np.round(rng.normal(0, 5, rows), 3)]])


def all_specs(lake_table, k):
    vals = list(lake_table.columns[0][:8])
    tuples = [(lake_table.columns[0][r], lake_table.columns[1][r])
              for r in range(6)]
    return [Seekers.SC(vals, k=k), Seekers.KW(vals, k=k),
            Seekers.MC(tuples, k=k),
            Seekers.Correlation(vals, [float(i) for i in range(8)], k=k,
                                h=64)]


def combiner_plan(lake_table, k):
    vals = list(lake_table.columns[0][:8])
    tuples = [(lake_table.columns[0][r], lake_table.columns[1][r])
              for r in range(5)]
    plan = Plan()
    plan.add("sc", Seekers.SC(vals, k=k))
    plan.add("kw", Seekers.KW(vals[:4], k=k))
    plan.add("mc", Seekers.MC(tuples, k=k))
    plan.add("c", Seekers.Correlation(vals, [float(i) for i in range(8)],
                                      k=k, h=64))
    plan.add("and", Combiners.Intersect(k=k), ["sc", "mc"])
    plan.add("or", Combiners.Union(k=k), ["and", "c"])
    plan.add("cnt", Combiners.Counter(k=k), ["sc", "kw"])
    plan.add("out", Combiners.Difference(k=k), ["or", "cnt"])
    return plan


def assert_rebuild_parity(session, tables_by_tid, probe_table,
                          backend="sorted", interpret=False):
    """Post-mutation scores must be bit-identical to a from-scratch rebuild
    of the live tables, for all four seekers and a 4-combiner plan."""
    live_ids = session.live.live_ids()
    rebuilt = DataLake([tables_by_tid[t] for t in live_ids])
    ref = Executor(build_index(rebuilt), backend=backend, interpret=interpret)
    k = session.index.n_tables
    for spec in all_specs(probe_table, k):
        a = np.asarray(session.executor.run_seeker(spec).scores)
        b = np.asarray(ref.run_seeker(spec).scores)
        np.testing.assert_array_equal(a[live_ids], b, err_msg=spec.kind)
        dead = np.ones(len(a), bool)
        dead[live_ids] = False
        assert (a[dead] == 0).all(), spec.kind
    pa, _ = session.executor.run(combiner_plan(probe_table, k))
    pb, _ = ref.run(combiner_plan(probe_table, k))
    np.testing.assert_array_equal(np.asarray(pa.scores)[live_ids],
                                  np.asarray(pb.scores))
    np.testing.assert_array_equal(np.asarray(pa.mask)[live_ids],
                                  np.asarray(pb.mask))


# --------------------------------------------------------------------------
# mutation parity (tentpole acceptance)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,interpret",
                         [("sorted", False), ("bucket", True)])
def test_mutation_parity_add_drop_compact(backend, interpret):
    lake = small_live_lake()
    session = blend.connect(lake, live=True, backend=backend,
                            interpret=interpret)
    tbl = dict(enumerate(lake.tables))
    probe = lake.tables[3]

    tids = []
    for i in range(3):
        t = extra_table(i)
        tids.append(session.add_table(t))
        tbl[tids[-1]] = t
    assert_rebuild_parity(session, tbl, probe, backend, interpret)

    session.drop_table(5)            # tombstone inside the base segment
    del tbl[5]
    session.drop_table(tids[1])      # whole-run delete of an L0 delta
    del tbl[tids[1]]
    assert_rebuild_parity(session, tbl, probe, backend, interpret)

    session.compact()                # merge + tombstone GC
    assert session.index_shape()["segments"] == 1
    assert_rebuild_parity(session, tbl, probe, backend, interpret)

    t = extra_table(9, rows=12)
    tbl[session.add_table(t)] = t    # delta on top of the compacted base
    assert_rebuild_parity(session, tbl, probe, backend, interpret)


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(st.lists(st.tuples(st.sampled_from(["add", "drop", "compact"]),
                          st.integers(0, 10 ** 6)),
                min_size=1, max_size=5))
def test_mutation_parity_hypothesis_random_sequences(ops):
    """Property: any add/drop/compact sequence preserves rebuild parity."""
    lake = small_live_lake(seed=11, n_tables=10)
    session = blend.connect(lake, live=True)
    tbl = dict(enumerate(lake.tables))
    for i, (op, arg) in enumerate(ops):
        if op == "add":
            t = extra_table(arg % 50, rows=6 + arg % 9)
            tbl[session.add_table(t, name=f"h{i}_{arg}")] = t
        elif op == "drop" and len(tbl) > 4:
            tid = sorted(tbl)[arg % len(tbl)]
            session.drop_table(tid)
            del tbl[tid]
        elif op == "compact":
            session.compact(full=arg % 2 == 0)
    assert_rebuild_parity(session, tbl, lake.tables[2])


def test_reclaim_ids_remaps_and_preserves_results():
    lake = small_live_lake(seed=13)
    session = blend.connect(lake, live=True)
    tbl = dict(enumerate(lake.tables))
    for ref in (1, 7, 9):
        session.drop_table(ref)
        del tbl[ref]
    vals = list(lake.tables[3].columns[0][:8])
    before = {session.live.store.table_names[t]
              for t in session.query(blend.sc(vals, k=30)).ids}
    remap = session.compact(reclaim_ids=True)
    assert sorted(remap.values()) == list(range(len(tbl)))
    after = {session.live.store.table_names[t]
             for t in session.query(blend.sc(vals, k=30)).ids}
    assert before == after            # same tables by name, new dense ids
    tbl2 = {remap[t]: tab for t, tab in tbl.items()}
    assert_rebuild_parity(session, tbl2, lake.tables[3])


# --------------------------------------------------------------------------
# LSM mechanics
# --------------------------------------------------------------------------

def test_add_is_delta_drop_is_tombstone_or_run_delete():
    lake = small_live_lake()
    ll = LiveLake(lake, auto_compact=False)
    base = ll.store.segments[0]
    tid = ll.add_table(extra_table(0))
    assert ll.store.segments[0] is base          # base untouched
    assert len(ll.store.segments) == 2
    ll.drop_table(tid)                           # sole table of its run
    assert len(ll.store.segments) == 1
    assert not ll.store.pending_dead
    assert tid in ll.store.free_ids              # slot immediately reusable
    ll.drop_table(2)                             # lives inside the base
    assert len(ll.store.segments) == 1           # no rewrite: tombstoned
    assert 2 in ll.store.pending_dead
    shape = ll.shape()
    assert shape["tombstoned"] == [lake.tables[2].name]


def test_auto_compact_bounds_segment_count():
    lake = small_live_lake(n_tables=8)
    policy = CompactionPolicy(max_segments=4, tier_fanout=2)
    ll = LiveLake(lake, policy=policy)
    for i in range(12):
        ll.add_table(extra_table(i))
    assert len(ll.store.segments) <= policy.max_segments
    # every live table still wholly inside exactly one segment
    owners = [s for i in range(ll.store.n_slots) if ll.store.alive[i]
              for s in ll.store.segments if i in s.tables]
    assert len(owners) == int(ll.store.alive.sum())


def test_id_reuse_never_resurrects_postings():
    lake = small_live_lake(seed=21)
    session = blend.connect(lake, live=True)
    ghost = Table("ghost", [["spectral_token"] * 6,
                            [float(i) for i in range(6)]])
    tid = session.add_table(ghost)
    session.drop_table(tid)
    reborn = Table("reborn", [["solid_token"] * 6,
                              [float(i) for i in range(6)]])
    tid2 = session.add_table(reborn)
    assert tid2 == tid                            # slot reused
    assert session.query(blend.kw(["spectral_token"], k=5)).ids == []
    assert session.query(blend.kw(["solid_token"], k=5)).ids == [tid2]


def test_plan_pins_epoch_against_midplan_mutation():
    """A mutation landing while a plan executes must not be observed until
    the next plan: every seeker of one request sees one epoch."""
    lake = small_live_lake()
    session = blend.connect(lake, live=True)
    ex = session.executor
    session.query(blend.kw(["tok_1"], k=5))
    engine = ex.engine
    ex._in_plan = True            # emulate: plan in flight, epoch pinned
    try:
        session.add_table(extra_table(0))
        rs = ex.run_seeker(Seekers.KW(["tok_1"], k=5))
        assert ex.engine is engine                     # old epoch served
        assert len(np.asarray(rs.scores)) == ex.n_tables
    finally:
        ex._in_plan = False
    session.query(blend.kw(["tok_1"], k=5))
    assert ex.engine is not engine                     # next plan refreshes


def test_epoch_bumps_and_engine_refresh():
    lake = small_live_lake()
    session = blend.connect(lake, live=True)
    ex = session.executor
    e0 = session.live.epoch
    engine0 = ex.engine
    tid = session.add_table(extra_table(0))
    assert session.live.epoch > e0
    assert ex.engine is engine0       # refresh is lazy ...
    session.query(blend.kw(["tok_1"], k=5))
    assert ex.engine is not engine0   # ... and happens at query entry
    assert ex._engine_epoch == session.live.epoch
    session.drop_table(tid)


# --------------------------------------------------------------------------
# retrace-free mutation serving + add_table speed (acceptance criteria)
# --------------------------------------------------------------------------

def test_add_table_zero_retrace_within_capacity_bucket():
    lake = small_live_lake(seed=31)
    session = blend.connect(lake, live=True)
    t3 = lake.tables[3]
    q = (blend.sc(list(t3.columns[0][:8]), k=20)
         & blend.mc([(t3.columns[0][r], t3.columns[1][r])
                     for r in range(5)], k=20)).top(10)
    session.query(q)
    # warm the mutated-topology jit entries once
    tid = session.add_table(extra_table(0))
    session.query(q)
    session.drop_table(tid)
    session.query(q)
    before = dict(seek.TRACE_COUNTS)
    # same capacity bucket (similar-size table, same padded segment rung):
    # the mutation and the queries after it compile nothing new
    tid = session.add_table(extra_table(1))
    session.query(q)
    session.drop_table(tid)
    session.query(q)
    assert dict(seek.TRACE_COUNTS) == before


@pytest.mark.slow
def test_add_table_much_faster_than_rebuild_bench_lake():
    """>= 10x on the 200-table bench lake (ISSUE 3 acceptance)."""
    lake = synthetic_lake(n_tables=200, rows=40, vocab=1500, seed=1)
    session = blend.connect(lake, live=True)
    small = extra_table(0, rows=40)
    t0 = time.perf_counter()
    tid = session.add_table(small)
    add_s = time.perf_counter() - t0
    session.drop_table(tid)
    t0 = time.perf_counter()
    build_index(lake)
    rebuild_s = time.perf_counter() - t0
    assert rebuild_s / add_s >= 10, (add_s, rebuild_s)


# --------------------------------------------------------------------------
# rowkey stride guards (satellite: aliasing fix)
# --------------------------------------------------------------------------

def test_row_stride_validation_guards():
    with pytest.raises(ValueError, match="alias"):
        validate_row_stride(10, 1 << 4, max_rows=100)
    with pytest.raises(ValueError, match="shard the lake"):
        validate_row_stride(2 ** 10, 1 << 22)
    validate_row_stride(100, 1 << 7, max_rows=100)


def test_build_index_auto_widens_stride():
    lake = small_live_lake()
    idx = build_index(lake)
    assert idx.row_stride >= max(t.n_rows for t in lake.tables)
    wide = build_index(lake, row_stride=1 << 10)
    assert wide.row_stride == 1 << 10      # explicit stride honored upward


def test_live_add_long_table_widens_stride_with_parity():
    lake = small_live_lake(seed=41)
    session = blend.connect(lake, live=True)
    stride0 = session.live.store.row_stride
    long = extra_table(3, rows=4 * stride0)
    tbl = dict(enumerate(lake.tables))
    tbl[session.add_table(long)] = long
    assert session.live.store.row_stride >= 4 * stride0
    assert_rebuild_parity(session, tbl, lake.tables[2])


def test_live_stride_overflow_raises():
    lake = small_live_lake()
    ll = LiveLake(lake)

    class HugeTable:            # geometry-only stand-in: rejected pre-build
        name = "huge"
        n_rows = (1 << 26) + 1
        n_cols = 2
        columns = []

    with pytest.raises(ValueError, match="shard the lake"):
        ll.add_table(HugeTable())
    assert ll.store.n_slots == lake.n_tables      # nothing was allocated


# --------------------------------------------------------------------------
# snapshot persistence
# --------------------------------------------------------------------------

def test_snapshot_roundtrip_parity(tmp_path):
    lake = small_live_lake(seed=51)
    session = blend.connect(lake, live=True)
    tbl = dict(enumerate(lake.tables))
    t = extra_table(2)
    tbl[session.add_table(t)] = t
    session.drop_table(4)
    del tbl[4]
    man = session.snapshot(tmp_path / "lake")
    assert man.exists() and (tmp_path / "lake.npz").exists()

    restored = blend.restore(tmp_path / "lake")
    probe = lake.tables[3]
    k = session.index.n_tables
    for spec in all_specs(probe, k):
        a = np.asarray(session.executor.run_seeker(spec).scores)
        b = np.asarray(restored.executor.run_seeker(spec).scores)
        live = session.live.live_ids()
        np.testing.assert_array_equal(a[live], b[restored.live.live_ids()])
    # restored lakes stay mutable
    t2 = extra_table(7)
    tid = restored.add_table(t2)
    assert tid in restored.live.live_ids()


def test_alloc_growth_validation_leaves_store_intact():
    """A rejected slot-capacity growth must not corrupt the store."""
    lake = small_live_lake(n_tables=8)           # slot capacity 16
    ll = LiveLake(lake, auto_compact=False)
    ll.store.row_stride = 1 << 26                # growth to 32 would overflow
    for i in range(8):                           # fill the remaining slots
        ll.add_table(extra_table(i))
    with pytest.raises(ValueError, match="shard the lake"):
        ll.add_table(extra_table(99))
    assert ll.store.n_slots == len(ll.store.alive) == 16
    assert ll.store.live_ids() == list(range(16))   # still consistent


def test_snapshot_preserves_with_quadrants(tmp_path):
    from repro.store.segments import SegmentStore
    lake = small_live_lake()
    ll = LiveLake(store=SegmentStore(lake, with_quadrants=False))
    ll.snapshot(tmp_path / "nq")
    restored = snap.load(tmp_path / "nq")
    assert restored.with_quadrants is False


def test_snapshot_version_check(tmp_path):
    lake = small_live_lake()
    ll = LiveLake(lake)
    ll.snapshot(tmp_path / "s")
    manifest = (tmp_path / "s.json")
    import json
    bad = json.loads(manifest.read_text())
    bad["version"] = 99
    manifest.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="version"):
        snap.load(tmp_path / "s")


# --------------------------------------------------------------------------
# observability + serving integration
# --------------------------------------------------------------------------

def test_explain_reports_index_shape():
    lake = small_live_lake()
    session = blend.connect(lake, live=True)
    session.add_table(extra_table(0))
    session.drop_table(1)
    ex = session.explain(blend.kw(["tok_1"], k=5))
    s = ex.index_shape
    assert s["mode"] == "live" and s["segments"] == 2
    assert s["epoch"] == session.live.epoch
    assert len(s["postings_per_segment"]) == 2
    assert s["tombstoned"] == [lake.tables[1].name]
    text = str(ex)
    assert "segments: 2" in text and "tombstoned" in text
    # static sessions report a single-segment shape
    st_shape = blend.connect(lake).explain(blend.kw(["tok_1"], k=5),
                                           execute=False).index_shape
    assert st_shape["mode"] == "static" and st_shape["segments"] == 1


def test_discovery_engine_live_mutations():
    from repro.serve.engine import DiscoveryEngine
    lake = small_live_lake()
    eng = DiscoveryEngine(lake, live=True)
    t = extra_table(0)
    tid = eng.add_table(t)
    resp = eng.serve(blend.kw([t.columns[0][0]], k=30))
    assert tid in resp.table_ids
    eng.drop_table(tid)
    assert tid not in eng.serve(blend.kw([t.columns[0][0]], k=30)).table_ids
    eng.compact()
    static = DiscoveryEngine(lake)
    with pytest.raises(RuntimeError, match="live=True"):
        static.add_table(t)


def test_sharded_store_accepts_live_mutations():
    from repro.dist.shard import ShardedStore
    lake = small_live_lake()
    ll = LiveLake(lake)
    ll.add_table(extra_table(0))
    ll.drop_table(2)
    merged = ll.store.merged_index()
    assert (np.diff(merged.cell_hash.astype(np.int64)) >= 0).all()
    assert 2 not in set(merged.table_id.tolist())
    # the sharded coordinator observes the same mutations shard-locally
    store = ShardedStore(lake, n_shards=2)
    sl = LiveLake(lake, store=store)
    sl.add_table(extra_table(0))
    sl.drop_table(2)
    assert sorted(sl.live_ids()) == sorted(ll.live_ids())
    assert store.n_postings == sum(s.n_postings for s in store.shards)
    assert 2 in store.pending_dead


def test_host_counts_live_only_excludes_tombstones():
    from repro.core.hashing import hash_array
    lake = small_live_lake()
    ll = LiveLake(lake)
    vals = list(lake.tables[2].columns[0][:6])
    h = np.unique(hash_array(vals))
    full = ll.store.host_counts(h)
    ll.drop_table(2)
    assert (ll.store.host_counts(h) == full).all()          # slots still held
    live = ll.store.host_counts(h, live_only=True)
    assert live.sum() < full.sum()


# --------------------------------------------------------------------------
# sketch tier: mutation / compaction / snapshot parity (approx discovery)
# --------------------------------------------------------------------------

SKETCH_FIELDS = ("kmv", "kmv_m", "tbl_kmv", "minhash", "samp_rows",
                 "samp_hash", "samp_quad")


def _assert_sketches_equal(got, want, msg=""):
    assert set(got) == set(want), msg
    for t in got:
        assert got[t].tbl_m == want[t].tbl_m, (msg, t)
        for f in SKETCH_FIELDS:
            np.testing.assert_array_equal(
                getattr(got[t], f), getattr(want[t], f),
                err_msg=f"{msg} table {t} field {f}")


def test_sketch_tier_survives_mutations_bit_identically():
    """Live-store sketches after add/drop/compact == a from-scratch build of
    the surviving tables (sketches are content-addressed, so the comparison
    is field-exact even though the rebuild assigns different table ids)."""
    lake = small_live_lake(seed=61)
    session = blend.connect(lake, live=True)
    tbl = dict(enumerate(lake.tables))
    for i in range(3):
        t = extra_table(i)
        tbl[session.add_table(t)] = t
    session.drop_table(5)
    del tbl[5]
    live_ids = session.live.live_ids()
    live_map = session.live.store.sketch_map()
    assert set(live_map) == set(live_ids)
    rebuilt = build_index(DataLake([tbl[t] for t in live_ids]))
    for pos, tid in enumerate(live_ids):
        for f in SKETCH_FIELDS:
            np.testing.assert_array_equal(
                getattr(live_map[tid], f), getattr(rebuilt.sketches[pos], f),
                err_msg=f"tid {tid} field {f}")
    before = dict(live_map)
    session.compact()                # merge must re-derive identical sketches
    _assert_sketches_equal(session.live.store.sketch_map(), before, "compact")


def test_sketch_tier_snapshot_roundtrip(tmp_path):
    lake = small_live_lake(seed=63)
    session = blend.connect(lake, live=True)
    session.add_table(extra_table(4))
    session.drop_table(2)
    before = dict(session.live.store.sketch_map())
    session.snapshot(tmp_path / "sk")
    restored = blend.restore(tmp_path / "sk")
    assert (restored.live.store.sketch_config
            == session.live.store.sketch_config)
    _assert_sketches_equal(restored.live.store.sketch_map(), before,
                           "restore")


def test_approx_query_parity_through_mutations():
    """approx(epsilon=0) ids stay identical to exact ids at every mutation
    stage — the sketch packs must track the store epoch, not go stale."""
    lake = small_live_lake(seed=65)
    session = blend.connect(lake, live=True, cache=True)
    t3 = lake.tables[3]
    vals = list(t3.columns[0][:8])
    specs = [Seekers.SC(vals, k=10), Seekers.KW(vals, k=10),
             Seekers.Correlation(vals, [float(i) for i in range(8)], k=10,
                                 h=64)]

    def check(stage):
        for spec in specs:
            p = Plan()
            p.add("out", spec)
            exact = session.query(p)
            approx = session.query(p, approx={"epsilon": 0.0})
            assert approx.ids == exact.ids, (stage, spec.kind)
            assert approx.approx is not None, (stage, spec.kind)

    check("initial")
    tid = session.add_table(extra_table(6))
    check("after add")
    session.drop_table(tid)
    session.drop_table(5)
    check("after drop")
    session.compact()
    check("after compact")
