"""Observability unit + integration tests: histogram bucketing and
percentile snapshots on a fake clock, span nesting/ordering, the Chrome
trace-event export schema, the per-request flight recorder (span presence
and queue+batch coverage of end-to-end latency), metrics flowing from every
instrumented layer, and parity — tracing/metrics/sync-timing change no ids
and no scores."""
import json
import math

import numpy as np
import pytest

import blend
from repro import obs
from repro.core.lake import synthetic_lake
from repro.obs.metrics import (Histogram, MetricsRegistry, NULL_REGISTRY,
                               NullRegistry)
from repro.obs.trace import (NULL_RECORDER, Recorder, Span, chrome_trace,
                             current, recording)
from repro.serve.engine import DiscoveryEngine
from repro.serve.server import DiscoveryServer


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled."""
    obs.disable()
    yield
    obs.disable()


# ------------------------------------------------------------------ metrics

def test_histogram_bucket_index_and_edges():
    h = Histogram("t", lo=1e-3, growth=2.0, n_buckets=8)
    assert h.bucket_index(0.0) == 0
    assert h.bucket_index(5e-4) == 0
    assert h.bucket_index(1e-3) == 1          # [lo, 2*lo)
    assert h.bucket_index(1.9e-3) == 1
    assert h.bucket_index(2.1e-3) == 2
    assert h.bucket_index(1e9) == 7           # clamps to last bucket
    lo, hi = h.bucket_edges(1)
    assert lo == pytest.approx(1e-3) and hi == pytest.approx(2e-3)
    assert h.bucket_edges(0) == (0.0, 1e-3)


def test_histogram_percentiles_bucket_resolution():
    h = Histogram("t", lo=1e-3, growth=2.0, n_buckets=32)
    for v in [0.002] * 50 + [0.016] * 49 + [1.0]:
        h.observe(v)
    # p50 lands in 0.002's bucket: within a factor sqrt(2) of the true value
    p50 = h.percentile(50)
    assert 0.002 / math.sqrt(2) <= p50 <= 0.002 * math.sqrt(2)
    p95 = h.percentile(95)
    assert 0.016 / math.sqrt(2) <= p95 <= 0.016 * math.sqrt(2)
    # the top observation lands in [0.512, 1.024): its reported quantile is
    # bucket-resolution but never exceeds the exact observed max
    assert 0.512 <= h.percentile(99.9) <= h.max == 1.0
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["min"] == 0.002 and snap["max"] == 1.0
    assert snap["mean"] == pytest.approx(h.sum / 100)


def test_histogram_single_value_percentile_exact():
    h = Histogram("t")
    h.observe(0.125)
    # clamped into [min, max]: a single-value distribution reports exactly
    for q in (50, 95, 99):
        assert h.percentile(q) == 0.125


def test_histogram_empty_snapshot():
    snap = Histogram("t").snapshot()
    assert snap == {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_registry_timer_fake_clock():
    clock = FakeClock()
    reg = MetricsRegistry(now=clock)
    with reg.timer("op_seconds"):
        clock.advance(0.25)
    h = reg.histogram("op_seconds")
    assert h.count == 1 and h.sum == pytest.approx(0.25)
    assert h.percentile(50) == pytest.approx(0.25)


def test_registry_counters_gauges_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(7)
    reg.gauge("g").dec(3)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == 4.0
    assert reg.render()                        # renders without error
    # one name, one meaning
    with pytest.raises(TypeError):
        reg.gauge("c")


def test_null_registry_is_shared_noop():
    assert NULL_REGISTRY.counter("a") is NULL_REGISTRY.counter("b")
    NULL_REGISTRY.counter("a").inc(100)
    assert NULL_REGISTRY.counter("a").value == 0.0
    with NULL_REGISTRY.timer("x"):
        pass
    assert NULL_REGISTRY.snapshot() == {"counters": {}, "gauges": {},
                                        "histograms": {}}
    assert not NullRegistry.enabled


def test_enable_disable_and_sync_timing():
    assert not obs.enabled()
    assert obs.registry() is NULL_REGISTRY
    reg = obs.enable(sync_timing=True)
    assert obs.enabled() and obs.registry() is reg and obs.sync_timing()
    reg.counter("x").inc()
    # enable() makes a FRESH registry: no cross-test pollution
    reg2 = obs.enable()
    assert reg2 is not reg and reg2.counter("x").value == 0.0
    obs.disable()
    assert obs.registry() is NULL_REGISTRY and not obs.sync_timing()


# ------------------------------------------------------------------ tracing

def test_span_nesting_and_ordering_fake_clock():
    clock = FakeClock()
    rec = Recorder(now=clock)
    with rec.span("outer") as outer:
        clock.advance(1.0)
        with rec.span("a", key="v"):
            clock.advance(2.0)
        with rec.span("b"):
            clock.advance(3.0)
    assert rec.roots == [outer]
    assert outer.t0 == 0.0 and outer.t1 == 6.0
    assert [c.name for c in outer.children] == ["a", "b"]
    a, b = outer.children
    assert (a.t0, a.t1) == (1.0, 3.0)
    assert (b.t0, b.t1) == (3.0, 6.0)
    assert a.attrs == {"key": "v"}
    assert outer.duration == 6.0
    assert [s.name for s in outer.walk()] == ["outer", "a", "b"]
    assert outer.find("b") is b and outer.find("zzz") is None
    assert "outer" in outer.render() and "a" in outer.render()


def test_recorder_record_premeasured_interval():
    rec = Recorder(now=FakeClock(10.0))
    with rec.span("root"):
        s = rec.record("queue", t0=4.0, t1=9.0, lane="interactive")
    assert rec.roots[0].children == [s]
    assert s.duration == pytest.approx(5.0)


def test_recording_contextvar():
    assert current() is NULL_RECORDER
    rec = Recorder()
    with recording(rec):
        assert current() is rec
        with current().span("x"):
            pass
    assert current() is NULL_RECORDER
    assert rec.roots[0].name == "x"
    # null recorder spans are inert and reusable
    with NULL_RECORDER.span("y") as s:
        assert s.set("a", 1) is s and s.duration == 0.0


def test_chrome_trace_schema_and_shared_subtree_once():
    clock = FakeClock()
    rec = Recorder(now=clock)
    with rec.span("batch", tid="dispatcher") as bspan:
        clock.advance(2.0)
    r1 = Span("request", t0=0.0, t1=2.0, tid="req-1", children=[bspan])
    r2 = Span("request", t0=0.0, t1=2.0, tid="req-2", children=[bspan])
    doc = chrome_trace([r1, r2])
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and doc["displayTimeUnit"] == "ms"
    xs = [e for e in evs if e["ph"] == "X"]
    ms = [e for e in evs if e["ph"] == "M"]
    assert len(xs) + len(ms) == len(evs)
    for e in xs:
        assert set(e) >= {"name", "ph", "pid", "tid", "ts", "dur", "args"}
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
    # the shared batch subtree is emitted exactly once
    assert sum(1 for e in xs if e["name"] == "batch") == 1
    assert sum(1 for e in xs if e["name"] == "request") == 2
    assert {e["args"]["name"] for e in ms} >= {"dispatcher", "req-1",
                                               "req-2"}
    json.dumps(doc)                            # JSON-serializable end to end


# --------------------------------------------------------- serving stack

def obs_lake():
    return synthetic_lake(n_tables=16, rows=14, cols=4, vocab=200, seed=9)


def obs_queries(lake, k=20):
    t = lake.tables[3]
    sc = blend.sc(list(t.columns[0][:8]), k=k)
    kw = blend.kw([t.columns[1][0], t.columns[1][2]], k=k)
    mc = blend.mc([(t.columns[0][r], t.columns[1][r]) for r in range(4)],
                  k=k)
    return [(sc & mc).top(10), (sc | kw).top(10), (mc - kw).top(10)]


def test_flight_recorder_and_metrics_end_to_end(tmp_path):
    lake = obs_lake()
    queries = obs_queries(lake)
    reg = obs.enable()
    with DiscoveryServer(DiscoveryEngine(lake, live=True, cache=True),
                         trace=True) as srv:
        resps = [f.result() for f in
                 [srv.submit(q) for q in queries for _ in range(2)]]
        # a repeat pass is served from the exact-result cache: it still
        # records a trace (queue/batch), just no probe work
        hits = [f.result() for f in [srv.submit(q) for q in queries]]
        assert all(h.trace is not None and h.trace.find("queue")
                   for h in hits)
        for r in resps:
            root = r.trace
            assert root is not None and root.name == "request"
            names = [s.name for s in root.walk()]
            for need in ("queue", "batch", "pin_epoch", "drain", "transfer",
                         "merge"):
                assert need in names, names
            assert any(n.startswith("probe:") for n in names)
            assert any(n.startswith("shard:") for n in names)
            # queue + batch are contiguous: spans cover end-to-end latency
            covered = sum(c.duration for c in root.children)
            assert covered == pytest.approx(root.duration, rel=0.10)
            # and the response's own telemetry agrees with the tree
            assert root.find("queue").duration == \
                pytest.approx(r.queue_seconds, abs=2e-3)
        # metrics flowed from every instrumented layer
        snap = reg.snapshot()
        assert snap["counters"]["server.served"] >= 9
        assert snap["counters"]["exec.plans"] >= 1
        assert snap["counters"]["cache.result.miss"] >= 1
        assert "server.batch_seconds" in snap["histograms"]
        assert "shard.probe_seconds.0" in snap["histograms"]
        # stats() is a thin reader of the same registry
        st = srv.stats()
        assert st["served"] == int(reg.counter("server.served").value)
        assert st["mutations"]["executed"] == 0
        # explain carries the metrics snapshot
        assert "== metrics ==" in str(srv.explain(queries[0]))
        # flight-recorder export is valid Chrome trace JSON
        path = srv.dump_trace(tmp_path / "trace.json")
        doc = json.loads((tmp_path / "trace.json").read_text())
        assert path == tmp_path / "trace.json"
        evs = doc["traceEvents"]
        assert evs and all(e["ph"] in ("X", "M") for e in evs)
        assert any(e["ph"] == "X" and e["name"] == "request" for e in evs)


def test_store_mutation_metrics():
    lake = obs_lake()
    reg = obs.enable()
    session = blend.connect(lake, live=True)
    t = lake.tables[0]
    tid = session.add_table(t)
    session.drop_table(tid)
    session.compact()
    snap = reg.snapshot()
    for name in ("store.add_table_seconds", "store.drop_table_seconds",
                 "store.compact_seconds"):
        assert snap["histograms"][name]["count"] == 1
    for g in ("store.segments", "store.postings", "store.live_tables",
              "store.compaction_debt", "store.tombstones"):
        assert g in snap["gauges"]
    assert snap["gauges"]["store.segments"] >= 1


def test_retrace_counter_bridges_trace_counts():
    from repro.core import seekers as seek
    reg = obs.enable()
    seek._mark_trace("TEST_KIND")
    assert reg.counter("exec.retraces").value == 1
    assert reg.counter("exec.retraces.TEST_KIND").value == 1
    seek.TRACE_COUNTS.pop("TEST_KIND", None)


def test_observability_changes_no_ids_or_scores():
    """Parity: tracing + metrics + synchronized timing are observation only."""
    lake = obs_lake()
    queries = obs_queries(lake)
    with DiscoveryServer(DiscoveryEngine(lake, live=True)) as srv:
        base = [f.result() for f in [srv.submit(q) for q in queries]]
    obs.enable(sync_timing=True)
    with DiscoveryServer(DiscoveryEngine(lake, live=True),
                         trace=True) as srv:
        traced = [f.result() for f in [srv.submit(q) for q in queries]]
    for b, t in zip(base, traced):
        assert b.table_ids == t.table_ids
        np.testing.assert_array_equal(np.asarray(b.scores),
                                      np.asarray(t.scores))


def test_server_uses_private_registry_when_disabled():
    lake = obs_lake()
    with DiscoveryServer(DiscoveryEngine(lake)) as srv:
        srv.serve(obs_queries(lake)[0])
        st = srv.stats()
        assert st["served"] == 1
        # nothing leaked into the (disabled) global registry
        assert obs.registry() is NULL_REGISTRY
        assert srv.metrics is not NULL_REGISTRY


def test_loadgen_report_queue_percentiles():
    from repro.serve.loadgen import ReplayReport
    rep = ReplayReport(offered=4, completed=4, shed=0, mutations=0,
                       makespan_s=1.0, latencies_s=[0.01, 0.02, 0.03, 0.04],
                       queue_s=[0.001, 0.002, 0.003, 0.1],
                       batch_sizes=[2, 2, 2, 2], shed_reasons={},
                       server_stats={"batches": {"size_hist": {}}})
    d = rep.as_dict()
    assert d["queue_ms_p50"] > 0
    assert d["queue_ms_p99"] >= d["queue_ms_p50"]
