"""Brute-force oracle: the ground truth the engine is conformance-tested to.

A pure-NumPy reference implementation of all four seekers and all four
combiners over a *raw* lake — no unified index, no MatchEngine, no kernels,
no jax.  Every score is computed by direct set algebra over the table cells,
mirroring the executor's documented semantics:

* value identity follows ``core.hashing.hash_value`` canonicalization
  (integral floats join like ints, bools like ints) — but by *value*, never
  by hash;
* SC/KW query values and C (join, target) pairs dedupe; MC tuples dedupe
  raw (a permuted duplicate tuple still scores separately);
* C replicates the in-index QCR reformulation: per (table, join-col,
  num-col) triple, ``|2 * n_agree - n_all| / n_all`` over the h-sampled
  numeric cells row-joined to the query key matches, with the ``rand``
  sampling permutation re-derived from the index's documented per
  (table-name, column) seeding;
* the top-k select matches ``combiners.topk_result`` bit-for-bit (stable
  index-order tie-break, positive scores only), and the QCR division is done
  in float32 so fractional scores compare exactly against the device.

Assumes the conformance lakes stay under the engine's static capacities
(match counts within the m_cap ladder, numeric columns per row within
row_cap) — the sweep in tests/test_oracle.py sizes its lakes accordingly.

Used by tests/test_oracle.py (both probe backends vs this oracle) and by
tests/test_query_cache.py (cache parity leans on the same ground truth).
"""
from __future__ import annotations

import numpy as np

from repro.core.hashing import fnv1a_bytes

MIN_SUPPORT = 3


def canon(v):
    """Value canonicalization mirroring ``hash_value`` (2.0 == 2, True == 1),
    applied before any set membership below."""
    if isinstance(v, (bool, np.bool_)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        v = float(v)
        return int(v) if v.is_integer() else v
    if isinstance(v, (int, np.integer)):
        return int(v)
    return v


def _columns(table):
    return [[canon(v) for v in col] for col in table.columns]


def _is_numeric_col(values) -> bool:
    seen = False
    for v in values:
        if v is None:
            continue
        if isinstance(v, (bool, str)):
            return False
        if not isinstance(v, (int, float, np.integer, np.floating)):
            return False
        seen = True
    return seen


# --------------------------------------------------------------------- seekers
def oracle_sc(lake, values) -> np.ndarray:
    """COUNT(DISTINCT value) per (table, column), table score = best column."""
    qs = {canon(v) for v in values}
    out = np.zeros(lake.n_tables, np.float32)
    for t, tab in enumerate(lake.tables):
        cols = _columns(tab)
        out[t] = max((len(qs & set(c)) for c in cols), default=0)
    return out


def oracle_kw(lake, values) -> np.ndarray:
    """Distinct query values present anywhere in the table."""
    qs = {canon(v) for v in values}
    out = np.zeros(lake.n_tables, np.float32)
    for t, tab in enumerate(lake.tables):
        allv: set = set()
        for c in _columns(tab):
            allv |= set(c)
        out[t] = len(qs & allv)
    return out


def oracle_mc(lake, tuples) -> np.ndarray:
    """Query tuples exactly joinable with some row (every tuple value in the
    same row, any column, any order).  Tuples dedupe raw, like the executor's
    ``dict.fromkeys`` — permuted duplicates each count."""
    qts = list(dict.fromkeys(tuple(t) for t in tuples))
    out = np.zeros(lake.n_tables, np.float32)
    for t, tab in enumerate(lake.tables):
        cols = _columns(tab)
        rows = [{c[r] for c in cols} for r in range(tab.n_rows)]
        n = 0
        for tup in qts:
            vals = [canon(v) for v in tup]
            if any(all(v in row for v in vals) for row in rows):
                n += 1
        out[t] = n
    return out


def _rand_ranks(table_name: str, col: int, n_rows: int,
                seed: int = 0) -> np.ndarray:
    """The index's ``rank_rand`` shuffle, re-derived from its documented per
    (table name, column) seeding (core/index.py table_postings)."""
    rng = np.random.default_rng(
        [seed, fnv1a_bytes(str(table_name).encode()), col])
    return rng.permutation(n_rows)


def oracle_c(lake, join_values, target_values, h_sample: int = 256,
             sampling: str = "conv", seed: int = 0,
             min_support: int = MIN_SUPPORT) -> np.ndarray:
    """QCR correlation scores: for every (join value -> target) pair, join
    on rows containing the value (any column is the join column), collect
    the h-sampled numeric cells of those rows per numeric column, and score
    each (join-col, num-col) triple ``|2a - n| / n``; table score = best
    triple with ``n >= min_support``."""
    pairs = list(dict.fromkeys(zip(join_values, target_values)))
    tgt = np.array([float(p[1]) for p in pairs])
    qbit = (tgt >= tgt.mean()).astype(np.int8)
    out = np.zeros(lake.n_tables, np.float32)
    for t, tab in enumerate(lake.tables):
        cols = _columns(tab)
        numeric = [c for c, col in enumerate(tab.columns)
                   if _is_numeric_col(col)]
        quad = {c: (np.array([float(v) for v in tab.columns[c]])
                    >= np.mean([float(v) for v in tab.columns[c]]))
                .astype(np.int8) for c in numeric}
        rank = {c: (np.arange(tab.n_rows) if sampling == "conv"
                    else _rand_ranks(tab.name, c, tab.n_rows, seed))
                for c in numeric}
        n_all: dict = {}
        n_agree: dict = {}
        for (v, _), bit in zip(pairs, qbit):
            vq = canon(v)
            for cj, col in enumerate(cols):
                for r, cell in enumerate(col):
                    if cell != vq:
                        continue
                    for nc in numeric:
                        if rank[nc][r] >= h_sample:
                            continue
                        key = (cj, nc)
                        n_all[key] = n_all.get(key, 0) + 1
                        if quad[nc][r] == bit:
                            n_agree[key] = n_agree.get(key, 0) + 1
        best = np.float32(0.0)
        for key, n in n_all.items():
            if n < min_support:
                continue
            a = np.float32(n_agree.get(key, 0))
            score = np.abs(np.float32(2.0) * a - np.float32(n)) / np.float32(n)
            best = max(best, score)
        out[t] = best
    return out


def oracle_seeker(lake, spec) -> np.ndarray:
    """Raw (pre-top-k) scores for one ``SeekerSpec``."""
    if spec.kind == "SC":
        return oracle_sc(lake, spec.values)
    if spec.kind == "KW":
        return oracle_kw(lake, spec.values)
    if spec.kind == "MC":
        return oracle_mc(lake, spec.values)
    if spec.kind == "C":
        return oracle_c(lake, spec.values, spec.target, h_sample=spec.h,
                        sampling=spec.sampling)
    raise ValueError(spec.kind)


# ------------------------------------------------------------------- combiners
def oracle_topk(scores: np.ndarray, k: int):
    """``combiners.topk_result``: top-k positive scores, stable index-order
    tie-break (lax.top_k keeps the lower index first on ties)."""
    scores = np.asarray(scores, np.float32)
    k = min(k, scores.shape[0])
    order = np.argsort(-scores, kind="stable")[:k]
    keep = scores[order] > 0
    mask = np.zeros(scores.shape[0], bool)
    mask[order[keep]] = True
    return np.where(mask, scores, np.float32(0.0)), mask


def _maybe_topk(scores, mask, k):
    if k is None:
        return np.where(mask, scores, np.float32(0.0)), mask
    return oracle_topk(np.where(mask, scores, np.float32(0.0)), k)


def oracle_intersect(results, k=None):
    scores, mask = results[0]
    scores, mask = scores.copy(), mask.copy()
    for s, m in results[1:]:
        mask &= m
        scores = scores + s
    return _maybe_topk(scores, mask, k)


def oracle_union(results, k=None):
    scores, mask = results[0]
    scores, mask = scores.copy(), mask.copy()
    for s, m in results[1:]:
        mask |= m
        scores = np.maximum(scores, s)
    return _maybe_topk(scores, mask, k)


def oracle_difference(a, b, k=None):
    mask = a[1] & ~b[1]
    return _maybe_topk(np.where(mask, a[0], np.float32(0.0)), mask, k)


def oracle_counter(results, k=None):
    counts = np.zeros_like(results[0][0])
    for _, m in results:
        counts = counts + m.astype(np.float32)
    return _maybe_topk(counts, counts > 0, k)


# ------------------------------------------------------------- plan evaluation
def oracle_run(lake, plan):
    """Evaluate a physical ``Plan`` the way ``Executor.run(optimize=False)``
    does — every seeker unrestricted, memoized per node — entirely against
    the raw lake.  Returns ``(scores, mask)`` of the output node."""
    memo: dict = {}

    def eval_node(name):
        if name in memo:
            return memo[name]
        node = plan.nodes[name]
        if node.is_seeker:
            rs = oracle_topk(oracle_seeker(lake, node.spec), node.spec.k)
        else:
            deps = [eval_node(d) for d in node.deps]
            kind, k = node.spec.kind, node.spec.k
            if kind == "intersect":
                rs = oracle_intersect(deps, k)
            elif kind == "union":
                rs = oracle_union(deps, k)
            elif kind == "difference":
                rs = oracle_difference(deps[0], deps[1], k)
            elif kind == "counter":
                rs = oracle_counter(deps, k)
            else:
                raise ValueError(kind)
        memo[name] = rs
        return rs

    return eval_node(plan.output)


def oracle_ids(scores: np.ndarray, mask: np.ndarray) -> list:
    """Selected table ids sorted by score desc — ``ResultSet.ids``."""
    ids = np.nonzero(mask)[0]
    return [int(t) for t in ids[np.argsort(-scores[ids], kind="stable")]]
